"""Serving-path benchmarks: FUSEE pool ops batched on-device, prefix-cache
effect in the engine, and the race_lookup kernel vs its oracle."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def bench_pool_ops() -> List[Dict]:
    from repro.serving import KVPool, PoolConfig
    rows = []
    pool = KVPool(PoolConfig(n_pages=8192, n_buckets=2048,
                             slots_per_bucket=8, replicas=3))
    keys = np.arange(1, 4001).astype(np.int32)
    pages = pool.alloc_pages(0, len(keys))
    pool.write_pages(0, pages, keys, opcode=1)
    t0 = time.perf_counter()
    ok = pool.insert_batch(0, keys, pages)
    t_ins = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(5):
        ptr, found = pool.search(keys)
    t_s = (time.perf_counter() - t0) / 5
    rows.append({"bench": "serving_pool", "op": "insert_batch",
                 "n": len(keys), "wall_s": t_ins,
                 "success": float(ok.mean()),
                 "epochs": pool.stats["epochs"]})
    rows.append({"bench": "serving_pool", "op": "search_batch",
                 "n": len(keys), "wall_s": t_s,
                 "hit": float(found.mean()),
                 "mops_host": len(keys) / t_s / 1e6})
    return rows


def bench_race_kernel() -> List[Dict]:
    from repro.kernels import race_lookup, race_lookup_ref
    rows = []
    nb, spb = 2048, 8
    rng = np.random.default_rng(0)
    index = jnp.asarray(rng.integers(0, 2**31 - 1, (nb, spb)), jnp.int32)
    keys = jnp.asarray(rng.integers(1, 2**31 - 1, 4096), jnp.int32)
    for name, fn in (("kernel_interpret",
                      lambda: race_lookup(keys, index)),
                     ("ref_jnp", lambda: race_lookup(keys, index,
                                                     use_kernel=False))):
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        rows.append({"bench": "race_lookup", "impl": name, "n_keys": 4096,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})
    return rows


def bench_engine_prefix() -> List[Dict]:
    from repro.configs import base as C
    from repro.models import build
    from repro.serving import PoolConfig, Request, ServeEngine
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    r = C.reduced(C.get("llama3-8b"))
    m = build(r, mesh)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, r.vocab, 128).astype(np.int32)
    rows = []
    for label, make_prompt in (
            ("shared_prefix", lambda i: np.concatenate(
                [shared, rng.integers(0, r.vocab, 16).astype(np.int32)])),
            ("disjoint", lambda i: rng.integers(0, r.vocab, 144)
             .astype(np.int32))):
        eng = ServeEngine(m, params, max_batch=4, max_len=256,
                          pool_cfg=PoolConfig(n_pages=1024, n_buckets=256,
                                              slots_per_bucket=8))
        for i in range(8):
            eng.submit(Request(rid=i, prompt=make_prompt(i), max_new=4))
        t0 = time.perf_counter()
        done = eng.run(max_ticks=200)
        rows.append({"bench": "engine", "workload": label,
                     "finished": len(done), "ticks": eng.steps,
                     "wall_s": time.perf_counter() - t0,
                     "prefix_hits": sum(q.prefix_hits for q in done),
                     "pool_epochs": eng.pool.stats["epochs"]})
    return rows


def run() -> List[Dict]:
    return bench_pool_ops() + bench_race_kernel() + bench_engine_prefix()
