"""Serving-path benchmarks: FUSEE pool ops batched on-device, prefix-cache
effect in the engine, and the race_lookup kernel vs its oracle."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_host_mesh


def bench_pool_ops() -> List[Dict]:
    """Device pool through the unified store API (core/api.py): one
    ``submit_batch`` of INSERTs = alloc + page write + SNAPSHOT epochs;
    one batch of GETs = a single race_lookup probe."""
    from repro.core.api import KVStore, Op
    from repro.core.events import OK
    from repro.serving import DeviceBackend, PoolConfig
    rows = []
    store = KVStore(DeviceBackend(PoolConfig(n_pages=8192, n_buckets=2048,
                                             slots_per_bucket=8, replicas=3)))
    keys = list(range(1, 4001))
    t0 = time.perf_counter()
    res = [f.result() for f in
           store.submit_batch([Op.insert(k, None) for k in keys])]
    t_ins = time.perf_counter() - t0
    ok = np.array([r.status == OK for r in res])
    t0 = time.perf_counter()
    for _ in range(5):
        got = [f.result() for f in
               store.submit_batch([Op.get(k) for k in keys])]
    t_s = (time.perf_counter() - t0) / 5
    found = np.array([r.status == OK for r in got])
    stats = store.stats()
    rows.append({"bench": "serving_pool", "op": "insert_batch",
                 "n": len(keys), "wall_s": t_ins,
                 "success": float(ok.mean()),
                 "epochs": stats["epochs"]})
    rows.append({"bench": "serving_pool", "op": "search_batch",
                 "n": len(keys), "wall_s": t_s,
                 "hit": float(found.mean()),
                 "mops_host": len(keys) / t_s / 1e6})
    return rows


def bench_race_kernel() -> List[Dict]:
    from repro.kernels import race_lookup, race_lookup_ref
    rows = []
    nb, spb = 2048, 8
    rng = np.random.default_rng(0)
    index = jnp.asarray(rng.integers(0, 2**31 - 1, (nb, spb)), jnp.int32)
    keys = jnp.asarray(rng.integers(1, 2**31 - 1, 4096), jnp.int32)
    for name, fn in (("kernel_interpret",
                      lambda: race_lookup(keys, index)),
                     ("ref_jnp", lambda: race_lookup(keys, index,
                                                     use_kernel=False))):
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        rows.append({"bench": "race_lookup", "impl": name, "n_keys": 4096,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})
    return rows


def bench_engine_prefix() -> List[Dict]:
    from repro.configs import base as C
    from repro.models import build
    from repro.serving import PoolConfig, Request, ServeEngine
    mesh = make_host_mesh((1, 1), ("data", "model"))
    r = C.reduced(C.get("llama3-8b"))
    m = build(r, mesh)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, r.vocab, 128).astype(np.int32)
    rows = []
    for label, make_prompt in (
            ("shared_prefix", lambda i: np.concatenate(
                [shared, rng.integers(0, r.vocab, 16).astype(np.int32)])),
            ("disjoint", lambda i: rng.integers(0, r.vocab, 144)
             .astype(np.int32))):
        eng = ServeEngine(m, params, max_batch=4, max_len=256,
                          pool_cfg=PoolConfig(n_pages=1024, n_buckets=256,
                                              slots_per_bucket=8))
        for i in range(8):
            eng.submit(Request(rid=i, prompt=make_prompt(i), max_new=4))
        t0 = time.perf_counter()
        done = eng.run(max_ticks=200)
        rows.append({"bench": "engine", "workload": label,
                     "finished": len(done), "ticks": eng.steps,
                     "wall_s": time.perf_counter() - t0,
                     "prefix_hits": sum(q.prefix_hits for q in done),
                     "pool_epochs": eng.pool.stats["epochs"]})
    return rows


def run() -> List[Dict]:
    return bench_pool_ops() + bench_race_kernel() + bench_engine_prefix()
