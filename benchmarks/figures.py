"""One benchmark per paper figure/table (Figs 2,3,10-21, Table 1).

Each ``fig*`` function returns a list of row-dicts; run.py drives them all
and validates the §Paper-claims targets (EXPERIMENTS.md).
FUSEE numbers come from the *executed* event simulation (every verb run,
RTTs measured); Clover/pDPM numbers from the documented baseline models.
Simulation scale (clients/keys/ops) is reduced vs the 22-machine testbed;
the netmodel composes measured per-op tallies into testbed-scale rates.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.api import Op
from repro.core.heap import DMConfig
from repro.core.store import FuseeCluster

from .baselines import clover_tput, pdpm_tput
from .common import (PAPER, YCSB, run_fleet_workload, run_workload,
                     throughput_mops)

MIX_MICRO = {"insert": 0.25, "update": 0.25, "search": 0.25, "delete": 0.25}


# --------------------------------------------------------------- figure 2 --
def fig02_metadata_cpu() -> List[Dict]:
    """Clover throughput vs #metadata-server CPU cores (YCSB-A-ish)."""
    rows = []
    for cores in [0.25, 0.5, 1, 2, 4, 6, 8]:
        r = clover_tput(n_clients=64, mix=YCSB["A"], md_cores=cores)
        rows.append({"bench": "fig02", "md_cores": cores, **r})
    return rows


# --------------------------------------------------------------- figure 3 --
def fig03_lock_consensus() -> List[Dict]:
    """Lock-based and serialized (consensus-like) replication of ONE shared
    object vs #clients — executed on the heap with CAS spin locks."""
    rows = []
    for n_clients in [1, 2, 4, 8, 16, 32]:
        # serialized consensus-like: one writer at a time, 3 RTT commit
        lat_serial = 3 * PAPER.rtt_us * 1e-6
        tput_serial = 1.0 / lat_serial                      # total, not xN
        # lock-based: acquire (>=1 RTT, contended retries), write, release
        hold = 3 * PAPER.rtt_us * 1e-6
        tput_lock = 1.0 / hold
        rows.append({"bench": "fig03", "clients": n_clients,
                     "derecho_mops": tput_serial / 1e6,
                     "lock_mops": tput_lock / 1e6,
                     "fusee_mops": throughput_mops(
                         run_workload(n_clients=n_clients, n_mns=2,
                                      mix={"update": 1.0}, n_ops=200,
                                      n_keys=1, preload=1, seed=n_clients),
                         n_clients=n_clients)["mops"]})
    return rows


# -------------------------------------------------------------- figure 10 --
def fig10_latency_cdf() -> List[Dict]:
    """Per-op latency CDFs (single client, conflict-free): RTT-exact."""
    cl = FuseeCluster(DMConfig(num_mns=5, replication=2), num_clients=1)
    kv = cl.store(0)
    lat = {k: [] for k in ("insert", "update", "search", "delete")}
    for i in range(300):
        lat["insert"].append(kv.insert(i, [i] * 16).rtts)
        lat["search"].append(kv.submit(Op.get(i)).result().rtts)
        lat["update"].append(kv.update(i, [i + 1] * 16).rtts)
        lat["delete"].append(kv.delete(i).rtts)
    rows = []
    for k, v in lat.items():
        arr = np.array(v) * PAPER.rtt_us
        rows.append({"bench": "fig10", "op": k,
                     "p50_us": float(np.percentile(arr, 50)),
                     "p99_us": float(np.percentile(arr, 99)),
                     "mean_us": float(arr.mean())})
    return rows


# -------------------------------------------------------------- figure 11 --
def fig11_micro_tput() -> List[Dict]:
    rows = []
    for op in ("insert", "update", "search", "delete"):
        st = run_workload(n_clients=16, n_mns=2, mix={op: 1.0}, n_ops=1200,
                          seed=11)
        r = throughput_mops(st, n_clients=128)
        rows.append({"bench": "fig11", "op": op, "system": "fusee",
                     "mops": r["mops"], "avg_rtts": r["avg_rtts"]})
        if op != "delete":
            rows.append({"bench": "fig11", "op": op, "system": "clover",
                         **{k: v for k, v in clover_tput(
                             n_clients=128, mix={op: 1.0},
                             md_cores=8).items() if k == "mops"}})
        rows.append({"bench": "fig11", "op": op, "system": "pdpm",
                     "mops": pdpm_tput(n_clients=128, mix={op: 1.0})["mops"]})
    return rows


# -------------------------------------------------------------- figure 12 --
def fig12_kv_sizes() -> List[Dict]:
    """FUSEE YCSB-C throughput vs KV size (NIC bandwidth cap)."""
    rows = []
    for vb in (256, 512, 1024):
        st = run_workload(n_clients=16, n_mns=2, mix=YCSB["C"], n_ops=800,
                          value_words=vb // 8, seed=12)
        r = throughput_mops(st, n_clients=128)
        rows.append({"bench": "fig12", "kv_bytes": vb, "mops": r["mops"],
                     "nic_cap_mops": r["nic_cap_mops"]})
    return rows


# -------------------------------------------------------------- figure 13 --
FIG13_CLIENTS = (16, 32, 64, 128, 256, 512, 1024)
# fused-megakernel scale tail: real runs too, but with a capped key space
# and op count so the two huge points stay interactive; run for the A/C
# headline mixes only
FIG13_TAIL_CLIENTS = (4096, 32768)
FIG13_TAIL_MIXES = ("A", "C")


def fig13_ycsb_scale() -> List[Dict]:
    """Throughput + per-op latency vs client count, 16 -> 32768 clients.

    Every point is a *real* fleet simulation at that client count
    (core/fleet.py: batched per-tick execution, one cluster-wide
    race_lookup probe per tick) — not an analytic rescale of a small run.
    The 16->1024 sweep keeps its historical parameters (bit-comparable
    across PRs); the 4096/32768 tail rides the fused tick with a capped
    key space.  Rows carry the measured p50/p99 per-op latency histogram
    and the batched-execution counters alongside the composed Mops."""
    rows = []

    def fusee_point(wl, n_clients, **kw):
        st = run_fleet_workload(
            n_clients=n_clients, mix=YCSB[wl], seed=13,
            # legacy flag: D now defaults to the paper-correct
            # read-latest draw; fig13 keeps plain zipfian so its
            # history stays comparable across PRs
            read_dist="zipfian", **kw)
        r = throughput_mops(st, n_clients=n_clients)
        rows.append({"bench": "fig13", "ycsb": wl, "clients": n_clients,
                     "system": "fusee", "mops": r["mops"],
                     "avg_rtts": r["avg_rtts"],
                     "lat_p50_us": st.lat_p50_us,
                     "lat_p99_us": st.lat_p99_us,
                     "sim_ops": st.n_ops, "sim_ticks": st.ticks,
                     "verbs_per_tick": st.verbs_per_tick,
                     "array_calls_per_tick": st.array_calls_per_tick,
                     "probe_invocations": st.probe_invocations,
                     "wall_s": st.wall_s})

    def model_points(wl, n_clients):
        rows.append({"bench": "fig13", "ycsb": wl, "clients": n_clients,
                     "system": "clover",
                     "mops": clover_tput(n_clients=n_clients,
                                         mix=YCSB[wl],
                                         md_cores=8)["mops"]})
        rows.append({"bench": "fig13", "ycsb": wl, "clients": n_clients,
                     "system": "pdpm",
                     "mops": pdpm_tput(n_clients=n_clients,
                                       mix=YCSB[wl])["mops"]})

    for wl in ("A", "B", "C", "D"):
        for n_clients in FIG13_CLIENTS:
            fusee_point(wl, n_clients,
                        ops_per_client=max(4, 2048 // n_clients))
            model_points(wl, n_clients)
    for wl in FIG13_TAIL_MIXES:
        for n_clients in FIG13_TAIL_CLIENTS:
            fusee_point(wl, n_clients, ops_per_client=2, n_keys=8192)
            model_points(wl, n_clients)
    return rows


# -------------------------------------------------------------- figure 14 --
def fig14_mn_scale() -> List[Dict]:
    """Throughput vs MN count — now a REAL scaling curve.

    With the single replicated RACE table, index traffic (and its CAS hot
    words) lands on the same r MNs no matter how many nodes the cluster
    has, so the NIC cap at the busiest MN never moves.  With S=8 index
    shards placed across the ring (core/ring.py), probe + CAS traffic
    spreads over min(S, N) MNs and throughput grows with N.  Both curves
    are measured per point; ``shards=1`` rows keep the old flat behavior
    for comparison."""
    rows = []
    for wl in ("A", "C"):
        for shards in (1, 8):
            for n_mns in (2, 3, 4, 5, 8):
                st = run_workload(n_clients=16, n_mns=n_mns, mix=YCSB[wl],
                                  n_ops=800, seed=14, index_shards=shards)
                # compose at 256 clients: enough closed-loop demand that
                # the busiest-MN NIC cap (what sharding moves) is the
                # binding resource across the whole MN sweep
                r = throughput_mops(st, n_clients=256)
                rows.append({"bench": "fig14", "ycsb": wl, "mns": n_mns,
                             "shards": shards, "mops": r["mops"],
                             "nic_cap_mops": r["nic_cap_mops"]})
    return rows


# ------------------------------------------- elasticity timeline (DINOMO) --
ELASTIC_WINDOW_TICKS = 48


def elastic_timeline() -> List[Dict]:
    """DINOMO-style elasticity timeline: windowed throughput of a live
    YCSB-A fleet while the cluster scales 2 -> 4 MNs and back to 3.

    The fleet keeps a closed-loop pipeline running the whole time;
    ``add_mn``/``remove_mn`` fire mid-run with ``wait=False`` so shard
    bulk-copies, dual-write windows, and epoch-bump cutovers ride the
    workload's own ticks.  Rows report per-window completed ops, the
    busiest-MN byte share, and live migration state — the measured
    evidence that reconfiguration is online (throughput dips but never
    reaches zero) and converges."""
    from repro.core.events import OK

    from .common import fleet_dmconfig

    n_clients, n_keys = 32, 256
    cfg = fleet_dmconfig(n_clients, n_keys, n_mns=2, replication=2,
                         index_shards=8)
    cl = FuseeCluster(cfg, num_clients=n_clients, seed=22)
    fleet = cl.fleet()
    sched = cl.scheduler
    backends = [cl.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    for k in range(n_keys):
        sched.submit(k % n_clients, "insert", k, [k] * 8)
    fleet.run()
    wl = cl.rng.stream("workload")

    events = {2: "add_mn", 5: "add_mn", 9: "remove_mn"}
    rows: List[Dict] = []
    op_seq = 0
    for window in range(13):
        ev = events.get(window)
        if ev == "add_mn":
            cl.add_mn(wait=False)
        elif ev == "remove_mn":
            cl.remove_mn(len(cl.pool.mns) - 1, wait=False)
        cl.pool.mn_bytes[:] = 0
        mark = len(sched.history)
        for _ in range(ELASTIC_WINDOW_TICKS):
            wave = []
            for c in range(n_clients):
                if sched.inflight(c) < 4:
                    kind = "update" if wl.random() < 0.5 else "search"
                    key = int(wl.integers(n_keys))
                    val = [op_seq] * 8 if kind == "update" else None
                    op_seq += 1
                    wave.append((backends[c], [Op(kind, key, val)]))
            if wave:
                fleet.submit_wave(wave)
            fleet.tick()
        recs = [r for r in sched.history[mark:]
                if r.result is not None and r.kind != "search_batch"]
        ok = sum(r.result.status == OK for r in recs)
        alive = [m for m in cl.pool.mns if m.alive]
        per_op = max(1, len(recs))
        busiest = max(float(cl.pool.mn_bytes[m.mid]) for m in alive) / per_op
        nic_cap = (PAPER.link_gbps * 1e9 / 8) / max(busiest, 1e-9)
        rows.append({"bench": "elastic", "window": window,
                     "event": ev or "", "mns_alive": len(alive),
                     "ops_done": len(recs), "ok_frac": ok / per_op,
                     "busiest_mn_bytes_per_op": busiest,
                     "nic_cap_mops": nic_cap / 1e6,
                     "migrating_regions": len(cl.migrator.active),
                     "epoch": cl.pool.epoch})
    fleet.run()
    if cl.migrator.busy:
        cl.migrator.drive()
    h = cl.health()
    rows.append({"bench": "elastic", "window": "final", "event": "drain",
                 "mns_alive": h.alive_mns, "ops_done": 0, "ok_frac": 1.0,
                 "busiest_mn_bytes_per_op": 0.0, "nic_cap_mops": 0.0,
                 "migrating_regions": h.migrating_regions,
                 "epoch": h.epoch})
    return rows


# -------------------------------------------------------------- figure 15 --
def fig15_rw_ratio() -> List[Dict]:
    rows = []
    for upd in (0.0, 0.25, 0.5, 0.75, 1.0):
        mix = ({"update": upd, "search": 1 - upd} if 0 < upd < 1
               else ({"update": 1.0} if upd == 1 else {"search": 1.0}))
        st = run_workload(n_clients=16, n_mns=2, mix=mix, n_ops=1000, seed=15)
        r = throughput_mops(st, n_clients=128)
        rows.append({"bench": "fig15", "update_frac": upd, "mops": r["mops"],
                     "clover_mops": clover_tput(n_clients=128, mix=mix,
                                                md_cores=8)["mops"],
                     "pdpm_mops": pdpm_tput(n_clients=128, mix=mix)["mops"]})
    return rows


# -------------------------------------------------------------- figure 16 --
def fig16_cache_threshold() -> List[Dict]:
    """Adaptive-cache threshold sweep under YCSB-A: higher threshold keeps
    using stale cache entries -> wasted (invalid) KV fetches."""
    rows = []
    for thr in (0.0, 0.2, 0.5, 0.8, 1.0):
        st = run_workload(n_clients=8, n_mns=2, mix=YCSB["A"], n_ops=1200,
                          cache_threshold=thr, theta=1.2, n_keys=64, seed=16)
        r = throughput_mops(st, n_clients=128)
        rows.append({"bench": "fig16", "threshold": thr, "mops": r["mops"],
                     "avg_rtts": r["avg_rtts"]})
    return rows


# -------------------------------------------------------------- figure 17 --
def fig17_alloc() -> List[Dict]:
    """Two-level vs MN-centric allocation: MN-centric pays one MN-CPU RPC
    per INSERT; two-level amortizes one RPC per block (measured)."""
    rows = []
    st = run_workload(n_clients=16, n_mns=2, mix=YCSB["A"], n_ops=1000,
                      seed=17)
    r = throughput_mops(st, n_clients=128)
    rows.append({"bench": "fig17", "alloc": "two-level", "ycsb": "A",
                 "mops": r["mops"], "alloc_rpcs_per_op": st.alloc_rpcs_per_op})
    # MN-centric: every write allocates at the MN (1 RPC/op on the weak core)
    mn_centric = dict(st.rtts_by_kind)
    cpu_cap = PAPER.mn_alloc_ops_per_s / 0.5     # 50% writes in YCSB-A
    rows.append({"bench": "fig17", "alloc": "mn-centric", "ycsb": "A",
                 "mops": min(r["client_cap_mops"] * 1e6, cpu_cap) / 1e6,
                 "alloc_rpcs_per_op": 0.5})
    for row, wl in ((0, "C"), (1, "C")):
        st2 = run_workload(n_clients=16, n_mns=2, mix=YCSB["C"], n_ops=600,
                           seed=18)
        r2 = throughput_mops(st2, n_clients=128)
        rows.append({"bench": "fig17", "alloc": ("two-level", "mn-centric")[row],
                     "ycsb": "C", "mops": r2["mops"],
                     "alloc_rpcs_per_op": 0.0})
    return rows


# --------------------------------------------------------- figures 18/19 --
def fig1819_replication() -> List[Dict]:
    """Median op latency + YCSB tput vs replication factor r; FUSEE vs
    FUSEE-CR (sequential CAS) vs FUSEE-NC (no cache).  RTT-exact."""
    rows = []
    for r_factor in (1, 2, 3, 4, 5):
        for system, kw in (("fusee", {}),
                           ("fusee-cr", {"replication_mode": "cr"}),
                           ("fusee-nc", {"enable_cache": False})):
            for op in ("insert", "update", "search", "delete"):
                st = run_workload(n_clients=4, n_mns=max(5, r_factor),
                                  replication=r_factor, mix={op: 1.0},
                                  n_ops=250, seed=19, **kw)
                rows.append({"bench": "fig19", "r": r_factor,
                             "system": system, "op": op,
                             "latency_us": st.rtts_by_kind[op] * PAPER.rtt_us})
        for wl in ("A", "C"):
            st = run_workload(n_clients=8, n_mns=max(5, r_factor),
                              replication=r_factor, mix=YCSB[wl],
                              n_ops=600, seed=19)
            rows.append({"bench": "fig18", "r": r_factor, "ycsb": wl,
                         "mops": throughput_mops(st, n_clients=128)["mops"]})
    return rows


# -------------------------------------------------------------- figure 20 --
def fig20_mn_crash() -> List[Dict]:
    """YCSB-C throughput timeline across an MN crash: searches continue on
    backups; bandwidth halves with one of two data replicas gone.  The
    crash goes through the cluster fault surface — detection and Alg-3
    re-homing happen inside the scheduler loop, no master calls."""
    cl = FuseeCluster(DMConfig(num_mns=2, replication=2,
                               region_words=1 << 15, regions_per_mn=16),
                      num_clients=8, enable_cache=False)
    pool, sched = cl.pool, cl.scheduler
    for k in range(64):
        sched.submit(k % 8, "insert", k, [k] * 16)
        sched.run_round_robin()
    rows = []
    rng = np.random.default_rng(20)
    for second in range(9):
        if second == 5:
            cl.crash_mn(1)
        pool.mn_bytes[:] = 0
        n_ops = 200
        for i in range(n_ops):
            sched.submit(i % 8, "search", int(rng.integers(64)), None)
            sched.run_round_robin()
        recs = sched.history[-n_ops:]
        ok = [r for r in recs if r.result.status == "OK"]
        avg_rtts = np.mean([r.rtts for r in ok])
        alive = [m for m in pool.mns if m.alive]
        busiest = max(pool.mn_bytes[m.mid] for m in alive) / n_ops
        nic_cap = (PAPER.link_gbps * 1e9 / 8) / busiest
        client_cap = 128 * 8 / (avg_rtts * PAPER.rtt_us * 1e-6)
        rows.append({"bench": "fig20", "t_s": second,
                     "mops": min(nic_cap, client_cap) / 1e6,
                     "ok_frac": len(ok) / n_ops})
    return rows


# -------------------------------------------------------------- figure 21 --
def fig21_elasticity() -> List[Dict]:
    """Throughput while client count steps 16 -> 32 -> 16 (YCSB-C)."""
    st = run_workload(n_clients=8, n_mns=5, mix=YCSB["C"], n_ops=600, seed=21)
    rows = []
    for t, n_clients in enumerate([16, 16, 32, 32, 32, 16, 16]):
        r = throughput_mops(st, n_clients=n_clients)
        rows.append({"bench": "fig21", "t_s": t, "clients": n_clients,
                     "mops": r["mops"]})
    return rows


# --------------------------------------------------------------- table 1 --
def tab1_recovery() -> List[Dict]:
    """Client recovery time breakdown after 1000 UPDATEs (mirrors Table 1).

    Log traversal / request recovery / free-list RTT counts are measured on
    the simulator; the connection+MR re-registration constant comes from
    the paper (it is a verbs-library property, not protocol work)."""
    cl = FuseeCluster(DMConfig(num_mns=5, replication=2,
                               region_words=1 << 15, regions_per_mn=16),
                      num_clients=2)
    kv = cl.store(0)
    for i in range(200):
        kv.insert(i, [i] * 8)
    for i in range(1000):
        kv.update(i % 200, [i] * 8)
    cl.crash_client(0)
    cl.recover_client(0, reassign_to_cid=1)
    st = cl.health().recovery        # cumulative RecoveryStats (health API)
    get_md = st.get_metadata_rtts * PAPER.rpc_rtt_us * 1e-3
    trav = st.traverse_log_rtts * PAPER.rtt_us * 1e-3
    rec = st.recover_requests_rtts * PAPER.rtt_us * 1e-3
    free = st.construct_free_list_rtts * PAPER.rtt_us * 1e-3
    total = PAPER.reconnect_ms + get_md + trav + rec + free
    return [{"bench": "tab1", "step": s, "ms": v, "pct": 100 * v / total}
            for s, v in [("reconnect_mr", PAPER.reconnect_ms),
                         ("get_metadata", get_md), ("traverse_log", trav),
                         ("recover_requests", rec),
                         ("construct_free_list", free), ("total", total)]]


# ----------------------------------------------------- API pipeline bench --
def api_batch_search() -> List[Dict]:
    """Batched vs serial SEARCH through the unified store API.

    Serial path: one cache-hit SEARCH per op = 1 RTT each.  Batched path:
    ``submit_batch`` matches the GET keys against the client's index cache
    via the race_lookup kernel and fuses every resident key into ONE
    doorbell batch — B ops per RTT.  Rows report measured ops/RTT for both
    paths plus the pipelined mixed-op depth sweep (ops in flight per
    client never blocks the client, §4.3)."""
    rows = []
    for batch in (4, 8, 16, 32, 64):
        cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=1,
                          seed=batch)
        kv = cl.store(0, max_inflight=max(16, batch))
        for f in kv.submit_batch([Op.put(k, [k] * 8) for k in range(batch)]):
            f.result()
        for k in range(batch):       # warm the adaptive index cache
            kv.get(k)
        serial = [kv.submit(Op.get(k)).result() for k in range(batch)]
        serial_rtts = sum(r.rtts for r in serial)
        mark = len(cl.scheduler.history)
        batched = [f.result() for f in
                   kv.submit_batch([Op.get(k) for k in range(batch)])]
        assert all(r.status == "OK" for r in batched)
        batch_rtts = sum(r.rtts for r in cl.scheduler.history[mark:])
        stats = kv.stats()
        rows.append({
            "bench": "api_batch", "batch": batch,
            "serial_rtts": serial_rtts,
            "serial_ops_per_rtt": batch / max(serial_rtts, 1),
            "batch_rtts": batch_rtts,
            "batch_ops_per_rtt": batch / max(batch_rtts, 1),
            "fast_hits": stats["batch_fast_hits"],
            "speedup": (batch / max(batch_rtts, 1))
                       / (batch / max(serial_rtts, 1)),
        })
    return rows


# ------------------------------------------------ YCSB-E (ordered scans) --
def ycsbe_scan() -> List[Dict]:
    """YCSB-E on the fleet engine: 0.95 SCAN / 0.05 INSERT, zipfian start
    keys, uniform scan length <= 100 — the workload class the ordered
    keydir (core/ordered.py) opens.  Scans are answered in batched leaf
    sweeps: starts located by ONE leaf_probe invocation per wave, leaf
    reads coalescing into the tick's single read sweep, values fetched +
    validated through the RACE index in two batched phases.  Rows carry
    measured per-op RTTs and the sweep counters; fully seed-replayable
    (workload drawn from the cluster SimRng 'workload' stream)."""
    rows = []
    for n_clients in (8, 32):
        st = run_fleet_workload(n_clients=n_clients, mix=YCSB["E"],
                                seed=23, n_keys=512,
                                ops_per_client=max(4, 256 // n_clients))
        # composed at the measured client count (like fig13) — the rows
        # are a real closed-loop scaling curve, not a 128-client model
        r = throughput_mops(st, n_clients=n_clients)
        rows.append({"bench": "ycsbe", "clients": n_clients,
                     "mops": r["mops"], "avg_rtts": r["avg_rtts"],
                     "scan_rtts": st.rtts_by_kind.get("scan", 0.0),
                     "insert_rtts": st.rtts_by_kind.get("insert", 0.0),
                     "mix_scan": st.mix.get("scan", 0.0),
                     "lat_p50_us": st.lat_p50_us,
                     "lat_p99_us": st.lat_p99_us,
                     "sim_ops": st.n_ops, "wall_s": st.wall_s, "seed": 23})
    return rows


def scan_batch() -> List[Dict]:
    """Batched-leaf scan traversal vs naive per-slot reads (the ordered
    index's headline RTT claim, >=4x ops/RTT).

    Batched: multi-leaf chain sweeps (ORD_SWEEP leaves per doorbell batch
    = 1 RTT) + two batched validation phases for the whole candidate set.
    Naive: one leaf read per RTT and one 2-RTT RACE verify per key — what
    bolting scans onto per-slot reads would cost.  Both paths return
    identical results (asserted); ops/RTT counts returned keys per
    executed critical-path RTT."""
    from repro.core.store import FuseeCluster as _FC

    from .common import fleet_dmconfig
    rows = []
    n_keys = 512
    cfg = fleet_dmconfig(4, n_keys, n_mns=4, replication=2, ordered=True)
    cl = _FC(cfg, num_clients=2, seed=7)
    sched = cl.scheduler
    for k in range(n_keys):
        sched.submit(k % 2, "insert", k, [k] * 4)
    sched.run_round_robin()
    client = cl.clients[0]
    for scan_len in (20, 100):
        starts = [37, 201, 390]
        for mode, batched in (("batched", True), ("naive", False)):
            mark = len(sched.history)
            results = []
            for s in starts:
                rec = sched.submit(0, "scan", s, scan_len,
                                   gen=client.op_scan(s, scan_len,
                                                      batched=batched))
                sched.run_round_robin()
                results.append(rec.result.value)
            rtts = sum(h.rtts for h in sched.history[mark:])
            keys_ret = sum(len(v) for v in results)
            rows.append({"bench": "scan_batch", "mode": mode,
                         "scan_len": scan_len, "keys": keys_ret,
                         "rtts": rtts,
                         "ops_per_rtt": keys_ret / max(rtts, 1)})
            if mode == "batched":
                batched_results = results
            else:
                assert results == batched_results, \
                    "naive and batched scans must return identical results"
    # pair up speedups
    by = {(r["mode"], r["scan_len"]): r for r in rows}
    for scan_len in (20, 100):
        b, n = by[("batched", scan_len)], by[("naive", scan_len)]
        b["speedup"] = b["ops_per_rtt"] / max(n["ops_per_rtt"], 1e-9)
    return rows


ALL_FIGURES = [fig02_metadata_cpu, fig03_lock_consensus, fig10_latency_cdf,
               fig11_micro_tput, fig12_kv_sizes, fig13_ycsb_scale,
               fig14_mn_scale, fig15_rw_ratio, fig16_cache_threshold,
               fig17_alloc, fig1819_replication, fig20_mn_crash,
               fig21_elasticity, elastic_timeline, tab1_recovery,
               api_batch_search, ycsbe_scan, scan_batch]
