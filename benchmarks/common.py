"""Shared benchmark machinery: the network cost model that turns the event
simulator's *executed* RTT/byte/CPU tallies into seconds, and a workload
runner driving the FUSEE cluster simulation.

The simulator executes every verb of every KV op (core/sim.py), so RTT
counts, per-MN byte traffic, and MN-CPU op counts are measured, not
assumed; this module only applies the testbed constants of §6.1
(2 us one-sided RTT, 56 Gbps RNICs, weak MN cores) to produce the
throughput/latency figures the paper reports.

Throughput composition (all rates in ops/s):
    client-limited  n_clients / avg_op_latency      (closed-loop clients)
    NIC-limited     per-MN bandwidth cap at the busiest MN
    MN-CPU-limited  ALLOC RPCs at the weak MN cores (two-level alloc
                    makes this negligible for FUSEE; not for MN-centric)
    overall         min of the applicable caps
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.fusee_paper import FuseePaperConfig
from repro.core.heap import DMConfig, DMPool
from repro.core.master import Master
from repro.core.client import FuseeClient
from repro.core.sim import Scheduler

PAPER = FuseePaperConfig()


def zipf_keys(n_keys: int, theta: float, size: int, rng) -> np.ndarray:
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-theta)
    p /= p.sum()
    return rng.choice(n_keys, size=size, p=p)


@dataclass
class WorkloadStats:
    n_ops: int
    rtts_by_kind: Dict[str, float]       # avg critical-path RTTs per op
    bg_rtts_by_kind: Dict[str, float]
    mix: Dict[str, float]
    mn_bytes_per_op: np.ndarray          # bytes at each MN / op
    alloc_rpcs_per_op: float
    invalid_fetches: int = 0
    wall_s: float = 0.0


def run_workload(*, n_clients: int, n_mns: int, replication: int = 2,
                 mix: Dict[str, float], n_ops: int = 2000,
                 n_keys: int = 512, theta: float = 0.99,
                 value_words: int = 16, seed: int = 0,
                 enable_cache: bool = True, cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot",
                 preload: int = 256, pipeline_depth: int = 1) -> WorkloadStats:
    """Run a mixed workload on the event simulator; return measured stats.

    ``pipeline_depth`` = ops each closed-loop client keeps in flight
    (the (cid, op_id) pipelines of core/sim.py; 1 = the classic
    one-op-per-client loop the paper figures assume)."""
    t0 = time.perf_counter()
    cfg = DMConfig(num_mns=n_mns, replication=replication,
                   region_words=1 << 15, regions_per_mn=16)
    pool = DMPool(cfg, num_clients=n_clients, seed=seed)
    master = Master(pool)
    clients = [FuseeClient(i, pool, enable_cache=enable_cache,
                           cache_threshold=cache_threshold,
                           replication_mode=replication_mode, seed=seed)
               for i in range(n_clients)]
    sched = Scheduler(pool, master, seed=seed)
    for c in clients:
        sched.add_client(c)
    rng = np.random.default_rng(seed)

    # preload keys so SEARCH/UPDATE have targets
    for k in range(preload):
        rec = sched.submit(clients[k % n_clients].cid, "insert", k,
                           [k] * value_words)
        sched.run_round_robin()
    pool.mn_bytes[:] = 0
    base_cpu = sum(m.cpu_ops for m in pool.mns)

    kinds = list(mix.keys())
    probs = np.array([mix[k] for k in kinds], float)
    probs /= probs.sum()
    ops_left = n_ops
    plan: Dict[int, List] = {c.cid: [] for c in clients}
    for i in range(n_ops):
        kind = kinds[int(rng.choice(len(kinds), p=probs))]
        key = int(zipf_keys(n_keys, theta, 1, rng)[0]) % preload \
            if kind != "insert" else preload + i
        val = [i] * value_words if kind in ("insert", "update") else None
        plan[clients[i % n_clients].cid].append((kind, key, val))

    # closed-loop: every client keeps ``pipeline_depth`` ops in flight
    while True:
        for cid, ops in plan.items():
            while ops and sched.inflight(cid) < pipeline_depth:
                kind, key, val = ops.pop(0)
                sched.submit(cid, kind, key, val)
        cids = sched.eligible_cids()
        if not cids:
            break
        cid = cids[int(rng.integers(len(cids)))]
        sched.step(cid, pick=int(rng.integers(4)))

    recs = [r for r in sched.history if r.result is not None][preload:]
    rtts, bg, cnt = {}, {}, {}
    for r in recs:
        rtts[r.kind] = rtts.get(r.kind, 0) + r.rtts
        bg[r.kind] = bg.get(r.kind, 0) + r.bg_rtts
        cnt[r.kind] = cnt.get(r.kind, 0) + 1
    n = max(len(recs), 1)
    alloc_rpcs = sum(m.cpu_ops for m in pool.mns) - base_cpu
    return WorkloadStats(
        n_ops=len(recs),
        rtts_by_kind={k: rtts[k] / cnt[k] for k in rtts},
        bg_rtts_by_kind={k: bg[k] / cnt[k] for k in bg},
        mix={k: cnt[k] / n for k in cnt},
        mn_bytes_per_op=pool.mn_bytes / n,
        alloc_rpcs_per_op=alloc_rpcs / n,
        wall_s=time.perf_counter() - t0,
    )


def throughput_mops(stats: WorkloadStats, *, n_clients: int,
                    coroutines: int = 8,
                    paper: FuseePaperConfig = PAPER) -> Dict[str, float]:
    """Compose the measured tallies into an overall ops/s figure."""
    avg_rtts = sum(stats.rtts_by_kind[k] * stats.mix[k]
                   for k in stats.rtts_by_kind)
    lat_s = avg_rtts * paper.rtt_us * 1e-6
    client_cap = n_clients * coroutines / lat_s          # closed loop
    nic_cap = np.inf
    busiest = stats.mn_bytes_per_op.max()
    if busiest > 0:
        nic_cap = (paper.link_gbps * 1e9 / 8) / busiest
    cpu_cap = np.inf
    if stats.alloc_rpcs_per_op > 0:
        cpu_cap = paper.mn_alloc_ops_per_s / stats.alloc_rpcs_per_op
    overall = min(client_cap, nic_cap, cpu_cap)
    return {"mops": overall / 1e6, "latency_us": avg_rtts * paper.rtt_us,
            "client_cap_mops": client_cap / 1e6,
            "nic_cap_mops": nic_cap / 1e6, "cpu_cap_mops": cpu_cap / 1e6,
            "avg_rtts": avg_rtts}


YCSB = {
    "A": {"search": 0.5, "update": 0.5},
    "B": {"search": 0.95, "update": 0.05},
    "C": {"search": 1.0},
    "D": {"search": 0.95, "insert": 0.05},
}
