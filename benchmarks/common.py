"""Shared benchmark machinery: the network cost model that turns the event
simulator's *executed* RTT/byte/CPU tallies into seconds, and a workload
runner driving the FUSEE cluster simulation.

The simulator executes every verb of every KV op (core/sim.py), so RTT
counts, per-MN byte traffic, and MN-CPU op counts are measured, not
assumed; this module only applies the testbed constants of §6.1
(2 us one-sided RTT, 56 Gbps RNICs, weak MN cores) to produce the
throughput/latency figures the paper reports.

Throughput composition (all rates in ops/s):
    client-limited  n_clients / avg_op_latency      (closed-loop clients)
    NIC-limited     per-MN bandwidth cap at the busiest MN
    MN-CPU-limited  ALLOC RPCs at the weak MN cores (two-level alloc
                    makes this negligible for FUSEE; not for MN-centric)
    overall         min of the applicable caps
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.fusee_paper import FuseePaperConfig
from repro.core.api import Op
from repro.core.heap import DMConfig, DMPool
from repro.core.master import Master
from repro.core.client import FuseeClient
from repro.core.sim import Scheduler
from repro.core.store import FuseeCluster

PAPER = FuseePaperConfig()


def zipf_keys(n_keys: int, theta: float, size: int, rng) -> np.ndarray:
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-theta)
    p /= p.sum()
    return rng.choice(n_keys, size=size, p=p)


def latest_key_at(rank: int, top: int) -> int:
    """Read-latest key draw (YCSB-D's actual distribution): map a
    zipfian *recency rank* (rank 0 = newest) onto the current key-space
    top, so the most recently inserted keys are the hottest.  The
    runners draw all ranks once (vectorized, via ``zipf_keys``) and map
    per-op against the growing ``top`` — one O(n) probability build per
    plan, not one per op."""
    return (top - 1) - (int(rank) % max(top, 1))


@dataclass
class WorkloadStats:
    n_ops: int
    rtts_by_kind: Dict[str, float]       # avg critical-path RTTs per op
    bg_rtts_by_kind: Dict[str, float]
    mix: Dict[str, float]
    mn_bytes_per_op: np.ndarray          # bytes at each MN / op
    alloc_rpcs_per_op: float             # cluster-wide ALLOC RPCs / op
    invalid_fetches: int = 0
    wall_s: float = 0.0
    # ALLOC RPCs served at each MN / op: the weak-core cap is a per-MN
    # resource (1-2 cores per MN, §2.1), so MN-CPU capacity — like NIC
    # bandwidth — binds at the *busiest* MN and scales with MN count
    mn_alloc_rpcs_per_op: Optional[np.ndarray] = None


def run_workload(*, n_clients: int, n_mns: int, replication: int = 2,
                 mix: Dict[str, float], n_ops: int = 2000,
                 n_keys: int = 512, theta: float = 0.99,
                 value_words: int = 16, seed: int = 0,
                 enable_cache: bool = True, cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot",
                 preload: int = 256, pipeline_depth: int = 1,
                 index_shards: int = 1,
                 read_dist: Optional[str] = None) -> WorkloadStats:
    """Run a mixed workload on the event simulator; return measured stats.

    ``pipeline_depth`` = ops each closed-loop client keeps in flight
    (the (cid, op_id) pipelines of core/sim.py; 1 = the classic
    one-op-per-client loop the paper figures assume).  ``index_shards``
    splits the RACE index into S shard regions spread over the MN ring
    (heap.py; S=1 = the paper's single-table layout).  ``read_dist``
    picks the non-insert key draw: None = paper-correct default (YCSB-D
    reads latest-skewed, everything else zipfian); pass ``"zipfian"``
    explicitly to keep the legacy fig13-comparable draw for D."""
    t0 = time.perf_counter()
    read_dist = read_dist or _default_read_dist(mix)
    cfg = DMConfig(num_mns=n_mns, replication=replication,
                   region_words=1 << 15, regions_per_mn=16,
                   index_shards=index_shards,
                   ordered_index="scan" in mix or "range" in mix)
    pool = DMPool(cfg, num_clients=n_clients, seed=seed)
    master = Master(pool)
    clients = [FuseeClient(i, pool, enable_cache=enable_cache,
                           cache_threshold=cache_threshold,
                           replication_mode=replication_mode, seed=seed)
               for i in range(n_clients)]
    sched = Scheduler(pool, master, seed=seed)
    for c in clients:
        sched.add_client(c)
    rng = np.random.default_rng(seed)

    # preload keys so SEARCH/UPDATE have targets
    for k in range(preload):
        rec = sched.submit(clients[k % n_clients].cid, "insert", k,
                           [k] * value_words)
        sched.run_round_robin()
    pool.mn_bytes[:] = 0
    base_cpu = sum(m.cpu_ops for m in pool.mns)
    base_cpu_per_mn = np.array([m.cpu_ops for m in pool.mns], np.int64)

    kinds = list(mix.keys())
    probs = np.array([mix[k] for k in kinds], float)
    probs /= probs.sum()
    ops_left = n_ops
    plan: Dict[int, List] = {c.cid: [] for c in clients}
    inserted = 0
    latest_ranks = zipf_keys(n_keys, theta, n_ops, rng) \
        if read_dist == "latest" else None
    for i in range(n_ops):
        kind = kinds[int(rng.choice(len(kinds), p=probs))]
        if kind == "insert":
            key = preload + inserted
            inserted += 1
        elif read_dist == "latest":
            # read-latest (YCSB-D): recency-skewed over the grown space
            key = latest_key_at(latest_ranks[i], preload + inserted)
        else:
            key = int(zipf_keys(n_keys, theta, 1, rng)[0]) % preload
        if kind == "scan":
            # YCSB-E: zipfian start key, uniform length <= MAX_SCAN_LEN
            val = 1 + int(rng.integers(MAX_SCAN_LEN))
        else:
            val = [i] * value_words if kind in ("insert", "update") else None
        plan[clients[i % n_clients].cid].append((kind, key, val))

    # closed-loop: every client keeps ``pipeline_depth`` ops in flight
    while True:
        for cid, ops in plan.items():
            while ops and sched.inflight(cid) < pipeline_depth:
                kind, key, val = ops.pop(0)
                sched.submit(cid, kind, key, val)
        cids = sched.eligible_cids()
        if not cids:
            break
        cid = cids[int(rng.integers(len(cids)))]
        sched.step(cid, pick=int(rng.integers(4)))

    recs = [r for r in sched.history if r.result is not None][preload:]
    rtts, bg, cnt = {}, {}, {}
    for r in recs:
        rtts[r.kind] = rtts.get(r.kind, 0) + r.rtts
        bg[r.kind] = bg.get(r.kind, 0) + r.bg_rtts
        cnt[r.kind] = cnt.get(r.kind, 0) + 1
    n = max(len(recs), 1)
    alloc_rpcs = sum(m.cpu_ops for m in pool.mns) - base_cpu
    cpu_per_mn = np.array([m.cpu_ops for m in pool.mns], np.int64) \
        - base_cpu_per_mn
    return WorkloadStats(
        n_ops=len(recs),
        rtts_by_kind={k: rtts[k] / cnt[k] for k in rtts},
        bg_rtts_by_kind={k: bg[k] / cnt[k] for k in bg},
        mix={k: cnt[k] / n for k in cnt},
        mn_bytes_per_op=pool.mn_bytes / n,
        alloc_rpcs_per_op=alloc_rpcs / n,
        mn_alloc_rpcs_per_op=cpu_per_mn / n,
        wall_s=time.perf_counter() - t0,
    )


def throughput_mops(stats: WorkloadStats, *, n_clients: int,
                    coroutines: int = 8,
                    paper: FuseePaperConfig = PAPER) -> Dict[str, float]:
    """Compose the measured tallies into an overall ops/s figure."""
    avg_rtts = sum(stats.rtts_by_kind[k] * stats.mix[k]
                   for k in stats.rtts_by_kind)
    lat_s = avg_rtts * paper.rtt_us * 1e-6
    client_cap = n_clients * coroutines / lat_s          # closed loop
    nic_cap = np.inf
    busiest = stats.mn_bytes_per_op.max()
    if busiest > 0:
        nic_cap = (paper.link_gbps * 1e9 / 8) / busiest
    cpu_cap = np.inf
    if stats.mn_alloc_rpcs_per_op is not None:
        # per-MN weak cores: the cap binds at the busiest MN's share
        busiest_alloc = stats.mn_alloc_rpcs_per_op.max()
        if busiest_alloc > 0:
            cpu_cap = paper.mn_alloc_ops_per_s / busiest_alloc
    elif stats.alloc_rpcs_per_op > 0:
        cpu_cap = paper.mn_alloc_ops_per_s / stats.alloc_rpcs_per_op
    overall = min(client_cap, nic_cap, cpu_cap)
    return {"mops": overall / 1e6, "latency_us": avg_rtts * paper.rtt_us,
            "client_cap_mops": client_cap / 1e6,
            "nic_cap_mops": nic_cap / 1e6, "cpu_cap_mops": cpu_cap / 1e6,
            "avg_rtts": avg_rtts}


YCSB = {
    "A": {"search": 0.5, "update": 0.5},
    "B": {"search": 0.95, "update": 0.05},
    "C": {"search": 1.0},
    # D is read-LATEST: reads draw from a recency-skewed distribution
    # over the growing key space (the runners default to that for this
    # mix; pass read_dist="zipfian" for the legacy fig13-comparable draw)
    "D": {"search": 0.95, "insert": 0.05},
    # E is the scan workload: 0.95 SCAN / 0.05 INSERT, zipfian start
    # keys, uniform scan length <= MAX_SCAN_LEN (needs ordered_index)
    "E": {"scan": 0.95, "insert": 0.05},
}

MAX_SCAN_LEN = 100


def _default_read_dist(mix: Dict[str, float]) -> str:
    """Paper-correct read distribution for a mix: YCSB-D (the read-latest
    workload) draws latest-skewed; everything else plain zipfian."""
    return "latest" if mix == YCSB["D"] else "zipfian"


# =========================================================== fleet workloads
@dataclass
class FleetStats(WorkloadStats):
    """WorkloadStats + the fleet-mode extras: per-op latency percentiles
    (from vectorized RTT accounting over the whole history) and the batched
    tick / probe counters that certify one-array-call-per-tick execution."""
    lat_p50_us: float = 0.0
    lat_p99_us: float = 0.0
    ticks: int = 0
    verbs_per_tick: float = 0.0
    array_calls_per_tick: float = 0.0
    probe_invocations: int = 0
    probe_hits: int = 0
    n_clients: int = 0


def fleet_dmconfig(n_clients: int, n_keys: int, *, n_mns: int = 4,
                   replication: int = 2, index_shards: int = 1,
                   ordered: bool = False) -> DMConfig:
    """Size a DMConfig for a fleet: index slots ≥ 4x keys, meta region
    covering every client's 64 metadata words, and ≥ 4 blocks of slab
    headroom per client.  ``ordered=True`` enables the ordered keydir
    (core/ordered.py) and sizes the region for the keyspace — 16-word
    leaves, 13 entries each, with generous slack for split churn and
    leaked loser leaves under concurrent splitters."""
    buckets = 256
    while buckets * 7 < 4 * n_keys:
        buckets *= 2
    region_words = 1 << 14
    while region_words < max(buckets * 7, n_clients * 64):
        region_words <<= 1
    if ordered:
        from repro.core.ordered import LEAF_ENTRIES, LEAF_WORDS
        need_leaves = 4 * n_keys // LEAF_ENTRIES + 4 * n_clients + 64
        while region_words < need_leaves * LEAF_WORDS + 8:
            region_words <<= 1
    block_words = 1 << 9
    bpr = region_words // (block_words + 1)
    regions_per_mn = max(8, -(-4 * n_clients // (bpr * n_mns)) + 1)
    return DMConfig(num_mns=n_mns, replication=replication,
                    region_words=region_words, block_words=block_words,
                    regions_per_mn=regions_per_mn, index_buckets=buckets,
                    index_shards=index_shards, ordered_index=ordered)


def run_fleet_workload(*, n_clients: int, n_mns: int = 4,
                       replication: int = 2, mix: Dict[str, float],
                       ops_per_client: int = 8, n_keys: Optional[int] = None,
                       theta: float = 0.99, value_words: int = 8,
                       seed: int = 0, pipeline_depth: int = 4,
                       batch_gets: bool = True, enable_cache: bool = True,
                       use_kernel: bool = True, fused: bool = True,
                       read_dist: Optional[str] = None) -> FleetStats:
    """Run a mixed workload at fleet scale: every client keeps
    ``pipeline_depth`` ops in flight, and every tick advances ALL clients'
    op-phases as batched array operations (core/fleet.py) — one kernel /
    array call per verb-kind per tick, not one per op.  Cache-resident
    GETs of a wave are probed with ONE cluster-wide race_lookup
    invocation and fused into 1-RTT multi-key SEARCHes; SCAN starts are
    located with ONE leaf_probe invocation per wave and their leaf sweeps
    coalesce into the tick's read sweep.

    ``read_dist=None`` uses the paper-correct draw per mix (YCSB-D reads
    latest-skewed over the growing key space; pass ``"zipfian"``
    explicitly for the legacy fig13-comparable behavior).  A mix with
    ``scan`` ops (YCSB-E) auto-enables the ordered keydir.

    Fully deterministic from ``(seed, config)``: workload generation draws
    from the cluster's SimRng 'workload' stream, fleet ticks are
    schedule-free."""
    t0 = time.perf_counter()
    read_dist = read_dist or _default_read_dist(mix)
    n_keys = n_keys if n_keys is not None else max(256, 2 * n_clients)
    has_scan = "scan" in mix or "range" in mix
    cfg = fleet_dmconfig(n_clients, n_keys, n_mns=n_mns,
                         replication=replication, ordered=has_scan)
    cluster = FuseeCluster(cfg, num_clients=n_clients, seed=seed,
                           enable_cache=enable_cache)
    fleet = cluster.fleet(use_kernel=use_kernel, fused=fused)
    sched = cluster.scheduler
    pool = cluster.pool
    backends = [cluster.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    wl = cluster.rng.stream("workload")

    # preload the key space (distinct keys -> bounded contention), fleet-driven
    for k in range(n_keys):
        sched.submit(k % n_clients, "insert", k, [k] * value_words)
    fleet.run()
    pool.mn_bytes[:] = 0
    base_cpu = sum(m.cpu_ops for m in pool.mns)
    base_cpu_per_mn = np.array([m.cpu_ops for m in pool.mns], np.int64)
    mark = len(sched.history)

    # per-client op plans, drawn from the seeded workload stream
    kinds = sorted(mix.keys())
    probs = np.array([mix[k] for k in kinds], float)
    probs /= probs.sum()
    n_ops = ops_per_client * n_clients
    kind_draw = [kinds[i] for i in wl.choice(len(kinds), size=n_ops, p=probs)]
    zipf_draw = zipf_keys(n_keys, theta, n_ops, wl)
    scan_lens = (1 + wl.integers(MAX_SCAN_LEN, size=n_ops)) if has_scan \
        else None
    latest_ranks = zipf_keys(n_keys, theta, n_ops, wl) \
        if read_dist == "latest" else None
    plans: List[List[Op]] = [[] for _ in range(n_clients)]
    fresh = n_keys
    for i in range(n_ops):
        kind = kind_draw[i]
        if kind == "insert":
            key, fresh = fresh, fresh + 1
        elif read_dist == "latest":
            key = latest_key_at(latest_ranks[i], fresh)
        else:
            key = int(zipf_draw[i])
        if kind == "scan":
            # YCSB-E: zipfian start key, uniform length <= MAX_SCAN_LEN
            val = int(scan_lens[i])
        else:
            val = [i] * value_words if kind in ("insert", "update") else None
        plans[i % n_clients].append(Op(kind, key, val))

    # closed loop: refill every client to pipeline_depth, tick the fleet
    cursor = [0] * n_clients
    while True:
        wave = []
        for c in range(n_clients):
            room = pipeline_depth - sched.inflight(c)
            if room > 0 and cursor[c] < len(plans[c]):
                ops = plans[c][cursor[c]:cursor[c] + room]
                cursor[c] += len(ops)
                wave.append((backends[c], ops))
        if wave:
            if batch_gets:
                fleet.submit_wave(wave)
            else:
                for be, ops in wave:
                    be.submit_many(ops)
        if not sched.has_work():
            break
        fleet.tick()

    # ---- vectorized RTT accounting over the history tail ------------------
    recs = [r for r in sched.history[mark:] if r.result is not None]
    kind_a = np.array([r.kind for r in recs])
    rtts_a = np.array([r.rtts for r in recs], np.int64)
    res_rtts = np.array([r.result.rtts for r in recs], np.int64)
    bg_a = np.array([r.bg_rtts for r in recs], np.int64)
    # per-op critical-path latency: executed phases; a key served by a fused
    # multi-key SEARCH observed the batch's single RTT (recorded on its
    # result), the parent search_batch record is bookkeeping, not a user op
    user = kind_a != "search_batch"
    lat = np.where(rtts_a > 0, rtts_a, res_rtts)[user]
    ks = kind_a[user]
    rtts_by_kind = {k: float(lat[ks == k].mean()) for k in np.unique(ks)}
    bg_by_kind = {k: float(bg_a[user][ks == k].mean()) for k in np.unique(ks)}
    n = max(int(user.sum()), 1)
    fst = fleet.stats()
    return FleetStats(
        n_ops=int(user.sum()),
        rtts_by_kind=rtts_by_kind,
        bg_rtts_by_kind=bg_by_kind,
        mix={k: float((ks == k).sum()) / n for k in np.unique(ks)},
        mn_bytes_per_op=pool.mn_bytes / n,
        alloc_rpcs_per_op=(sum(m.cpu_ops for m in pool.mns) - base_cpu) / n,
        mn_alloc_rpcs_per_op=(
            np.array([m.cpu_ops for m in pool.mns], np.int64)
            - np.pad(base_cpu_per_mn,          # MNs may have joined mid-run
                     (0, len(pool.mns) - len(base_cpu_per_mn)))) / n,
        wall_s=time.perf_counter() - t0,
        lat_p50_us=float(np.percentile(lat, 50)) * PAPER.rtt_us,
        lat_p99_us=float(np.percentile(lat, 99)) * PAPER.rtt_us,
        ticks=fst["ticks"], verbs_per_tick=fst["verbs_per_tick"],
        array_calls_per_tick=fst["array_calls_per_tick"],
        probe_invocations=fst["probe_invocations"],
        probe_hits=fst["probe_hits"],
        n_clients=n_clients,
    )
