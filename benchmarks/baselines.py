"""Comparison systems: Clover-sim, pDPM-Direct-sim, FUSEE-CR, FUSEE-NC.

Clover (semi-disaggregated, §2.2): clients read KV data one-sided but ALL
index updates and allocations go through a monolithic metadata server.  The
model executes the same per-op RTT schedule the paper describes (SEARCH:
cached index + 1 READ; UPDATE/INSERT: write + metadata-server RPC) and caps
throughput at the metadata server's core budget — Fig. 2's bottleneck.

pDPM-Direct (fully client-managed, lock-based): every write takes a remote
spin lock (CAS), updates index + data, unlocks.  Under Zipf contention the
hot keys serialize: we model an M/D/1-style serialization of the hot-key
mass (the measured contention model; Fig. 3/13's collapse) on top of the
same RTT accounting.

FUSEE-CR / FUSEE-NC run on the real simulator (replication_mode='cr',
enable_cache=False).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from .common import PAPER, WorkloadStats, zipf_keys


# ------------------------------------------------------------- Clover-sim --
def clover_tput(*, n_clients: int, mix: Dict[str, float], md_cores: float,
                value_bytes: int = 1024, n_mns: int = 2,
                coroutines: int = 8) -> Dict[str, float]:
    """Throughput model for Clover with ``md_cores`` metadata-server cores."""
    # RTTs per op (paper §2.2 workflow; index cached client-side)
    rtt = {"search": 1, "update": 2, "insert": 2, "delete": 2}
    md_ops = {"search": 0.0,      # metadata cached on clients
              "update": 1.0, "insert": 1.0, "delete": 1.0}
    avg_rtt = sum(rtt[k] * w for k, w in mix.items())
    avg_md = sum(md_ops[k] * w for k, w in mix.items())
    lat_s = avg_rtt * PAPER.rtt_us * 1e-6 + avg_md * PAPER.rpc_rtt_us * 1e-6
    client_cap = n_clients * coroutines / lat_s
    md_cap = (md_cores * PAPER.mdserver_ops_per_core_s / avg_md
              if avg_md > 0 else np.inf)
    bytes_per_op = value_bytes + 64
    nic_cap = n_mns * (PAPER.link_gbps * 1e9 / 8) / bytes_per_op
    overall = min(client_cap, md_cap, nic_cap)
    return {"mops": overall / 1e6, "latency_us": lat_s * 1e6,
            "md_cap_mops": md_cap / 1e6, "client_cap_mops": client_cap / 1e6}


# -------------------------------------------------------- pDPM-Direct-sim --
def pdpm_tput(*, n_clients: int, mix: Dict[str, float],
              n_keys: int = 100_000, theta: float = 0.99,
              value_bytes: int = 1024, n_mns: int = 2,
              coroutines: int = 8) -> Dict[str, float]:
    """Lock-based fully-disaggregated baseline with Zipf lock contention."""
    # lock + read-modify-write + unlock; lock hold = 4 RTTs of work
    rtt = {"search": 2, "update": 6, "insert": 6, "delete": 5}
    hold_rtts = 4.0
    avg_rtt = sum(rtt[k] * w for k, w in mix.items())
    write_frac = sum(w for k, w in mix.items() if k != "search")
    lat0 = avg_rtt * PAPER.rtt_us * 1e-6
    demand = n_clients * coroutines / lat0          # offered load, ops/s
    # serialization cap: hottest key's writes hold its lock exclusively
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-theta)
    p /= p.sum()
    hot_mass = p[0]                                  # Zipf(0.99): ~7-10%
    lock_rate = 1.0 / (hold_rtts * PAPER.rtt_us * 1e-6)
    # writes to the hottest key cannot exceed lock_rate
    cap_serial = (lock_rate / (hot_mass * write_frac)
                  if write_frac > 0 else np.inf)
    # retries amplify traffic as demand approaches the cap
    util = min(demand / cap_serial, 0.999) if np.isfinite(cap_serial) else 0
    retry_blowup = 1.0 / max(1.0 - util, 1e-3) if write_frac else 1.0
    lat_s = lat0 * (1 + util * retry_blowup * write_frac)
    client_cap = n_clients * coroutines / lat_s
    nic_cap = n_mns * (PAPER.link_gbps * 1e9 / 8) / (value_bytes + 96)
    overall = min(client_cap, cap_serial if write_frac else np.inf, nic_cap)
    return {"mops": overall / 1e6, "latency_us": lat_s * 1e6,
            "serial_cap_mops": cap_serial / 1e6}
