"""Fleet-tick roofline: the fused megakernel dispatch vs the per-kind
batch oracle, on a **captured verb ledger**.

What's measured: a seeded 1024-client YCSB-A fleet run is executed once
with the fused engine while every ``DMPool.exec_fused_tick`` call records
its argument tuples — the exact per-tick READ/WRITE/CAS/FAA sweeps the
protocol issued.  That ledger is then replayed against the (restored)
pool under both execution paths:

  * **oracle** — the four per-kind ``*_batch`` calls per tick, each
    dispatching one gather/scatter per (region, replica[, length]) group;
  * **fused**  — one ``exec_fused_tick`` per tick over the flat region
    slab with global word addresses.

Replaying the ledger isolates the array-dispatch layer the fusion
targets from the Python op generators above it (which are identical in
both modes and dominate end-to-end wall-clock).  The slab bytes are
restored between timed passes, so both paths execute bit-identical work.
Rows report ms/tick per path, the speedup, and the verb-traffic roofline
terms (bytes/tick, effective GB/s).

``run()`` feeds ``benchmarks/run.py``; the ≥3x-at-1024-clients claim is
checked in ``validate_claims``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

LEDGER_CLIENTS = (256, 1024)
REPEATS = 5


def _ledger_bytes(ledger) -> int:
    """Verb traffic of the ledger in bytes (words x 8): reads move n
    words, writes len(words), CAS/FAA two words each (RDMA semantics)."""
    words = 0
    for reads, writes, cass, faas in ledger:
        if reads:
            words += sum(int(n) for n in reads[3])
        if writes:
            words += sum(len(w) for w in writes[3])
        if cass:
            words += 2 * len(cass[0])
        if faas:
            words += 2 * len(faas[0])
    return words * 8


def capture_ledger(n_clients: int, *, seed: int = 13,
                   ops_per_client: int = 4):
    """Run a fused YCSB-A fleet workload, recording the argument tuples
    of every ``exec_fused_tick`` call (one per fused tick).  Returns
    ``(cluster, ledger)`` with the pool in its end-of-run state."""
    from repro.core import FuseeCluster, Op

    from .common import fleet_dmconfig

    n_keys = max(256, 2 * n_clients)
    cl = FuseeCluster(fleet_dmconfig(n_clients, n_keys),
                      num_clients=n_clients, seed=seed)
    fleet = cl.fleet(fused=True)
    sched, pool = cl.scheduler, cl.pool
    ledger: List[Tuple] = []
    orig = pool.exec_fused_tick

    def record(reads=None, writes=None, cass=None, faas=None):
        ledger.append((reads, writes, cass, faas))
        return orig(reads, writes, cass, faas)

    pool.exec_fused_tick = record      # instance-attr wrapper (tracer trick)
    backends = [cl.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    for k in range(n_keys):
        sched.submit(k % n_clients, "insert", k, [k] * 8)
    fleet.run()
    wl = cl.rng.stream("workload")
    plans = [[] for _ in range(n_clients)]
    for i in range(n_clients * ops_per_client):
        kind = "update" if wl.random() < 0.5 else "search"
        key = int(wl.integers(n_keys))
        plans[i % n_clients].append(
            Op(kind, key, [i] * 8 if kind == "update" else None))
    cursor = [0] * n_clients
    while True:
        wave = []
        for c in range(n_clients):
            room = 4 - sched.inflight(c)
            if room > 0 and cursor[c] < len(plans[c]):
                ops = plans[c][cursor[c]:cursor[c] + room]
                cursor[c] += len(ops)
                wave.append((backends[c], ops))
        if wave:
            fleet.submit_wave(wave)
        if not sched.has_work():
            break
        fleet.tick()
    pool.exec_fused_tick = orig
    return cl, ledger


def _oracle_args(tick):
    """The per-kind oracle receives plain Python lists in production
    (built by ``FleetEngine._exec_kind``); the fused engine hands the
    pool int64 arrays plus the pre-flattened write values.  Convert —
    and drop the fused-only write extras — outside the timed region so
    each path replays its own production input format."""
    reads, writes, cass, faas = tick
    if writes:
        writes = writes[:4]
    return tuple(
        t if t is None else tuple(
            x.tolist() if isinstance(x, np.ndarray) else x for x in t)
        for t in (reads, writes, cass, faas))


def _replay(pool, ledger, *, fused: bool, repeats: int = REPEATS) -> float:
    """Best-of-N wall-clock (seconds) for one full ledger replay.  The
    slab bytes and byte counters are restored before every pass, so each
    pass — and each path — executes bit-identical work."""
    snap = pool.slab.buf.copy()
    snap_bytes = pool.mn_bytes.copy()
    oracle = None if fused else [_oracle_args(t) for t in ledger]
    best = float("inf")
    for _ in range(repeats):
        pool.slab.buf[:] = snap
        pool.mn_bytes[:] = snap_bytes
        t0 = time.perf_counter()
        if fused:
            for reads, writes, cass, faas in ledger:
                pool.exec_fused_tick(reads, writes, cass, faas)
        else:
            for reads, writes, cass, faas in oracle:
                if reads:
                    pool.read_batch(*reads)
                if writes:
                    pool.write_batch(*writes)
                if cass:
                    pool.cas_batch(*cass)
                if faas:
                    pool.faa_batch(*faas)
        best = min(best, time.perf_counter() - t0)
    pool.slab.buf[:] = snap
    pool.mn_bytes[:] = snap_bytes
    return best


def run() -> List[Dict]:
    rows: List[Dict] = []
    for n_clients in LEDGER_CLIENTS:
        cl, ledger = capture_ledger(n_clients)
        pool = cl.pool
        if not ledger:
            continue
        nbytes = _ledger_bytes(ledger)
        verbs = sum((len(r[0]) if r else 0)
                    for tick in ledger for r in tick)
        t_un = _replay(pool, ledger, fused=False)
        t_fu = _replay(pool, ledger, fused=True)
        ticks = len(ledger)
        rows.append({
            "bench": "roofline", "mode": "fleet-tick",
            "clients": n_clients, "ticks": ticks, "verbs": verbs,
            "verbs_per_tick": verbs / ticks,
            "bytes_per_tick": nbytes / ticks,
            "t_unfused_ms_per_tick": 1e3 * t_un / ticks,
            "t_fused_ms_per_tick": 1e3 * t_fu / ticks,
            "speedup": t_un / t_fu,
            "gbps_unfused": nbytes / t_un / 1e9,
            "gbps_fused": nbytes / t_fu / 1e9,
        })
    return rows


def print_table(rows: List[Dict]):
    hdr = (f"{'clients':>8s} {'ticks':>6s} {'verbs/tick':>11s} "
           f"{'KB/tick':>9s} {'oracle ms':>10s} {'fused ms':>9s} "
           f"{'speedup':>8s} {'GB/s':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("mode") != "fleet-tick":
            continue
        print(f"{r['clients']:8d} {r['ticks']:6d} "
              f"{r['verbs_per_tick']:11.0f} "
              f"{r['bytes_per_tick'] / 1024:9.1f} "
              f"{r['t_unfused_ms_per_tick']:10.3f} "
              f"{r['t_fused_ms_per_tick']:9.3f} "
              f"{r['speedup']:8.1f} {r['gbps_fused']:7.2f}")


if __name__ == "__main__":
    print_table(run())
