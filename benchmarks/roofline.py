"""§Roofline table: read the dry-run artifacts and print the three terms per
(arch x shape x mesh), plus MODEL_FLOPS / HLO_FLOPs usefulness ratios."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_artifacts(art_dir: str = "artifacts") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if "roofline" not in d:
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "step": d.get("step", "?"),
            "t_compute_s": r["t_compute"], "t_memory_s": r["t_memory"],
            "t_collective_s": r["t_collective"],
            "bottleneck": r["bottleneck"],
            "gb_per_dev": d["memory"]["per_device_bytes"] / 1e9,
            "fits_16g": d["memory"]["fits_v5e_16g"],
            "useful_ratio": d.get("useful_flops_ratio"),
            "mfu_bound": (r["t_compute"] * d.get("useful_flops_ratio", 0)
                          / max(r["t_bound"], 1e-30)),
        })
    return rows


def run(art_dir: str = "artifacts") -> List[Dict]:
    rows = load_artifacts(art_dir)
    if not rows:
        return [{"bench": "roofline",
                 "note": "no artifacts; run repro.launch.dryrun --all first"}]
    for r in rows:
        r["bench"] = "roofline"
    return rows


def print_table(rows: List[Dict]):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'bottleneck':10s} "
           f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'GB/dev':>7s} "
           f"{'fit':>4s} {'useful':>7s} {'MFU*':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r.get("arch", ""), r.get("shape", ""),
                                         r.get("mesh", ""))):
        if "arch" not in r:
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['bottleneck']:10s} {r['t_compute_s']:9.2e} "
              f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
              f"{r['gb_per_dev']:7.2f} {str(r['fits_16g'])[:4]:>4s} "
              f"{r['useful_ratio']:7.3f} {r['mfu_bound']:6.3f}")


if __name__ == "__main__":
    print_table(run())
