"""Benchmark driver: one function per paper table/figure + roofline +
serving.  Prints ``name,us_per_call,derived`` CSV rows per bench and writes
the full row dump to bench_results.json.

    PYTHONPATH=src python -m benchmarks.run [--only fig13] [--skip-serving]

Regression gate: ``--compare benchmarks/BASELINE.json`` checks this run's
claim metrics (``claim_metrics``) against a committed baseline and exits
non-zero on any >10% regression in the metric's bad direction
(percentage-point metrics additionally need a >1.5-point absolute move, so
wall-clock ratio noise does not flap the gate).  ``--claims-out PATH``
writes the current metrics in the baseline format; refresh the committed
baseline with it when a PR intentionally shifts performance.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-serving", action="store_true")
    ap.add_argument("--out", default="bench_results.json")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a registry metrics snapshot (JSON) of a "
                         "seeded churn-storm telemetry run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto/Chrome-trace JSON of the same "
                         "telemetry run (load at ui.perfetto.dev)")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="regression gate: compare this run's claim "
                         "metrics against a baseline; exit 1 on any >10% "
                         "regression")
    ap.add_argument("--claims-out", default=None, metavar="PATH",
                    help="write this run's claim metrics as a baseline "
                         "JSON (commit as benchmarks/BASELINE.json)")
    args = ap.parse_args(argv)

    from . import figures, roofline
    benches = [(f.__name__, f) for f in figures.ALL_FIGURES]
    benches.append(("trace_overhead", trace_overhead))
    benches.append(("obs_overhead", obs_overhead))
    benches.append(("explore_dpor", explore_dpor))
    benches.append(("roofline", roofline.run))
    if not args.skip_serving:
        from . import serving_bench
        benches.append(("serving", serving_bench.run))

    if args.only:
        selected = [(n, f) for n, f in benches if args.only in n]
        if not selected:
            # exit non-zero with the menu instead of silently running
            # nothing and writing an empty results file
            ap.error(f"--only {args.only!r} matches no benchmark; "
                     f"available: {', '.join(n for n, _ in benches)}")
        benches = selected

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            rows = fn()
            dt = (time.perf_counter() - t0) * 1e6
            derived = summarize(name, rows)
            print(f"{name},{dt:.0f},{derived}", flush=True)
            all_rows.extend(rows)
        except Exception as e:  # pragma: no cover
            print(f"{name},FAIL,{e!r}")
            raise
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1, default=float)
    if args.metrics_out or args.trace_out:
        export_telemetry(args.metrics_out, args.trace_out)
    if args.claims_out:
        with open(args.claims_out, "w") as f:
            json.dump(claim_metrics(all_rows), f, indent=1, sort_keys=True)
        print(f"claim metrics -> {args.claims_out}")
    regressed = False
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        regressed = not compare_baseline(claim_metrics(all_rows), baseline)
    validate_claims(all_rows)
    if regressed:
        sys.exit(1)


def trace_overhead():
    """Verb-tracer overhead on the fleet tick path (sanitizer suite guard).

    Three modes over the identical seeded YCSB-A fleet workload:
    ``off`` (no tracer attached — the bare ``if tracer is None`` hook),
    ``paused`` (tracer attached, recording disabled — the "leave it on in
    production" mode), and ``recording``.  Each mode reports the median
    us/tick over repeats; the claims check asserts the disabled-mode
    (paused) overhead stays under 3% of the detached baseline.
    """
    import gc
    import statistics

    from repro.analysis.trace import VerbTracer
    from repro.core import FuseeCluster
    from .common import YCSB, fleet_dmconfig

    n_clients, n_keys, repeats, batches = 64, 256, 5, 3
    mix, value_words = YCSB["A"], 8

    def one_run(mode):
        """Build one cluster and time `batches` successive op waves on it,
        returning the per-tick cost of each wave."""
        cfg = fleet_dmconfig(n_clients, n_keys)
        cl = FuseeCluster(cfg, num_clients=n_clients, seed=21)
        sched, fleet = cl.scheduler, cl.fleet()
        tr = None
        if mode != "off":
            tr = VerbTracer(capacity=1 << 16).attach(cl.pool)
            if mode == "paused":
                tr.pause()
        for k in range(n_keys):
            sched.submit(k % n_clients, "insert", k, [k] * value_words)
        fleet.run()
        wl = cl.rng.stream("workload")
        kinds = list(mix)
        weights = [mix[k] for k in kinds]
        samples = []
        for _ in range(batches):
            for i in range(n_clients * 8):
                kind = kinds[int(wl.choice(len(kinds), p=weights))]
                key = int(wl.integers(n_keys))
                v = [i] * value_words if kind in ("insert", "update") \
                    else None
                sched.submit(i % n_clients, kind, key, v)
            gc.collect()
            gc.disable()                 # GC pauses are the loudest noise
            try:
                t0 = time.perf_counter()
                ticks0 = sched.tick
                fleet.run()
                dt = time.perf_counter() - t0
            finally:
                gc.enable()
            samples.append(dt * 1e6 / max(1, sched.tick - ticks0))
        return samples

    modes = ("off", "paused", "recording")
    one_run("off")                       # warmup: JIT / allocator caches
    times = {m: [] for m in modes}
    for _ in range(repeats):             # interleaved: drift hits all modes
        for m in modes:
            times[m].extend(one_run(m))
    # min-of-repeats: scheduling noise is one-sided additive, so the
    # fastest observation is the cleanest estimate of the true cost
    best = {m: min(times[m]) for m in modes}
    return [{"bench": "trace_overhead", "mode": m,
             "us_per_tick": best[m],
             "us_per_tick_median": statistics.median(times[m]),
             "overhead_pct": 100.0 * (best[m] / best["off"] - 1.0)}
            for m in modes]


def obs_overhead():
    """Observability-hub overhead on the fused fleet tick path.

    Three modes over the identical seeded YCSB-A fleet workload:
    ``detached`` (``cluster.detach_obs()`` — every hook site collapses to
    one attribute load + ``is None`` test), ``attached`` (the default
    always-on hub: flight recorder, latency histograms, heat sketch, and
    the per-MN load series all recording), and ``profiled`` (attached
    hub + the hot-key/skew monitor enabled — the full online profiling
    surface; the verb tracer's separate cost is ``trace_overhead``'s
    business).  Each mode reports us/tick; the claims check asserts both
    attached recording AND the profiled mode cost < 5% over the detached
    baseline, which is what justifies leaving them on for the life of a
    cluster.
    """
    import gc
    import statistics

    from repro.core import FuseeCluster
    from .common import YCSB, fleet_dmconfig

    n_clients, n_keys, repeats, batches = 64, 256, 5, 3
    mix, value_words = YCSB["A"], 8

    def one_run(mode):
        cfg = fleet_dmconfig(n_clients, n_keys)
        cl = FuseeCluster(cfg, num_clients=n_clients, seed=23)
        if mode == "detached":
            cl.detach_obs()
        elif mode == "profiled":
            cl.enable_hotspot()
        sched, fleet = cl.scheduler, cl.fleet()
        for k in range(n_keys):
            sched.submit(k % n_clients, "insert", k, [k] * value_words)
        fleet.run()
        wl = cl.rng.stream("workload")
        kinds = list(mix)
        weights = [mix[k] for k in kinds]
        samples = []
        for _ in range(batches):
            for i in range(n_clients * 8):
                kind = kinds[int(wl.choice(len(kinds), p=weights))]
                key = int(wl.integers(n_keys))
                v = [i] * value_words if kind in ("insert", "update") \
                    else None
                sched.submit(i % n_clients, kind, key, v)
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                ticks0 = sched.tick
                fleet.run()
                dt = time.perf_counter() - t0
            finally:
                gc.enable()
            samples.append(dt * 1e6 / max(1, sched.tick - ticks0))
        return samples

    modes = ("detached", "attached", "profiled")
    one_run("detached")                  # warmup: JIT / allocator caches
    times = {m: [] for m in modes}
    for _ in range(repeats):             # interleaved: drift hits all modes
        for m in modes:
            times[m].extend(one_run(m))
    best = {m: min(times[m]) for m in modes}
    return [{"bench": "obs_overhead", "mode": m,
             "us_per_tick": best[m],
             "us_per_tick_median": statistics.median(times[m]),
             "overhead_pct": 100.0 * (best[m] / best["detached"] - 1.0)}
            for m in modes]


def export_telemetry(metrics_path=None, trace_path=None, *, seed=33):
    """Seeded churn-storm telemetry run for the CI artifacts: drives a
    crash/recover/add-MN storm on the fleet engine, then writes the
    registry snapshot (``--metrics-out``) and a Perfetto trace of the
    fault-triggered flight dump (``--trace-out``).  Deterministic: the
    metrics JSON is byte-identical for a given seed."""
    import os
    import tempfile

    from repro.core import (ClientCrashed, DMConfig, FaultPlan,
                            FuseeCluster, Op)
    from repro.obs import flight_to_perfetto, load_flight, metrics_to_json

    n_clients, n_mns, repl, total_ops = 6, 5, 3, 160
    with tempfile.TemporaryDirectory() as td:
        cl = FuseeCluster(DMConfig(num_mns=n_mns, replication=repl,
                                   region_words=1 << 15, regions_per_mn=16,
                                   index_shards=4),
                          num_clients=n_clients, seed=seed,
                          obs_dump_dir=td)
        plan = FaultPlan.storm(cl.rng.stream("faults"),
                               clients=range(n_clients), mns=n_mns,
                               replication=repl, n_client_crashes=2,
                               n_mn_crashes=1, n_add_mns=1,
                               remove_added=True, first_op=10, spacing=14,
                               recover_delay=8)
        cl.inject(plan)
        fleet = cl.fleet()
        stores = {c: cl.store(c, max_inflight=0) for c in range(n_clients)}
        submitted = 0
        while submitted < total_ops:
            for c in range(n_clients):
                if submitted >= total_ops:
                    break
                k = submitted
                submitted += 1
                try:
                    stores[c].submit(Op.put(k, [k, c]))
                except ClientCrashed:
                    pass
            for _ in range(4):
                if cl.scheduler.has_work():
                    fleet.tick()
        fleet.run()
        if cl.migrator.busy:
            cl.migrator.drive()
            fleet.run()
        if metrics_path:
            metrics_to_json(cl.metrics(), metrics_path)
            print(f"telemetry: metrics snapshot -> {metrics_path}")
        if trace_path:
            # prefer the first fault-triggered dump; fall back to a
            # manual end-of-run dump if the storm somehow never fired
            dumps = sorted(cl.obs.dumped.values())
            path = dumps[0] if dumps else cl.obs.dump("manual", force=True)
            flight_to_perfetto(load_flight(path), trace_path)
            print(f"telemetry: perfetto trace ({os.path.basename(path)}) "
                  f"-> {trace_path}")


def explore_dpor():
    """Model-checker bench on the 2-client/1-key insert-race scope.

    Three measurements:

    * ``dpor`` — the real checker (DPOR + sleep sets), run twice; the
      repeat must reproduce the state count AND the order-sensitive
      visit digest bit-identically (the determinism claim).
    * ``dedup`` — exploration with DPOR off (every enabled choice from
      every state), kept tractable by state-hash dedup cuts.  This run
      doubles as ground truth for the reachable-state count.
    * ``naive`` — true naive enumeration (no reduction, no dedup): every
      maximal schedule, every tree node.  Running it is infeasible, so
      it is counted EXACTLY instead: a replay-driven BFS builds the full
      state graph (possible because ``dedup`` proved it small), and a
      DP over that DAG counts the enumeration tree's transitions and
      maximal schedules a no-reduction DFS would execute.

    The claims check asserts dpor-explored transitions prune >= 5x vs
    the naive enumeration tree, and determinism across repeats.
    """
    from repro.analysis.explore import SCOPES, Explorer, state_hash

    scope = "insert_race"
    t0 = time.perf_counter()
    r1 = Explorer(scope).run()
    dpor_s = time.perf_counter() - t0
    r2 = Explorer(scope).run()
    deterministic = (r1.states == r2.states
                     and r1.executions == r2.executions
                     and r1.visit_digest == r2.visit_digest)
    t0 = time.perf_counter()
    rd = Explorer(scope, naive=True).run()
    dedup_s = time.perf_counter() - t0

    # --- exact naive-enumeration count: BFS the state graph by replay,
    # then DP.  succ[h] holds one entry PER CHOICE (two choices reaching
    # the same state are distinct tree edges).
    build = SCOPES[scope].build
    succ = {}
    root = build()
    h0 = state_hash(root.cluster)
    frontier = [(h0, ())]
    succ[h0] = None
    edges = 0
    while frontier:
        h, sched = frontier.pop()
        setup = build()
        cl = setup.cluster
        for ch in sched:
            cl.fire(ch)
        cs = cl.choices()
        outs = []
        for i, ch in enumerate(cs):
            if i > 0:                      # rebuild: fire() mutates
                setup = build()
                cl = setup.cluster
                for c in sched:
                    cl.fire(c)
            cl.fire(ch)
            h2 = state_hash(cl)
            outs.append(h2)
            edges += 1
            if h2 not in succ:
                succ[h2] = None
                frontier.append((h2, sched + (ch,)))
        succ[h] = outs

    import sys as _sys
    _sys.setrecursionlimit(100_000)
    tree_memo, sched_memo = {}, {}

    def tree_transitions(h):               # nodes the unreduced DFS fires
        if h not in tree_memo:
            tree_memo[h] = sum(1 + tree_transitions(t) for t in succ[h])
        return tree_memo[h]

    def schedules(h):                      # maximal schedules it executes
        if h not in sched_memo:
            sched_memo[h] = sum(schedules(t) for t in succ[h]) \
                if succ[h] else 1
        return sched_memo[h]

    naive_transitions = tree_transitions(h0)
    naive_schedules = schedules(h0)
    dpor_work = r1.transitions + r1.replay_fires
    return [{
        "bench": "explore", "scope": scope,
        "dpor_states": r1.states, "dpor_executions": r1.executions,
        "dpor_transitions": r1.transitions,
        "dpor_replay_fires": r1.replay_fires,
        "dpor_work": dpor_work, "dpor_s": dpor_s,
        "dpor_states_per_s": r1.states / max(dpor_s, 1e-9),
        "deterministic": deterministic, "visit_digest": r1.visit_digest,
        "dedup_states": rd.states, "dedup_executions": rd.executions,
        "dedup_s": dedup_s,
        "graph_states": len(succ), "graph_edges": edges,
        "naive_transitions": float(naive_transitions),
        "naive_schedules": float(naive_schedules),
        "reduction_transitions": naive_transitions / max(dpor_work, 1),
        "reduction_schedules": naive_schedules / max(r1.executions, 1),
    }]


# ------------------------------------------------------- regression gate
# metric fields worth gating, by good direction.  Simulated metrics
# (mops, RTTs, ok_frac, ...) are deterministic per seed so a relative
# threshold is exact; the wall-clock-derived ratios (speedup,
# overhead_pct) are kept because same-machine ratios are stable, with an
# absolute floor on the *_pct family so near-zero values cannot flap.
_HIGHER_BETTER = ("mops", "ops_per_rtt", "batch_ops_per_rtt", "speedup",
                  "ok_frac", "reduction_transitions", "reduction_schedules")
_LOWER_BETTER = ("latency_us", "lat_p99_us", "ms", "scan_rtts",
                 "overhead_pct")
# row fields that identify a measurement (stable key parts)
_KEY_FIELDS = ("ycsb", "clients", "system", "shards", "mns", "r", "op",
               "batch", "step", "window", "mode", "alloc", "scope",
               "scan_len")
REGRESSION_REL = 0.10          # >10% move in the bad direction regresses
REGRESSION_PCT_FLOOR = 1.5     # *_pct metrics also need >1.5 points


def claim_metrics(rows):
    """Flatten bench rows into ``{stable-name: value}`` for the
    regression gate — only fields from the gated whitelists, keyed by the
    row's identifying fields so baselines survive row reordering."""
    out = {}
    for r in rows:
        bench = r.get("bench")
        if not bench:
            continue
        key = ".".join([str(bench)] + [f"{k}={r[k]}" for k in _KEY_FIELDS
                                       if r.get(k) is not None])
        for f in _HIGHER_BETTER + _LOWER_BETTER:
            v = r.get(f)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"{key}.{f}"] = float(v)
    return out


def compare_baseline(current, baseline) -> bool:
    """Print a regression report; True when no gated metric moved >10%
    in its bad direction vs the baseline.  Metrics missing on either
    side (bench not run / newly added) are skipped, so ``--only`` runs
    gate just their own rows."""
    regressions, improved, checked = [], 0, 0
    for name in sorted(set(current) & set(baseline)):
        old, new = baseline[name], current[name]
        field = name.rsplit(".", 1)[-1]
        lower_better = field in _LOWER_BETTER
        delta = (new - old) if lower_better else (old - new)   # bad if > 0
        denom = max(abs(old), 1e-9)
        rel = delta / denom
        checked += 1
        bad = rel > REGRESSION_REL
        if field.endswith("_pct"):
            bad = bad and abs(delta) > REGRESSION_PCT_FLOOR
        if bad:
            regressions.append((name, old, new, rel))
        elif rel < -REGRESSION_REL:
            improved += 1
    print(f"\n== baseline comparison ({checked} metrics) ==")
    for name, old, new, rel in regressions:
        print(f"  [REGRESSED] {name}: {old:.4g} -> {new:.4g} "
              f"({100 * rel:+.1f}% worse)")
    if not regressions:
        print(f"  no regressions >{100 * REGRESSION_REL:.0f}% "
              f"({improved} metrics improved >10%)")
    skipped = len(set(current) - set(baseline))
    if skipped:
        print(f"  ({skipped} new metric(s) not in baseline — refresh it "
              f"with --claims-out)")
    return not regressions


def summarize(name: str, rows) -> str:
    if not rows:
        return "no-rows"
    if name == "trace_overhead":
        by = {r["mode"]: r for r in rows}
        return (f"fleet tick {by['off']['us_per_tick']:.0f}us/tick; "
                f"paused {by['paused']['overhead_pct']:+.1f}% "
                f"recording {by['recording']['overhead_pct']:+.1f}%")
    if name == "obs_overhead":
        by = {r["mode"]: r for r in rows}
        return (f"fleet tick {by['detached']['us_per_tick']:.0f}us/tick "
                f"detached; attached "
                f"{by['attached']['overhead_pct']:+.1f}% profiled "
                f"{by['profiled']['overhead_pct']:+.1f}%")
    if name == "explore_dpor":
        r = rows[0]
        return (f"{r['scope']}: dpor {r['dpor_states']} states/"
                f"{r['dpor_executions']} execs "
                f"({r['dpor_states_per_s']:.0f} states/s) vs naive "
                f"{r['naive_schedules']:.2e} schedules — "
                f"{r['reduction_transitions']:.0f}x transition reduction"
                f"{', deterministic' if r['deterministic'] else ''}")
    if name == "fig13_ycsb_scale":
        f = {(r["ycsb"], r["clients"], r["system"]): r["mops"] for r in rows}
        sp_c = f[("A", 128, "fusee")] / max(f[("A", 128, "clover")], 1e-9)
        sp_p = f[("A", 128, "fusee")] / max(f[("A", 128, "pdpm")], 1e-9)
        return (f"YCSB-A@128: fusee={f[('A', 128, 'fusee')]:.2f}Mops "
                f"{sp_c:.1f}x-clover {sp_p:.1f}x-pdpm")
    if name == "fig14_mn_scale":
        f = {(r["ycsb"], r["shards"], r["mns"]): r["mops"] for r in rows}
        s = f[("A", 8, 8)] / max(f[("A", 8, 2)], 1e-9)
        flat = f[("A", 1, 8)] / max(f[("A", 1, 2)], 1e-9)
        return (f"YCSB-A 2->8 MNs: S=8 {s:.1f}x scaling "
                f"(S=1 baseline {flat:.2f}x)")
    if name == "elastic_timeline":
        ev = {r["window"]: r for r in rows}
        worst = min((r["ok_frac"] for r in rows if r["ops_done"]),
                    default=0.0)
        return (f"{len(rows) - 1} windows, 2->4->3 MNs online; "
                f"min ok_frac {worst:.2f}, final migrations "
                f"{ev['final']['migrating_regions']}")
    if name == "tab1_recovery":
        t = {r["step"]: r for r in rows}
        return (f"total={t['total']['ms']:.1f}ms "
                f"reconnect={t['reconnect_mr']['pct']:.0f}% "
                f"traverse={t['traverse_log']['pct']:.1f}%")
    if name == "fig1819_replication":
        lat = {(r["r"], r["system"], r.get("op")): r.get("latency_us")
               for r in rows if r["bench"] == "fig19"}
        return (f"UPDATE r=1: fusee={lat.get((1, 'fusee', 'update'), 0):.1f}us"
                f" r=5: fusee={lat.get((5, 'fusee', 'update'), 0):.1f}us"
                f" cr={lat.get((5, 'fusee-cr', 'update'), 0):.1f}us")
    if name == "api_batch_search":
        best = max(rows, key=lambda r: r["batch"])
        return (f"batch SEARCH {best['batch_ops_per_rtt']:.0f} ops/RTT vs "
                f"serial {best['serial_ops_per_rtt']:.1f} "
                f"({best['speedup']:.1f}x at B={best['batch']})")
    if name == "ycsbe_scan":
        best = max(rows, key=lambda r: r["clients"])
        return (f"YCSB-E@{best['clients']}: {best['mops']:.2f}Mops "
                f"scan={best['scan_rtts']:.1f}RTTs "
                f"p99={best['lat_p99_us']:.0f}us")
    if name == "scan_batch":
        sp = [r for r in rows if r.get("speedup")]
        worst = min(sp, key=lambda r: r["speedup"])
        return (f"batched leaf sweep {worst['ops_per_rtt']:.1f} ops/RTT, "
                f"{worst['speedup']:.1f}x naive (len={worst['scan_len']})")
    if name == "roofline" and "arch" in rows[0]:
        worst = min(rows, key=lambda r: r.get("mfu_bound", 1))
        return (f"{len(rows)} cells; worst MFU-bound "
                f"{worst['arch']}/{worst['shape']}={worst['mfu_bound']:.3f}")
    if name == "roofline" and rows[0].get("mode") == "fleet-tick":
        top = max(rows, key=lambda r: r["clients"])
        return (f"fleet tick @{top['clients']}: "
                f"{top['t_fused_ms_per_tick']:.2f}ms fused vs "
                f"{top['t_unfused_ms_per_tick']:.2f}ms oracle "
                f"({top['speedup']:.1f}x, {top['gbps_fused']:.2f}GB/s)")
    return f"{len(rows)} rows"


def validate_claims(rows):
    """§Paper-claims quick checks (full narrative in EXPERIMENTS.md)."""
    checks = []
    f13 = {(r.get("ycsb"), r.get("clients"), r.get("system")): r["mops"]
           for r in rows if r.get("bench") == "fig13"}
    if f13:
        sp = f13[("A", 128, "fusee")] / max(f13[("A", 128, "clover")], 1e-9)
        checks.append(("fusee >= 4x clover @128 clients (paper: 4.9x)",
                       sp >= 4.0, f"{sp:.1f}x"))
        spp = f13[("A", 128, "fusee")] / max(f13[("A", 128, "pdpm")], 1e-9)
        checks.append(("fusee >> pdpm @128 clients (paper: 117x)",
                       spp >= 20.0, f"{spp:.0f}x"))
    f14 = {(r.get("ycsb"), r.get("shards"), r.get("mns")): r["mops"]
           for r in rows if r.get("bench") == "fig14"}
    if f14:
        sp = f14[("A", 8, 8)] / max(f14[("A", 8, 2)], 1e-9)
        checks.append(("sharded index scales with MNs (>=1.5x, 2->8 MNs, S=8)",
                       sp >= 1.5, f"{sp:.1f}x"))
    el = [r for r in rows if r.get("bench") == "elastic"
          and r.get("window") != "final"]
    if el:
        alive = min((r["ok_frac"] for r in el if r["ops_done"]),
                    default=0.0)
        fin = [r for r in rows if r.get("bench") == "elastic"
               and r.get("window") == "final"]
        checks.append(("store stays available through add/remove MN",
                       alive > 0.9 and bool(fin)
                       and all(r["ops_done"] > 0 for r in el)
                       and fin[0]["migrating_regions"] == 0,
                       f"min ok_frac {alive:.2f}"))
    f19 = [(r["r"], r["system"], r["latency_us"]) for r in rows
           if r.get("bench") == "fig19" and r.get("op") == "update"]
    if f19:
        fus = {r: l for r, s, l in f19 if s == "fusee"}
        cr = {r: l for r, s, l in f19 if s == "fusee-cr"}
        flat = fus[5] / fus[1]
        lin = cr[5] / cr[1]
        checks.append(("SNAPSHOT latency ~flat in r; CR grows linearly",
                       flat < 1.8 and lin > 2.0,
                       f"fusee x{flat:.2f}, cr x{lin:.2f} from r=1->5"))
    t1 = {r["step"]: r for r in rows if r.get("bench") == "tab1"}
    if t1:
        checks.append(("recovery dominated by reconnect (paper: 92%)",
                       t1["reconnect_mr"]["pct"] > 80,
                       f"{t1['reconnect_mr']['pct']:.0f}%"))
    ab = [r for r in rows if r.get("bench") == "api_batch"]
    if ab:
        worst = min(r["speedup"] for r in ab)
        checks.append(("batched SEARCH beats serial ops/RTT at every size",
                       worst > 1.0, f"min speedup {worst:.1f}x"))
    sb = [r for r in rows if r.get("bench") == "scan_batch"
          and r.get("speedup")]
    if sb:
        worst = min(r["speedup"] for r in sb)
        checks.append(("batched leaf traversal >= 4x naive per-slot ops/RTT",
                       worst >= 4.0, f"min speedup {worst:.1f}x"))
    ye = [r for r in rows if r.get("bench") == "ycsbe"]
    if ye:
        ok = all(r["sim_ops"] > 0 and r["mops"] > 0 for r in ye)
        checks.append(("YCSB-E runs end to end on the fleet engine",
                       ok, f"{max(r['mops'] for r in ye):.2f} Mops"))
    f17 = {r["alloc"]: r["mops"] for r in rows
           if r.get("bench") == "fig17" and r.get("ycsb") == "A"}
    if f17:
        drop = 1 - f17["mn-centric"] / f17["two-level"]
        checks.append(("MN-centric alloc collapses under YCSB-A (paper: -90.9%)",
                       drop > 0.5, f"-{100 * drop:.0f}%"))
    to = {r["mode"]: r for r in rows if r.get("bench") == "trace_overhead"}
    if to:
        ov = to["paused"]["overhead_pct"]
        checks.append(("tracer disabled-mode overhead on fleet ticks < 3%",
                       ov < 3.0,
                       f"paused {ov:+.1f}%, recording "
                       f"{to['recording']['overhead_pct']:+.1f}%"))
    oo = {r["mode"]: r for r in rows if r.get("bench") == "obs_overhead"}
    if oo:
        ov = oo["attached"]["overhead_pct"]
        checks.append(("attached obs hub overhead on fleet ticks < 5%",
                       ov < 5.0, f"attached {ov:+.1f}%"))
        op = oo["profiled"]["overhead_pct"]
        checks.append(("hub + hot-key monitor (profiled) overhead < 5%",
                       op < 5.0, f"profiled {op:+.1f}%"))
    rl = [r for r in rows if r.get("bench") == "roofline"
          and r.get("mode") == "fleet-tick"]
    if rl:
        top = max(rl, key=lambda r: r["clients"])
        checks.append(("fused tick >= 3x per-kind oracle @1024 clients",
                       top["clients"] >= 1024 and top["speedup"] >= 3.0,
                       f"{top['speedup']:.1f}x at {top['clients']} clients "
                       f"({top['t_unfused_ms_per_tick']:.2f} -> "
                       f"{top['t_fused_ms_per_tick']:.2f} ms/tick)"))
    exp = [r for r in rows if r.get("bench") == "explore"]
    if exp:
        r = exp[0]
        checks.append(("DPOR prunes >= 5x vs naive enumeration "
                       "(insert-race scope)",
                       r["reduction_transitions"] >= 5.0
                       and r["reduction_schedules"] >= 5.0,
                       f"{r['reduction_transitions']:.0f}x transitions, "
                       f"{r['reduction_schedules']:.0f}x schedules "
                       f"({r['dpor_work']} fired vs "
                       f"{r['naive_transitions']:.2e} naive)"))
        checks.append(("exploration bit-identical across repeat runs",
                       bool(r["deterministic"]),
                       f"digest {r['visit_digest'][:16]}"))
        checks.append(("dpor finds no violations on the clean scope",
                       r["dpor_states"] > 0 and r["dedup_states"] > 0
                       and r["dpor_states"] <= r["dedup_states"],
                       f"{r['dpor_states']}/{r['dedup_states']} states"))
    print("\n== paper-claims validation ==")
    ok = True
    for name, passed, detail in checks:
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
        ok &= passed
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
