"""Serving example: continuous batching over the FUSEE-managed KV pool with
shared-prefix requests (the disaggregated prefix cache at work).

    PYTHONPATH=src python examples/serve_fusee.py
"""
import time

import jax
import numpy as np

from repro.configs import base as C
from repro.models import build
from repro.serving import PoolConfig, Request, ServeEngine
from repro.launch.mesh import make_host_mesh


def main():
    mesh = make_host_mesh((1, 1), ("data", "model"))
    cfg = C.reduced(C.get("llama3-8b"))
    model = build(cfg, mesh, use_kernels=True)   # Pallas attn (interpret)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_batch=4, max_len=256,
                      pool_cfg=PoolConfig(n_pages=2048, n_buckets=512,
                                          slots_per_bucket=8, replicas=3))
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab, 128).astype(np.int32)
    for i in range(8):
        user = rng.integers(0, cfg.vocab, 32).astype(np.int32)
        eng.submit(Request(rid=i, max_new=8,
                           prompt=np.concatenate([system_prompt, user])))
    t0 = time.perf_counter()
    done = eng.run(max_ticks=200)
    dt = time.perf_counter() - t0
    toks = sum(len(q.out) for q in done)
    print(f"served {len(done)} requests / {toks} tokens "
          f"in {dt:.1f}s ({eng.steps} ticks)")
    hits = sum(q.prefix_hits for q in done)
    print(f"prefix-cache: {hits} block hits across requests "
          f"(shared 128-token system prompt = 2 blocks)")
    print(f"pool: {eng.pool.stats}  replicas converged: "
          f"{eng.pool.check_replicas_converged()}")
    for q in done[:3]:
        print(f"  rid={q.rid} -> {q.out}")


if __name__ == "__main__":
    main()
