"""End-to-end training driver: train a ~smollm-family model for a few
hundred steps on the synthetic pipeline, with async checkpointing and the
straggler watchdog.  On CPU this uses a reduced config by default; pass
--full to build the real 360M config (slow on CPU).

    PYTHONPATH=src python examples/train_smollm.py --steps 200
"""
import argparse

import jax

from repro.configs import base as C
from repro.data import DataConfig, SyntheticLM
from repro.models import build
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = C.get(args.arch)
    if not args.full:
        cfg = C.reduced(cfg, n_layers=4, d_model=128, vocab=512,
                        d_ff_scale=64)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    model = build(cfg, mesh)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    tr = Trainer(model,
                 OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
                 TrainConfig(ckpt_every=50, ckpt_dir=args.ckpt), data)
    if not tr.restore():
        tr.init_state(jax.random.PRNGKey(0))
        print("fresh start")
    else:
        print(f"restored from step {tr.step}")
    losses = tr.run(args.steps)
    print(f"step {tr.step}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("watchdog:", tr.watchdog.summary())


if __name__ == "__main__":
    main()
