"""Fault drill: kill the trainer mid-run, restore from the last committed
checkpoint, finish, and verify the loss curve is seamless.  Also drills an
MN crash + client crash in the KV store.

    PYTHONPATH=src python examples/fault_drill.py
"""
import shutil

import jax
import numpy as np

from repro.configs import base as C
from repro.core import DMConfig, FuseeCluster
from repro.data import DataConfig, SyntheticLM
from repro.models import build
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer
from repro.launch.mesh import make_host_mesh


def train_drill():
    print("== training fault drill ==")
    shutil.rmtree("/tmp/repro_fault_ckpt", ignore_errors=True)
    cfg = C.reduced(C.get("smollm-360m"))
    mesh = make_host_mesh((1, 1), ("data", "model"))
    model = build(cfg, mesh)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, seed=0))
    tr = Trainer(model, OptConfig(lr=3e-3, warmup_steps=5, total_steps=60),
                 TrainConfig(ckpt_every=10, ckpt_dir="/tmp/repro_fault_ckpt"),
                 data)
    tr.init_state(jax.random.PRNGKey(0))
    losses, recovered = tr.run_with_recovery(40, fail_at=25)
    print(f" killed at step 25, recovered={recovered}, "
          f"resumed from {tr.ckpt.latest()}")
    print(f" finished at step {tr.step}; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


def store_drill():
    print("\n== KV-store crash drill (MN + client) ==")
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3)
    kv = cluster.store(0)
    for k in range(32):
        kv.insert(k, [k * 10])
    print(" 32 keys inserted on client 0")
    cluster.crash_mn(2)
    cluster.master.maybe_recover_mns()
    ok = all(cluster.store(1).get(k) == [k * 10] for k in range(32))
    print(f" MN 2 crashed + master re-homed regions: all keys readable={ok}")
    cluster.crash_client(0)
    st = cluster.recover_client(0, reassign_to_cid=1)
    print(f" client 0 crashed: recovery reclaimed {st.reclaimed_objects} "
          f"objects, redid {st.redone_ops} ops, "
          f"~{st.reconnect_ms:.0f}ms reconnect")
    ok = all(cluster.store(2).get(k) == [k * 10] for k in range(32))
    print(f" data intact after both failures: {ok}")


if __name__ == "__main__":
    train_drill()
    store_drill()
