"""Fault drill: kill the trainer mid-run, restore from the last committed
checkpoint, finish, and verify the loss curve is seamless.  Then drill the
KV store through the declarative fault surface: an MN crash and a client
crash fire from a ``FaultPlan`` while a pipelined workload is in flight,
in-flight futures settle to the typed retriable ``CRASHED`` outcome, the
crashed client is recovered and replaced via dynamic membership, and
``cluster.health()`` reports the whole story.

    PYTHONPATH=src python examples/fault_drill.py [--skip-train]
"""
import argparse
import shutil

from repro.core import (CRASHED, OK, ClientCrashed, DMConfig, FaultPlan,
                        FuseeCluster, Op)


def train_drill():
    import jax

    from repro.configs import base as C
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models import build
    from repro.optim import OptConfig
    from repro.train import TrainConfig, Trainer

    print("== training fault drill ==")
    shutil.rmtree("/tmp/repro_fault_ckpt", ignore_errors=True)
    cfg = C.reduced(C.get("smollm-360m"))
    mesh = make_host_mesh((1, 1), ("data", "model"))
    model = build(cfg, mesh)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, seed=0))
    tr = Trainer(model, OptConfig(lr=3e-3, warmup_steps=5, total_steps=60),
                 TrainConfig(ckpt_every=10, ckpt_dir="/tmp/repro_fault_ckpt"),
                 data)
    tr.init_state(jax.random.PRNGKey(0))
    losses, recovered = tr.run_with_recovery(40, fail_at=25)
    print(f" killed at step 25, recovered={recovered}, "
          f"resumed from {tr.ckpt.latest()}")
    print(f" finished at step {tr.step}; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


def store_drill():
    print("\n== KV-store fault drill (declarative MN + client crash) ==")
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3)
    kv = cluster.store(0)
    for k in range(32):
        kv.insert(k, [k * 10])
    print(" 32 keys inserted on client 0")

    # Declarative plan: MN 2 dies while the UPDATE batch below is in flight
    # (auto-detected and re-homed by the scheduler loop, no master calls),
    # then client 0 crash-stops 16 completed ops later, mid-pipeline.
    injector = cluster.inject(FaultPlan()
                              .crash_mn(2, after_ops=40)
                              .crash_client(0, after_ops=48))
    futs = kv.submit_batch([Op.update(k, [k * 10]) for k in range(32)])
    res = [f.result() for f in futs]
    n_ok = sum(r.status == OK for r in res)
    n_crashed = sum(r.status == CRASHED for r in res)
    print(f" UPDATE x32 under the plan -> {n_ok} OK, {n_crashed} CRASHED "
          f"(all retriable="
          f"{all(r.retriable for r in res if r.status == CRASHED)})")
    assert injector.done, injector.pending

    try:
        kv.get(0)
    except ClientCrashed as e:
        print(f" submit on the dead client -> typed ClientCrashed "
              f"(cid={e.cid}, reason={e.reason!r})")

    retried = [cluster.store(1).get(k) for k in range(32)]
    print(f" retried on live client 1   -> all keys readable="
          f"{retried == [[k * 10] for k in range(32)]}")

    st = cluster.recover_client(0, reassign_to_cid=1)
    print(f" client 0 recovered: reclaimed {st.reclaimed_objects} objects, "
          f"redid {st.redone_ops} ops, ~{st.reconnect_ms:.0f}ms reconnect")

    cid = cluster.add_client()            # elastic replacement joins
    ok = all(cluster.store(cid).get(k) == [k * 10] for k in range(32))
    print(f" replacement client {cid} joined (epoch "
          f"{cluster.clients[cid].epoch}): all keys readable={ok}")

    h = cluster.health()
    print(f" health: {h.summary()}")
    dead = [m.mid for m in h.mns if not m.alive]
    print(f" MNs down={dead}, recovery total "
          f"traverse={h.recovery.traverse_log_rtts} RTTs "
          f"redo={h.recovery.redone_ops} ops")


def elastic_drill():
    """Online MN scale-out under load: 2 -> 4 MNs while a fleet workload
    keeps writing.  Index shards re-home by live migration (bulk copy +
    dual-write window + epoch-bump cutover); nothing acked is lost."""
    print("\n== elastic drill (2 -> 4 MNs under live load) ==")
    n_clients = 8
    cluster = FuseeCluster(DMConfig(num_mns=2, replication=2, index_shards=8,
                                    region_words=1 << 15, regions_per_mn=8),
                           num_clients=n_clients, seed=3)
    fleet = cluster.fleet()
    sched = cluster.scheduler
    backends = [cluster.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    print(f" index shards: {len(cluster.pool.index_regions)} over "
          f"{len(cluster.pool.mns)} MNs "
          f"{dict((g, cluster.pool.placement[g]) for g in cluster.pool.index_regions[:3])}...")
    futs, k = [], 0
    added = []
    while k < 256 or cluster.migrator.busy or sched.has_work():
        for c in range(n_clients):
            if k < 256 and sched.inflight(c) < 4:
                futs.append((k, backends[c].submit_many([Op.put(k, [k])])[0]))
                k += 1
        if k >= 64 and len(added) == 0:
            added.append(cluster.add_mn(wait=False))
            print(f" MN {added[-1]} joined at op {k} (migration rides the "
                  f"workload ticks)")
        if k >= 128 and len(added) == 1:
            added.append(cluster.add_mn(wait=False))
            print(f" MN {added[-1]} joined at op {k}")
        fleet.tick()
    ok = sum(f.result().status == OK for _, f in futs)
    print(f" {ok}/{len(futs)} writes OK across both scale-outs, "
          f"{cluster.migrator.counters['cutovers']} shard cutovers, "
          f"{cluster.migrator.counters['copied_words']} words copied")
    reader = cluster.store(1)
    lost = [kk for kk, f in futs
            if f.result().status == OK and reader.get(kk) != [kk]]
    print(f" acked-write loss after migration: {len(lost)} (expect 0)")
    assert not lost, lost
    shards_by_mn = {}
    for g in cluster.pool.index_regions:
        shards_by_mn.setdefault(cluster.pool.placement[g][0], []).append(g)
    print(f" shard primaries by MN: "
          f"{ {m: len(gs) for m, gs in sorted(shards_by_mn.items())} }")
    print(f" health: {cluster.health().summary()}")


def scan_drill():
    """Ordered-index fault drill: crash a client mid-leaf-split while
    YCSB-E traffic (scans + inserts) is live, repair via Alg-3/§5.3, and
    audit that no acknowledged insert is missing from subsequent scans."""
    import numpy as np

    from repro.core import ordered

    print("\n== scan drill (crash mid-leaf-split under live YCSB-E) ==")
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3,
                                    ordered_index=True,
                                    region_words=1 << 15, regions_per_mn=16),
                           num_clients=4, seed=11)
    sched = cluster.scheduler
    kv1 = cluster.store(1)
    for k in range(24):                     # fill ~2 leaves
        kv1.insert(k, [k])
    print(" 24 keys preloaded; "
          f"{len(ordered.ordered_keys_direct(cluster.pool))} in the keydir")

    # client 0: a pipeline of inserts that will split leaves; clients 2-3:
    # live YCSB-E scans.  Crash client 0 at an arbitrary verb boundary —
    # with splits in flight, that is a half-split tree.
    recs = [sched.submit(0, "insert", 24 + i, [24 + i]) for i in range(12)]
    scan_recs = [sched.submit(2 + (i % 2), "scan", int(i * 7) % 30, 20)
                 for i in range(6)]
    rng = np.random.default_rng(11)
    for _ in range(700):     # far enough that some inserts acked mid-split
        cids = sched.eligible_cids()
        if not cids:
            break
        sched.step(cids[int(rng.integers(len(cids)))],
                   pick=int(rng.integers(4)))
    cluster.crash_client(0)
    acked = [24 + i for i, r in enumerate(recs)
             if r.result is not None and r.result.status == OK]
    n_crashed = sum(1 for r in recs
                    if r.result is not None and r.result.status == CRASHED)
    print(f" client 0 crashed mid-split: {len(acked)} inserts acked, "
          f"{n_crashed} in-flight CRASHED")
    st = cluster.recover_client(0, reassign_to_cid=1)
    cluster.drain()
    print(f" Alg-3/§5.3 repair: {st.redone_ops} redone, "
          f"{st.reclaimed_objects} reclaimed")

    res = cluster.store(1).scan(0, 100)
    got = [k for k, _ in res]
    missing = [k for k in list(range(24)) + acked if k not in got]
    live_scans = sum(1 for r in scan_recs
                     if r.result is not None and r.result.status == OK)
    print(f" scans during the storm: {live_scans}/{len(scan_recs)} OK; "
          f"post-repair scan sees {len(got)} keys")
    print(f" acked-insert loss after repair: {len(missing)} (expect 0)")
    assert not missing, missing
    assert got == sorted(set(got)), "torn scan result"
    print(f" health: {cluster.health().summary()}")


def metrics_drill():
    """Telemetry drill: run a fleet workload through an MN crash with the
    observability hub armed, then read the story back three ways — op
    latency percentiles from the registry histograms, the per-MN load
    table from the ``mn.load`` time-series, and the fault-triggered
    flight-recorder dump exported to a Perfetto trace."""
    import tempfile

    from repro.obs import flight_to_perfetto, load_flight

    print("\n== telemetry drill (histograms / per-MN load / flight dump) ==")
    dump_dir = tempfile.mkdtemp(prefix="fusee_flight_")
    n_clients = 8
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3,
                                    region_words=1 << 15, regions_per_mn=16,
                                    index_shards=4),
                           num_clients=n_clients, seed=7,
                           obs_dump_dir=dump_dir)
    cluster.inject(FaultPlan().crash_mn(3, after_ops=120))
    fleet = cluster.fleet()
    stores = {c: cluster.store(c, max_inflight=0) for c in range(n_clients)}
    for k in range(256):
        stores[k % n_clients].submit(Op.put(k, [k]))
        if k % 32 == 31:
            fleet.run()
    for k in range(256):
        stores[k % n_clients].submit(Op.get(k))
        if k % 32 == 31:
            fleet.run()
    fleet.run()

    m = cluster.metrics()
    print(f" ops: {m['counters']['op.begun']} begun, "
          f"{m['counters']['op.settled']} settled, "
          f"{m['counters']['op.crashed']} crashed")
    print(" op latency percentiles (conservative bucket upper edges):")
    print(f"  {'metric':<34}{'count':>7}{'p50':>6}{'p99':>6}{'p999':>7}")
    for name, p in sorted(m["percentiles"].items()):
        if ".kind." in name:
            print(f"  {name:<34}{p['count']:>7}{p['p50']:>6}"
                  f"{p['p99']:>6}{p['p999']:>7}")

    series = m["series"]["mn.load"]
    by = {f: i for i, f in enumerate(series["fields"])}
    per_mn = {}
    for row in series["rows"]:
        agg = per_mn.setdefault(int(row[by["mid"]]),
                                {"bytes": 0.0, "verbs": 0.0,
                                 "cpu_ops": 0.0, "util": []})
        agg["bytes"] += row[by["bytes"]]
        agg["verbs"] += row[by["verbs"]]
        agg["cpu_ops"] += row[by["cpu_ops"]]
        agg["util"].append(row[by["util"]])
    print(f" per-MN load ({len(series['rows'])} window samples):")
    print(f"  {'mn':>4}{'bytes':>10}{'verbs':>8}{'cpu_ops':>9}"
          f"{'peak util':>11}")
    for mid, agg in sorted(per_mn.items()):
        print(f"  {mid:>4}{agg['bytes']:>10.0f}{agg['verbs']:>8.0f}"
              f"{agg['cpu_ops']:>9.0f}{max(agg['util']):>10.4f}")

    print(" dump-on-fault:")
    for reason, path in sorted(cluster.obs.dumped.items()):
        dump = load_flight(path)
        trace_path = path.replace(".npz", ".perfetto.json")
        flight_to_perfetto(dump, trace_path)
        print(f"  {reason}: {len(dump['tick'])} events -> {path}")
        print(f"   perfetto trace (ui.perfetto.dev) -> {trace_path}")
    assert cluster.obs.dumped, "MN crash must trigger a flight dump"


def profile_drill():
    """Causal-profiler drill: a planted zipf(0.99) fleet workload through
    an MN crash with the verb tracer AND the hot-key monitor armed, then
    the full profiling surface read back — the top-5 critical-path rows
    (op kind x protocol phase x retry cause, RTT-conservation-checked),
    the hot-key top-k table with the online zipf-θ estimate, and a
    Perfetto trace with the causal phase sub-spans nested under each op
    lane."""
    import tempfile

    import numpy as np

    from repro.obs import flight_to_perfetto
    from repro.obs.profile import format_report

    print("\n== profile drill (critical path + hot keys, zipf 0.99) ==")
    n_clients, n_keys, n_ops, theta = 8, 256, 1200, 0.99
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3,
                                    region_words=1 << 15, regions_per_mn=16,
                                    index_shards=4),
                           num_clients=n_clients, seed=5)
    cluster.attach_tracer(capacity=1 << 17)
    cluster.enable_hotspot()
    cluster.inject(FaultPlan().crash_mn(3, after_ops=300))
    fleet = cluster.fleet()
    stores = {c: cluster.store(c, max_inflight=0) for c in range(n_clients)}
    for k in range(n_keys):
        stores[k % n_clients].submit(Op.insert(k, [k]))
        if k % 32 == 31:
            fleet.run()
    fleet.run()
    # planted zipfian read/update mix (the hot head is keys 0, 1, 2, ...)
    wl = cluster.rng.stream("workload")
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-theta)
    p /= p.sum()
    keys = wl.choice(n_keys, size=n_ops, p=p)
    for i, k in enumerate(keys):
        st = stores[i % n_clients]
        op = Op.update(int(k), [i]) if i % 2 else Op.get(int(k))
        st.submit(op)
        if i % 64 == 63:
            fleet.run()
    fleet.run()

    prof = cluster.profile()
    print(" top-5 critical-path rows:")
    print("  " + format_report(prof, top=5).replace("\n", "\n  "))
    c = prof["conservation"]
    assert c["ok"], f"RTT conservation violated: {c}"

    hot = cluster.metrics()["hotspot"]
    print(f" hot-key monitor: θ~{hot['theta_milli'] / 1000:.2f} "
          f"(planted {theta}), regime={hot['regime']}, "
          f"{hot['keys_seen']} keys folded:")
    print(f"  {'key':>6}{'count':>8}{'err':>6}")
    for key, count, err in hot["top"][:10]:
        print(f"  {key:>6}{count:>8}{err:>6}")

    trace_path = tempfile.mktemp(prefix="fusee_profile_",
                                 suffix=".perfetto.json")
    flight_to_perfetto({"labels": cluster.obs.labels(),
                        **cluster.obs.flight_events(),
                        "dropped": cluster.obs.flight.dropped},
                       trace_path, spans=prof["spans"])
    print(f" perfetto trace with causal sub-spans -> {trace_path}")
    if "tick_phases" in prof:
        tp = prof["tick_phases"]
        print(f" fused tick phases: coord {tp['coord_build_frac']:.0%} "
              f"sweep {tp['sweep_frac']:.0%} "
              f"scatter {tp['scatter_frac']:.0%} "
              f"bookkeeping {tp['bookkeeping_frac']:.0%} "
              f"({tp['us_per_tick']:.0f}us/tick over {tp['ticks']} ticks)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-train", action="store_true",
                    help="only run the KV-store drill (CI failure-path smoke)")
    ap.add_argument("--elastic", action="store_true",
                    help="also run the online MN scale-out drill")
    ap.add_argument("--scan", action="store_true",
                    help="also run the ordered-index crash-mid-split drill")
    ap.add_argument("--metrics", action="store_true",
                    help="also run the telemetry drill (latency percentiles, "
                         "per-MN load table, dump-on-fault + Perfetto export)")
    ap.add_argument("--profile", action="store_true",
                    help="also run the causal-profiler drill (critical-path "
                         "RTT attribution, hot-key top-k, Perfetto sub-spans)")
    args = ap.parse_args()
    if not args.skip_train:
        train_drill()
    store_drill()
    if args.elastic:
        elastic_drill()
    if args.scan:
        scan_drill()
    if args.metrics:
        metrics_drill()
    if args.profile:
        profile_drill()
