"""Quickstart: the FUSEE KV store end-to-end in 60 seconds.

One public API (``repro.core.api.KVStore``: pipelined submit/submit_batch
futures + blocking get/put/delete), two substrates:

1. the paper-faithful event-level store (SNAPSHOT + two-level alloc +
   embedded log) — bytes keys/values, batched ops, crash recovery;
2. the serving-side device pool: the same Op batches lowered onto jitted
   index epochs + the race_lookup Pallas kernel.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DMConfig, FuseeCluster, Op
from repro.core.api import KVStore
from repro.serving import DeviceBackend, PoolConfig


def main():
    print("== 1. event-level FUSEE store (paper protocol, verb by verb) ==")
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=2)
    kv = cluster.store(0)
    kv2 = cluster.store(1)
    r = kv.put(b"user:42", b"hello fusee")
    print(f" PUT    user:42         -> {r.status}, {r.rtts} RTTs "
          f"(first op: +2 one-time block-grant/list-head RTTs; steady = 4)")
    r = kv2.submit(Op.get(b"user:42")).result()
    print(f" GET    user:42 (other) -> {r.status} value={r.value} "
          f"{r.rtts} RTTs")
    r = kv.update(b"user:42", b"v2")
    print(f" UPDATE user:42         -> {r.status}, rule={r.rule}, "
          f"{r.rtts} RTTs")
    r = kv.delete(b"user:42")
    print(f" DELETE user:42         -> {r.status}, {r.rtts} RTTs")

    print("\n pipelined batch: 16 PUTs in flight at once, then one fused GET")
    futs = kv.submit_batch([Op.put(f"k{i}".encode(), f"v{i}".encode())
                            for i in range(16)])
    print(f" batch PUT x16          -> "
          f"{sum(f.result().status == 'OK' for f in futs)}/16 OK")
    for i in range(16):
        kv.get(f"k{i}".encode())          # warm the adaptive index cache
    futs = kv.submit_batch([Op.get(f"k{i}".encode()) for i in range(16)])
    res = [f.result() for f in futs]
    st = kv.stats()
    print(f" batch GET x16          -> {sum(r.status == 'OK' for r in res)}"
          f"/16 OK in 1 RTT (race_lookup fast path, "
          f"{st['batch_fast_hits']} kernel hits)")

    print("\n ordered keydir: range scans over a second, ordered index")
    ocl = FuseeCluster(DMConfig(num_mns=4, replication=2,
                                ordered_index=True), num_clients=1)
    okv = ocl.store(0)
    for k in range(40):
        okv.insert(k, [k * 10])
    res = okv.scan(10, 5)
    print(f" SCAN(10, 5)            -> {[(k, v) for k, v in res]}")
    print(f" RANGE(30, 34)          -> {[k for k, _ in okv.range(30, 34)]} "
          f"(batched leaf sweeps; see README 'Ordered index & range scans')")

    print("\n crash client 0 mid-flight, recover from the embedded log:")
    for k in range(8):
        kv.put(100 + k, [k])
    cluster.crash_client(0)
    stats = cluster.recover_client(0, reassign_to_cid=1)
    print(f" recovery: used={stats.used_objects} "
          f"reclaimed={stats.reclaimed_objects} "
          f"redone={stats.redone_ops} (reconnect {stats.reconnect_ms}ms)")
    print(f" data survives: k=104 -> {cluster.store(1).get(104)}")
    print(f" health: {cluster.health().summary()}")
    print(" (examples/fault_drill.py drills the full membership/fault API:"
          " FaultPlan, CRASHED futures, add/remove_client)")

    print("\n== 2. serving pool (same API, batched, device-resident) ==")
    store = KVStore(DeviceBackend(PoolConfig(n_pages=1024, n_buckets=256,
                                             slots_per_bucket=8, replicas=3)))
    keys = list(range(1, 257))
    ins = [f.result() for f in
           store.submit_batch([Op.insert(k, b"page-payload") for k in keys])]
    got = [f.result() for f in
           store.submit_batch([Op.get(k) for k in keys])]
    stats = store.stats()
    print(f" batched INSERT x{len(keys)}: "
          f"success={np.mean([r.status == 'OK' for r in ins]):.2f} "
          f"in {stats['epochs']} SNAPSHOT epoch(s)")
    print(f" batched GET x{len(keys)}: "
          f"hits={np.mean([r.status == 'OK' for r in got]):.2f} "
          f"(race_lookup kernel), value[0]={got[0].value!r}")
    print(f" index replicas converged: "
          f"{store.backend.pool.check_replicas_converged()}")


if __name__ == "__main__":
    main()
