"""Quickstart: the FUSEE KV store end-to-end in 60 seconds.

1. the paper-faithful event-level store (SNAPSHOT + two-level alloc +
   embedded log) — insert/search/update/delete + crash recovery;
2. the serving-side pool: batched device-resident index ops.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DMConfig, FuseeCluster
from repro.serving import KVPool, PoolConfig


def main():
    print("== 1. event-level FUSEE store (paper protocol, verb by verb) ==")
    cluster = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=2)
    kv = cluster.store(0)
    kv2 = cluster.store(1)
    r = kv.insert(42, [1, 2, 3])
    print(f" INSERT k=42           -> {r.status}, {r.rtts} RTTs "
          f"(first op: +2 one-time block-grant/list-head RTTs; steady = 4)")
    r = kv2.search(42)
    print(f" SEARCH k=42 (other)   -> {r.status} value={r.value} "
          f"{r.rtts} RTTs")
    r = kv.update(42, [9, 9])
    print(f" UPDATE k=42           -> {r.status}, rule={r.rule}, "
          f"{r.rtts} RTTs")
    r = kv.delete(42)
    print(f" DELETE k=42           -> {r.status}, {r.rtts} RTTs")

    print("\n crash client 0 mid-flight, recover from the embedded log:")
    for k in range(8):
        kv.insert(100 + k, [k])
    cluster.crash_client(0)
    stats = cluster.recover_client(0, reassign_to_cid=1)
    print(f" recovery: used={stats.used_objects} "
          f"reclaimed={stats.reclaimed_objects} "
          f"redone={stats.redone_ops} (reconnect {stats.reconnect_ms}ms)")
    print(f" data survives: k=104 -> {cluster.store(1).get(104)}")

    print("\n== 2. serving pool (batched, device-resident, jitted) ==")
    pool = KVPool(PoolConfig(n_pages=1024, n_buckets=256,
                             slots_per_bucket=8, replicas=3))
    keys = np.arange(1, 257).astype(np.int32)
    pages = pool.alloc_pages(cid=0, n=len(keys))
    pool.write_pages(0, pages, keys, opcode=1)
    ok = pool.insert_batch(0, keys, pages)
    ptr, found = pool.search(keys)
    print(f" batched INSERT x{len(keys)}: success={ok.mean():.2f} "
          f"in {pool.stats['epochs']} SNAPSHOT epoch(s)")
    print(f" batched SEARCH x{len(keys)}: hits={found.mean():.2f} "
          f"(race_lookup kernel)")
    print(f" index replicas converged: {pool.check_replicas_converged()}")


if __name__ == "__main__":
    main()
