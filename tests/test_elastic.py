"""Elastic shard subsystem tests: placement ring, shard routing edges,
online MN scale-out/in, live migration, and crash-during-migration.

Covers the subsystem contract:

* S=1 is degenerate — the classic single-table layout, bit-identical
  region map and deterministic behavior;
* shard routing works for S > num_mns and spreads placement;
* placement is PINNED: a crashed-but-undetected MN re-homes nothing
  (the directory regression for the old recompute-on-read ring);
* ``add_mn`` during live fleet traffic loses no acknowledged write,
  settles every future, and is seed-replayable bit-identically;
* ``remove_mn`` drains and retires; below the replication factor it
  raises the typed ``InsufficientReplicas``;
* batched SEARCH waves span shards (the fused 1-RTT fast path probes a
  cache whose keys route to different shard regions);
* a crash during migration aborts the window, Alg-3 re-homes, and the
  re-planned migration converges with nothing lost.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CRASHED, OK, DMConfig, FuseeCluster,
                        InsufficientReplicas, Op)
from repro.core.heap import (FIRST_DATA_REGION, INDEX_REGION, META_REGION,
                             DMPool)


def _cfg(**kw):
    base = dict(num_mns=2, replication=2, region_words=1 << 15,
                regions_per_mn=8)
    base.update(kw)
    return DMConfig(**base)


# ------------------------------------------------------------ S=1 degenerate
def test_s1_layout_is_classic():
    """S=1 must be the pre-shard layout word-for-word: one index region at
    id 0, meta at 1, data contiguous from 2, every key routed to shard 0."""
    pool = DMPool(_cfg(index_shards=1))
    assert pool.index_regions == [INDEX_REGION]
    assert pool.num_shards == 1
    assert pool.data_regions == list(range(FIRST_DATA_REGION,
                                           pool.num_regions))
    assert META_REGION in pool.placement
    for key in (0, 1, 17, 2 ** 63, 123456789):
        assert pool.shard_of(key) == 0
        assert pool.index_region_of(key) == INDEX_REGION


def test_s1_matches_default_run_bit_identically():
    """A workload under explicit S=1 equals the default-config run exactly
    (statuses, rtts, tick count): sharding S=1 changes nothing."""
    def run(cfg):
        cl = FuseeCluster(cfg, num_clients=4, seed=5)
        kv = cl.store(0)
        sigs = []
        for k in range(48):
            r = kv.put(k, [k, k + 1])
            sigs.append((r.status, r.rtts, r.rule))
        for k in range(48):
            r = kv.submit(Op.get(k)).result()
            sigs.append((r.status, r.rtts, tuple(r.value)))
        return sigs, cl.scheduler.tick

    assert run(_cfg()) == run(_cfg(index_shards=1))


# ---------------------------------------------------------- routing edges
def test_more_shards_than_mns():
    """S > num_mns: every shard still gets r replicas, keys route across
    all shards, and the store works."""
    cl = FuseeCluster(_cfg(index_shards=8), num_clients=2, seed=1)
    pool = cl.pool
    assert pool.num_shards == 8 > len(pool.mns)
    for g in pool.index_regions:
        assert len(pool.placement[g]) == 2
        assert len(set(pool.placement[g])) == 2
    kv = cl.store(0)
    for k in range(96):
        assert kv.put(k, [k]).status == OK
    hit = {pool.shard_of(__import__("repro.core.codec", fromlist=["x"])
           .encode_key(k)) for k in range(96)}
    assert len(hit) > 1, "keys should spread over shards"
    assert all(kv.get(k) == [k] for k in range(96))


def test_shard_placement_spreads_over_ring():
    """With S shards and N >= S MNs, shard primaries land on S distinct
    MNs (the stride placement): the CAS hot words no longer share nodes."""
    pool = DMPool(_cfg(num_mns=8, index_shards=8))
    primaries = [pool.placement[g][0] for g in pool.index_regions]
    assert len(set(primaries)) == 8


# -------------------------------------------------- pinned-placement ring
def test_placement_stable_while_mn_crashed_but_undetected():
    """Regression for the recompute-on-read ring: an MN death must not
    re-home ANY region until Alg-3 recovery actually runs."""
    cl = FuseeCluster(_cfg(num_mns=4, index_shards=4), num_clients=2,
                      seed=0, mn_detect_delay=10 ** 9)
    pool = cl.pool
    before = {g: list(reps) for g, reps in pool.placement.items()}
    versions = {g: pool.directory.version(g) for g in pool.placement}
    cl.crash_mn(2)                      # crashed, detection far in the future
    kv = cl.store(0)
    kv.put(7, [7])                      # traffic while undetected
    assert {g: list(r) for g, r in pool.placement.items()} == before
    assert {g: pool.directory.version(g) for g in pool.placement} == versions
    # once detection runs, recovery DOES re-home (through the directory)
    cl.master.maybe_recover_mns()
    assert any(2 not in reps for g, reps in pool.placement.items()
               if 2 in before[g])
    assert any(pool.directory.version(g) > versions[g] for g in before)


# ------------------------------------------------------------ remove_mn
def test_remove_mn_below_replication_raises_typed():
    cl = FuseeCluster(_cfg(num_mns=2, replication=2), num_clients=1, seed=0)
    with pytest.raises(InsufficientReplicas):
        cl.remove_mn(0)
    # membership unchanged by the rejected call
    assert cl.pool.directory.members == [0, 1]
    assert not cl.pool.mns[0].retired


def test_remove_mn_invalid_targets():
    cl = FuseeCluster(_cfg(num_mns=4), num_clients=1, seed=0)
    with pytest.raises(ValueError):
        cl.remove_mn(99)
    cl.crash_mn(3)
    with pytest.raises(ValueError):
        cl.remove_mn(3)                 # crashed MNs go through Alg-3


def test_remove_mn_drains_and_retires():
    cl = FuseeCluster(_cfg(num_mns=4, index_shards=4), num_clients=2, seed=2)
    kv = cl.store(0)
    for k in range(64):
        assert kv.put(k, [k * 2]).status == OK
    cl.remove_mn(1)
    mn = cl.pool.mns[1]
    assert mn.retired and not mn.regions
    assert 1 not in cl.pool.directory.members
    assert all(1 not in reps for reps in cl.pool.placement.values())
    assert all(cl.store(1).get(k) == [k * 2] for k in range(64))
    h = cl.health()
    assert h.retired_mns == 1 and h.migrating_regions == 0


# ------------------------------------------------- batched SEARCH waves
def test_batch_search_wave_spans_shards():
    """The fused 1-RTT batched SEARCH probes cache entries whose keys live
    on different shard regions — one doorbell batch, many shards."""
    cl = FuseeCluster(_cfg(num_mns=4, index_shards=4, replication=3),
                      num_clients=1, seed=3)
    kv = cl.store(0, max_inflight=32)
    keys = list(range(32))
    for f in kv.submit_batch([Op.put(k, [k] * 4) for k in keys]):
        assert f.result().status == OK
    for k in keys:                       # warm the adaptive cache
        assert kv.get(k) == [k] * 4
    pool = cl.pool
    shards = {pool.shard_of(__import__("repro.core.codec", fromlist=["x"])
              .encode_key(k)) for k in keys}
    assert len(shards) > 1
    res = [f.result() for f in kv.submit_batch([Op.get(k) for k in keys])]
    assert all(r.status == OK and r.value == [k] * 4
               for k, r in zip(keys, res))
    st = kv.stats()
    assert st["batch_fast_hits"] > 0


# --------------------------------------------- live scale-out under load
def _fleet_ycsb_a_with_add_mn(seed, *, crash_mid=None):
    """YCSB-A fleet run with add_mn fired mid-traffic (and optionally an
    MN crash while the migration copies).  Returns a full signature for
    replay comparison plus the objects for invariant checks."""
    from benchmarks.common import fleet_dmconfig
    n_clients, n_keys = 16, 96
    cfg = dataclasses.replace(
        fleet_dmconfig(n_clients, n_keys, n_mns=3, replication=2),
        index_shards=8)
    cl = FuseeCluster(cfg, num_clients=n_clients, seed=seed)
    fleet = cl.fleet()
    sched = cl.scheduler
    backends = [cl.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    for k in range(n_keys):
        sched.submit(k % n_clients, "insert", k, [k])
    fleet.run()
    wl = cl.rng.stream("workload")
    plans = [[] for _ in range(n_clients)]
    writes = {}
    for i in range(n_clients * 10):
        kind = "update" if wl.random() < 0.5 else "search"
        key = int(wl.integers(n_keys))
        plans[i % n_clients].append(
            Op(kind, key, [i] if kind == "update" else None))
    futs, cursor, tick = [], [0] * n_clients, 0
    added = crashed = False
    while True:
        wave = []
        for c in range(n_clients):
            room = 4 - sched.inflight(c)
            if room > 0 and cursor[c] < len(plans[c]):
                ops = plans[c][cursor[c]:cursor[c] + room]
                cursor[c] += len(ops)
                wave.append((backends[c], ops))
                for op in ops:
                    futs.append((op, wave[-1][0].cid, len(futs)))
        if wave:
            for be_futs, (be, ops) in zip(fleet.submit_wave(wave), wave):
                for op, f in zip(ops, be_futs):
                    writes[len(writes)] = (op, f)
        if tick == 6 and not added:
            cl.add_mn(wait=False)
            added = True
        if (crash_mid is not None and added and not crashed
                and cl.migrator.active):
            cl.crash_mn(crash_mid)      # crash while shard copies in flight
            crashed = True
        if not sched.has_work() and not cl.migrator.busy:
            break
        fleet.tick()
        tick += 1
    assert added
    if crash_mid is not None:
        assert crashed, "crash never fired while migrating"
    return cl, writes


@pytest.mark.parametrize("seed", [0, 11])
def test_add_mn_under_live_fleet_traffic(seed):
    cl, writes = _fleet_ycsb_a_with_add_mn(seed)
    # every future settled
    assert all(f.done() for _, f in writes.values())
    # zero acknowledged-write loss: latest acked update per key (or the
    # preload) must be readable afterwards; updates are concurrent per
    # key, so accept any acked value for keys with racing acked updates
    acked_by_key = {}
    for op, f in writes.values():
        r = f.result()
        assert r.status in (OK, CRASHED)
        if op.kind == "update" and r.status == OK:
            acked_by_key.setdefault(op.key, set()).add(tuple(op.value))
    reader = cl.store(0)
    for key, vals in acked_by_key.items():
        got = reader.get(key)
        assert got is not None, f"acked key {key} lost"
        assert tuple(got) in vals | {(key,)} or got == [key], \
            (key, got, vals)
    # the new MN actually serves index shards now
    new_mid = len(cl.pool.mns) - 1
    assert any(new_mid in cl.pool.placement[g]
               for g in cl.pool.index_regions)
    assert cl.migrator.counters["cutovers"] > 0


def test_add_mn_migration_is_seed_replayable():
    """Same seed -> bit-identical run including the migration: statuses,
    tick counts, epochs, migration counters, and final index bytes."""
    def signature(run):
        cl, writes = run
        idx = []
        for g in sorted(cl.pool.index_regions):
            prim = cl.pool.mns[cl.pool.placement[g][0]]
            idx.append(prim.regions[g][:cl.pool.cfg.index_words].tobytes())
        return (tuple(f.result().status for _, f in writes.values()),
                cl.scheduler.tick, cl.pool.epoch,
                tuple(sorted(cl.migrator.counters.items())),
                tuple(idx))
    assert signature(_fleet_ycsb_a_with_add_mn(7)) == \
        signature(_fleet_ycsb_a_with_add_mn(7))


def test_crash_during_migration_aborts_and_replans():
    cl, writes = _fleet_ycsb_a_with_add_mn(4, crash_mid=1)
    assert cl.migrator.counters["aborts"] > 0, \
        "crash while migrating should abort in-flight windows"
    assert not cl.migrator.busy
    # invariant: acked updates survive the abort + Alg-3 + re-plan chain
    acked_by_key = {}
    for op, f in writes.values():
        if op.kind == "update" and f.result().status == OK:
            acked_by_key.setdefault(op.key, set()).add(tuple(op.value))
    reader = cl.store(0)
    for key, vals in acked_by_key.items():
        got = reader.get(key)
        assert got is not None, f"acked key {key} lost after crash-mid-migration"


def test_remove_mn_while_migrations_headed_for_it():
    """Regression: remove_mn of a node that in-flight migrations (from a
    just-issued add_mn) are still targeting must abort + re-plan them —
    otherwise their cutovers install shards ONTO the draining node and
    the drain strands forever."""
    cl = FuseeCluster(_cfg(num_mns=3, index_shards=8), num_clients=2, seed=4)
    kv = cl.store(0)
    for k in range(48):
        assert kv.put(k, [k]).status == OK
    mid = cl.add_mn(wait=False)          # shard moves toward mid in flight
    assert cl.migrator.active
    cl.remove_mn(mid, wait=False)        # immediately drain it again
    cl.migrator.drive(max_ticks=200_000)
    assert cl.pool.mns[mid].retired
    assert all(mid not in reps for reps in cl.pool.placement.values())
    assert all(cl.store(1).get(k) == [k] for k in range(48))


def test_trace_replay_reproduces_migration_run():
    """Step-mode trace()/replay() across a mid-run add_mn: replaying the
    recorded (cid, pick) schedule on a fresh same-seed cluster — with the
    membership call re-issued at the same decision boundary — reproduces
    op outcomes, epochs, and the final shard bytes bit-identically."""
    def drive(cl, trace=None, split=None):
        sched = cl.scheduler
        for k in range(32):
            sched.submit(k % 2, "insert", k, [k])
        if trace is None:
            rng = np.random.default_rng(123)
            for _ in range(200):
                cids = sched.eligible_cids()
                if not cids:
                    break
                sched.step(cids[int(rng.integers(len(cids)))],
                           pick=int(rng.integers(4)))
            split = len(sched.decisions)
            cl.add_mn(wait=False)
            sched.run_round_robin()
            if cl.migrator.busy:
                cl.migrator.drive()
            return cl.trace(), split
        for (cid, pick) in trace.decisions[:split]:
            cl.scheduler.step(cid, pick=pick)
        cl.add_mn(wait=False)              # same boundary as the record run
        for (cid, pick) in trace.decisions[split:]:
            cl.scheduler.step(cid, pick=pick)
        if cl.migrator.busy:
            cl.migrator.drive()
        return None, None

    def signature(cl):
        shards = []
        for g in sorted(cl.pool.index_regions):
            prim = cl.pool.mns[cl.pool.placement[g][0]]
            shards.append(prim.regions[g][:cl.pool.cfg.index_words]
                          .tobytes())
        return (tuple((r.kind, r.key, r.result.status, r.rtts)
                      for r in cl.scheduler.history
                      if r.result is not None),
                cl.pool.epoch, tuple(shards),
                tuple(sorted(cl.migrator.counters.items())))

    cfg = _cfg(num_mns=2, index_shards=4)
    c1 = FuseeCluster(cfg, num_clients=2, seed=6)
    trace, split = drive(c1)
    c2 = FuseeCluster(cfg, num_clients=2, seed=6)
    drive(c2, trace=trace, split=split)
    assert signature(c1) == signature(c2)


# ------------------------------------------------- dual-write mechanics
def test_dual_write_window_mirrors_primary_writes():
    cl = FuseeCluster(_cfg(num_mns=3, index_shards=2), num_clients=1, seed=9)
    pool = cl.pool
    g = pool.index_regions[0]
    old_primary = pool.placement[g][0]
    # open a window by hand: migrate shard g to a fabricated replica set
    new_reps = [m for m in pool.directory.members][:2][::-1]
    started = cl.migrator._start(g, new_reps)
    if not started:                      # placement already equal: retarget
        new_reps = [pool.placement[g][1], pool.placement[g][0]]
        assert cl.migrator._start(g, new_reps)
    cl.migrator._ensure_hook()           # _start is the internal entry
    mig = cl.migrator.active[g]
    if not mig.targets:
        pytest.skip("retarget produced no fresh destinations")
    # a legal replicated write (all replicas, like object writes): the
    # primary's application must mirror into the staged targets
    for i in range(len(pool.placement[g])):
        pool.write(g, i, 5, [0xBEEF])
    for arr in mig.targets.values():
        assert int(arr[5]) == 0xBEEF     # mirrored before its chunk copied
    cl.migrator.drive()
    assert pool.placement[g] == new_reps
    for mid in new_reps:
        assert int(pool.mns[mid].regions[g][5]) == 0xBEEF
