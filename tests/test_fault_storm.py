"""Seeded fault-storm stress test (tier-1 + the CI seed matrix).

A randomized ``FaultPlan.storm`` — crash/recover clients and crash MNs at
random completed-op boundaries, all drawn from the run's ``SimRng`` —
fires while a fleet-driven insert workload runs.  Invariants:

* **no acknowledged write is ever lost** — every key whose insert
  resolved OK is readable (with its exact value) from a live client
  after the storm;
* **every future settles** — no hangs: each submitted op ends OK or
  CRASHED (crashed-client submits are rejected up front with the typed
  ``ClientCrashed`` and never enter the pipeline);
* **health converges** — empty pipelines everywhere, one lease epoch
  across live clients, every MN crash detected + Alg-3-recovered, and
  the whole plan fired.

Seeds come from ``FUSEE_STORM_SEEDS`` (comma-separated; CI runs a 3-seed
matrix).  Every assertion message carries the reproducing seed.
"""
import os

import pytest

# the storm matrix is the heavyweight part of tier-1: CI runs it (plus the
# property suite) in the dedicated sim-seeds / slow jobs
pytestmark = pytest.mark.slow

from repro.analysis.races import report
from repro.core import (CRASHED, OK, ClientCrashed, DMConfig, FaultPlan,
                        FuseeCluster, Op)

SEEDS = [int(s) for s in
         os.environ.get("FUSEE_STORM_SEEDS", "0,1").split(",")]

N_CLIENTS, N_MNS, REPL = 6, 5, 3
TOTAL_OPS = 160


def _run_storm(seed, **churn):
    cl = FuseeCluster(DMConfig(num_mns=N_MNS, replication=REPL,
                               region_words=1 << 15, regions_per_mn=16,
                               index_shards=churn.pop("index_shards", 1)),
                      num_clients=N_CLIENTS, seed=seed)
    cl.attach_tracer()                 # sanitizers run over every storm
    storm_kw = dict(clients=range(N_CLIENTS), mns=N_MNS, replication=REPL,
                    n_client_crashes=2, n_mn_crashes=2, first_op=10,
                    spacing=14, recover_delay=8)
    storm_kw.update(churn)             # churn overrides (e.g. n_mn_crashes)
    plan = FaultPlan.storm(cl.rng.stream("faults"), **storm_kw)
    injector = cl.inject(plan)
    fleet = cl.fleet()
    stores = {c: cl.store(c, max_inflight=0) for c in range(N_CLIENTS)}
    futs, rejected = [], 0
    submitted = 0
    while submitted < TOTAL_OPS:
        for c in range(N_CLIENTS):
            if submitted >= TOTAL_OPS:
                break
            k = submitted
            submitted += 1
            try:
                futs.append((k, c, stores[c].submit(Op.put(k, [k, c]))))
            except ClientCrashed:
                rejected += 1          # typed rejection: op never entered
        for _ in range(4):             # let faults fire mid-workload
            if cl.scheduler.has_work():
                fleet.tick()
    fleet.run()
    if cl.migrator.busy:               # drain membership churn (add/remove)
        cl.migrator.drive()
    return cl, plan, injector, futs, rejected


@pytest.mark.parametrize("seed", SEEDS)
def test_fault_storm_invariants(seed):
    msg = f"(reproduce with FUSEE_STORM_SEEDS={seed})"
    cl, plan, injector, futs, rejected = _run_storm(seed)

    # the storm actually happened, in full
    assert injector.done and len(injector.fired) == len(plan), msg
    crashes = [e for _, e in injector.fired if e.action == "crash_client"]
    mn_crashes = [e for _, e in injector.fired if e.action == "crash_mn"]
    assert crashes and mn_crashes, msg

    # every future settled: OK or typed-retriable CRASHED, nothing hung
    acked = {}
    for k, c, f in futs:
        assert f.done(), f"future for key {k} never settled {msg}"
        r = f.result()
        assert r.status in (OK, CRASHED), \
            f"key {k} ended {r.status} {msg}"
        if r.status == OK:
            acked[k] = [k, c]
    assert acked, msg

    # no acknowledged write is ever lost: every OK'd key is readable with
    # its exact value from a live client after recovery
    live = [c for c, cc in cl.clients.items() if not cc.crashed]
    assert live, msg
    reader = cl.store(live[0])
    for k, v in acked.items():
        got = reader.get(k)
        assert got == v, f"acked key {k} lost: read {got!r} {msg}"

    # health converges after the storm
    h = cl.health()
    assert all(c.inflight == 0 for c in h.clients), msg
    assert h.alive_mns == N_MNS - len(mn_crashes), msg
    assert h.mn_recoveries == len(mn_crashes), msg    # Alg-3 ran per crash
    assert h.client_recoveries == len(crashes), msg   # §5.3 ran per crash
    epochs = {c.epoch for c in h.clients if c.status == "live"}
    assert len(epochs) == 1, f"epoch split-brain {epochs} {msg}"
    assert h.crashed_ops == sum(c.crashed_ops for c in h.clients), msg

    # sanitizers: the verb trace is race-free and the heap audits clean
    findings = cl.race_findings()
    assert findings == [], report(findings, cl.pool._tracer) + msg
    rep = cl.heap_audit()
    assert rep.ok, f"{rep} {msg}"


@pytest.mark.parametrize("seed", SEEDS[:1])
def test_fault_storm_is_seed_deterministic(seed):
    """The same storm seed reproduces the same fault schedule and the same
    op outcomes — the replay contract under fault injection."""
    def signature(run):
        cl, _plan, injector, futs, rejected = run
        return (tuple((t, e.action, e.target) for t, e in injector.fired),
                tuple((k, c, f.result().status) for k, c, f in futs),
                rejected, cl.scheduler.tick)
    assert signature(_run_storm(seed)) == signature(_run_storm(seed)), \
        f"(reproduce with FUSEE_STORM_SEEDS={seed})"


# ------------------------------------------------------- membership churn --
# one base MN crash (instead of two) leaves headroom for the
# crash-during-migration extra crash AND the drain of the added MN
CHURN = dict(index_shards=4, n_add_mns=1, remove_added=True,
             crash_during_migration=True, n_mn_crashes=1)


@pytest.mark.parametrize("seed", SEEDS)
def test_membership_churn_storm_invariants(seed):
    """Storm + membership churn: an MN joins mid-run (shard migrations
    ride the workload ticks), an original MN crashes WHILE the copies are
    in flight, and the added MN is drained + retired again — on top of
    the base client/MN crash storm.  Invariants: the full plan fires, no
    acknowledged write is lost across any cutover, every future settles,
    and the cluster converges with no open migration windows."""
    msg = f"(reproduce with FUSEE_STORM_SEEDS={seed})"
    cl, plan, injector, futs, rejected = _run_storm(seed, **CHURN)

    assert injector.done and len(injector.fired) == len(plan), msg
    actions = [e.action for _, e in injector.fired]
    assert "add_mn" in actions and "remove_mn" in actions, msg

    acked = {}
    for k, c, f in futs:
        assert f.done(), f"future for key {k} never settled {msg}"
        r = f.result()
        assert r.status in (OK, CRASHED), f"key {k} ended {r.status} {msg}"
        if r.status == OK:
            acked[k] = [k, c]
    assert acked, msg

    live = [c for c, cc in cl.clients.items() if not cc.crashed]
    reader = cl.store(live[0])
    for k, v in acked.items():
        got = reader.get(k)
        assert got == v, f"acked key {k} lost across cutover: {got!r} {msg}"

    h = cl.health()
    assert h.migrating_regions == 0 and not cl.migrator.busy, msg
    assert all(c.inflight == 0 for c in h.clients), msg
    epochs = {c.epoch for c in h.clients if c.status == "live"}
    assert len(epochs) == 1, f"epoch split-brain {epochs} {msg}"
    # the added MN either retired cleanly or crashed while draining
    added_mid = N_MNS
    assert (cl.pool.mns[added_mid].retired
            or not cl.pool.mns[added_mid].alive), msg

    # sanitizers: race-free trace, clean heap/epoch audit across cutovers
    findings = cl.race_findings()
    assert findings == [], report(findings, cl.pool._tracer) + msg
    rep = cl.heap_audit()
    assert rep.ok, f"{rep} {msg}"


@pytest.mark.parametrize("seed", SEEDS[:1])
def test_membership_churn_storm_is_seed_deterministic(seed):
    """Migration runs replay bit-identically: same seed -> same fault +
    membership schedule, same op outcomes, same migration counters, and
    byte-identical primary index shards."""
    def signature(run):
        cl, _plan, injector, futs, rejected = run
        shards = []
        for g in sorted(cl.pool.index_regions):
            prim = cl.pool.mns[cl.pool.placement[g][0]]
            shards.append(prim.regions[g][:cl.pool.cfg.index_words]
                          .tobytes())
        return (tuple((t, e.action, e.target) for t, e in injector.fired),
                tuple((k, c, f.result().status) for k, c, f in futs),
                rejected, cl.scheduler.tick, cl.pool.epoch,
                tuple(sorted(cl.migrator.counters.items())), tuple(shards))
    assert signature(_run_storm(seed, **dict(CHURN))) == \
        signature(_run_storm(seed, **dict(CHURN))), \
        f"(reproduce with FUSEE_STORM_SEEDS={seed})"
