"""Tests for fleet mode (core/fleet.py + core/rng.py + trace replay).

Covers: batched ticks advance every client's op-phases per tick (array
calls bounded per tick, not per op), the cluster-wide single-invocation
race_lookup probe wave, correctness + per-key linearizability under fleet
driving, the determinism regression bar (same seed -> bit-identical op
results / RTT counts / health; different seeds differ), trace()-based
schedule replay, and a 1024-client smoke."""
import numpy as np
import pytest

from repro.core import (CRASHED, OK, DMConfig, FaultPlan, FleetEngine,
                        FuseeCluster, Op, SimRng)
from repro.core.linearize import check_linearizable, records_to_hops


def _fleet_cluster(n_clients, *, seed=0, num_mns=4, replication=2,
                   region_words=1 << 15, regions_per_mn=16,
                   index_buckets=256, **kw):
    cl = FuseeCluster(DMConfig(num_mns=num_mns, replication=replication,
                               region_words=region_words,
                               regions_per_mn=regions_per_mn,
                               index_buckets=index_buckets),
                      num_clients=n_clients, seed=seed, **kw)
    return cl, cl.fleet()


def _run_seeded_workload(seed, *, n_clients=6, ops_per_client=6):
    """A small mixed workload drawn entirely from the cluster's SimRng:
    everything about the run derives from (seed, config)."""
    cl, fleet = _fleet_cluster(n_clients, seed=seed)
    stores = [cl.store(c, max_inflight=0) for c in range(n_clients)]
    wl = cl.rng.stream("workload")
    for k in range(16):                      # preload
        cl.scheduler.submit(k % n_clients, "insert", k, [k])
    fleet.run()
    kinds = ["insert", "update", "search", "delete"]
    futs = []
    for c in range(n_clients):
        ops = []
        for i in range(ops_per_client):
            kind = kinds[int(wl.integers(len(kinds)))]
            key = int(wl.integers(16)) if kind != "insert" \
                else 100 + 10 * c + i
            val = [int(wl.integers(1000))] if kind in ("insert", "update") \
                else None
            ops.append(Op(kind, key, val))
        futs += stores[c].submit_batch(ops)
    fleet.run()
    assert all(f.done() for f in futs)
    return cl, futs


def _history_signature(cl):
    """Canonical per-op signature: results, RTT counts, timing."""
    return tuple(
        (r.cid, r.op_id, r.kind, r.key, r.inv_tick, r.resp_tick, r.rtts,
         r.bg_rtts, r.result.status,
         tuple(r.result.value) if isinstance(r.result.value, list) else None)
        for r in cl.scheduler.history if r.result is not None)


def _health_signature(cl):
    h = cl.health()
    return (h.epoch, h.tick, h.crashed_ops, h.client_recoveries,
            h.mn_recoveries,
            tuple((m.mid, m.alive, m.primary_regions, m.hosted_regions,
                   m.bytes_served) for m in h.mns),
            tuple((c.cid, c.status, c.epoch, c.inflight, c.cache_entries,
                   c.completed_ops, c.crashed_ops) for c in h.clients))


# ----------------------------------------------------------- batched ticks --
def test_fleet_tick_advances_all_clients_batched():
    """One tick executes the head verb of every (client, MN) lane with a
    bounded number of array calls — per tick, not per op."""
    n = 12
    cl, fleet = _fleet_cluster(n)
    for c in range(n):
        for k in range(4):
            cl.scheduler.submit(c, "insert", 100 * c + k, [c, k])
    ticks = fleet.run()
    st = fleet.stats()
    assert st["verbs"] > 4 * ticks            # many verbs per tick...
    assert st["max_lanes"] >= n               # ...every client advanced at once
    # array calls are per (verb-kind) per tick, never per verb: reads +
    # writes + cas + faa <= 4 batched calls per tick
    assert st["array_calls"] <= 4 * ticks
    assert st["verbs_per_tick"] > 8
    recs = [r for r in cl.scheduler.history if r.result is not None]
    assert all(r.result.status == OK for r in recs)
    kv = cl.store(0)
    for c in range(n):
        for k in range(4):
            assert kv.get(100 * c + k) == [c, k]


def test_fleet_matches_step_results_on_disjoint_keys():
    """Fleet driving and per-verb step driving agree wherever the outcome
    is schedule-independent (disjoint key sets)."""
    def run(drive_fleet):
        cl, fleet = _fleet_cluster(4, seed=11)
        for c in range(4):
            for k in range(5):
                cl.scheduler.submit(c, "insert", 10 * c + k, [c + k])
        if drive_fleet:
            fleet.run()
        else:
            cl.scheduler.run_round_robin()
        return {(r.cid, r.key): (r.result.status, tuple(r.result.value or []))
                for r in cl.scheduler.history if r.result is not None}
    assert run(True) == run(False)


def test_fleet_contended_key_linearizable():
    cl, fleet = _fleet_cluster(5, seed=7)
    cl.attach_tracer()              # contention runs under the race detector
    sched = cl.scheduler
    sched.submit(0, "insert", 42, [0])
    fleet.run()
    for c in range(1, 5):
        sched.submit(c, "update", 42, [10 + c])
        sched.submit(c, "search", 42)
        sched.submit(c, "delete" if c == 3 else "update", 42,
                     None if c == 3 else [20 + c])
    fleet.run()
    hops = records_to_hops(sched.history, 42)
    assert check_linearizable(hops, initial=None)
    from repro.analysis.races import report
    findings = cl.race_findings()
    assert findings == [], report(findings, cl.pool._tracer)
    assert cl.heap_audit().ok


def test_fleet_probe_wave_single_invocation():
    """All clients' cache-resident GETs in one wave = ONE race_lookup
    invocation, and every key fuses into a 1-RTT multi-key SEARCH."""
    n = 6
    cl, fleet = _fleet_cluster(n)
    stores = [cl.store(c, max_inflight=0) for c in range(n)]
    for c, kv in enumerate(stores):
        for f in kv.submit_batch([Op.put(100 * c + k, [c, k])
                                  for k in range(8)]):
            pass
    fleet.run()
    for c, kv in enumerate(stores):
        for k in range(8):
            assert kv.get(100 * c + k) == [c, k]   # warm adaptive caches
    mark = len(cl.scheduler.history)
    wave = [(kv.backend, [Op.get(100 * c + k) for k in range(8)])
            for c, kv in enumerate(stores)]
    futs = fleet.submit_wave(wave)
    fleet.run()
    st = fleet.stats()
    assert st["probe_invocations"] == 1
    assert st["probe_keys"] == 8 * n and st["probe_hits"] == 8 * n
    for c, fs in enumerate(futs):
        assert [f.result().value for f in fs] == [[c, k] for k in range(8)]
    fused = [r for r in cl.scheduler.history[mark:]
             if r.kind == "search_batch"]
    assert len(fused) == n and all(r.rtts == 1 for r in fused)


def test_fleet_with_fault_injection():
    """Fault hooks fire inside fleet ticks: a crashed client's in-flight
    futures settle CRASHED, MN crash auto-recovers, the rest completes."""
    cl, fleet = _fleet_cluster(4, replication=3)
    stores = [cl.store(c, max_inflight=0) for c in range(4)]
    cl.inject(FaultPlan().crash_client(2, after_ops=6).crash_mn(1, after_ops=10))
    futs = {c: stores[c].submit_batch([Op.put(50 * c + k, [k])
                                       for k in range(8)]) for c in range(4)}
    fleet.run()
    flat = [f for fs in futs.values() for f in fs]
    assert all(f.done() for f in flat)
    statuses = {f.result().status for f in flat}
    assert statuses <= {OK, CRASHED} and CRASHED in statuses
    assert all(f.result().status == CRASHED for f in futs[2][-1:])
    assert cl.scheduler.mn_recoveries == 1
    assert not cl.pool.mns[1].alive
    kv = cl.store(0)
    for c in (0, 1, 3):
        for k, f in enumerate(futs[c]):
            if f.result().status == OK:
                assert kv.get(50 * c + k) == [k]


# ----------------------------------------------------- determinism replay ---
def test_same_seed_runs_bit_identical():
    """The determinism regression bar: same (seed, config) -> identical op
    results, RTT counts, and health snapshots; different seeds differ."""
    cl_a, _ = _run_seeded_workload(123)
    cl_b, _ = _run_seeded_workload(123)
    assert _history_signature(cl_a) == _history_signature(cl_b)
    assert _health_signature(cl_a) == _health_signature(cl_b)
    cl_c, _ = _run_seeded_workload(124)
    assert _history_signature(cl_a) != _history_signature(cl_c)


def test_simrng_streams_independent_and_deterministic():
    a, b = SimRng(5), SimRng(5)
    # draws are per-name deterministic...
    xs = a.stream("workload").integers(1 << 30, size=8)
    # ...and independent of whether other streams were touched first
    b.stream("faults").integers(1 << 30, size=100)
    ys = b.stream("workload").integers(1 << 30, size=8)
    np.testing.assert_array_equal(xs, ys)
    assert not np.array_equal(
        xs, SimRng(6).stream("workload").integers(1 << 30, size=8))
    # fresh() rewinds to the stream origin without disturbing the memoized one
    np.testing.assert_array_equal(
        a.fresh("workload").integers(1 << 30, size=8), xs)


def test_trace_replay_reproduces_run():
    """trace() captures every step-mode (cid, pick) decision; replaying it
    on a fresh same-(seed, config) cluster with the same submissions
    reproduces the history bit-identically."""
    def build(seed):
        cl = FuseeCluster(DMConfig(num_mns=4, replication=3),
                          num_clients=3, seed=seed)
        sched = cl.scheduler
        sched.submit(0, "insert", 9, [1])
        for c in range(3):
            sched.submit(c, "update", 9, [10 + c])
            sched.submit(c, "search", 9)
        return cl
    cl_a = build(77)
    cl_a.scheduler.run_random()              # seeded scheduler stream
    trace = cl_a.trace()
    assert len(trace) == cl_a.scheduler.tick  # one decision per tick
    cl_b = build(77)
    cl_b.replay(trace)
    assert _history_signature(cl_a) == _history_signature(cl_b)
    assert cl_b.scheduler.tick == cl_a.scheduler.tick


# -------------------------------------------------------- 1024-client smoke -
@pytest.mark.slow
def test_fleet_scales_to_1024_clients():
    """≥1024 concurrent clients, all in flight at once, driven to
    completion with batched ticks (the tentpole acceptance smoke)."""
    n = 1024
    cl, fleet = _fleet_cluster(n, region_words=1 << 17, regions_per_mn=10,
                               replication=2, index_buckets=1024)
    sched = cl.scheduler
    for c in range(n):
        sched.submit(c, "insert", c, [c])
    assert sum(sched.inflight(c) for c in range(n)) == n
    ticks = fleet.run()
    for c in range(n):
        sched.submit(c, "search", c)
    ticks += fleet.run()
    recs = [r for r in sched.history if r.result is not None]
    assert len(recs) == 2 * n
    assert all(r.result.status == OK for r in recs)
    searches = [r for r in recs if r.kind == "search"]
    assert all(tuple(r.result.value) == (r.key,) for r in searches)
    # batched execution: ~1024 lanes advanced per tick, not one op per tick
    st = fleet.stats()
    assert st["max_lanes"] >= 512
    assert ticks < 2 * n                      # far fewer ticks than verbs
