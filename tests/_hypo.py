"""Minimal stand-in for ``hypothesis`` when the package is not installed.

The tier-1 suite property-tests the protocol with hypothesis; this shim
keeps those tests collectable *and runnable* in hypothesis-less
environments by replaying each property over a deterministic sample of
random examples (no shrinking, no database — just coverage).

Only the strategy surface this repo uses is implemented: ``integers``,
``booleans``, ``lists``, ``sampled_from``.  Test modules import it as:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypo import given, settings, strategies as st
"""
from __future__ import annotations

import random
import zlib

DEFAULT_EXAMPLES = 12
MAX_EXAMPLES_CAP = 25       # keep hypothesis-less runs quick


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -(2 ** 31) if min_value is None else min_value
        hi = 2 ** 31 if max_value is None else max_value
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)


st = strategies


def given(*gargs, **gkwargs):
    def deco(fn):
        # NOTE: no functools.wraps — copying fn's signature (or setting
        # __wrapped__) would make pytest treat the drawn parameters as
        # fixtures; the wrapper must present a bare () signature.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_EXAMPLES)
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random(seed0 + i)
                drawn = tuple(s.example(rng) for s in gargs)
                dkw = {k: s.example(rng) for k, s in gkwargs.items()}
                try:
                    fn(*args, *drawn, **kwargs, **dkw)
                except Exception:
                    print(f"[_hypo] falsifying example #{i}: "
                          f"args={drawn} kwargs={dkw}")
                    raise
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._stub_given = True
        return wrapper
    return deco


def settings(max_examples=None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = min(max_examples, MAX_EXAMPLES_CAP)
        return fn
    return deco
