"""Protocol sanitizer suite: tracer, race detector, heap auditor, lints.

The acceptance-critical regressions live here: the two PR-3 bug classes
are re-introduced behind test-only flags (``client.UNSAFE_ACK_LOST_EMPTY_CAS``
and ``sim.UNSAFE_EXEC_STALE_EPOCH``) and the race detector must pin each
one — offending word address, cids, verbs — while the same runs with the
flags off produce zero findings.
"""
import numpy as np
import pytest

import repro.core.client as client_mod
import repro.core.sim as sim_mod
from repro.analysis.heapcheck import audit
from repro.analysis.lint import lint_source
from repro.analysis.races import (ALL_RULES, _OpInfo, detect, detect_events,
                                  report)
from repro.analysis.trace import (CAS, FIELDS, READ, WRITE, MASTER_CID,
                                  VerbTracer)
from repro.core import DMConfig, FuseeCluster, Op
from repro.core import layout as L
from repro.core.race import bucket_pair


# ---------------------------------------------------------------- helpers
def _ev(rows):
    """Build a detect_events-shaped column dict from row dicts."""
    defaults = dict(seq=0, tick=0, cid=0, op_id=0, phase=0, label=0,
                    cause=0, bg=0, verb=WRITE, region=0, replica=0, off=0,
                    n=1, epoch_issue=0, epoch_exec=0, ok=1, arg=0, val=0,
                    old=0)
    cols = {f: np.asarray([int(r.get(f, defaults[f])) for r in rows],
                          np.int64) for f in FIELDS}
    if "seq" not in rows[0]:
        cols["seq"] = np.arange(len(rows), dtype=np.int64)
    return cols


def _detect(rows, *, ops=None, rules=None, index_regions={0},
            ordered_regions=frozenset()):
    return detect_events(_ev(rows), ["master", "p"],
                         index_regions=set(index_regions),
                         ordered_regions=set(ordered_regions),
                         ops=ops or {}, rules=rules)


def _small_cluster(seed=0, **kw):
    return FuseeCluster(num_clients=kw.pop("num_clients", 2), seed=seed, **kw)


# ================================================================= tracer
def test_tracer_attach_detach_restores_fast_path():
    cl = _small_cluster()
    pool = cl.pool
    assert "read" not in pool.__dict__          # class methods: zero-cost
    tr = cl.attach_tracer()
    assert pool.__dict__["read"] is not None    # instance wrappers installed
    assert cl.attach_tracer() is tr             # idempotent
    s = cl.store(0)
    s.put(1, [7])
    assert tr.n > 0
    tr.detach()
    assert "read" not in pool.__dict__ and pool._tracer is None
    n = tr.n
    s.put(2, [8])                               # verbs still work, unrecorded
    assert s.get(2) == [8] and tr.n == n


def test_tracer_pause_skips_recording():
    cl = _small_cluster()
    tr = cl.attach_tracer()
    s = cl.store(0)
    s.put(1, [1])
    n = tr.n
    tr.pause()
    s.put(2, [2])
    assert tr.n == n
    tr.resume()
    s.put(3, [3])
    assert tr.n > n


def test_tracer_ring_wrap_keeps_newest():
    cl = _small_cluster()
    tr = VerbTracer(capacity=16).attach(cl.pool)
    s = cl.store(0)
    for k in range(8):
        s.put(k, [k])
    assert tr.n > 16 and tr.dropped == tr.n - 16
    ev = tr.events()
    assert len(ev["seq"]) == 16
    assert list(ev["seq"]) == list(range(tr.n - 16, tr.n))  # seq-ascending


def test_tracer_records_op_context_and_epoch():
    cl = _small_cluster()
    tr = cl.attach_tracer()
    s = cl.store(0)
    s.put(5, [50])
    ev = tr.events()
    mine = ev["cid"] == 0
    assert mine.any()
    assert (ev["op_id"][mine] >= 0).all()
    assert (ev["epoch_issue"][mine] == cl.pool.epoch).all()
    # master-context actions (client recovery) record under the master cid
    tr.set_master_ctx(tick=cl.scheduler.tick)
    cl.crash_client(0)
    cl.recover_client(0)
    ev = tr.events()
    assert (ev["cid"] == MASTER_CID).any()


def test_tracer_batch_context_via_fleet():
    cl = _small_cluster(num_clients=3)
    tr = cl.attach_tracer()
    stores = {c: cl.store(c, max_inflight=0) for c in range(3)}
    futs = [stores[c].submit(Op.put(10 + c, [c])) for c in range(3)]
    cl.fleet().run()
    assert all(f.result().status == "OK" for f in futs)
    ev = tr.events()
    cids = set(int(c) for c in ev["cid"][ev["cid"] >= 0])
    assert cids == {0, 1, 2}                    # batch ctx threads per-lane


def test_tracer_save_load_roundtrip(tmp_path):
    cl = _small_cluster()
    tr = cl.attach_tracer()
    cl.store(0).put(1, [9])
    p = tmp_path / "trace.npz"
    tr.save(p)
    ev2, labels = VerbTracer.load(p)
    ev1 = tr.events()
    assert labels == tr.labels
    for f in FIELDS:
        assert (ev1[f] == ev2[f]).all(), f


# ================================================= detector (synthetic) ==
def test_rule_stale_epoch_flags_mutations_only():
    rows = [dict(verb=WRITE, epoch_issue=0, epoch_exec=1, off=9),
            dict(verb=READ, epoch_issue=0, epoch_exec=1, off=9)]
    got = _detect(rows, rules=("stale_epoch",))
    assert [f.rule for f in got] == ["stale_epoch"]
    assert got[0].off == 9 and got[0].verbs == ("write",)


def test_rule_index_plain_write():
    rows = [dict(verb=WRITE, region=0, cid=1, off=4),   # index: flagged
            dict(verb=WRITE, region=5, cid=1, off=4),   # data: fine
            dict(verb=WRITE, region=0, cid=MASTER_CID)]  # master: fine
    got = _detect(rows, rules=("index_plain_write",))
    assert len(got) == 1 and got[0].cids == (1,)


def test_rule_clear_order():
    bad = [dict(verb=WRITE, region=0, off=7, arg=0, n=1, replica=0, phase=1),
           dict(verb=WRITE, region=0, off=7, arg=0, n=1, replica=1, phase=2)]
    good = [dict(verb=WRITE, region=0, off=7, arg=0, n=1, replica=1, phase=1),
            dict(verb=WRITE, region=0, off=7, arg=0, n=1, replica=0, phase=2)]
    assert [f.rule for f in _detect(bad, rules=("clear_order",))] \
        == ["clear_order"]
    assert _detect(good, rules=("clear_order",)) == []
    # data-region clears are out of scope: objects validate by CRC + used
    data = [dict(r, region=5) for r in bad]
    assert _detect(data, rules=("clear_order",)) == []


def test_rule_ww_race_and_exclusions():
    ops = {1: _OpInfo(cid=1, inv=0, resp=10),
           2: _OpInfo(cid=2, inv=0, resp=10),
           3: _OpInfo(cid=2, inv=20, resp=30)}
    race = [dict(verb=WRITE, region=5, off=40, arg=11, cid=1, op_id=1),
            dict(verb=WRITE, region=5, off=40, arg=22, cid=2, op_id=2)]
    got = _detect(race, ops=ops, rules=("ww_race",))
    assert len(got) == 1 and sorted(got[0].cids) == [1, 2]

    same_value = [dict(r, arg=11) for r in race]
    assert _detect(same_value, ops=ops, rules=("ww_race",)) == []

    ordered = [dict(race[0]), dict(race[1], op_id=3)]   # real-time ordered
    assert _detect(ordered, ops=ops, rules=("ww_race",)) == []

    guarded = [dict(verb=CAS, region=5, off=38, arg=0, val=9, old=0,
                    cid=1, op_id=1)] + race             # CAS claim nearby
    assert _detect(guarded, ops=ops, rules=("ww_race",)) == []


def test_rule_torn_read():
    rows = [dict(verb=WRITE, region=0, off=7, n=2, cid=1, op_id=4, phase=2,
                 seq=0),
            dict(verb=READ, region=0, off=8, n=1, cid=2, op_id=5, seq=1),
            dict(verb=WRITE, region=0, off=8, n=1, cid=1, op_id=4, phase=2,
                 seq=2)]
    got = _detect(rows, rules=("torn_read",))
    assert [f.rule for f in got] == ["torn_read"]
    assert 2 in got[0].cids


def test_rule_lost_cas_ack_needs_acked_op():
    v_mine, v_other = 77 | (5 << 56), 123 | (9 << 56)   # distinct slot fps
    lost = dict(verb=CAS, region=0, off=16, arg=0, val=v_mine, old=v_other,
                cid=1, op_id=9)
    acked = {9: _OpInfo(cid=1, inv=0, resp=5, status="OK", rule="LOSE")}
    got = _detect([lost], ops=acked, rules=("lost_cas_ack",))
    assert len(got) == 1 and got[0].off == 16

    # op not acked OK / master-arbitrated / value later installed: clean
    retried = {9: _OpInfo(cid=1, inv=0, resp=5, status="FULL")}
    assert _detect([lost], ops=retried, rules=("lost_cas_ack",)) == []
    master = {9: _OpInfo(cid=1, inv=0, resp=5, status="OK",
                         rule="MASTER_WIN")}
    assert _detect([lost], ops=master, rules=("lost_cas_ack",)) == []
    landed = [lost, dict(verb=CAS, region=0, off=24, arg=0, val=v_mine,
                         old=0, cid=1, op_id=9, seq=1)]
    assert _detect(landed, ops=acked, rules=("lost_cas_ack",)) == []


def test_report_formats_findings():
    rows = [dict(verb=WRITE, region=0, cid=1, off=4)]
    got = _detect(rows, rules=("index_plain_write",))
    txt = report(got)
    assert "1 finding(s)" in txt and "index_plain_write" in txt
    assert "clean" in report([])


# ============================================= regressions (acceptance) ==
def _bucket_sharing_keys():
    cfg = DMConfig()
    k1 = 1001
    b1 = bucket_pair(k1, cfg.index_buckets)[0]
    k2 = next(k for k in range(2000, 100000)
              if bucket_pair(k, cfg.index_buckets)[0] == b1)
    return k1, k2


@pytest.mark.parametrize("unsafe", [True, False])
def test_regression_lost_write_cas_race(monkeypatch, unsafe):
    """PR-3 bug class 1: acking OK after losing an empty-slot index CAS.

    Two clients insert different keys sharing a primary bucket; round-robin
    stepping interleaves their bucket reads before either CAS lands, so one
    loses the empty-slot race.  With the bug re-introduced the loser acks
    OK anyway — the detector must pin the lost write (word, cid, verb).
    With the guard in place (flag off), the loser retries and the same
    schedule yields zero findings.
    """
    monkeypatch.setattr(client_mod, "UNSAFE_ACK_LOST_EMPTY_CAS", unsafe)
    k1, k2 = _bucket_sharing_keys()
    cl = FuseeCluster(num_clients=2, seed=3)
    tr = cl.attach_tracer()
    s0, s1 = cl.store(0, max_inflight=0), cl.store(1, max_inflight=0)
    f1 = s0.submit(Op.put(k1, [11]))
    f2 = s1.submit(Op.put(k2, [22]))
    cl.drain()
    assert f1.result().status == "OK" and f2.result().status == "OK"
    findings = cl.race_findings()
    if unsafe:
        assert s1.get(k2) is None               # the acked write IS lost
        assert [f.rule for f in findings] == ["lost_cas_ack"]
        f = findings[0]
        assert f.region in cl.pool.index_region_set
        assert f.verbs == ("cas",) and f.cids == (1,)
        assert f.off >= 0 and "acked OK" in f.detail
    else:
        assert s0.get(k1) == [11] and s1.get(k2) == [22]
        assert findings == []


@pytest.mark.slow
@pytest.mark.parametrize("unsafe", [True, False])
def test_regression_stale_epoch_redirection(monkeypatch, unsafe):
    """PR-3 bug class 2: verbs issued under an expired lease epoch landing
    instead of bouncing.  An MN-crash storm bumps the epoch mid-flight;
    with the §5.2 guard bypassed the detector must flag every stale
    mutation, and the identical seed with the guard on is clean."""
    from repro.analysis.races import _storm_run
    monkeypatch.setattr(sim_mod, "UNSAFE_EXEC_STALE_EPOCH", unsafe)
    cl, tr = _storm_run(0)
    findings = cl.race_findings()
    stale = [f for f in findings if f.rule == "stale_epoch"]
    if unsafe:
        assert stale, "guard bypass must produce stale-epoch landings"
        f = stale[0]
        assert f.verbs[0] in ("write", "cas", "faa")
        assert "executed at pool epoch" in f.detail
    else:
        assert findings == []


@pytest.mark.slow
def test_known_bug_seed7_churn_loses_acked_writes():
    """Regression for the churn-cutover acked-write loss (was a strict
    xfail): an upsert retry that crossed the cutover's epoch bump
    re-observed its own half-installed slot value as v_old and freed its
    own object post-ack.  Fixed by the own-object guard on ``bg:free_old``
    (client.py); the bug stays reproducible under
    ``client.UNSAFE_FREE_OWN_ON_RETRY`` for the model checker."""
    from repro.analysis.races import _storm_run
    cl, _tr = _storm_run(7, churn=True)
    rep = audit(cl)
    assert rep.ok, str(rep)


@pytest.mark.slow
def test_seed7_churn_bug_reproducible_under_unsafe_flag():
    """The test-only revert flag re-introduces the seed-7-class
    use-after-free (so the explorer's cutover scope has a bug to
    rediscover).  The companion seed-13 fix (primary-CAS result check)
    perturbs seed 7's exact interleaving, so the revert now manifests on
    other churn seeds of the neighborhood — seed 4 here."""
    from repro.core import client as client_mod
    from repro.analysis.races import _storm_run
    client_mod.UNSAFE_FREE_OWN_ON_RETRY = True
    try:
        cl, _tr = _storm_run(4, churn=True)
        rep = audit(cl)
    finally:
        client_mod.UNSAFE_FREE_OWN_ON_RETRY = False
    assert not rep.ok
    assert any("use after free" in e or "invalidated" in e
               for e in rep.errors), str(rep)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(16))
def test_churn_storm_seed_neighborhood_clean(seed):
    """The seed-7 neighborhood (0-15) with membership churn: race
    detector and heap/epoch auditor must both come back clean now that
    the cutover acked-write-loss and the seed-13 primary-CAS-unchecked
    holes are fixed."""
    from repro.analysis.races import _storm_run, detect
    cl, tr = _storm_run(seed, churn=True)
    findings = detect(tr, scheduler=cl.scheduler)
    assert findings == [], "\n".join(str(f) for f in findings)
    rep = audit(cl)
    assert rep.ok, str(rep)


# =============================================================== heapcheck
def _loaded_cluster(n_keys=12):
    cl = _small_cluster()
    s = cl.store(0)
    for k in range(n_keys):
        s.put(k, [k, k])
    return cl


def _first_ref(pool):
    """(slot word offset, slot value) of some occupied index slot."""
    g = pool.index_regions[0]
    mem = pool.mns[pool.placement[g][0]].regions[g]
    for w in range(pool.cfg.index_words):
        if int(mem[w]) != 0:
            return g, w, int(mem[w])
    raise AssertionError("no occupied slot")


def _poke(pool, region, off, value):
    for mid in pool.placement[region]:
        pool.mns[mid].regions[region][off] = np.uint64(value & (2**64 - 1))


def test_heapcheck_clean_run():
    cl = _loaded_cluster()
    rep = cl.heap_audit()
    assert rep.ok and rep.errors == [], str(rep)
    assert rep.stats["index_slots_used"] >= 12
    assert rep.stats["leaks"] == 0 and not rep.stats["lenient"]


def test_heapcheck_use_after_free(monkeypatch):
    cl = _loaded_cluster()
    pool = cl.pool
    _g, _w, slot = _first_ref(pool)
    ptr = L.slot_ptr(slot)
    region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
    cfg = pool.cfg
    blk = (off - cfg.bat_words) // cfg.block_words
    obj_idx = (off - pool.block_base(blk)) // L.MIN_OBJ_WORDS
    woff = pool.bitmap_base(blk) + obj_idx // 64
    for mid in pool.placement[region]:
        mem = pool.mns[mid].regions[region]
        mem[woff] = np.uint64(int(mem[woff]) | (1 << (obj_idx % 64)))
    rep = audit(cl)
    assert not rep.ok
    assert any("use after free" in e for e in rep.errors), str(rep)


def test_heapcheck_invalidated_but_referenced():
    cl = _loaded_cluster()
    pool = cl.pool
    _g, _w, slot = _first_ref(pool)
    ptr, sc = L.slot_ptr(slot), L.slot_size_class(slot)
    region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
    tail_off = off + L.size_class_words(sc) - 1
    mem0 = pool.mns[pool.placement[region][0]].regions[region]
    _poke(pool, region, tail_off, int(mem0[tail_off]) | L.INVALID_BIT)
    rep = audit(cl)
    assert not rep.ok
    assert any("invalidated but still referenced" in e
               for e in rep.errors), str(rep)


def test_heapcheck_dangling_reference():
    cl = _loaded_cluster()
    pool = cl.pool
    g = pool.index_regions[0]
    mem = pool.mns[pool.placement[g][0]].regions[g]
    empty = next(w for w in range(pool.cfg.index_words) if int(mem[w]) == 0)
    blk = pool.cfg.blocks_per_region - 1        # never allocated here
    bogus = L.pack_slot(5, 0, L.pack_ptr(pool.data_regions[0],
                                         pool.block_base(blk)))
    _poke(pool, g, empty, int(bogus))
    rep = audit(cl)
    assert not rep.ok
    assert any("UNALLOCATED" in e for e in rep.errors), str(rep)


def test_heapcheck_epoch_mismatch():
    cl = _loaded_cluster(n_keys=2)
    cl.pool.epoch += 1                          # membership commit w/o fence
    rep = audit(cl)
    assert not rep.ok
    assert any("lease epoch" in e for e in rep.errors), str(rep)


# ==================================================================== lint
def test_lint_L001_verb_without_epoch_guard():
    src = ("def f(pool, v):\n"
           "    return pool.cas(v.region, v.replica, v.off, v.exp, v.new)\n")
    got = lint_source(src, "sim.py", rel="core/sim.py")
    assert [f.rule for f in got] == ["L001"]
    guarded = ("def f(pool, v):\n"
               "    if v.epoch != pool.epoch:\n"
               "        return None\n"
               "    return pool.cas(v.region, v.replica, v.off, v.exp, v.new)\n")
    assert lint_source(guarded, "sim.py", rel="core/sim.py") == []
    # master authority module: exempt
    assert lint_source(src, "master.py", rel="core/master.py") == []


def test_lint_L002_nondeterminism():
    # argless default_rng draws OS entropy: flagged everywhere but rng.py
    src = ("import numpy as np\n"
           "def f():\n"
           "    return np.random.default_rng()\n")
    got = lint_source(src, "x.py", rel="core/x.py")
    assert [f.rule for f in got] == ["L002"]
    assert lint_source(src, "rng.py", rel="core/rng.py") == []
    # module-level draws are never seeded: flagged
    draw = ("import numpy as np\n"
            "def f():\n"
            "    return np.random.rand()\n")
    assert [f.rule for f in lint_source(draw, "x.py", rel="core/x.py")] \
        == ["L002"]
    # explicitly seeded constructors are deterministic in their inputs
    seeded = ("import numpy as np\n"
              "def f(seed):\n"
              "    return np.random.default_rng(seed)\n")
    assert lint_source(seeded, "x.py", rel="core/x.py") == []
    # annotations and keyed jax.random are not draws
    ann = ("import numpy as np\n"
           "def f(rng: 'np.random.Generator', key):\n"
           "    import jax\n"
           "    return jax.random.split(key)\n")
    assert lint_source(ann, "x.py", rel="core/x.py") == []


def test_lint_L003_pool_array_mutation():
    src = ("def f(pool, g):\n"
           "    mem = pool.mns[0].regions[g]\n"
           "    mem[3] = 1\n")
    got = lint_source(src, "x.py", rel="core/x.py")
    assert [f.rule for f in got] == ["L003"]
    assert lint_source(src, "heap.py", rel="core/heap.py") == []
    reads = ("def f(pool, g):\n"
             "    mem = pool.mns[0].regions[g]\n"
             "    return int(mem[3])\n")
    assert lint_source(reads, "x.py", rel="core/x.py") == []


def test_lint_L004_scalar_loop_in_batch_path():
    src = ("def tick(self, pool, verbs):\n"
           "    for v in verbs:\n"
           "        pool.read(v.region, v.replica, v.off, v.n)\n")
    got = lint_source(src, "fleet.py", rel="core/fleet.py",
                      rules={"L004"})
    assert [f.rule for f in got] == ["L004"]
    assert lint_source(src, "client.py", rel="core/client.py",
                       rules={"L004"}) == []


def test_lint_L007_loop_in_fused_path():
    src = ("def _fused_read_sweep(self, regions):\n"
           "    for r in regions:\n"
           "        pass\n")
    got = lint_source(src, "heap.py", rel="core/heap.py", rules={"L007"})
    assert [f.rule for f in got] == ["L007"]
    # same loop outside a *fused* function, or outside fleet/heap: clean
    assert lint_source(src.replace("_fused_read_sweep", "read_batch"),
                       "heap.py", rel="core/heap.py", rules={"L007"}) == []
    assert lint_source(src, "client.py", rel="core/client.py",
                       rules={"L007"}) == []
    # a justified pragma on the loop line suppresses it
    ok = ("def _fused_read_sweep(self, regions):\n"
          "    for r in regions:  # lint: allow-fused-loop (unpack at the"
          " API boundary)\n"
          "        pass\n")
    assert lint_source(ok, "heap.py", rel="core/heap.py",
                       rules={"L006", "L007"}) == []


def test_lint_L005_bare_assert():
    src = "def f(x):\n    assert x > 0\n"
    got = lint_source(src, "client.py", rel="core/client.py")
    assert [f.rule for f in got] == ["L005"]
    # non-core code may assert freely
    assert lint_source(src, "run.py", rel="benchmarks/run.py") == []


def test_lint_pragmas_suppress_and_are_checked():
    line = ("def f(x):\n"
            "    assert x > 0  # lint: allow-assert (internal invariant)\n")
    assert lint_source(line, "c.py", rel="core/c.py") == []
    deffed = ("def f(x):  # lint: allow-assert (whole body exempt)\n"
              "    assert x > 0\n"
              "    assert x < 9\n")
    assert lint_source(deffed, "c.py", rel="core/c.py") == []
    typo = "def f(x):\n    assert x  # lint: allow-asert (typo)\n"
    rules = [f.rule for f in lint_source(typo, "c.py", rel="core/c.py")]
    assert "E001" in rules and "L005" in rules


def test_lint_L006_pragma_hygiene():
    # a working pragma without a justification is flagged
    bare = ("def f(x):\n"
            "    assert x > 0  # lint: allow-assert\n")
    got = lint_source(bare, "c.py", rel="core/c.py")
    assert [f.rule for f in got] == ["L006"]
    assert "justification" in got[0].msg
    # a justified pragma whose rule no longer fires on the line is stale
    stale = ("def f(x):\n"
             "    return x  # lint: allow-assert (left over from a refactor)\n")
    got = lint_source(stale, "c.py", rel="core/c.py")
    assert [f.rule for f in got] == ["L006"]
    assert "stale" in got[0].msg
    # justified AND suppressing: clean
    ok = ("def f(x):\n"
          "    assert x > 0  # lint: allow-assert (documented invariant)\n")
    assert lint_source(ok, "c.py", rel="core/c.py") == []
    # the pragma pattern inside a string literal is NOT a pragma — it
    # neither suppresses nor counts as stale
    in_str = ('MSG = "add `# lint: allow-assert (<why>)`"\n'
              "def f(x):\n"
              "    assert x > 0\n")
    assert [f.rule for f in
            lint_source(in_str, "c.py", rel="core/c.py")] == ["L005"]


def test_lint_repo_is_clean():
    # the whole checkout: package AND tests/ AND benchmarks/
    from repro.analysis.lint import default_paths, lint_paths
    assert lint_paths(default_paths()) == []
