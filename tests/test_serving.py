"""Serving-layer tests: SNAPSHOT epoch vs numpy oracle + protocol
invariants (hypothesis over seeds), KV pool lifecycle, crash recovery,
engine integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.launch.mesh import make_host_mesh
from repro.serving import (PoolConfig, Request, ServeEngine,
                           snapshot_epoch, snapshot_epoch_np)
from repro.serving.kvpool import KVPool  # internal substrate (whitebox)


# ------------------------------------------------------- SNAPSHOT epoch ----
@settings(max_examples=60, deadline=None)
@given(trial=st.integers(0, 100_000), r=st.integers(1, 4),
       W=st.integers(1, 12), stale=st.booleans())
def test_snapshot_epoch_invariants(trial, r, W, stale):
    rng = np.random.default_rng(trial)
    M = 32
    base = (rng.integers(0, 3, M).astype(np.int32)) * 7
    index = np.tile(base, (r, 1))
    slot = rng.integers(-1, M, W).astype(np.int32)
    v_old = base[np.maximum(slot, 0)].astype(np.int32)
    if stale and W:
        v_old[0] += 1  # a writer with a stale read
    v_new = (rng.permutation(1000)[:W] + 10).astype(np.int32)
    res = snapshot_epoch(jnp.asarray(index), jnp.asarray(slot),
                         jnp.asarray(v_old), jnp.asarray(v_new),
                         jax.random.PRNGKey(trial))
    win = np.asarray(res.win)
    idx = np.asarray(res.index)
    for s in set(int(x) for x in slot if x >= 0):
        fresh = [w for w in range(W) if slot[w] == s and v_old[w] == base[s]]
        winners = [w for w in fresh if win[w]]
        # exactly one winner among fresh writers on a contested slot
        assert len(winners) == (1 if fresh else 0), (s, fresh, winners)
        if winners:
            # the winner's value is committed on EVERY replica
            assert (idx[:, s] == v_new[winners[0]]).all()
    # replicas converge on every touched slot
    touched = sorted(set(int(x) for x in slot if x >= 0))
    assert (idx[:, touched] == idx[0, touched]).all()
    # stale writers never win
    for w in range(W):
        if slot[w] >= 0 and v_old[w] != base[slot[w]]:
            assert not win[w]


@settings(max_examples=30, deadline=None)
@given(trial=st.integers(0, 10_000))
def test_snapshot_epoch_matches_numpy_oracle_semantics(trial):
    """The jnp epoch and the sequential numpy oracle must agree on the SET
    of possible outcomes: same single-winner slots; committed values drawn
    from the proposals.  (Arrival orders differ, so the specific winner may
    differ — the protocol guarantees agreement, not determinism.)"""
    rng = np.random.default_rng(trial)
    r, M, W = 3, 16, 6
    base = np.zeros(M, np.int32)
    index = np.tile(base, (r, 1))
    slot = rng.integers(0, 4, W).astype(np.int32)  # heavy contention
    v_old = np.zeros(W, np.int32)
    v_new = (rng.permutation(100)[:W] + 1).astype(np.int32)
    res = snapshot_epoch(jnp.asarray(index), jnp.asarray(slot),
                         jnp.asarray(v_old), jnp.asarray(v_new),
                         jax.random.PRNGKey(trial))
    order = [list(rng.permutation(W)) for _ in range(r)]
    idx_np, win_np, com_np, _ = snapshot_epoch_np(index, slot, v_old, v_new,
                                                  order)
    for s in set(int(x) for x in slot):
        writers = [w for w in range(W) if slot[w] == s]
        assert sum(bool(np.asarray(res.win)[w]) for w in writers) == 1
        assert sum(bool(win_np[w]) for w in writers) == 1
        # committed value is one of the proposals in both executions
        props = {int(v_new[w]) for w in writers}
        assert int(np.asarray(res.index)[0, s]) in props
        assert int(idx_np[0, s]) in props


# ----------------------------------------------------------- KV pool -------
@pytest.fixture
def pool():
    return KVPool(PoolConfig(n_pages=512, n_buckets=128, slots_per_bucket=4,
                             replicas=3))


def test_pool_insert_search_delete(pool):
    keys = np.arange(100, 200).astype(np.int32)
    pages = pool.alloc_pages(0, len(keys))
    assert (pages >= 0).all()
    pool.write_pages(0, pages, keys, opcode=1)
    ok = pool.insert_batch(0, keys, pages)
    assert ok.all()
    assert pool.check_replicas_converged()
    ptr, found = pool.search(keys)
    assert found.all()
    # key verification on pages makes pointers exact despite fp collisions
    assert (ptr == pages).all()
    okd = pool.delete_batch(0, keys[:50])
    assert okd.all()
    _, found2 = pool.search(keys)
    assert abs(found2.mean() - 0.5) < 0.05


def test_pool_two_level_allocation_amortizes_grants(pool):
    pages = pool.alloc_pages(1, 100)
    # 100 pages out of 64-page chunks -> only 2 coarse grants (ALLOC RPCs)
    assert pool.stats["alloc_rpcs"] == 2
    assert len(set(pages.tolist())) == 100


def test_pool_elastic_add_shard(pool):
    """The serving twin of add_mn: a new grant shard joins the ring,
    ungranted chunks re-home onto it, granted chunks (live pages) stay
    owned, and allocation keeps working across the scale-out."""
    keys = np.arange(1, 65).astype(np.int32)
    pages = pool.alloc_pages(0, len(keys))
    pool.write_pages(0, pages, keys, opcode=1)
    assert pool.insert_batch(0, keys, pages).all()
    before = pool.grant.copy()
    new_shard = pool.add_shard()
    assert pool.cfg.n_shards == new_shard + 1
    assert (pool.grant == before).all()          # ownership never moves
    assert (pool.shard_of_chunk[pool.grant == 0] == new_shard).any()
    _, found = pool.search(keys)
    assert found.all()                           # live pages untouched
    p2 = pool.alloc_pages(7, 32)                 # allocation still works
    assert (p2 >= 0).all()


def test_pool_free_and_reclaim(pool):
    pages = pool.alloc_pages(0, 64)
    pool.write_pages(0, pages, np.arange(64).astype(np.int32) + 1, opcode=1)
    pool.free_pages(pages[:32])
    n = pool.reclaim(0)
    assert n >= 32
    # reclaimed pages are reusable
    p2 = pool.alloc_pages(0, 32)
    assert (p2 >= 0).all()


def test_pool_concurrent_writers_single_winner(pool):
    """Two clients INSERT the same keys -> exactly one wins per key and the
    index replicas converge (the SNAPSHOT guarantee at the pool level)."""
    keys = np.arange(500, 532).astype(np.int32)
    pg0 = pool.alloc_pages(0, len(keys))
    pg1 = pool.alloc_pages(1, len(keys))
    pool.write_pages(0, pg0, keys, opcode=1)
    pool.write_pages(1, pg1, keys, opcode=1)
    ok0 = pool.insert_batch(0, keys, pg0)
    ok1 = pool.insert_batch(1, keys, pg1)
    ptr, found = pool.search(keys)
    assert found.all()
    assert pool.check_replicas_converged()
    # each key points at exactly one of the two proposals
    assert ((ptr == pg0) | (ptr == pg1)).all()


def test_pool_crash_recovery_redoes_uncommitted(pool):
    keys = np.arange(300, 340).astype(np.int32)
    pages = pool.alloc_pages(1, len(keys))
    pool.write_pages(1, pages, keys, opcode=1)
    # crash BEFORE the index insert: pages written, log uncommitted
    pool.crash_client(1)
    st = pool.recover_client(1, reassign_to=2)
    assert st["used_pages"] == len(keys)
    assert st["redone"] == len(keys)
    _, found = pool.search(keys)
    assert found.all()
    # recovered pages re-owned by client 2
    assert (pool.grant == 2 + 1).sum() >= 1


def test_pool_recovery_idempotent(pool):
    keys = np.arange(700, 720).astype(np.int32)
    pages = pool.alloc_pages(3, len(keys))
    pool.write_pages(3, pages, keys, opcode=1)
    ok = pool.insert_batch(3, keys, pages)   # committed normally
    assert ok.all()
    st = pool.recover_client(3)
    assert st["redone"] == 0, "committed ops must never be redone"


# ------------------------------------------------------------ engine -------
def test_engine_serves_and_hits_prefix_cache():
    from repro.configs import base as C
    from repro.models import build
    mesh = make_host_mesh((1, 1), ("data", "model"))
    r = C.reduced(C.get("llama3-8b"))
    m = build(r, mesh, use_kernels=True)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, max_batch=2, max_len=128,
                      pool_cfg=PoolConfig(n_pages=256, n_buckets=64,
                                          slots_per_bucket=4))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, r.vocab, 64).astype(np.int32)
    for i in range(4):
        tail = rng.integers(0, r.vocab, 16).astype(np.int32)
        eng.submit(Request(rid=i, prompt=np.concatenate([shared, tail]),
                           max_new=4))
    done = eng.run(max_ticks=60)
    assert len(done) == 4
    assert all(len(q.out) == 4 for q in done)
    # later requests hit the shared 64-token prefix block
    assert sum(q.prefix_hits for q in done) >= 2
    assert eng.pool.check_replicas_converged()
    # ordered listing of live prefixes (the serving scan twin): sorted
    # block-hash keys, each backed by a live page in the device index
    listed = eng.list_prefixes(0, 64)
    assert listed, "prefix blocks were inserted, listing must see them"
    keys = [k for k, _p in listed]
    assert keys == sorted(set(keys))
    assert all(p >= 0 for _k, p in listed)
