"""Model-checker regression suite (repro.analysis.explore).

The explorer's contract is exercised from both sides:

  * clean scopes stay clean — full-depth enumeration of the no-fault,
    client-crash and insert-race scopes (and bounded prefixes of the
    heavier MN-crash / churn-cutover scopes) finds no violation;
  * known bugs are rediscovered cold — re-enabling a PR-3 protocol hole
    behind its test-only flag (``client.UNSAFE_ACK_LOST_EMPTY_CAS``,
    ``sim.UNSAFE_EXEC_STALE_EPOCH``) or the seed-7 churn hole
    (``client.UNSAFE_FREE_OWN_ON_RETRY``) makes the same explorer find a
    violation and ddmin it to a small replayable counterexample.

Exploration is deterministic: same scope + bounds => bit-identical state
count, execution count and visit digest on every run.
"""
import pytest

import repro.core.client as client_mod
import repro.core.master as master_mod
from repro.analysis.explore import (SCOPES, Explorer, check_invariants,
                                    explore, load_counterexample, main,
                                    replay, save_counterexample)
from repro.core.sim import Choice


def _lane(mn):
    return Choice("lane", cid=0, mn=mn)


# The ddmin'd seed-7 churn counterexample: under UNSAFE_FREE_OWN_ON_RETRY
# an add_mn epoch bump bounces one backup CAS, fail_query's tiebreak turns
# the split evidence into RETRY, cutover repair spreads the half-installed
# value, and the retry frees its *own* object => acked write lost +
# use-after-free.  15 choice points, found and minimized by the explorer.
SEED7_MIN_SCHEDULE = [
    _lane(0), _lane(0), _lane(1), _lane(2), _lane(0),
    _lane(1), _lane(1), _lane(1), _lane(2), _lane(2),
    Choice("event", name="add_mn"),
    _lane(0),
    Choice("master", cid=0),
    Choice("event", name="migrate"),
    Choice("event", name="migrate"),
]


def _drain(cl, cap=10_000):
    n = 0
    while n < cap:
        cs = cl.choices()
        if not cs:
            return n
        cl.fire(cs[0])
        n += 1
    raise AssertionError("leftmost continuation did not drain")


def _fire_schedule(scope_name, schedule):
    setup = SCOPES[scope_name].build()
    for ch in schedule:
        setup.cluster.fire(ch)
    _drain(setup.cluster)
    return setup


# ------------------------------------------------------------ scope registry
def test_scopes_build_and_enumerate():
    for name, scope in SCOPES.items():
        setup = scope.build()
        assert setup.cluster.choices(), f"scope {name} starts with no choices"


# --------------------------------------------------------------- clean scopes
def test_clean_scopes_full_depth():
    for scope in ("no_fault", "crash", "insert_race"):
        res = explore(scope, minimize=False)
        assert res.complete, scope
        assert not res.violations, (scope, res.summary())


def test_clean_scopes_bounded_prefixes():
    # the MN-crash and churn-cutover scopes are too large for full-depth
    # tier-1; a bounded prefix still covers every schedule the DFS reaches
    # first (including the fixed seed-7 and bg-cleanup-reaim neighborhoods)
    for scope, bound in (("stale_epoch", 300), ("cutover", 150)):
        res = explore(scope, minimize=False, max_states=bound)
        assert not res.violations, (scope, res.summary())


def test_exploration_is_deterministic():
    a = Explorer("no_fault").run()
    b = Explorer("no_fault").run()
    assert (a.states, a.executions, a.visit_digest) \
        == (b.states, b.executions, b.visit_digest)
    assert a.visit_digest  # non-empty digest actually computed


def test_naive_mode_agrees_on_clean_scope():
    # naive enumeration (no DPOR, dedup cuts allowed) must reach at least
    # every state DPOR reaches and likewise find nothing
    dpor = Explorer("no_fault").run()
    naive = Explorer("no_fault", naive=True).run()
    assert not naive.violations
    assert naive.states >= dpor.states


# ------------------------------------------------- PR-3 holes, rediscovered
def test_explorer_rediscovers_lost_ack(tmp_path):
    res = explore("lost_ack",
                  flags={"client.UNSAFE_ACK_LOST_EMPTY_CAS": True})
    assert res.violations, res.summary()
    v = res.violations[0]
    assert v.kind in ("acked_write_lost", "linearizability")
    assert v.minimized is not None and len(v.minimized) <= 25
    # counterexample round-trips through the pickle-free npz format and
    # reproduces on replay
    path = str(tmp_path / "lost_ack.npz")
    save_counterexample(path, "lost_ack", v,
                        flags={"client.UNSAFE_ACK_LOST_EMPTY_CAS": True})
    scope_name, kind, _, sched, flags = load_counterexample(path)
    assert scope_name == "lost_ack" and kind == v.kind
    assert sched == tuple(v.minimized)
    assert flags == {"client.UNSAFE_ACK_LOST_EMPTY_CAS": True}
    lines = []
    assert replay(path, out=lines.append)
    assert any("VIOLATION" in ln for ln in lines)


def test_explorer_rediscovers_stale_epoch_exec():
    res = explore("stale_epoch", flags={"sim.UNSAFE_EXEC_STALE_EPOCH": True},
                  max_states=2000)
    assert res.violations, res.summary()
    v = res.violations[0]
    assert v.minimized is not None and len(v.minimized) <= 25


# ------------------------------------------------------ seed-7 churn cutover
def test_seed7_cutover_schedule_is_clean_with_fix():
    setup = _fire_schedule("cutover", SEED7_MIN_SCHEDULE)
    assert check_invariants(setup) == []


def test_seed7_cutover_schedule_violates_with_fix_reverted(monkeypatch):
    monkeypatch.setattr(client_mod, "UNSAFE_FREE_OWN_ON_RETRY", True)
    setup = _fire_schedule("cutover", SEED7_MIN_SCHEDULE)
    kinds = {v.kind for v in check_invariants(setup)}
    assert "acked_write_lost" in kinds, kinds


@pytest.mark.slow
def test_seed7_cutover_cold_start_find_and_minimize():
    # the acceptance end-to-end: with the fix reverted, the explorer finds
    # the acked-write-loss from nothing but the scope definition and ddmins
    # it to a small schedule (~8 min full sweep of the flagged scope)
    ex = Explorer("cutover", flags={"client.UNSAFE_FREE_OWN_ON_RETRY": True})
    res = ex.run()
    kinds = {v.kind for v in res.violations}
    assert "acked_write_lost" in kinds, res.summary()
    v = next(x for x in res.violations if x.kind == "acked_write_lost")
    ex.minimize(v)
    assert len(v.minimized) <= 25


# -------------------------------------------------- seeds-8/15 torn redo
# The ddmin'd storm seeds-8/15 counterexample: client 1 dies mid-insert
# with its KV object written to the primary replica only (the crash drops
# the backup-write lane), §5.3 recovery redoes the logged op — installing
# the index slot off the one good copy — and the leftmost continuation
# then crashes the MN holding that copy; Alg-3 re-homes onto the all-zero
# surviving replica and the slot references garbage.  6 choice points,
# found and minimized by the explorer.
LOSER_RESET_MIN_SCHEDULE = [
    Choice("lane", cid=1, mn=1),
    Choice("lane", cid=1, mn=1),
    Choice("lane", cid=1, mn=0),
    Choice("lane", cid=1, mn=1),
    Choice("event", name="crash_client:1"),
    Choice("event", name="recover_client:1"),
]


def test_loser_reset_schedule_is_clean_with_fix():
    setup = _fire_schedule("loser_reset", LOSER_RESET_MIN_SCHEDULE)
    assert check_invariants(setup) == []


def test_loser_reset_schedule_violates_with_fix_reverted(monkeypatch):
    monkeypatch.setattr(master_mod, "UNSAFE_REDO_NO_CONVERGE", True)
    setup = _fire_schedule("loser_reset", LOSER_RESET_MIN_SCHEDULE)
    kinds = {v.kind for v in check_invariants(setup)}
    assert "heap_audit" in kinds, kinds


def test_loser_reset_clean_bounded_prefix():
    res = explore("loser_reset", minimize=False, max_states=300)
    assert not res.violations, res.summary()


@pytest.mark.slow
def test_loser_reset_cold_start_find_and_minimize():
    # with the fix reverted the explorer rediscovers the heap corruption
    # from nothing but the scope definition and ddmins it small
    ex = Explorer("loser_reset",
                  flags={"master.UNSAFE_REDO_NO_CONVERGE": True})
    res = ex.run()
    kinds = {v.kind for v in res.violations}
    assert "heap_audit" in kinds, res.summary()
    v = next(x for x in res.violations if x.kind == "heap_audit")
    ex.minimize(v)
    assert len(v.minimized) <= 25


# ------------------------------------------------------------- CLI smoke
def test_cli_list():
    assert main(["--list"]) == 0
