"""Differential replay oracle for the fused fleet tick (core/fleet.py +
heap.DMPool.exec_fused_tick).

The fused path executes a tick's READ/WRITE/CAS/FAA sweeps as ONE pool
dispatch over the flat region slab; the per-kind ``*_batch`` path is the
oracle.  The contract under test: a same-seed run is **bit-identical**
under both — final pool bytes, ``health()`` views, per-kind verb
counters, per-MN byte accounting, and the full per-op history — across
YCSB-A/C/E mixes, a churn fault storm, and an ``add_mn`` fired mid-run
(whose migration dual-write window forces the per-tick fallback, so the
mixed fused/fallback schedule is covered too).  A recording tracer must
force the fallback rather than silently dropping verbs.
"""
import json

import numpy as np
import pytest

from repro.core import (OK, ClientCrashed, DMConfig, FaultPlan,
                        FuseeCluster, Op)
from repro.obs import deterministic_view


# --------------------------------------------------------------- signatures
def _pool_bytes(cl):
    """Every hosted region copy, canonically ordered: the byte-level
    ground truth the fused and oracle paths must agree on."""
    pool = cl.pool
    return b"".join(np.ascontiguousarray(mn.regions[g]).tobytes()
                    for mn in pool.mns for g in sorted(mn.regions))


def _counter_signature(fleet):
    """Every engine counter that must not depend on the execution path.
    ``array_calls`` (the fusion's whole point) and the fused/fallback
    tick tallies are intentionally excluded."""
    c = fleet.counters
    keys = [k for k in c if k.startswith("verbs")] + [
        "ticks", "master_calls", "max_lanes", "index_probe_verbs",
        "ord_leaf_verbs", "probe_invocations", "probe_keys", "probe_hits",
        "scan_locate_invocations", "scan_locate_keys"]
    return {k: c[k] for k in keys}


def _health_signature(cl):
    h = cl.health()
    return (h.epoch, h.tick, h.crashed_ops, h.client_recoveries,
            h.mn_recoveries,
            tuple((m.mid, m.alive, m.primary_regions, m.hosted_regions,
                   m.bytes_served) for m in h.mns),
            tuple((c.cid, c.status, c.epoch, c.inflight, c.cache_entries,
                   c.completed_ops, c.crashed_ops) for c in h.clients))


def _history_signature(cl):
    return tuple(
        (r.cid, r.op_id, r.kind, r.key, r.inv_tick, r.resp_tick, r.rtts,
         r.bg_rtts, r.result.status,
         tuple(r.result.value) if isinstance(r.result.value, list) else None)
        for r in cl.scheduler.history if r.result is not None)


def _metrics_signature(cl):
    """The whole metrics registry minus the path-dependent names
    (PATH_DEPENDENT): latency histograms, per-MN load series, heat
    sketch, flight-derived counters — all must be bit-identical across
    the fused and oracle paths."""
    return json.dumps(deterministic_view(cl.metrics()), sort_keys=True)


def _signature(cl, fleet):
    return (_pool_bytes(cl), _health_signature(cl), _history_signature(cl),
            _counter_signature(fleet), tuple(cl.pool.mn_bytes.tolist()),
            _metrics_signature(cl))


def _assert_differential(run, *, expect_fused_ticks=True):
    """Run a scenario twice (oracle, then fused) and compare signatures
    component-wise."""
    cl_o, fl_o = run(fused=False)
    cl_f, fl_f = run(fused=True)
    sig_o, sig_f = _signature(cl_o, fl_o), _signature(cl_f, fl_f)
    for name, a, b in zip(("pool_bytes", "health", "history", "counters",
                           "mn_bytes", "metrics"), sig_o, sig_f):
        assert a == b, f"fused/oracle divergence in {name}"
    if expect_fused_ticks:
        assert fl_f.counters["fused_ticks"] > 0
    assert fl_o.counters["fused_ticks"] == 0
    # the fusion must not cost MORE dispatches than the per-kind path
    assert fl_f.counters["array_calls"] <= fl_o.counters["array_calls"]
    return cl_o, fl_o, cl_f, fl_f


# ----------------------------------------------------------- YCSB scenarios
def _mk_ycsb_runner(mix_name, seed, *, n_clients=24, n_keys=64,
                    ops_per_client=6):
    from benchmarks.common import MAX_SCAN_LEN, YCSB, fleet_dmconfig

    mix = YCSB[mix_name]
    has_scan = "scan" in mix

    def run(*, fused):
        cfg = fleet_dmconfig(n_clients, n_keys, ordered=has_scan)
        cl = FuseeCluster(cfg, num_clients=n_clients, seed=seed)
        fleet = cl.fleet(fused=fused)
        sched = cl.scheduler
        backends = [cl.store(c, max_inflight=0).backend
                    for c in range(n_clients)]
        for k in range(n_keys):
            sched.submit(k % n_clients, "insert", k, [k])
        fleet.run()
        wl = cl.rng.stream("workload")
        kinds = sorted(mix)
        probs = np.array([mix[k] for k in kinds], float)
        probs /= probs.sum()
        plans = [[] for _ in range(n_clients)]
        fresh = n_keys
        for i in range(n_clients * ops_per_client):
            kind = kinds[int(wl.choice(len(kinds), p=probs))]
            if kind == "insert":
                key, fresh = fresh, fresh + 1
            else:
                key = int(wl.integers(n_keys))
            if kind == "scan":
                val = 1 + int(wl.integers(MAX_SCAN_LEN))
            elif kind in ("insert", "update"):
                val = [i, i]
            else:
                val = None
            plans[i % n_clients].append(Op(kind, key, val))
        cursor = [0] * n_clients
        while True:
            wave = []
            for c in range(n_clients):
                room = 4 - sched.inflight(c)
                if room > 0 and cursor[c] < len(plans[c]):
                    ops = plans[c][cursor[c]:cursor[c] + room]
                    cursor[c] += len(ops)
                    wave.append((backends[c], ops))
            if wave:
                fleet.submit_wave(wave)
            if not sched.has_work():
                break
            fleet.tick()
        return cl, fleet

    return run


@pytest.mark.parametrize("mix_name,seed", [
    ("A", 0), ("A", 7), ("C", 0), ("C", 3), ("E", 0), ("E", 5)])
def test_fused_matches_oracle_ycsb(mix_name, seed):
    _assert_differential(_mk_ycsb_runner(mix_name, seed))


# ------------------------------------------------------------- churn storm
def _mk_storm_runner(seed):
    n_clients, n_mns, repl, total_ops = 6, 5, 3, 120

    def run(*, fused):
        cl = FuseeCluster(DMConfig(num_mns=n_mns, replication=repl,
                                   region_words=1 << 15, regions_per_mn=16,
                                   index_shards=4),
                          num_clients=n_clients, seed=seed)
        plan = FaultPlan.storm(cl.rng.stream("faults"),
                               clients=range(n_clients), mns=n_mns,
                               replication=repl, n_client_crashes=2,
                               n_mn_crashes=1, n_add_mns=1,
                               remove_added=True, first_op=10, spacing=14,
                               recover_delay=8)
        cl.inject(plan)
        fleet = cl.fleet(fused=fused)
        stores = {c: cl.store(c, max_inflight=0) for c in range(n_clients)}
        submitted = 0
        while submitted < total_ops:
            for c in range(n_clients):
                if submitted >= total_ops:
                    break
                k = submitted
                submitted += 1
                try:
                    stores[c].submit(Op.put(k, [k, c]))
                except ClientCrashed:
                    pass
            for _ in range(4):
                if cl.scheduler.has_work():
                    fleet.tick()
        fleet.run()
        if cl.migrator.busy:
            cl.migrator.drive()
            fleet.run()
        return cl, fleet

    return run


@pytest.mark.parametrize("seed", [0, 8, 15])
def test_fused_matches_oracle_churn_storm(seed):
    # the storm mixes fused ticks with forced fallbacks (migration
    # dual-write windows) and covers crash/recover of clients and MNs —
    # including the loser-reset seeds the model checker pinned
    _cl_o, _fl_o, _cl_f, fl_f = _assert_differential(_mk_storm_runner(seed))
    assert fl_f.counters["fused_ticks"] > 0


# ------------------------------------------------------------ add_mn midrun
def _mk_add_mn_runner(seed):
    from benchmarks.common import fleet_dmconfig
    import dataclasses
    n_clients, n_keys = 16, 96

    def run(*, fused):
        cfg = dataclasses.replace(
            fleet_dmconfig(n_clients, n_keys, n_mns=3, replication=2),
            index_shards=8)
        cl = FuseeCluster(cfg, num_clients=n_clients, seed=seed)
        fleet = cl.fleet(fused=fused)
        sched = cl.scheduler
        backends = [cl.store(c, max_inflight=0).backend
                    for c in range(n_clients)]
        for k in range(n_keys):
            sched.submit(k % n_clients, "insert", k, [k])
        fleet.run()
        wl = cl.rng.stream("workload")
        plans = [[] for _ in range(n_clients)]
        for i in range(n_clients * 10):
            kind = "update" if wl.random() < 0.5 else "search"
            key = int(wl.integers(n_keys))
            plans[i % n_clients].append(
                Op(kind, key, [i] if kind == "update" else None))
        cursor, tick, added = [0] * n_clients, 0, False
        while True:
            wave = []
            for c in range(n_clients):
                room = 4 - sched.inflight(c)
                if room > 0 and cursor[c] < len(plans[c]):
                    ops = plans[c][cursor[c]:cursor[c] + room]
                    cursor[c] += len(ops)
                    wave.append((backends[c], ops))
            if wave:
                fleet.submit_wave(wave)
            if tick == 6 and not added:
                cl.add_mn(wait=False)
                added = True
            if not sched.has_work() and not cl.migrator.busy:
                break
            fleet.tick()
            tick += 1
        assert added
        return cl, fleet

    return run


@pytest.mark.parametrize("seed", [0, 11])
def test_fused_matches_oracle_add_mn_midrun(seed):
    _cl_o, _fl_o, _cl_f, fl_f = _assert_differential(_mk_add_mn_runner(seed))
    # the dual-write migration window must have forced per-tick fallbacks
    assert fl_f.counters["fallback_ticks"] > 0


# ------------------------------------------------- tracer fallback contract
def test_recording_tracer_forces_fallback_not_drop():
    """With a recording tracer attached, a fused engine must fall back to
    the instrumented oracle path — every verb recorded, zero fused ticks
    — and must resume fusing once the tracer detaches."""
    def run(*, fused, trace):
        cl = FuseeCluster(DMConfig(), num_clients=8, seed=2)
        if trace:
            cl.attach_tracer()
        fleet = cl.fleet(fused=fused)
        for c in range(8):
            for k in range(4):
                cl.scheduler.submit(c, "insert", 10 * c + k, [c, k])
        fleet.run()
        return cl, fleet

    cl_t, fl_t = run(fused=True, trace=True)
    assert fl_t.counters["fused_ticks"] == 0
    assert fl_t.counters["fallback_ticks"] > 0
    cl_o, fl_o = run(fused=False, trace=True)
    # identical recorded verb streams: nothing was dropped
    ev_t, ev_o = cl_t.pool._tracer.events(), cl_o.pool._tracer.events()
    assert set(ev_t) == set(ev_o)
    for k in ev_t:
        assert np.array_equal(ev_t[k], ev_o[k]), k
    # detached tracer: fusing resumes
    cl_d, fl_d = run(fused=True, trace=False)
    assert fl_d.counters["fused_ticks"] > 0
    assert _pool_bytes(cl_d) == _pool_bytes(cl_t)


def test_fused_engine_is_deterministic():
    run = _mk_ycsb_runner("A", 4)
    cl_a, fl_a = run(fused=True)
    cl_b, fl_b = run(fused=True)
    assert _signature(cl_a, fl_a) == _signature(cl_b, fl_b)


# ------------------------------------------------------------- 32k smoke
@pytest.mark.slow
def test_fused_fleet_32k_clients_smoke():
    """The scale headline: a 32768-client fused YCSB-C run completes at
    interactive wall-clock with ~1 array dispatch per tick."""
    from benchmarks.common import YCSB, run_fleet_workload
    st = run_fleet_workload(n_clients=32768, mix=YCSB["C"], seed=13,
                            ops_per_client=2, n_keys=8192,
                            read_dist="zipfian")
    assert st.n_ops == 32768 * 2
    assert st.array_calls_per_tick <= 1.5
    assert st.wall_s <= 60, f"32k fused run took {st.wall_s:.1f}s"
