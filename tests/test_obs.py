"""Telemetry determinism + round-trip tests for the observability layer
(repro.obs): registry semantics, same-seed bit-identical snapshots,
flight-recorder dumps (one per injected fault class), and the Perfetto /
JSON exporters' load round-trips."""
import json
import os

import numpy as np
import pytest

from repro.core import DMConfig, FaultPlan, FuseeCluster, Op
from repro.obs import (EV_FAULT, FlightRecorder, Histogram, Registry,
                       deterministic_view, flight_to_perfetto, load_flight,
                       load_metrics, load_perfetto, metrics_to_json,
                       snapshot_diff, snapshot_merge)


# ------------------------------------------------------------ registry units
def test_histogram_log2_buckets():
    h = Histogram("t", "ticks", n_buckets=8)
    h.observe_many(np.array([0, 1, 2, 3, 4, 7, 8, 1 << 40]))
    # bucket 0={0}, 1={1}, 2=[2,3], 3=[4,7], 4=[8,15], last absorbs overflow
    assert h.counts.tolist() == [1, 1, 2, 2, 1, 0, 0, 1]
    assert h.total == 8
    assert h.upper_edges().tolist() == [0, 1, 3, 7, 15, 31, 63, 127]


def test_histogram_percentiles_conservative():
    h = Histogram("t", "ticks")
    h.observe_many(np.full(99, 2))       # bucket [2,3] -> upper edge 3
    h.observe(1000)                      # [512,1023] -> upper edge 1023
    assert h.percentile(0.5) == 3
    assert h.percentile(0.99) == 3
    assert h.percentile(0.9999) == 1023
    assert Histogram("e").percentile(0.5) == 0


def test_registry_type_conflict_and_snapshot_shape():
    r = Registry()
    r.counter("a.x").inc(3)
    r.gauge("a.g").set_max(7)
    r.histogram("a.h", "rtts").observe(5)
    r.series("a.s", ("tick", "v")).append_rows(np.array([[1.0, 2.0]]))
    r.heat("a.heat", 8).touch(3)
    with pytest.raises(TypeError):
        r.gauge("a.x")
    snap = r.snapshot()
    assert snap["counters"] == {"a.x": 3}
    assert snap["gauges"] == {"a.g": 7}
    assert snap["histograms"]["a.h"]["unit"] == "rtts"
    assert snap["series"]["a.s"]["rows"] == [[1.0, 2.0]]
    assert snap["heat"]["a.heat"][3] == 1
    json.dumps(snap)                     # JSON-pure by construction


def test_snapshot_diff_and_merge():
    r = Registry()
    c = r.counter("n")
    h = r.histogram("h")
    old = r.snapshot()
    c.inc(5)
    h.observe(4)
    new = r.snapshot()
    d = snapshot_diff(new, old)
    assert d["counters"]["n"] == 5
    assert sum(d["histograms"]["h"]["counts"]) == 1
    m = snapshot_merge(new, new)
    assert m["counters"]["n"] == 10
    assert sum(m["histograms"]["h"]["counts"]) == 2


def test_series_ring_wraps_keeping_newest():
    r = Registry()
    s = r.series("s", ("t",), capacity=4)
    s.append_rows(np.arange(6, dtype=np.float64)[:, None])
    assert s.rows()[:, 0].tolist() == [2.0, 3.0, 4.0, 5.0]
    assert s.dropped == 2


def test_flight_ring_wrap_and_roundtrip(tmp_path):
    fr = FlightRecorder(capacity=4)
    rows = np.arange(6 * 10, dtype=np.int64).reshape(6, 10)
    fr.push_rows(rows)
    ev = fr.events()
    assert fr.dropped == 2
    assert ev["tick"].tolist() == rows[2:, 0].tolist()   # oldest dropped
    path = str(tmp_path / "f.npz")
    fr.save(path, ["alpha", "beta"])
    dump = load_flight(path)
    assert dump["labels"] == ["alpha", "beta"]
    assert dump["dropped"] == 2
    assert dump["tick"].tolist() == ev["tick"].tolist()


# ------------------------------------------------------- cluster determinism
def _seeded_run(seed, *, dump_dir=None):
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3,
                      seed=seed, obs_dump_dir=dump_dir)
    kv = cl.store(0)
    for i in range(30):
        kv.put(f"k{i}", f"v{i}")
    for i in range(30):
        kv.get(f"k{i}")
    kv.drain()
    return cl


def test_same_seed_metrics_bit_identical():
    a = json.dumps(_seeded_run(11).metrics(), sort_keys=True)
    b = json.dumps(_seeded_run(11).metrics(), sort_keys=True)
    assert a == b


def test_metrics_snapshot_contents():
    cl = _seeded_run(3)
    m = cl.metrics()
    assert m["counters"]["op.settled"] == 60
    assert m["counters"]["op.begun"] == 60
    assert m["counters"]["op.crashed"] == 0
    # latency histograms per kind, plus the percentile summary
    ins = m["histograms"]["op.lat_ticks.kind.insert"]
    assert ins["unit"] == "ticks" and sum(ins["counts"]) == 30
    p = m["percentiles"]["op.lat_rtts.kind.search"]
    assert p["count"] == 30 and p["p50"] >= 1 and p["p99"] >= p["p50"]
    # heat sketch saw the cache path
    assert sum(m["heat"]["cache.heat"]) > 0
    # per-shard and per-MN attribution dimensions exist
    assert any(k.startswith("op.lat_ticks.shard.")
               for k in m["histograms"])
    assert any(k.startswith("op.lat_ticks.mn.") for k in m["histograms"])


def test_detached_hub_records_nothing_new():
    cl = _seeded_run(5)
    before = cl.metrics()["counters"]["op.settled"]
    cl.detach_obs()
    assert cl.scheduler.obs is None and cl.pool._obs is None
    kv = cl.store(1)
    for i in range(5):
        kv.put(f"d{i}", b"x")
    kv.drain()
    assert cl.metrics()["counters"]["op.settled"] == before
    cl.attach_obs()
    kv.put("post", b"y")
    kv.drain()
    assert cl.metrics()["counters"]["op.settled"] == before + 1


def test_legacy_counters_deprecated_but_live():
    import warnings
    cl = _seeded_run(1)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c = cl.fleet().counters
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    # the view reads through the registry handles
    assert c["ticks"] == cl.scheduler.metrics.get("fleet.ticks").value
    with pytest.raises(TypeError):
        c["ticks"] = 5                     # read-only Mapping


def test_fleet_run_populates_series_and_heat():
    from benchmarks.common import YCSB, fleet_dmconfig
    n = 16
    cfg = fleet_dmconfig(n, 128)
    cl = FuseeCluster(cfg, num_clients=n, seed=2)
    fleet = cl.fleet()
    backends = [cl.store(c, max_inflight=0).backend for c in range(n)]
    for k in range(128):
        cl.scheduler.submit(k % n, "insert", k, [k])
    fleet.run()
    for r in range(6):                     # several windows of GET waves
        fleet.submit_wave([(be, [Op.get(int(k)) for k in range(8)])
                           for be in backends])
        fleet.run()
    m = cl.metrics()
    rows = m["series"]["mn.load"]["rows"]
    assert rows, "per-MN series never sampled"
    fields = m["series"]["mn.load"]["fields"]
    assert fields == ["tick", "mid", "bytes", "verbs", "qdepth",
                      "cpu_ops", "util"]
    by = {f: i for i, f in enumerate(fields)}
    assert sum(r[by["bytes"]] for r in rows) > 0
    assert sum(r[by["verbs"]] for r in rows) > 0
    assert all(r[by["util"]] >= 0 for r in rows)
    assert sum(m["heat"]["cache.heat"]) > 0
    top = cl.obs.heat.top(4)
    assert top and top[0][1] >= top[-1][1]


# ------------------------------------------------------- dumps + fault storm
def test_storm_dumps_once_per_fault_class(tmp_path):
    dump_dir = str(tmp_path / "dumps")
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3,
                      seed=9, obs_dump_dir=dump_dir)
    plan = (FaultPlan().crash_mn(2, after_ops=12)
            .crash_client(0, after_ops=18)
            .crash_client(1, after_ops=24)       # same class: no second dump
            .recover_client(0, after_ops=30))
    cl.inject(plan)
    kv = cl.store(2)
    for i in range(60):
        kv.put(i, [i])
    kv.drain()
    files = sorted(os.listdir(dump_dir))
    classes = {f.split("_t")[0] for f in files}
    assert classes == {"flight_fault_crash_mn", "flight_fault_crash_client",
                       "flight_fault_recover_client"}
    assert len(files) == 3                # exactly one per fault class
    dump = load_flight(os.path.join(dump_dir, files[0]))
    assert (dump["etype"] == EV_FAULT).sum() >= 1
    # fault labels intern alongside op kinds
    assert "crash_mn" in dump["labels"]


def test_undumped_cluster_never_writes(tmp_path):
    cl = _seeded_run(4)                   # no dump_dir: disarmed
    assert cl.obs.dump("anything") is None
    cl.crash_mn(1)
    assert cl.obs.dumped == {}


# ------------------------------------------------------------------ exports
def test_metrics_json_roundtrip(tmp_path):
    cl = _seeded_run(6)
    path = str(tmp_path / "m.json")
    metrics_to_json(cl.metrics(), path)
    m = load_metrics(path)
    assert m == json.loads(json.dumps(cl.metrics(), sort_keys=True))


def test_perfetto_export_roundtrip(tmp_path):
    dump_dir = str(tmp_path / "d")
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2, index_shards=4),
                      num_clients=2, seed=8, obs_dump_dir=dump_dir)
    kv = cl.store(0)
    for i in range(20):
        kv.put(i, [i])
    kv.drain()
    cl.crash_mn(2)                        # fault instant + Alg-3 recovery
    kv2 = cl.store(1)
    for i in range(10):
        kv2.put(100 + i, [i])
    kv2.drain()
    cl.add_mn()                           # migration windows (start->cutover)
    path = cl.obs.dump("manual", force=True)
    trace = flight_to_perfetto(load_flight(path),
                               str(tmp_path / "trace.json"))
    loaded = load_perfetto(str(tmp_path / "trace.json"))
    assert loaded == trace
    cats = {e.get("cat") for e in trace["traceEvents"]}
    assert {"op", "fault", "migration"} <= cats
    ops = [e for e in trace["traceEvents"] if e.get("cat") == "op"]
    assert ops and all(e["ph"] == "X" and e["dur"] > 0 for e in ops)
    migs = [e for e in trace["traceEvents"] if e.get("cat") == "migration"]
    assert any(e["args"]["phase"] == "cutover" for e in migs)
    # ts ordering is deterministic
    ts = [e.get("ts", 0) for e in trace["traceEvents"]]
    assert ts == sorted(ts)
    with pytest.raises(ValueError):
        json_path = str(tmp_path / "bogus.json")
        with open(json_path, "w") as f:
            json.dump({"nope": 1}, f)
        load_perfetto(json_path)


def test_deterministic_view_drops_path_dependent():
    cl = _seeded_run(2)
    cl.fleet()                            # registers fleet.* counters
    v = deterministic_view(cl.metrics())
    assert "fleet.fused_ticks" not in v["counters"]
    assert "fleet.array_calls" not in v["counters"]
    assert "op.settled" in v["counters"]


def test_serving_metrics_twin():
    pytest.importorskip("jax")
    from repro.serving import PoolConfig, ServeEngine

    class _Stub:                          # never stepped: metrics-only engine
        def decode_step(self, params, cache, token):
            raise NotImplementedError

    eng = ServeEngine(_Stub(), None, max_batch=2,
                      pool_cfg=PoolConfig(n_pages=64, n_buckets=32,
                                          slots_per_bucket=4))
    m = eng.metrics()
    assert set(m) == {"counters", "gauges", "histograms", "series", "heat"}
    assert all(k.startswith("serve.") for k in m["counters"])
    assert m["gauges"]["serve.slots_free"] == 2
    json.dumps(m)
    merged = snapshot_merge(m, m)
    assert merged["gauges"]["serve.slots_free"] == 2
