"""Property-based linearizability suite (tier-1, bounded example counts).

Random op mixes, pipeline depths, and interleavings are generated per
example (hypothesis when installed, the deterministic tests/_hypo.py shim
otherwise) and every per-key history is checked against the Wing&Gong
checker in core/linearize.py.  Crash-during-commit histories are covered
by crashing a client at a random verb boundary mid-pipeline, running §5.3
recovery, and accepting a history iff SOME subset of the crashed
(unacknowledged) writes can be linearized as having taken effect — the
correctness contract of the CRASHED outcome: a crashed op may or may not
have executed, but never partially and never twice."""
import itertools

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.core.client import FuseeClient
from repro.core.events import CRASHED, OK
from repro.core.heap import DMConfig, DMPool
from repro.core.linearize import HOp, check_linearizable, records_to_hops
from repro.core.master import Master
from repro.core.sim import Scheduler
from repro.core.store import FuseeCluster

KINDS = ("insert", "update", "search", "delete")
_FAR_FUTURE = 10 ** 9


def _fresh(num_clients=4, r=3, num_mns=4):
    pool = DMPool(DMConfig(num_mns=num_mns, replication=r),
                  num_clients=num_clients)
    master = Master(pool)
    clients = [FuseeClient(i, pool) for i in range(num_clients)]
    sched = Scheduler(pool, master)
    for c in clients:
        sched.add_client(c)
    return pool, master, clients, sched


def _submit_random_mix(sched, clients, rng, keys, depth):
    """Fill every client's pipeline to ``depth`` with random ops over
    ``keys``; returns the submitted records."""
    recs, val = [], 100
    for c in clients:
        for _ in range(depth):
            kind = KINDS[int(rng.integers(len(KINDS)))]
            key = keys[int(rng.integers(len(keys)))]
            v = [val] if kind in ("insert", "update") else None
            val += 1
            recs.append(sched.submit(c.cid, kind, key, v))
    return recs


def _crashed_write_subsets_linearizable(hops, crashed_recs, initial):
    """A history with crashed writes is correct iff SOME subset of them can
    be treated as applied (resp = far future: a never-responding op may
    linearize anywhere after its invocation)."""
    writes = [r for r in crashed_recs
              if r.kind in ("insert", "update", "delete")]
    for n in range(len(writes) + 1):
        for sub in itertools.combinations(writes, n):
            extra = [HOp(op_id=r.op_id, kind=r.kind, inv=r.inv_tick,
                         resp=_FAR_FUTURE,
                         wrote=tuple(r.value) if r.value is not None else None,
                         read=None, status="OK")
                     for r in sub]
            if check_linearizable(hops + extra, initial=initial):
                return True
    return False


# ------------------------------------------------------------ random mixes --
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000), depth=st.integers(1, 5))
def test_random_mix_any_pipeline_depth_linearizable(seed, depth):
    """Mixed ops at random pipeline depths over one contended key, driven
    by a random interleaving, linearize per key."""
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=3)
    rec0 = sched.submit(clients[0].cid, "insert", 5, [1])
    sched.run_round_robin()
    assert rec0.result.status == OK
    _submit_random_mix(sched, clients, rng, keys=[5], depth=depth)
    sched.run_random(rng=rng)
    assert check_linearizable(records_to_hops(sched.history, 5), initial=None)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_random_mix_two_keys_linearizable_per_key(seed):
    """Per-key linearizability holds for each key of a two-key mix (ops on
    different keys interleave arbitrarily)."""
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=4)
    for k in (5, 6):
        sched.submit(clients[0].cid, "insert", k, [k])
    sched.run_round_robin()
    _submit_random_mix(sched, clients, rng, keys=[5, 6], depth=3)
    sched.run_random(rng=rng)
    for k in (5, 6):
        assert check_linearizable(records_to_hops(sched.history, k),
                                  initial=None), f"key {k} (seed={seed})"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000), r=st.integers(1, 4))
def test_random_mix_replication_sweep_linearizable(seed, r):
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=3, r=r,
                                          num_mns=max(4, r))
    sched.submit(clients[0].cid, "insert", 7, [1])
    sched.run_round_robin()
    _submit_random_mix(sched, clients, rng, keys=[7], depth=3)
    sched.run_random(rng=rng)
    assert check_linearizable(records_to_hops(sched.history, 7), initial=None)


# ------------------------------------------------------ crash during commit --
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000), steps=st.integers(0, 160))
def test_crash_during_commit_history_linearizable(seed, steps):
    """Crash a client at a random verb boundary (possibly mid-SNAPSHOT-
    commit, mid-doorbell-batch) with a pipeline of writes in flight,
    recover it via §5.3 (log traversal + redo), finish the survivors, and
    check the whole per-key history — completed ops exactly once, crashed
    ops at-most-once — linearizes."""
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=3)
    rec0 = sched.submit(clients[0].cid, "insert", 9, [1])
    sched.run_round_robin()
    assert rec0.result.status == OK
    # victim pipeline: 2 writes; survivor: mixed ops on the same key
    sched.submit(clients[1].cid, "update", 9, [20])
    sched.submit(clients[1].cid, "delete" if seed % 3 == 0 else "update",
                 9, None if seed % 3 == 0 else [21])
    sched.submit(clients[2].cid, "update", 9, [30])
    sched.submit(clients[2].cid, "search", 9)
    for _ in range(steps):                    # random partial execution
        cids = sched.eligible_cids()
        if not cids:
            break
        sched.step(cids[int(rng.integers(len(cids)))],
                   pick=int(rng.integers(4)))
    sched.crash_client(1)
    master.recover_client(1, reassign_to=clients[2])
    sched.run_random(rng=rng)                 # survivors finish
    # a fresh read observes the post-recovery state
    final = sched.submit(clients[2].cid, "search", 9)
    sched.run_round_robin()
    hops = records_to_hops(sched.history, 9)
    crashed = [r for r in sched.history
               if r.key == 9 and r.result is not None
               and r.result.status == CRASHED]
    assert _crashed_write_subsets_linearizable(hops, crashed, initial=None), \
        f"seed={seed} steps={steps} final={final.result}"


# --------------------------------------------- membership churn mid-history --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000), steps=st.integers(0, 120))
def test_random_mix_across_add_mn_cutover_linearizable(seed, steps):
    """A random mixed-op pipeline over one contended key stays per-key
    linearizable when an MN joins mid-history: shard migrations open a
    dual-write window under the in-flight ops and the epoch-bump cutover
    bounces their stale verbs — none of which may reorder, lose, or
    double-apply an acknowledged write."""
    rng = np.random.default_rng(seed)
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2, index_shards=4,
                               region_words=1 << 15, regions_per_mn=8),
                      num_clients=3, seed=seed)
    sched = cl.scheduler
    rec0 = sched.submit(0, "insert", 5, [1])
    sched.run_round_robin()
    assert rec0.result.status == OK
    clients = [cl.clients[c] for c in range(3)]
    _submit_random_mix(sched, clients, rng, keys=[5], depth=3)
    for _ in range(steps):                    # random partial execution
        cids = sched.eligible_cids()
        if not cids:
            break
        sched.step(cids[int(rng.integers(len(cids)))],
                   pick=int(rng.integers(4)))
    cl.add_mn(wait=False)                     # join mid-history
    sched.run_random(rng=rng)                 # survivors + migration finish
    if cl.migrator.busy:
        cl.migrator.drive()
    final = sched.submit(0, "search", 5)
    sched.run_round_robin()
    assert check_linearizable(records_to_hops(sched.history, 5),
                              initial=None), \
        f"seed={seed} steps={steps} final={final.result}"


# ------------------------------------------------- quiescent scan totality --
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 100_000), depth=st.integers(1, 4))
def test_quiescent_scan_contains_exactly_committed_keys(seed, depth):
    """The ordered-keydir contract (core/ordered.py): after a random
    mixed insert/update/delete/scan history quiesces, a scan of
    ``[start, end)`` returns EXACTLY the keys whose point reads succeed —
    every committed key appears, no deleted/uncommitted key does, in
    order, with the committed value."""
    rng = np.random.default_rng(seed)
    cl = FuseeCluster(DMConfig(num_mns=4, replication=2,
                               ordered_index=True, region_words=1 << 15,
                               regions_per_mn=16),
                      num_clients=3, seed=seed)
    sched = cl.scheduler
    keys = list(range(24))
    for k in keys[:12]:
        sched.submit(0, "insert", k, [k])
    sched.run_round_robin()
    kinds = ("insert", "update", "delete", "scan")
    val = 1000
    for c in range(3):
        for _ in range(depth):
            kind = kinds[int(rng.integers(len(kinds)))]
            key = keys[int(rng.integers(len(keys)))]
            if kind == "scan":
                sched.submit(c, "scan", key, 1 + int(rng.integers(12)))
            else:
                v = [val] if kind in ("insert", "update") else None
                val += 1
                sched.submit(c, kind, key, v)
    sched.run_random(rng=rng)          # random interleaving, then quiesce
    kv = cl.store(0)
    committed = {k: kv.get(k) for k in keys}
    live = sorted(k for k, v in committed.items() if v is not None)
    for start, end in ((0, 24), (5, 17), (11, 12), (23, 24)):
        res = kv.range(start, end)
        want = [k for k in live if start <= k < end]
        assert [k for k, _ in res] == want, \
            f"seed={seed} range[{start},{end}): {res} != {want}"
        for k, v in res:
            assert committed[k] == v, f"seed={seed} key={k}"


# ------------------------------------------------------- sanitizer coverage --
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000), depth=st.integers(1, 4),
       steps=st.integers(0, 120))
def test_random_mix_with_crash_is_race_free(seed, depth, steps):
    """The property domain — random mixes, random interleavings, a client
    crash at a random verb boundary + §5.3 recovery — runs under the
    verb-trace race detector with zero findings: every legal protocol race
    is scoped out by the rules, so anything flagged is a real bug."""
    from repro.analysis.races import detect, report
    from repro.analysis.trace import VerbTracer
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=3)
    tr = VerbTracer(capacity=1 << 14).attach(pool)
    sched.submit(clients[0].cid, "insert", 5, [1])
    sched.run_round_robin()
    _submit_random_mix(sched, clients, rng, keys=[5, 6], depth=depth)
    for _ in range(steps):                    # random partial execution
        cids = sched.eligible_cids()
        if not cids:
            break
        sched.step(cids[int(rng.integers(len(cids)))],
                   pick=int(rng.integers(4)))
    sched.crash_client(1)
    tr.set_master_ctx(sched.tick)             # recovery is master traffic
    master.recover_client(1, reassign_to=clients[2])
    sched.run_random(rng=rng)                 # survivors finish
    findings = detect(tr, scheduler=sched)
    assert findings == [], f"seed={seed} steps={steps}: " \
        + report(findings, tr)
