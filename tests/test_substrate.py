"""Substrate tests: optimizer math + int8 moments, schedules, gradient
compression, checkpoint atomicity/integrity/elasticity, sharding resolver,
HLO analysis differentials."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.launch.mesh import make_host_mesh
from repro.optim import (Moment, OptConfig, Optimizer, clip_by_global_norm,
                         global_norm, schedule)


# ------------------------------------------------------------- optimizer ---
def test_adamw_matches_reference_math():
    cfg = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                    min_lr_ratio=1.0)
    opt = Optimizer(cfg)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = opt.init(p)
    new_p, state, _ = opt.update(g, state, p)
    # manual: m = .1*g, v = .01*g^2; bias-corrected step = g/|g| elementwise
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    step = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.array([1.0, -2.0, 3.0]) - 0.1 * step,
                               rtol=1e-5)


def test_int8_moments_track_fp32_closely():
    k = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(k, (64, 256))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 0.01}
    cfg = dict(lr=1e-2, warmup_steps=0, total_steps=10**9, min_lr_ratio=1.0)
    o32 = Optimizer(OptConfig(moments="fp32", **cfg))
    o8 = Optimizer(OptConfig(moments="int8", **cfg))
    s32, s8 = o32.init(p), o8.init(p)
    p32, p8 = p, p
    for i in range(10):
        p32, s32, _ = o32.update(g, s32, p32)
        p8, s8, _ = o8.update(g, s8, p8)
    # aggregate tracking is what matters for 8-bit Adam: mean relative error
    # and update-direction cosine (isolated tiny-|g| elements may deviate —
    # inherent to blockwise linear quantization)
    diff = np.abs(np.asarray(p32["w"]) - np.asarray(p8["w"]))
    disp = np.abs(np.asarray(p32["w"]) - np.asarray(p["w"]))
    assert diff.mean() / disp.mean() < 0.05
    d32 = (np.asarray(p32["w"]) - np.asarray(p["w"])).ravel()
    d8 = (np.asarray(p8["w"]) - np.asarray(p["w"])).ravel()
    cos = np.dot(d32, d8) / (np.linalg.norm(d32) * np.linalg.norm(d8))
    assert cos > 0.99, f"update direction diverged: cos={cos:.4f}"
    assert s8["m"]["w"].value.dtype == jnp.int8


def test_lion_and_sgdm_step():
    for name in ("lion", "sgdm"):
        opt = Optimizer(OptConfig(name=name, lr=1e-2, warmup_steps=0,
                                  total_steps=10**9, min_lr_ratio=1.0,
                                  weight_decay=0.0))
        p = {"w": jnp.ones((4,))}
        g = {"w": jnp.ones((4,))}
        state = opt.init(p)
        new_p, state, _ = opt.update(g, state, p)
        assert float(new_p["w"][0]) < 1.0


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.array(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.array(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.array(110))) - 0.1) < 1e-6


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == 20.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_pod_compression_error_feedback_converges(seed):
    """int8-compressed mean with error feedback: running average of the
    compressed stream tracks the true mean (bias -> 0 over steps)."""
    from repro.optim.compress import _quant
    rng = np.random.default_rng(seed)
    g_true = rng.normal(size=(128,)).astype(np.float32) * 0.01
    err = np.zeros_like(g_true)
    acc_c, acc_t = np.zeros_like(g_true), np.zeros_like(g_true)
    for step in range(50):
        g = g_true + rng.normal(size=g_true.shape).astype(np.float32) * 1e-3
        x = g + err
        q, s = _quant(jnp.asarray(x))
        deq = np.asarray(q, np.float32) * np.asarray(s)
        err = x - deq
        acc_c += deq
        acc_t += g
    assert np.abs(acc_c - acc_t).max() / np.abs(acc_t).max() < 0.02


# ------------------------------------------------------------ checkpoint ---
def _tree():
    return {"a": jnp.arange(1024, dtype=jnp.float32).reshape(32, 32),
            "nested": {"b": jnp.ones((8,), jnp.bfloat16),
                       "c": jnp.array(7, jnp.int32)}}


def test_checkpoint_roundtrip_preserves_dtypes(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    loaded, step, _ = load_checkpoint(str(tmp_path), t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_commit_survives_partial_write(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash mid-save: a stale .tmp dir must be invisible
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "junk.npy").write_bytes(b"xx")
    loaded, step, _ = load_checkpoint(str(tmp_path), t)
    assert step == 1


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    # flip bytes in a shard file
    target = [f for f in os.listdir(path) if f.startswith("a")][0]
    fp = os.path.join(path, target)
    raw = bytearray(open(fp, "rb").read())
    raw[-8] ^= 0xFF
    open(fp, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), t)


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save unsharded, restore sharded onto a 2-device mesh (topology
    change across restart)."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    from jax.sharding import PartitionSpec as P
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, t)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    loaded, _, _ = load_checkpoint(str(tmp_path), t, mesh=mesh,
                                   specs={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.asarray(t["w"]))


# ------------------------------------------------------ sharding resolver --
def test_sharding_resolver_rules_and_fallbacks():
    from repro.models.sharding import BASELINE_RULES, ShardingResolver
    mesh = make_host_mesh((1, 1), ("data", "model"))
    res = ShardingResolver(mesh, BASELINE_RULES)
    # 1-device mesh: everything resolves to replicated specs without error
    spec = res.spec(("batch", None, "mlp"), (16, 4, 64))
    assert len(spec) == 3


def test_sharding_resolver_divisibility_fallback():
    import os
    from repro.models.sharding import BASELINE_RULES, ShardingResolver
    # force multi-"device" check via axis sizes in the virtual mesh if
    # available; on 1 device the fallback path is a no-op but must not raise
    mesh = make_host_mesh((1, 1), ("data", "model"))
    res = ShardingResolver(mesh, BASELINE_RULES)
    res.spec(("heads",), (15,))  # 15 never divides a >1 axis: falls back


# ----------------------------------------------------------- hlo analysis --
def test_hlo_analysis_scan_equals_unroll():
    from repro.launch.hlo_analysis import analyze
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def scanned(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), ()
        return jax.lax.scan(body, x, None, length=9)[0].sum()

    def unrolled(w, x):
        for _ in range(9):
            x = jnp.tanh(x @ w)
        return x.sum()

    cs = analyze(jax.jit(scanned).lower(w, x).compile().as_text())
    cu = analyze(jax.jit(unrolled).lower(w, x).compile().as_text())
    assert cs.flops == pytest.approx(cu.flops, rel=1e-6)
    assert cs.flops == pytest.approx(9 * 2 * 32 * 128 * 128, rel=1e-6)


def test_hlo_analysis_panel_discount():
    from repro.launch.hlo_analysis import analyze

    def f(q, k):
        return jnp.einsum("qd,sd->qs", q, k).sum()

    q = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    k = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    txt = jax.jit(f).lower(q, k).compile().as_text()
    raw = analyze(txt)
    kern = analyze(txt, panel_dims=[(256, 512)])
    assert kern.hbm_bytes < raw.hbm_bytes
    assert kern.hbm_bytes_raw == raw.hbm_bytes_raw
