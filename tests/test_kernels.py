"""Per-kernel correctness sweeps: Pallas (interpret mode) vs ref.py oracle
across shapes and dtypes, plus hypothesis property tests on race_lookup."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.kernels import (flash_attention, flash_attention_ref,
                           paged_attention, paged_attention_ref, race_lookup,
                           race_lookup_ref)
from repro.kernels.race_lookup.ref import bucket_pair, fingerprint
from repro.serving import slots_jax as SL


def tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,H,KV,Sq,Skv,hd,causal,dt", [
    (2, 4, 2, 256, 256, 64, True, jnp.float32),
    (1, 8, 8, 512, 512, 128, True, jnp.bfloat16),
    (2, 6, 2, 256, 512, 64, False, jnp.float32),
    (1, 2, 1, 128, 128, 128, True, jnp.bfloat16),
    (3, 3, 3, 128, 256, 64, False, jnp.bfloat16),
])
def test_flash_attention_matches_oracle(B, H, KV, Sq, Skv, hd, causal, dt):
    ks = jax.random.split(jax.random.PRNGKey(B * 7 + Sq), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dt)
    k = jax.random.normal(ks[1], (B, KV, Skv, hd), dt)
    v = jax.random.normal(ks[2], (B, KV, Skv, hd), dt)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_kv=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dt), rtol=tol(dt))


def test_flash_attention_block_shape_independent():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    outs = [flash_attention(q, k, v, block_q=bq, block_kv=bk)
            for bq, bk in [(128, 128), (256, 512), (512, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------- paged attention
@pytest.mark.parametrize("nb,tb,B,KV,H,hd,vl,dt", [
    (4, 128, 2, 2, 4, 64, 300, jnp.float32),
    (8, 256, 1, 8, 8, 128, 2000, jnp.bfloat16),
    (2, 128, 3, 1, 2, 64, 17, jnp.float32),
    (16, 128, 1, 4, 8, 128, 2048, jnp.bfloat16),
])
def test_paged_attention_matches_oracle(nb, tb, B, KV, H, hd, vl, dt):
    ks = jax.random.split(jax.random.PRNGKey(nb * 31 + B), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dt)
    kc = jax.random.normal(ks[1], (nb, tb, B, KV, hd), dt)
    vc = jax.random.normal(ks[2], (nb, tb, B, KV, hd), dt)
    out = paged_attention(q, kc, vc, jnp.array(vl))
    ref = paged_attention_ref(q, kc, vc, jnp.array(vl))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dt), rtol=tol(dt))


def test_paged_attention_masks_tail():
    """Garbage beyond valid_len must not affect the output."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 2, 64))
    kc = jax.random.normal(ks[1], (4, 64, 1, 2, 64))
    vc = jax.random.normal(ks[2], (4, 64, 1, 2, 64))
    out1 = paged_attention(q, kc, vc, jnp.array(100))
    kc2 = kc.at[2:].set(999.0)
    vc2 = vc.at[2:].set(-999.0)
    out2 = paged_attention(q, kc2, vc2, jnp.array(100))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# -------------------------------------------------------------- race lookup
def _build_index(keys, nb, spb, ptr_of):
    b1, _ = bucket_pair(jnp.asarray(keys, jnp.int32), nb)
    fp = fingerprint(jnp.asarray(keys, jnp.int32))
    index = np.zeros((nb, spb), np.int64)
    inserted = []
    for i, k in enumerate(keys):
        b = int(b1[i])
        for s in range(spb):
            if index[b, s] == 0:
                index[b, s] = (int(fp[i]) << 24) | ptr_of(i)
                inserted.append(i)
                break
    return jnp.asarray((index & 0xFFFFFFFF).astype(np.uint32)
                       .view(np.int32)), inserted


@pytest.mark.parametrize("nb,spb,n_keys", [(256, 8, 512), (1024, 4, 1024),
                                           (128, 16, 256)])
def test_race_lookup_kernel_matches_oracle(nb, spb, n_keys):
    keys = np.arange(1, n_keys + 1, dtype=np.int32)
    index, inserted = _build_index(keys, nb, spb, lambda i: i + 1)
    kj = jnp.asarray(keys)
    ptr, found = race_lookup(kj, index, block_keys=128)
    ptr_r, found_r = race_lookup_ref(kj, index)
    assert (np.asarray(ptr) == np.asarray(ptr_r)).all()
    assert (np.asarray(found) == np.asarray(found_r)).all()
    # every inserted key is found; the pointer is right except when an
    # 8-bit fingerprint collision shadows it (the paper resolves those by
    # verifying the key on the KV pair — done at the pool level)
    f = np.asarray(found)
    p = np.asarray(ptr)
    assert all(f[i] for i in inserted)
    exact = np.mean([p[i] == i + 1 for i in inserted])
    assert exact > 0.95, f"too many fp collisions: {exact:.3f}"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_race_lookup_no_false_negatives(seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(1 << 20, size=256, replace=False).astype(np.int32) + 1
    index, inserted = _build_index(keys, 128, 8, lambda i: i + 1)
    ptr, found = race_lookup_ref(jnp.asarray(keys), index)
    f = np.asarray(found)
    assert all(f[i] for i in inserted)


# ------------------------------------------------------------- leaf probe --
@pytest.mark.parametrize("n_starts,n_lows", [(256, 7), (512, 100), (128, 1)])
def test_leaf_probe_kernel_matches_oracle_and_numpy(n_starts, n_lows):
    """The ordered-index leaf probe: Pallas kernel (interpret mode), jnp
    oracle, and the numpy mirror (core.ordered.leaf_probe_np, a uint64
    searchsorted) must be bit-exact — including lows straddling the
    32-bit boundary, which exercises the hi/lo pair compare."""
    from repro.core.ordered import leaf_probe_np
    from repro.kernels.leaf_probe.kernel import leaf_probe_fwd
    from repro.kernels.leaf_probe.ref import leaf_probe_ref

    rng = np.random.default_rng(n_starts + n_lows)
    lows = np.sort(rng.choice(np.array(
        [0, 1, 5, (1 << 32) - 1, 1 << 32, (1 << 32) + 7, 1 << 40,
         (1 << 64) - 2], np.uint64), size=n_lows, replace=True))
    lows = np.unique(np.concatenate(
        [lows, rng.integers(0, 1 << 63, size=max(n_lows - len(lows), 1),
                            dtype=np.uint64)]))[:n_lows]
    lows = np.sort(lows)
    starts = rng.integers(0, 1 << 64, size=n_starts, dtype=np.uint64)
    starts[: len(lows)] = lows[: len(lows)]          # exact-hit edges
    want = leaf_probe_np(starts, lows)
    shi = jnp.asarray((starts >> 32).astype(np.uint32))
    slo = jnp.asarray((starts & 0xFFFFFFFF).astype(np.uint32))
    lhi = jnp.asarray((lows >> 32).astype(np.uint32))
    llo = jnp.asarray((lows & 0xFFFFFFFF).astype(np.uint32))
    got_ref = np.asarray(leaf_probe_ref(shi, slo, lhi, llo))
    got_k = np.asarray(leaf_probe_fwd(shi, slo, lhi, llo,
                                      block_keys=128, interpret=True))
    assert (got_ref == want).all()
    assert (got_k == want).all()


def test_leaf_probe_batch_entry_point():
    from repro.kernels import leaf_probe_batch
    lows = np.array([0, 10, 20, 30], np.uint64)
    starts = np.array([0, 5, 10, 29, 30, 31, 2 ** 63], np.uint64)
    got = leaf_probe_batch(starts, lows)
    assert got.tolist() == [0, 0, 1, 2, 3, 3, 3]


# ------------------------------------------------------- fleet-tick read --
@pytest.mark.parametrize("n_verbs,n", [(16, 1), (48, 7), (32, 16)])
def test_fleet_read_sweep_kernel_matches_numpy(n_verbs, n):
    """The fused-tick READ sweep device twin: Pallas kernel (interpret
    mode, scalar-prefetched cell routing), jnp oracle, and the numpy
    entry point must be bit-exact on uint64 slab words — including words
    straddling the 32-bit boundary (the hi/lo split)."""
    from repro.kernels.fleet_tick.kernel import fleet_read_fwd
    from repro.kernels.fleet_tick.ref import fleet_read_ref
    from repro.kernels.fleet_tick import fleet_read_sweep

    rng = np.random.default_rng(n_verbs * 31 + n)
    n_cells, region_words = 6, 64
    slab = rng.integers(0, 1 << 64, size=n_cells * region_words,
                        dtype=np.uint64)
    slab[::7] = (1 << 32) - 1                        # hi/lo boundary words
    slab[::11] = 1 << 32
    cells = rng.integers(0, n_cells, size=n_verbs).astype(np.int64)
    offs = rng.integers(0, region_words - n + 1,
                        size=n_verbs).astype(np.int64)
    slab2d = slab.reshape(n_cells, region_words)
    want = slab2d[cells[:, None], offs[:, None] + np.arange(n)]

    got_np = fleet_read_sweep(slab, region_words, cells, offs, n,
                              prefer_kernel=False)
    assert (got_np == want).all()
    hi = jnp.asarray((slab2d >> np.uint64(32)).astype(np.uint32))
    lo = jnp.asarray((slab2d & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    ci = jnp.asarray(cells, jnp.int32)
    oi = jnp.asarray(offs, jnp.int32)
    for rhi, rlo in (fleet_read_ref(hi, lo, ci, oi, n=n),
                     fleet_read_fwd(hi, lo, ci, oi, n=n, interpret=True)):
        got = (np.asarray(rhi, np.uint64) << np.uint64(32)) \
            | np.asarray(rlo, np.uint64)
        assert (got == want).all()


def test_fleet_read_sweep_matches_pool_sweep():
    """The device twin gathers the same rows the pool's fused read sweep
    returns for uniform-length verbs on a live cluster slab."""
    from repro.core import FuseeCluster, DMConfig
    from repro.kernels.fleet_tick import fleet_read_sweep

    cl = FuseeCluster(DMConfig(), num_clients=4, seed=3)
    for c in range(4):
        for k in range(6):
            cl.scheduler.submit(c, "insert", 10 * c + k, [c, k, 7])
    cl.fleet().run()
    pool = cl.pool
    table = pool.placement
    regions = np.array([g for g in sorted(table) for _ in (0, 1)][:8],
                       np.int64)
    replicas = np.zeros(len(regions), np.int64)
    offs = np.arange(len(regions), dtype=np.int64)
    n = 3
    want = pool._fused_read_sweep(regions, replicas, offs,
                                  np.full(len(regions), n, np.int64))
    cells, _mids = pool._fused_cells(regions, replicas)
    got = fleet_read_sweep(pool.slab.buf, pool.slab.region_words,
                           cells, offs, n, prefer_kernel=False)
    for w, g in zip(want, got):
        assert (np.asarray(w) == g).all()


# ------------------------------------------------------ slot packing twin --
@settings(max_examples=50, deadline=None)
@given(fp=st.integers(1, 255), ptr=st.integers(0, (1 << 24) - 1))
def test_slot_packing_jax_numpy_twin(fp, ptr):
    sj = SL.pack_slot(jnp.int32(fp), jnp.int32(ptr))
    sn = SL.pack_slot_np(fp, ptr)
    assert int(sj) == int(sn)
    assert int(SL.slot_fp(sj)) == fp == int(SL.slot_fp_np(sn))
    assert int(SL.slot_ptr(sj)) == ptr == int(SL.slot_ptr_np(sn))


# ------------------------------------------------ sLSTM deferred-VJP -------
def test_slstm_custom_vjp_matches_autodiff():
    """The deferred-reduction sLSTM VJP (§Perf cell 3) must be gradient-
    exact vs plain autodiff through the same scan."""
    from repro.models import xlstm as X
    from repro.models.common import ParamBuilder, split_tree

    def plain_seq_loss(p, x):
        B, S, D = x.shape
        st = X.init_slstm_state(B, D)
        xin = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))

        def step(st, xt):
            st2 = X._slstm_cell(p, xt, st)
            return st2, st2.h

        _, hs = jax.lax.scan(step, st, jnp.moveaxis(xin, 1, 0))
        y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
        y = X.rms_norm(y, p["norm"])
        y = jax.nn.gelu(jnp.einsum("bsd,dp->bsp", y, p["up"].astype(x.dtype)))
        return jnp.einsum("bsp,pd->bsd", y, p["down"].astype(x.dtype)).sum()

    pb = ParamBuilder(jax.random.PRNGKey(0), False, jnp.float32)
    p, _ = split_tree(X.make_slstm_params(pb, 64, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    g_plain = jax.grad(lambda p: plain_seq_loss(p, x))(p)
    g_vjp = jax.grad(lambda p: X.slstm_seq(p, x)[0].sum())(p)
    for k in g_plain:
        np.testing.assert_allclose(np.asarray(g_plain[k]),
                                   np.asarray(g_vjp[k]),
                                   rtol=2e-5, atol=2e-5)
