"""Codec edge cases (core/codec.py): empty values, maximum-size keys and
values, non-UTF8 byte keys — all round-tripped through the real store —
and typed ``CodecError`` on malformed/ambiguous inputs."""
import numpy as np
import pytest

from repro.core import CodecError, DMConfig, FuseeCluster, codec
from repro.core import layout as L
from repro.core.events import FULL, OK

# Largest byte payload that fits the biggest slab object of the default
# block geometry: the object (header 2w + value + log 3w) must fit the
# largest power-of-two size class not exceeding the block payload, and the
# codec spends one word on the length header.
_CFG = DMConfig()
_MAX_SC_WORDS = 1 << (L.MIN_OBJ_WORDS - 1).bit_length()
while _MAX_SC_WORDS * 2 <= _CFG.block_payload_words:
    _MAX_SC_WORDS *= 2
MAX_VALUE_BYTES = (_MAX_SC_WORDS - L.HDR_WORDS - L.LOG_WORDS - 1) * 8


def _store():
    return FuseeCluster(DMConfig(num_mns=4, replication=2),
                        num_clients=1).store(0)


# ------------------------------------------------------------ empty values --
def test_empty_value_roundtrip():
    words = codec.encode_value(b"")
    assert codec.decode_value(words) == b""
    assert codec.decode_value(codec.encode_value("")) == b""


def test_empty_value_through_store():
    kv = _store()
    assert kv.put(b"k", b"").status == OK
    assert kv.get(b"k") == b""                 # empty bytes, not None/missing
    assert kv.get(b"absent") is None


# --------------------------------------------------------- maximum sizes ----
def test_max_size_value_roundtrip_through_store():
    kv = _store()
    big = bytes(range(256)) * (MAX_VALUE_BYTES // 256 + 1)
    big = big[:MAX_VALUE_BYTES]
    assert MAX_VALUE_BYTES == 2000             # pin the default geometry
    assert kv.put(b"big", big).status == OK
    assert kv.get(b"big") == big


def test_oversized_value_reports_full_not_corruption():
    kv = _store()
    r = kv.put(b"too-big", b"x" * (MAX_VALUE_BYTES + 8))
    assert r.status == FULL                    # typed outcome, no crash
    assert kv.get(b"too-big") is None


def test_max_size_keys_roundtrip():
    kv = _store()
    k64k = b"\x00\xffkey" * (1 << 14)          # 64 KiB key, hashed to 64 bits
    assert kv.put(k64k, b"v").status == OK
    assert kv.get(k64k) == b"v"
    assert kv.get(k64k[:-1]) is None           # prefix is a different key


# -------------------------------------------------------- non-UTF8 keys -----
def test_non_utf8_byte_keys_roundtrip():
    kv = _store()
    keys = [b"\xff\xfe\xfd", b"\x80tail", b"nul\x00mid", bytes(range(256))]
    for i, k in enumerate(keys):
        assert kv.put(k, bytes([i]) * 3).status == OK
    for i, k in enumerate(keys):
        assert kv.get(k) == bytes([i]) * 3
    # bytes keys are NOT utf-8 decoded: b"\xc3\xa9" != "é" would be the
    # same key if they were; encode_key treats str as its utf-8 bytes
    assert codec.encode_key("é") == codec.encode_key("é".encode())
    assert codec.encode_key(b"\xc3\xa9") == codec.encode_key("é")
    assert codec.encode_key(b"\xe9") != codec.encode_key("é")


# ------------------------------------------------------------ typed errors --
def test_bad_key_type_raises_codec_error():
    with pytest.raises(CodecError):
        codec.encode_key(3.14)
    with pytest.raises(CodecError):
        codec.encode_key(["not", "a", "key"])
    assert issubclass(CodecError, TypeError)   # legacy except clauses work
    assert issubclass(CodecError, ValueError)


def test_ambiguous_raw_word_list_raises_codec_error():
    tagged_like = [(codec.VALUE_TAG << 48) | 3, 0x636261]
    with pytest.raises(CodecError):
        codec.encode_value(tagged_like)


def test_malformed_tag_strict_decode_raises():
    # tag present but the length field disagrees with the word count
    bad_len = [(codec.VALUE_TAG << 48) | 3]
    with pytest.raises(CodecError):
        codec.decode_value(bad_len, strict=True)
    # tag present but nonzero padding beyond the stated length
    bad_pad = [(codec.VALUE_TAG << 48) | 1, 2 ** 63]
    with pytest.raises(CodecError):
        codec.decode_value(bad_pad, strict=True)
    # default (lenient) mode keeps the legacy raw-word-list fallback
    assert codec.decode_value(bad_len) == bad_len
    assert codec.decode_value(bad_pad) == bad_pad
    # well-formed tags decode identically in both modes
    words = codec.encode_value(b"abc")
    assert codec.decode_value(words, strict=True) == b"abc"


def test_untagged_words_pass_strict_decode():
    assert codec.decode_value([1, 2, 3], strict=True) == [1, 2, 3]
    assert codec.decode_value(None, strict=True) is None


# -------------------------------------------------- scan start-key edges ----
def _ordered_store():
    return FuseeCluster(DMConfig(num_mns=4, replication=2,
                                 ordered_index=True),
                        num_clients=1).store(0)


def test_scan_with_64kib_and_non_utf8_start_keys():
    """SCAN start keys go through the same codec boundary as every other
    key: 64 KiB byte strings and non-UTF8 bytes hash into the ordered
    64-bit key space, and the scan starts at that hashed position."""
    kv = _ordered_store()
    keys = [b"\xff\xfe\xfd", b"nul\x00mid", b"\x00\xffkey" * (1 << 14)]
    for i, k in enumerate(keys):
        assert kv.put(k, bytes([i + 1]) * 2).status == OK
    enc = sorted(codec.encode_key(k) for k in keys)
    # scanning from 0 sees all three, in hashed-key order
    res = kv.scan(0, 10)
    assert [k for k, _ in res] == enc
    # a 64 KiB start key scans from ITS hashed position
    k64k = keys[2]
    res = kv.scan(k64k, 10)
    assert [k for k, _ in res] == \
        [e for e in enc if e >= codec.encode_key(k64k)]
    # range between two byte keys honors the [start, end) bound
    lo, hi = sorted(codec.encode_key(k) for k in keys[:2])
    res = kv.range(lo, hi)
    assert [k for k, _ in res] == [e for e in enc if lo <= e < hi]


def test_scan_boundary_start_keys():
    kv = _ordered_store()
    for k in (0, 1, 2 ** 63, 2 ** 64 - 2):
        assert kv.put(k, [1]).status == OK
    assert [k for k, _ in kv.scan(0, 10)] == [0, 1, 2 ** 63, 2 ** 64 - 2]
    assert [k for k, _ in kv.scan(2 ** 63, 10)] == [2 ** 63, 2 ** 64 - 2]
    assert [k for k, _ in kv.range(1, 2 ** 63)] == [1]
    assert kv.range(2 ** 64 - 1, 2 ** 64 - 1) == []
