"""Unit + property tests for the FUSEE core protocol (SNAPSHOT, RACE index,
two-level allocation, embedded log)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.core import layout as L
from repro.core import race
from repro.core.api import Op
from repro.core.client import FuseeClient, evaluate_rules_pure, R1, R2, LOSE, FAILV
from repro.core.events import OK, NOT_FOUND
from repro.core.heap import DMConfig, DMPool, INDEX_REGION
from repro.core.linearize import check_linearizable, records_to_hops
from repro.core.master import Master
from repro.core.sim import Scheduler
from repro.core.store import FuseeCluster


# ---------------------------------------------------------------- layout ----
def test_slot_packing_roundtrip():
    for fp, sc, ptr in [(1, 0, 0), (255, 7, (1 << 48) - 1), (17, 3, 123456789)]:
        s = L.pack_slot(fp, sc, ptr)
        assert L.slot_fp(s) == fp
        assert L.slot_size_class(s) == sc
        assert L.slot_ptr(s) == ptr


@given(st.integers(0, (1 << 20) - 2), st.integers(0, (1 << 28) - 1))
def test_ptr_packing_roundtrip(region, off):
    p = L.pack_ptr(region, off)
    assert L.ptr_region(p) == region
    assert L.ptr_offset(p) == off


@given(st.integers(min_value=0, max_value=2**63 - 1),
       st.lists(st.integers(0, 2**63 - 1), max_size=6),
       st.integers(0, (1 << 48) - 1), st.integers(0, (1 << 48) - 1),
       st.sampled_from([L.OPCODE_INSERT, L.OPCODE_UPDATE, L.OPCODE_DELETE]))
def test_object_roundtrip(key, value, nxt, prv, opcode):
    words, sc = L.build_object(key, value, nxt, prv, opcode)
    assert len(words) == L.size_class_words(sc)
    obj = L.parse_object(words)
    assert obj["key"] == key
    assert obj["value"] == [v & 0xFFFFFFFFFFFFFFFF for v in value]
    assert obj["next_ptr"] == nxt
    assert obj["prev_ptr"] == prv
    assert obj["opcode"] == opcode
    assert obj["used"] and not obj["invalid"] and obj["crc_ok"]
    assert int(obj["old_value"]) == 0  # uncommitted


def test_fingerprint_nonzero():
    assert all(L.fingerprint(k) != 0 for k in range(1000))


# ------------------------------------------------------------ rule eval -----
def test_rule1_unanimous_win():
    assert evaluate_rules_pure([5, 5, 5], v_new=5) == R1


def test_rule1_unanimous_lose():
    assert evaluate_rules_pure([7, 7, 7], v_new=5) == LOSE


def test_rule2_majority():
    assert evaluate_rules_pure([5, 5, 9], v_new=5) == R2
    assert evaluate_rules_pure([5, 5, 9], v_new=9) == LOSE


def test_rule3_needs_check():
    assert evaluate_rules_pure([5, 9], v_new=5) == "NEED_CHECK"
    assert evaluate_rules_pure([5, 9], v_new=9) == "NEED_CHECK"


def test_absent_value_loses():
    assert evaluate_rules_pure([5, 9, 13], v_new=7) == LOSE


def test_fail_propagates():
    assert evaluate_rules_pure([5, None, 5], v_new=5) == FAILV


# ----------------------------------------------------------- race index -----
def test_bucket_pair_distinct():
    for k in range(500):
        b1, b2 = race.bucket_pair(k, 64)
        assert b1 != b2


# -------------------------------------------------------- basic KV ops ------
@pytest.fixture
def cluster():
    return FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=4)


def test_rtt_counts_match_paper(cluster):
    kv = cluster.store(0)
    kv.insert(1, [10])               # warm up block allocation
    r = kv.insert(2, [20])
    assert r.rtts == 4, "conflict-free INSERT must be 4 RTTs (Fig 9)"
    r = kv.update(2, [21])
    assert r.rtts == 4, "conflict-free UPDATE must be 4 RTTs (Fig 9)"
    r = kv.submit(Op.get(2)).result()
    assert r.rtts == 1, "cache-hit SEARCH must be 1 RTT (Fig 9)"
    kv2 = cluster.store(1)
    r = kv2.submit(Op.get(2)).result()
    assert r.rtts == 2, "cache-miss SEARCH must be 2 RTTs (Fig 9)"


def test_insert_search_update_delete(cluster):
    kv = cluster.store(0)
    assert kv.insert(5, [1, 2]).status == OK
    assert kv.get(5) == [1, 2]
    assert kv.update(5, [3]).status == OK
    assert kv.get(5) == [3]
    assert kv.delete(5).status == OK
    assert kv.submit(Op.get(5)).result().status == NOT_FOUND
    assert kv.update(5, [9]).status == NOT_FOUND
    assert kv.delete(5).status == NOT_FOUND


def test_cross_client_visibility(cluster):
    kv0, kv1 = cluster.store(0), cluster.store(1)
    kv0.insert(100, [7])
    assert kv1.get(100) == [7]
    kv1.update(100, [8])
    assert kv0.get(100) == [8]  # kv0's cache must detect invalidation


def test_many_keys_many_clients(cluster):
    stores = [cluster.store(i) for i in range(4)]
    for k in range(200):
        assert stores[k % 4].insert(k, [k]).status == OK
    for k in range(200):
        assert stores[(k + 1) % 4].get(k) == [k]


def test_replica_consistency_after_ops(cluster):
    kv = cluster.store(0)
    for k in range(50):
        kv.insert(k, [k * 2])
    for k in range(0, 50, 2):
        kv.update(k, [k * 3])
    pool = cluster.pool
    reps = pool.placement[INDEX_REGION]
    arrays = [pool.mns[m].regions[INDEX_REGION] for m in reps]
    for a in arrays[1:]:
        assert np.array_equal(arrays[0], a), "index replicas diverged at rest"


# ------------------------------------------------- concurrent write races ---
def _fresh(num_clients=4, r=3, num_mns=4):
    cfg = DMConfig(num_mns=num_mns, replication=r)
    pool = DMPool(cfg, num_clients=num_clients)
    master = Master(pool)
    clients = [FuseeClient(i, pool) for i in range(num_clients)]
    sched = Scheduler(pool, master)
    for c in clients:
        sched.add_client(c)
    return pool, master, clients, sched


def _seed_key(sched, clients, key, value):
    rec = sched.submit(clients[0].cid, "insert", key, value)
    sched.run_round_robin()
    assert rec.result.status == OK


def _read_key_direct(pool, key):
    """Read a key's committed value straight from the heap (test oracle)."""
    cfg = pool.cfg
    for off in race.slot_offsets(key, cfg.index_buckets, cfg.slots_per_bucket):
        w = pool.read(INDEX_REGION, 0, off, 1)
        if w is None or int(w[0]) == 0:
            continue
        s = int(w[0])
        if L.slot_fp(s) != L.fingerprint(key):
            continue
        ptr, sc = L.slot_ptr(s), L.slot_size_class(s)
        raw = pool.read(L.ptr_region(ptr), 0, L.ptr_offset(ptr),
                        L.size_class_words(sc))
        if raw is None:
            continue
        obj = L.parse_object(list(raw))
        if obj["key"] == key:
            return obj["value"]
    return None


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), n_writers=st.integers(2, 4))
def test_concurrent_updates_linearizable(seed, n_writers):
    pool, master, clients, sched = _fresh(num_clients=n_writers + 1)
    _seed_key(sched, clients, 42, [0])
    recs = []
    for i in range(n_writers):
        recs.append(sched.submit(clients[i + 1].cid, "update", 42, [100 + i]))
    sched.run_random(rng=np.random.default_rng(seed))
    assert all(r.result.status == OK for r in recs)
    # all index replicas converge
    reps = pool.placement[INDEX_REGION]
    arrays = [pool.mns[m].regions[INDEX_REGION] for m in reps]
    for a in arrays[1:]:
        assert np.array_equal(arrays[0], a)
    # final value is one of the writers' values
    final = _read_key_direct(pool, 42)
    assert final in [[100 + i] for i in range(n_writers)]
    # history is linearizable and consistent with the final state: append a
    # virtual read that happened after everything completed
    hops = records_to_hops(sched.history, 42)
    from repro.core.linearize import HOp
    hops.append(HOp(op_id=10_000, kind="search", inv=sched.tick + 1,
                    resp=sched.tick + 2, wrote=None, read=tuple(final),
                    status=OK))
    assert check_linearizable(hops, initial=(0,))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_mixed_ops_linearizable(seed):
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=5)
    _seed_key(sched, clients, 7, [1])
    kinds = ["update", "search", "delete", "insert"]
    recs = []
    for i in range(4):
        kind = kinds[int(rng.integers(len(kinds)))]
        val = [int(rng.integers(1000)) + 2] if kind in ("update", "insert") else None
        recs.append(sched.submit(clients[i + 1].cid, kind, 7, val))
    sched.run_random(rng=rng)
    hops = records_to_hops(sched.history, 7)
    assert check_linearizable(hops, initial=None)  # includes the seeding insert
    reps = pool.placement[INDEX_REGION]
    arrays = [pool.mns[m].regions[INDEX_REGION] for m in reps]
    for a in arrays[1:]:
        assert np.array_equal(arrays[0], a)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), r=st.integers(1, 4))
def test_replication_factor_sweep(seed, r):
    pool, master, clients, sched = _fresh(num_clients=3, r=r, num_mns=max(4, r))
    _seed_key(sched, clients, 11, [0])
    recs = [sched.submit(clients[1].cid, "update", 11, [1]),
            sched.submit(clients[2].cid, "update", 11, [2])]
    sched.run_random(rng=np.random.default_rng(seed))
    assert all(rec.result.status == OK for rec in recs)
    hops = records_to_hops(sched.history, 11)
    assert check_linearizable(hops)


# ----------------------------------------------------- allocator invariants -
def test_no_double_allocation():
    pool, master, clients, sched = _fresh(num_clients=3)
    seen = set()
    for i, c in enumerate(clients):
        for k in range(60):
            rec = sched.submit(c.cid, "insert", 1000 * i + k, [k])
            sched.run_round_robin()
            assert rec.result.status == OK
    # all allocated objects distinct (via slot pointers)
    reps = pool.placement[INDEX_REGION]
    arr = pool.mns[reps[0]].regions[INDEX_REGION]
    ptrs = [L.slot_ptr(int(w)) for w in arr if int(w) != 0]
    assert len(ptrs) == len(set(ptrs)) == 180


def test_block_ownership_recorded():
    pool, master, clients, sched = _fresh(num_clients=2)
    rec = sched.submit(clients[1].cid, "insert", 1, [1])
    sched.run_round_robin()
    owners = set()
    for g in range(2, pool.num_regions):
        mem = pool.mns[pool.primary_mn(g)].regions[g]
        for b in range(pool.cfg.blocks_per_region):
            if int(mem[b]) != 0:
                owners.add(int(mem[b]) - 1)
    assert owners == {clients[1].cid}


def test_free_and_reclaim_reuses_memory():
    cfg = DMConfig(num_mns=4, replication=2)
    cl = FuseeCluster(cfg, num_clients=1)
    kv = cl.store(0)
    for k in range(20):
        kv.insert(k, [k])
    for k in range(20):
        kv.update(k, [k + 1])   # frees 20 old objects
    before = sum(len(s.free) for s in cl.clients[0].slab.values())
    r = kv.reclaim()
    after = sum(len(s.free) for s in cl.clients[0].slab.values())
    assert r.value[0] >= 20
    assert after >= before + 20
    # reclaimed objects must be reusable without corruption
    for k in range(20, 60):
        assert kv.insert(k, [k]).status == OK
    for k in range(60):
        expect = [k + 1] if k < 20 else [k]
        assert kv.get(k) == expect
