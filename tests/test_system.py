"""End-to-end system tests: per-arch smoke (forward + train step on reduced
configs), prefill/decode consistency, trainer learning + fault drill,
checkpoint atomicity + elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as C
from repro.data import DataConfig, SyntheticLM
from repro.models import build, param_stats
from repro.optim import OptConfig, Optimizer
from repro.train import TrainConfig, Trainer, make_train_step


def mesh1():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh((1, 1), ("data", "model"))


def tiny_batch(cfg, model, B=2, S=32, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model),
                                   model.dtype) * 0.01
    return batch


# ------------------------------------------------- per-arch smoke tests ----
@pytest.mark.parametrize("arch", C.all_archs())
def test_arch_smoke_forward_and_train_step(arch):
    cfg = C.reduced(C.get(arch))
    model = build(cfg, mesh1())
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, model)
    logits = jax.jit(model.forward)(params, batch["tokens"],
                                    batch.get("frames"))
    assert logits.shape == (2, 32, model.vocab_p)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one real train step
    opt = Optimizer(OptConfig(lr=1e-3))
    step = jax.jit(make_train_step(model, opt))
    state = {"params": params, "opt": opt.init(params)}
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], state2["params"]))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ["llama3-8b", "kimi-k2-1t-a32b",
                                  "jamba-1.5-large-398b", "xlstm-350m",
                                  "whisper-medium", "qwen3-32b"])
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    cfg = C.reduced(C.get(arch))
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build(cfg, mesh1())
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    frames = (jnp.ones((B, cfg.enc_seq, cfg.d_model), model.dtype) * 0.01
              if cfg.enc_dec else None)
    full = model._forward_mode(params, tokens, "train", frames=frames)
    lg, cache = model.prefill(params, tokens[:, :S - 1], frames=frames)
    lg2, cache2 = model.decode_step(params, cache, tokens[:, S - 1:S])
    a = np.asarray(full[:, S - 2], np.float32)
    b = np.asarray(lg[:, 0], np.float32)
    c = np.asarray(full[:, S - 1], np.float32)
    d = np.asarray(lg2[:, 0], np.float32)
    scale = np.abs(a).max() + 1e-9
    assert np.abs(a - b).max() / scale < 2e-2, "prefill != forward"
    assert np.abs(c - d).max() / scale < 2e-2, "decode != forward"
    assert int(cache2["length"]) == S


def test_param_stats_sane():
    m = build(C.reduced(C.get("kimi-k2-1t-a32b")), mesh1())
    st = param_stats(m)
    assert st["active"] < st["total"]  # MoE: active strictly less
    assert st["non_embed"] > 0


# ----------------------------------------------------- training substrate --
def _make_trainer(tmp_path, n_micro=1, arch="smollm-360m"):
    cfg = C.reduced(C.get(arch))
    model = build(cfg, mesh1())
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, seed=0))
    tcfg = TrainConfig(n_micro=n_micro, ckpt_every=5,
                       ckpt_dir=str(tmp_path / "ckpt"))
    return Trainer(model, OptConfig(lr=3e-3, warmup_steps=5,
                                    total_steps=60), tcfg, data)


def test_training_loss_decreases(tmp_path):
    tr = _make_trainer(tmp_path)
    tr.init_state(jax.random.PRNGKey(0))
    losses = tr.run(30)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, f"no learning: {first:.3f} -> {last:.3f}"


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    cfg = C.reduced(C.get("llama3-8b"))
    model = build(cfg, mesh1())
    opt = Optimizer(OptConfig(lr=1e-3))
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, model, B=4)
    s1 = jax.jit(make_train_step(model, opt, n_micro=1))
    s4 = jax.jit(make_train_step(model, opt, n_micro=4))
    st1, m1 = s1({"params": params, "opt": opt.init(params)}, batch)
    st4, m4 = s4({"params": params, "opt": opt.init(params)}, batch)
    # same grads up to reduction order => same loss & new params
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        st1["params"], st4["params"]))
    assert max(diffs) < 5e-2


def test_fault_drill_recovers_and_finishes(tmp_path):
    tr = _make_trainer(tmp_path)
    tr.init_state(jax.random.PRNGKey(0))
    losses, recovered = tr.run_with_recovery(16, fail_at=12)
    assert recovered
    assert tr.step == 16
    assert tr.ckpt.latest() is not None


def test_straggler_watchdog_flags_outliers():
    from repro.train import StragglerWatchdog
    wd = StragglerWatchdog(factor=3.0)
    for i in range(20):
        wd.record(i, 0.1)
    wd.record(20, 1.0)
    assert 20 in wd.straggler_steps
    assert wd.summary()["stragglers"] == 1


# --------------------------------------------------------------- datasets --
def test_synthetic_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    s0 = SyntheticLM(cfg, shard_id=0, num_shards=2).batch_at(7)
    s1 = SyntheticLM(cfg, shard_id=1, num_shards=2).batch_at(7)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
