"""Ordered secondary index (core/ordered.py): scan/range correctness,
leaf splits under concurrency, crash-mid-split repair, migration cutover,
fleet-wide batched locates, the serving twin, and the seeded scan-storm
acceptance invariants (no acked insert lost, no torn scans, bit-identical
same-seed replay)."""
import os

import numpy as np
import pytest

from repro.core import (CRASHED, OK, DMConfig, FaultPlan, FuseeCluster, Op,
                        OrderedIndexDisabled, codec, ordered)
from repro.core.api import SimBackend
from repro.core.events import NOT_FOUND

CFG = dict(num_mns=4, replication=3, ordered_index=True,
           region_words=1 << 15, regions_per_mn=16)


def _cluster(num_clients=2, seed=0, mn_detect_delay=0, **over):
    return FuseeCluster(DMConfig(**{**CFG, **over}), num_clients=num_clients,
                        seed=seed, mn_detect_delay=mn_detect_delay)


def _sound(res, start, end=None):
    """A scan result is well-formed: sorted, deduped, within range."""
    keys = [k for k, _v in res]
    assert keys == sorted(set(keys))
    assert all(k >= start for k in keys)
    if end is not None:
        assert all(k < end for k in keys)


# ----------------------------------------------------------- basic scans --
def test_scan_returns_ordered_keys_and_values():
    kv = _cluster().store(0)
    for k in range(60):
        assert kv.insert(k, [k * 3]).status == OK
    res = kv.scan(10, 20)
    assert [k for k, _ in res] == list(range(10, 30))
    assert all(v == [k * 3] for k, v in res)
    assert [k for k, _ in kv.range(40, 45)] == list(range(40, 45))


def test_scan_count_clips_at_end_of_keyspace():
    kv = _cluster().store(0)
    for k in range(10):
        kv.insert(k, [k])
    assert [k for k, _ in kv.scan(7, 50)] == [7, 8, 9]
    assert kv.scan(100, 5) == []


def test_empty_range_and_inverted_range():
    kv = _cluster().store(0)
    for k in range(10):
        kv.insert(k, [k])
    assert kv.range(5, 5) == []
    assert kv.range(7, 3) == []
    assert kv.range(100, 200) == []


def test_delete_removes_from_scans_update_keeps():
    kv = _cluster().store(0)
    for k in range(30):
        kv.insert(k, [k])
    kv.delete(11)
    kv.update(12, [999])
    res = kv.scan(10, 4)
    assert [k for k, _ in res] == [10, 12, 13, 14]
    assert dict(res)[12] == [999]


def test_scan_through_op_future_surface():
    kv = _cluster().store(0)
    for k in range(20):
        kv.insert(k, bytes([k]) * 3)
    r = kv.submit(Op.scan(5, 4)).result()
    assert r.status == OK
    assert [(k, v) for k, v in r.value] == [
        (k, bytes([k]) * 3) for k in range(5, 9)]
    r = kv.submit(Op.range(5, 8)).result()
    assert [k for k, _ in r.value] == [5, 6, 7]


def test_scan_disabled_raises_typed():
    cl = FuseeCluster(DMConfig(num_mns=2), num_clients=1)
    with pytest.raises(OrderedIndexDisabled):
        cl.store(0).scan(0, 4)


def test_byte_keys_scan_in_hashed_order():
    kv = _cluster().store(0)
    keys = [b"\xff\xfe", b"user:1", "caf\xe9", b"\x00" * 100]
    for i, k in enumerate(keys):
        assert kv.put(k, bytes([i + 1])).status == OK
    res = kv.scan(0, 10)
    assert [k for k, _ in res] == sorted(codec.encode_key(k) for k in keys)


# ----------------------------------------------- splits under concurrency --
def test_many_keys_force_splits_scan_complete():
    cl = _cluster()
    kv = cl.store(0)
    n = 200          # >> 13 entries/leaf: many splits
    for k in range(n):
        assert kv.insert(k, [k]).status == OK
    res = kv.scan(0, n)
    assert [k for k, _ in res] == list(range(n))
    # keydir whitebox agrees
    assert set(ordered.ordered_keys_direct(cl.pool)) >= set(range(n))


@pytest.mark.parametrize("seed", [1, 7, 23])
def test_scan_spanning_split_in_flight(seed):
    """A scan racing concurrent inserts (which split leaves under it) is a
    sound snapshot: sorted/deduped/in-range, contains every key committed
    BEFORE the scan began, and every value it returns was committed."""
    rng = np.random.default_rng(seed)
    cl = _cluster(num_clients=3, seed=seed)
    sched = cl.scheduler
    pre = 26         # two full leaves
    for k in range(pre):
        sched.submit(0, "insert", k, [k])
    sched.run_round_robin()
    scan_rec = sched.submit(0, "scan", 0, 500)
    for c, k in ((1, 100), (2, 101), (1, 13), (2, 14)):
        sched.submit(c, "insert", 300 + k, [k])
    for k in range(40):  # enough inserts to force splits mid-scan
        sched.submit(1 + k % 2, "insert", pre + k, [pre + k])
    sched.run_random(rng=rng)
    res = scan_rec.result
    assert res.status == OK
    _sound(res.value, 0)
    got = dict(res.value)
    for k in range(pre):
        assert k in got and got[k] == [k], f"pre-scan key {k} missing"


def test_naive_and_batched_scans_agree():
    cl = _cluster()
    kv = cl.store(0)
    for k in range(80):
        kv.insert(k, [k])
    sched, client = cl.scheduler, cl.clients[0]
    out = {}
    for mode in (True, False):
        rec = sched.submit(0, "scan", 15, 30,
                           gen=client.op_scan(15, 30, batched=mode))
        sched.run_round_robin()
        out[mode] = rec.result.value
    assert out[True] == out[False]
    assert [k for k, _ in out[True]] == list(range(15, 45))


# -------------------------------------------------------- crash + repair --
@pytest.mark.parametrize("steps", [5, 17, 33, 61, 95])
def test_crash_mid_split_no_acked_insert_lost(steps):
    """Crash a client at an arbitrary verb boundary while its inserts are
    splitting leaves; after Alg-3/§5.3 repair, a quiescent scan contains
    every ACKED insert (the half-split tree is repaired, stranded entries
    re-homed)."""
    cl = _cluster(num_clients=3, seed=steps)
    sched = cl.scheduler
    for k in range(24):      # nearly two full leaves
        sched.submit(1, "insert", k, [k])
    sched.run_round_robin()
    recs = [sched.submit(0, "insert", 24 + i, [24 + i]) for i in range(12)]
    for _ in range(steps):   # partial execution: maybe mid-split
        if not sched.eligible_cids():
            break
        sched.step(0)
    cl.crash_client(0)
    cl.recover_client(0, reassign_to_cid=1)
    cl.drain()
    acked = [24 + i for i, r in enumerate(recs)
             if r.result is not None and r.result.status == OK]
    res = cl.store(1).scan(0, 100)
    got = [k for k, _ in res]
    _sound(res, 0)
    missing = [k for k in list(range(24)) + acked if k not in got]
    assert not missing, f"committed keys missing after repair: {missing}"
    # scans agree with point reads after recovery (no torn results)
    kv1 = cl.store(1)
    for k, v in res:
        assert kv1.get(k) == v


def test_repair_reaps_unlinked_half_split_leaf():
    cl = _cluster()
    kv = cl.store(0)
    for k in range(20):
        kv.insert(k, [k])
    pool = cl.pool
    g = pool.ordered_regions[0]
    # forge a half-split: a fully-written (valid CRC) leaf that was never
    # linked — exactly what a client crash between write_leaf and link
    # leaves behind
    arrays = [pool.mns[m].regions[g] for m in pool.placement[g]]
    new_id = int(arrays[0][ordered.CURSOR_OFF])
    words = ordered.build_leaf(low=7, ver=0, next_id=0, prev=0,
                               entries=[ordered.stored(999)])
    for a in arrays:
        a[ordered.CURSOR_OFF] = np.uint64(new_id + 1)
        a[ordered.leaf_off(new_id):ordered.leaf_off(new_id) + 16] = \
            np.array([w & ordered.MASK64 for w in words], np.uint64)
    assert 999 not in [k for k, _ in kv.scan(0, 100)]   # unreachable
    ordered.repair_ordered(pool)
    lf = ordered.parse_leaf(
        arrays[0][ordered.leaf_off(new_id):ordered.leaf_off(new_id) + 16])
    assert not lf["valid"], "half-split leaf must be voided by repair"
    assert [k for k, _ in kv.scan(0, 100)] == list(range(20))


def test_repair_rehomes_stranded_entries():
    cl = _cluster()
    kv = cl.store(0)
    for k in range(40):
        kv.insert(k, [k])
    pool = cl.pool
    g = pool.ordered_regions[0]
    arrays = [pool.mns[m].regions[g] for m in pool.placement[g]]
    # strand an entry: drop key 5 into the LAST leaf (outside its fences),
    # as a crashed splitter's unfinished move would
    kv.delete(5)
    assert 5 not in [k for k, _ in kv.scan(0, 100)]
    kv.insert(5, [50])       # live again, entry in the right place
    # now strand a DIFFERENT live key: clear 17's entry and graft it into
    # the head leaf's free slot region beyond its window
    mem = arrays[0]
    n = int(mem[ordered.CURSOR_OFF])
    # clear every entry equal to stored(17) everywhere
    sv = ordered.stored(17)
    for i in range(n):
        for j in range(ordered.LEAF_ENTRIES):
            if int(mem[ordered.entry_off(i, j)]) == sv:
                for a in arrays:
                    a[ordered.entry_off(i, j)] = np.uint64(0)
    # graft into the last allocated valid leaf (wrong window w.h.p.)
    lastleaf = n - 1
    for a in arrays:
        a[ordered.entry_off(lastleaf, ordered.LEAF_ENTRIES - 1)] = \
            np.uint64(sv)
    ordered.repair_ordered(pool)
    res = cl.store(0).scan(0, 100)
    assert 17 in [k for k, _ in res], "stranded entry must be re-homed"
    _sound(res, 0)


def test_repair_salvages_acked_entries_from_primary_only_link():
    """A split whose link CAS landed only on the primary is observable
    (all reads go to replica 0): a claim acked into the new leaf before
    the crash must survive repair, even though adopt-backup reverts the
    link and the reap voids the leaf — its entries are salvaged into the
    reachable chain."""
    cl = _cluster()
    kv = cl.store(0)
    for k in range(20):
        kv.insert(k, [k])
    pool = cl.pool
    g = pool.ordered_regions[0]
    arrays = [pool.mns[m].regions[g] for m in pool.placement[g]]
    # forge: new leaf N fully replicated, linked from leaf 0 on the
    # PRIMARY only (splitter crashed before ord:link_backups), holding an
    # independently-acked claim for key 999 (live in RACE)
    assert kv.insert(999, [9990]).status == OK
    sv = ordered.stored(999)
    for a in arrays:       # remove 999's real entry wherever ensure put it
        n = int(a[ordered.CURSOR_OFF])
        for i in range(n):
            for j in range(ordered.LEAF_ENTRIES):
                if int(a[ordered.entry_off(i, j)]) == sv:
                    a[ordered.entry_off(i, j)] = np.uint64(0)
    head = ordered.parse_leaf(arrays[0][ordered.leaf_off(0):
                                        ordered.leaf_off(0) + 16])
    new_id = int(arrays[0][ordered.CURSOR_OFF])
    words = ordered.build_leaf(low=head["low"] + 1, ver=0,
                               next_id=head["next"], prev=0,
                               entries=[sv])
    for a in arrays:       # N fully replicated
        a[ordered.CURSOR_OFF] = np.uint64(new_id + 1)
        a[ordered.leaf_off(new_id):ordered.leaf_off(new_id) + 16] = \
            np.array([w & ordered.MASK64 for w in words], np.uint64)
    link = ordered.pack_meta(head["ver"] + 1, new_id,
                             ordered.leaf_crc(head["low"], head["prev"]))
    arrays[0][ordered.leaf_off(0) + 1] = np.uint64(link)  # primary ONLY
    assert 999 in [k for k, _ in kv.scan(0, 2000)]        # observable
    ordered.repair_ordered(pool)
    cl.clients[0].ord_fences = {}                         # drop stale cache
    got = [k for k, _ in cl.store(0).scan(0, 2000)]
    assert 999 in got, "acked claim in a primary-only-linked leaf lost"
    assert got == sorted(set(got))


def test_repair_rehomes_multiple_stranded_keys_across_split():
    """Re-homing several stranded keys whose covering leaf is full forces
    a master-side direct split mid-repair; the later placements must use
    the POST-split fence windows or a committed key lands outside its
    leaf's range and scans miss it."""
    cl = _cluster()
    kv = cl.store(0)
    for k in range(13):          # exactly one full leaf covering [0, inf)
        kv.insert(k, [k])
    for k in (50, 60):
        assert kv.insert(k, [k]).status == OK
    pool = cl.pool
    g = pool.ordered_regions[0]
    arrays = [pool.mns[m].regions[g] for m in pool.placement[g]]
    mem = arrays[0]
    # strand 50 and 60: clear their entries everywhere, then graft both
    # into a forged linked leaf whose low (100) excludes them
    for key in (50, 60):
        sv = ordered.stored(key)
        n = int(mem[ordered.CURSOR_OFF])
        for a in arrays:
            for i in range(n):
                for j in range(ordered.LEAF_ENTRIES):
                    if int(a[ordered.entry_off(i, j)]) == sv:
                        a[ordered.entry_off(i, j)] = np.uint64(0)
    head = ordered.parse_leaf(mem[ordered.leaf_off(0):
                                  ordered.leaf_off(0) + 16])
    new_id = int(mem[ordered.CURSOR_OFF])
    words = ordered.build_leaf(low=100, ver=0, next_id=head["next"],
                               prev=0, entries=[ordered.stored(50),
                                                ordered.stored(60)])
    link = ordered.pack_meta(head["ver"] + 1, new_id,
                             ordered.leaf_crc(head["low"], head["prev"]))
    for a in arrays:
        a[ordered.CURSOR_OFF] = np.uint64(new_id + 1)
        a[ordered.leaf_off(new_id):ordered.leaf_off(new_id) + 16] = \
            np.array([w & ordered.MASK64 for w in words], np.uint64)
        a[ordered.leaf_off(0) + 1] = np.uint64(link)
    ordered.repair_ordered(pool)
    cl.clients[0].ord_fences = {}
    got = [k for k, _ in cl.store(0).scan(0, 2000)]
    for key in list(range(13)) + [50, 60]:
        assert key in got, f"committed key {key} missing after re-home"
    # scans starting past the mid-repair split still see the re-homed keys
    assert 60 in [k for k, _ in cl.store(0).scan(14, 2000)]


def test_batch_with_scan_on_disabled_cluster_rejects_upfront():
    """OrderedIndexDisabled must fire BEFORE any op of the batch is
    accepted — no stranded futures for already-submitted ops."""
    cl = FuseeCluster(DMConfig(num_mns=2), num_clients=1)
    kv = cl.store(0)
    with pytest.raises(OrderedIndexDisabled):
        kv.submit_batch([Op.put(1, [1]), Op.scan(0, 10)])
    assert cl.scheduler.inflight(0) == 0, "put was submitted before reject"
    assert kv.get(1) is None


def test_mn_crash_during_scans_recovers():
    cl = _cluster(num_clients=2, seed=5, num_mns=4, replication=3)
    kv = cl.store(0)
    for k in range(60):
        kv.insert(k, [k])
    cl.crash_mn(2)
    res = kv.scan(0, 100)
    assert [k for k, _ in res] == list(range(60))


# ----------------------------------------------------- migration cutover --
def test_scan_across_add_mn_cutover():
    cl = _cluster(num_clients=4, seed=9, num_mns=2, replication=2,
                  index_shards=4)
    fleet = cl.fleet()
    sched = cl.scheduler
    backends = [cl.store(c, max_inflight=0).backend for c in range(4)]
    for k in range(120):
        sched.submit(k % 4, "insert", k, [k])
    fleet.run()
    # scans in flight while the ordered region (and shards) re-home
    futs = fleet.submit_wave(
        [(backends[c], [Op.scan(c * 7, 40)]) for c in range(4)])
    cl.add_mn(wait=False)
    fleet.run()
    if cl.migrator.busy:
        cl.migrator.drive()
    for c, fs in enumerate(futs):
        r = fs[0].result()
        assert r.status == OK
        assert [k for k, _ in r.value] == list(range(c * 7, c * 7 + 40))
    # the ordered region was re-homed as a first-class region
    g = cl.pool.ordered_regions[0]
    assert cl.migrator.counters["cutovers"] >= 1
    res = cl.store(0).scan(0, 200)
    assert [k for k, _ in res] == list(range(120))


# ------------------------------------------------------------- fleet mode --
def test_fleet_locate_wave_single_invocation():
    cl = _cluster(num_clients=8, seed=3)
    fleet = cl.fleet()
    sched = cl.scheduler
    backends = [cl.store(c, max_inflight=0).backend for c in range(8)]
    for k in range(100):
        sched.submit(k % 8, "insert", k, [k])
    fleet.run()
    # warm fences
    fleet.submit_wave([(backends[c], [Op.scan(0, 4)]) for c in range(8)])
    fleet.run()
    base = fleet.counters["scan_locate_invocations"]
    futs = fleet.submit_wave(
        [(backends[c], [Op.scan(c * 9, 6), Op.scan(c * 3, 2)])
         for c in range(8)])
    assert fleet.counters["scan_locate_invocations"] == base + 1
    assert fleet.counters["scan_locate_keys"] >= 16
    fleet.run()
    for c, fs in enumerate(futs):
        assert [k for k, _ in fs[0].result().value] == \
            list(range(c * 9, c * 9 + 6))
    assert fleet.counters["ord_leaf_verbs"] > 0


def test_ycsbe_fleet_same_seed_bit_identical():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import YCSB, run_fleet_workload

    a = run_fleet_workload(n_clients=8, mix=YCSB["E"], seed=42,
                           ops_per_client=6, n_keys=96)
    b = run_fleet_workload(n_clients=8, mix=YCSB["E"], seed=42,
                           ops_per_client=6, n_keys=96)
    assert a.n_ops == b.n_ops
    assert a.rtts_by_kind == b.rtts_by_kind
    assert a.mix == b.mix
    assert np.array_equal(a.mn_bytes_per_op, b.mn_bytes_per_op)
    assert a.lat_p50_us == b.lat_p50_us and a.lat_p99_us == b.lat_p99_us
    assert "scan" in a.rtts_by_kind and a.mix["scan"] > 0.8


# ------------------------------------------------------------ scan storm --
@pytest.mark.slow
@pytest.mark.parametrize(
    "seed", [int(s) for s in
             os.environ.get("FUSEE_STORM_SEEDS", "0,1").split(",")])
def test_scan_storm_no_acked_write_lost_no_torn_scans(seed):
    """Acceptance: a seeded crash-storm over mixed scan/insert traffic
    loses no acked write and returns no torn scan results after recovery
    — bit-identical under same-seed replay."""
    def run(seed):
        cl = _cluster(num_clients=6, seed=seed, num_mns=4, replication=2,
                      mn_detect_delay=2)
        fleet = cl.fleet()
        sched = cl.scheduler
        backends = {c: cl.store(c, max_inflight=0).backend
                    for c in range(6)}
        for k in range(60):
            sched.submit(k % 6, "insert", k, [k])
        fleet.run()
        plan = FaultPlan.storm(cl.rng.stream("faults"),
                               clients=range(6), mns=4, replication=2,
                               n_client_crashes=2, n_mn_crashes=1,
                               first_op=80, spacing=24, recover_delay=12)
        cl.inject(plan)
        wl = cl.rng.stream("workload")
        acked_inserts = {}
        scan_results = []
        fresh = 60
        futs = []
        for wave_i in range(30):
            wave = []
            for c in range(6):
                if cl.clients.get(c) is None or cl.clients[c].crashed:
                    continue
                if sched.inflight(c) >= 4:
                    continue
                if wl.random() < 0.3:
                    op = Op.insert(fresh, [fresh])
                    futs.append((fresh, backends[c], op,
                                 backends[c].submit_many([op])[0]))
                    fresh += 1
                else:
                    start = int(wl.integers(fresh))
                    n = 1 + int(wl.integers(40))
                    wave.append((backends[c], [Op.scan(start, n)]))
            if wave:
                try:
                    for fs in fleet.submit_wave(wave):
                        scan_results.append(fs[0])
                except Exception:
                    pass
            fleet.tick()
        fleet.run()
        # recover any still-crashed clients, then quiesce
        for c in range(6):
            cli = cl.clients.get(c)
            if cli is not None and cli.crashed:
                cl.recover_client(c)
        cl.drain()
        for key, be, op, f in futs:
            if f.done() and f.result().status == OK:
                acked_inserts[key] = True
        live = next(c for c in range(6)
                    if cl.clients.get(c) is not None
                    and not cl.clients[c].crashed)
        kv = cl.store(live)
        final = kv.scan(0, 10_000)
        # torn-scan audit on every completed mid-storm scan
        torn = 0
        for f in scan_results:
            if not f.done():
                continue
            r = f.result()
            if r.status not in (OK,):
                continue
            if r.value is None:
                continue
            keys = [k for k, _ in r.value]
            if keys != sorted(set(keys)):
                torn += 1
        return (sorted(acked_inserts), [k for k, _ in final],
                [(k, tuple(v)) for k, v in final], torn)

    acked, final_keys, final_full, torn = run(seed)
    assert torn == 0, f"seed={seed}: torn mid-storm scan results"
    missing = [k for k in acked if k not in final_keys]
    assert not missing, \
        f"seed={seed}: acked inserts missing from post-recovery scan: {missing}"
    assert final_keys == sorted(set(final_keys)), f"seed={seed}"
    # bit-identical same-seed replay
    acked2, final_keys2, final_full2, torn2 = run(seed)
    assert (acked, final_keys, final_full, torn) == \
        (acked2, final_keys2, final_full2, torn2), f"seed={seed}: not replayable"


# ------------------------------------------------------------ serving twin --
def test_device_backend_scan_twin():
    from repro.core.api import KVStore
    from repro.serving import DeviceBackend, PoolConfig

    store = KVStore(DeviceBackend(PoolConfig(n_pages=256)))
    for k in range(40):
        store.insert(k, bytes([k % 250]) * 2)
    res = store.submit(Op.scan(10, 8)).result()
    assert res.status == OK
    assert [k for k, _ in res.value] == list(range(10, 18))
    assert all(v == bytes([k % 250]) * 2 for k, v in res.value)
    store.delete(12)
    res = store.submit(Op.scan(10, 8)).result()
    assert 12 not in [k for k, _ in res.value]
    res = store.submit(Op.range(30, 35)).result()
    assert [k for k, _ in res.value] == list(range(30, 35))


def test_leaf_probe_hint_roundtrip():
    """locate_leaves hints agree with the actual covering leaves."""
    cl = _cluster()
    kv = cl.store(0)
    for k in range(100):
        kv.insert(k, [k])
    client = cl.clients[0]
    assert client.ord_fences           # warmed by ensure path
    hints = ordered.locate_leaves(client, [0, 13, 57, 99])
    fences = client.ord_fences
    for start, leaf in zip([0, 13, 57, 99], hints):
        assert fences[leaf] <= start