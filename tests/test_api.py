"""Tests for the unified pipelined store API (core/api.py + core/codec.py).

Covers: codec round trips (bytes key/value -> slab words -> bytes),
multi-op-per-client pipelines under random schedules (linearizability of
mixed INSERT/UPDATE/DELETE/SEARCH with >= 4 ops in flight per client), the
batched cache-resident SEARCH fast path (race_lookup kernel + stale-entry
fallback), and the device backend speaking the same surface."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # pragma: no cover - hypothesis-less environments
    from _hypo import given, settings, strategies as st

from repro.core import codec
from repro.core.api import KVStore, Op, SimBackend
from repro.core.client import FuseeClient
from repro.core.events import NOT_FOUND, OK
from repro.core.heap import DMConfig, DMPool
from repro.core.linearize import check_linearizable, records_to_hops
from repro.core.master import Master
from repro.core.sim import Scheduler
from repro.core.store import FuseeCluster


# ----------------------------------------------------------------- codec ----
def test_encode_key_int_passthrough():
    assert codec.encode_key(42) == 42
    assert codec.encode_key(2**64 - 1) == 2**64 - 1


def test_encode_key_bytes_str_consistent():
    assert codec.encode_key("abc") == codec.encode_key(b"abc")
    assert codec.encode_key(b"abc") != codec.encode_key(b"abd")
    assert codec.encode_key(b"") != codec.encode_key(b"\x00")
    # 64-bit range, deterministic
    k = codec.encode_key(b"some-key")
    assert 0 <= k < 2**64 and k == codec.encode_key(b"some-key")


@settings(max_examples=40, deadline=None)
@given(n=st.integers(0, 64), seed=st.integers(0, 10_000))
def test_value_roundtrip_random_bytes(n, seed):
    rng = np.random.default_rng(seed)
    b = bytes(rng.integers(0, 256, n, dtype=np.uint8).tolist())
    assert codec.decode_value(codec.encode_value(b)) == b


def test_value_roundtrip_edge_lengths():
    for n in (0, 1, 7, 8, 9, 15, 16, 17, 255):
        b = bytes(range(256))[:n]
        words = codec.encode_value(b)
        assert all(0 <= w < 2**64 for w in words)
        assert codec.decode_value(words) == b


def test_value_str_and_raw_words():
    assert codec.decode_value(codec.encode_value("héllo")) == "héllo".encode()
    # untagged word lists pass through unchanged (legacy callers)
    assert codec.decode_value([1, 2, 3]) == [1, 2, 3]
    assert codec.encode_value([7, 8]) == [7, 8]
    assert codec.decode_value(None) is None
    assert codec.encode_value(None) == []


def test_raw_word_list_tag_collision_rejected():
    """A raw word list that would masquerade as a tagged byte payload is
    rejected at encode time; near-misses stay raw lists on decode."""
    tagged_like = [(codec.VALUE_TAG << 48) | 3, 0x636261]   # would be b'abc'
    with pytest.raises(ValueError):
        codec.encode_value(tagged_like)
    # header tag but INCONSISTENT length -> treated as a raw word list
    assert codec.decode_value([(codec.VALUE_TAG << 48) | 3]) == \
        [(codec.VALUE_TAG << 48) | 3]
    assert codec.decode_value([(codec.VALUE_TAG << 48) | 3, 1, 2]) == \
        [(codec.VALUE_TAG << 48) | 3, 1, 2]
    # nonzero padding beyond the stated length -> raw word list
    assert codec.decode_value([(codec.VALUE_TAG << 48) | 1, 2**63]) == \
        [(codec.VALUE_TAG << 48) | 1, 2**63]


def test_store_bytes_roundtrip_through_slabs():
    """bytes key/value -> slab object words -> bytes, via the real store."""
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=2)
    kv = cl.store(0)
    payloads = {f"key-{i}".encode(): bytes([i]) * (i * 3 + 1)
                for i in range(12)}
    for k, v in payloads.items():
        assert kv.put(k, v).status == OK
    kv1 = cl.store(1)
    for k, v in payloads.items():
        assert kv1.get(k) == v, k
    assert kv1.get(b"missing") is None


# ------------------------------------------------------- pipelined futures --
def test_submit_batch_pipelines_beyond_depth():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=2), num_clients=1)
    kv = cl.store(0, max_inflight=4)
    futs = kv.submit_batch([Op.put(i, [i]) for i in range(40)])
    assert all(f.result().status == OK for f in futs)
    assert kv.stats()["inflight"] == 0
    assert all(kv.get(i) == [i] for i in range(40))


def test_multiple_ops_in_flight_same_client():
    """>= 4 concurrent ops on ONE client actually overlap in time."""
    pool = DMPool(DMConfig(num_mns=4, replication=2), num_clients=1)
    master = Master(pool)
    c = FuseeClient(0, pool)
    sched = Scheduler(pool, master)
    sched.add_client(c)
    recs = [sched.submit(0, "insert", k, [k]) for k in range(6)]
    assert sched.inflight(0) == 6
    sched.run_random(rng=np.random.default_rng(0))
    assert all(r.result.status == OK for r in recs)
    # invocation ticks all precede every response tick: the ops overlapped
    assert max(r.inv_tick for r in recs) < min(r.resp_tick for r in recs)


# ------------------------------------------------ pipelined linearizability -
def _fresh(num_clients=4, r=3, num_mns=4):
    cfg = DMConfig(num_mns=num_mns, replication=r)
    pool = DMPool(cfg, num_clients=num_clients)
    master = Master(pool)
    clients = [FuseeClient(i, pool) for i in range(num_clients)]
    sched = Scheduler(pool, master)
    for c in clients:
        sched.add_client(c)
    return pool, master, clients, sched


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pipelined_mixed_ops_linearizable(seed):
    """Random schedules over pipelines of >= 4 mixed ops per client on one
    contended key stay linearizable (the acceptance bar for the pipelined
    scheduler rework)."""
    rng = np.random.default_rng(seed)
    pool, master, clients, sched = _fresh(num_clients=3)
    rec0 = sched.submit(clients[0].cid, "insert", 7, [1])
    sched.run_round_robin()
    assert rec0.result.status == OK
    kinds = ["update", "search", "delete", "insert"]
    recs = []
    val = 10
    for c in clients[1:]:
        for _ in range(4):                      # 4 ops in flight per client
            kind = kinds[int(rng.integers(len(kinds)))]
            v = [val] if kind in ("update", "insert") else None
            val += 1
            recs.append(sched.submit(c.cid, kind, 7, v))
    for c in clients[1:]:
        assert sched.inflight(c.cid) == 4
    sched.run_random(rng=rng)
    hops = records_to_hops(sched.history, 7)
    assert check_linearizable(hops, initial=None)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pipelined_api_batches_linearizable(seed):
    """Same bar, driven through the public submit_batch surface (which adds
    the fused multi-key SEARCH records to the history)."""
    rng = np.random.default_rng(seed)
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3,
                      seed=seed)
    kv0, kv1, kv2 = (cl.store(i) for i in range(3))
    key = b"contended"
    assert kv0.put(key, [1]).status == OK
    kv1.get(key)
    kv2.get(key)                               # warm both caches
    futs = []
    futs += kv1.submit_batch([Op.get(key), Op.update(key, [2]),
                              Op.get(key), Op.get(key)])
    futs += kv2.submit_batch([Op.get(key), Op.update(key, [3]),
                              Op.get(key), Op.delete(key)])
    # drive to completion under a random global schedule
    sched = cl.scheduler
    while sched.has_work():
        cids = sched.eligible_cids()
        sched.step(cids[int(rng.integers(len(cids)))],
                   pick=int(rng.integers(4)))
    assert all(f.done() for f in futs)
    hops = records_to_hops(sched.history, key)
    assert check_linearizable(hops, initial=None)


# ------------------------------------------------- batched SEARCH fast path -
def test_batch_search_fast_path_one_rtt():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=1)
    kv = cl.store(0)
    for f in kv.submit_batch([Op.put(i, [i * 7]) for i in range(16)]):
        assert f.result().status == OK
    for i in range(16):
        kv.get(i)                               # warm the adaptive cache
    mark = len(cl.scheduler.history)
    res = [f.result() for f in kv.submit_batch([Op.get(i) for i in range(16)])]
    assert all(r.status == OK for r in res)
    assert [r.value for r in res] == [[i * 7] for i in range(16)]
    new = cl.scheduler.history[mark:]
    fused = [r for r in new if r.kind == "search_batch"]
    assert len(fused) == 1 and fused[0].rtts == 1
    # whole batch cost 1 network RTT
    assert sum(r.rtts for r in new) == 1
    st_ = kv.stats()
    assert st_["batch_fast_hits"] == 16 and st_["batch_fallbacks"] == 0


def test_batch_search_stale_cache_falls_back():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=2)
    kv0, kv1 = cl.store(0), cl.store(1)
    for i in range(8):
        assert kv0.put(i, [i]).status == OK
        kv0.get(i)
    # another client overwrites half the keys -> client 0's cache is stale
    for i in range(0, 8, 2):
        assert kv1.update(i, [100 + i]).status == OK
    res = [f.result() for f in kv0.submit_batch([Op.get(i) for i in range(8)])]
    assert all(r.status == OK for r in res)
    assert [r.value for r in res] == \
        [[100 + i] if i % 2 == 0 else [i] for i in range(8)]
    st_ = kv0.stats()
    assert st_["batch_fallbacks"] >= 1      # stale entries took the slow path


def test_batch_search_misses_report_not_found():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=2), num_clients=1)
    kv = cl.store(0)
    for i in range(6):
        kv.put(i, [i])
        kv.get(i)
    futs = kv.submit_batch([Op.get(i) for i in range(4)]
                           + [Op.get(999), Op.get(1000)])
    res = [f.result() for f in futs]
    assert [r.status for r in res[:4]] == [OK] * 4
    assert [r.status for r in res[4:]] == [NOT_FOUND] * 2


def test_shadow_hash_matches_kernel_ref():
    """The fast path only works while api._hash32_np stays in lockstep with
    the race_lookup kernel's hash; drift would silently turn every probe
    into a fallback, so pin them to each other here."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.api import _hash32_np
    from repro.kernels.race_lookup.ref import hash32
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2**32, 512, dtype=np.uint32)
    for seed in (1, 2, 7):
        ours = _hash32_np(x, seed)
        kern = np.asarray(hash32(jnp.asarray(x.view(np.int32)), seed))
        np.testing.assert_array_equal(ours, kern.view(np.uint32))


def test_shadow_memo_reuses_table():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=2), num_clients=1)
    kv = cl.store(0)
    for i in range(8):
        kv.put(i, [i])
        kv.get(i)
    ops = [Op.get(i) for i in range(8)]
    [f.result() for f in kv.submit_batch(ops)]
    st1 = kv.stats()["shadow_rebuilds"]
    # cache untouched between identical batches -> no rebuild... but the
    # fused search bumps access counters, so one more rebuild at most
    [f.result() for f in kv.submit_batch(ops)]
    [f.result() for f in kv.submit_batch(ops)]
    st3 = kv.stats()
    assert st3["shadow_rebuilds"] <= st1 + 2
    assert st3["batch_fast_hits"] == 24


# ------------------------------------------------------------ device twin ---
def test_device_backend_same_surface():
    from repro.serving import DeviceBackend, PoolConfig
    store = KVStore(DeviceBackend(PoolConfig(n_pages=256, n_buckets=64,
                                             slots_per_bucket=4, replicas=2)))
    res = [f.result() for f in
           store.submit_batch([Op.put(f"blk-{i}", b"v%d" % i)
                               for i in range(32)])]
    assert all(r.status == OK for r in res)
    assert all(r.page is not None and r.page >= 0 for r in res)
    got = [f.result() for f in
           store.submit_batch([Op.get(f"blk-{i}") for i in range(32)])]
    assert [r.value for r in got] == [b"v%d" % i for i in range(32)]
    assert store.delete("blk-0").status == OK
    assert store.get("blk-0") is None
    assert store.stats()["backend"] == "device"


def test_device_backend_duplicate_keys_in_one_batch():
    """Duplicate keys batched together are concurrent upserts: one page,
    last value wins, and no resolved future holds a freed page."""
    from repro.serving import DeviceBackend, PoolConfig
    be = DeviceBackend(PoolConfig(n_pages=64, n_buckets=32,
                                  slots_per_bucket=4, replicas=2))
    store = KVStore(be)
    r1, r2 = [f.result() for f in store.submit_batch(
        [Op.put(b"k", b"v1"), Op.put(b"k", b"v2")])]
    assert r1.status == OK and r2.status == OK
    assert r1.page == r2.page                       # one page, shared result
    assert np.asarray(be.pool.free_bitmap).sum() == 0   # nothing freed
    live = store.submit(Op.get(b"k")).result()
    assert live.page == r1.page and live.value == b"v2"  # last writer wins


def test_device_backend_upsert_does_not_leak_pages():
    """Repeated PUTs of one key supersede the old page each time; the pool
    must recycle them instead of exhausting (regression: upsert leak)."""
    from repro.serving import DeviceBackend, PoolConfig
    store = KVStore(DeviceBackend(PoolConfig(n_pages=64, n_buckets=32,
                                             slots_per_bucket=4,
                                             replicas=2, chunk_pages=16)))
    for i in range(300):        # >> n_pages
        r = store.put("hot-key", b"v%d" % i)
        assert r.status == OK, f"pool exhausted at upsert #{i}"
    assert store.get("hot-key") == b"v299"


def test_device_backend_surplus_release():
    """A page whose index slot was superseded is unreachable; releasing it
    returns it to the pool (the engine's retire path)."""
    from repro.serving import DeviceBackend, PoolConfig
    be = DeviceBackend(PoolConfig(n_pages=256, n_buckets=64,
                                  slots_per_bucket=4, replicas=2))
    store = KVStore(be)
    r1 = store.put("k", b"first")
    r2 = store.put("k", b"second")          # supersedes page r1.page
    assert r1.page != r2.page
    live = store.submit(Op.get("k")).result()
    assert live.page == r2.page and live.value == b"second"
    be.release_pages(np.array([r1.page], np.int32))
    assert be.pool.reclaim(be.cid) >= 1     # surplus page came back
