"""Causal op profiler + hot-key monitor tests (repro.obs.spans /
profile / hotspot): span-tree reconstruction against the verb ring, the
RTT-conservation guarantee under faults / cutovers / wrapped rings,
same-seed bit-identical profiles, the critical-path fold, the streaming
top-k / zipf-θ / regime machinery, and the obs-hub flush hardening."""
import json

import numpy as np
import pytest

from repro.core import CRASHED, OK, DMConfig, FaultPlan, FuseeCluster, Op
from repro.obs import (EV_REGIME, FLAG_CRASHED, FLAG_OPEN, FLAG_PARTIAL,
                       HotKeyMonitor, SpaceSaving, build_spans,
                       critical_path_report, flight_to_perfetto,
                       format_report, spans_from_cluster, zipf_theta)


# ----------------------------------------------------------------- helpers
def _drive(cl, n_clients, ops, *, batch=64):
    """Submit (cid, Op) pairs through per-client stores on fleet ticks."""
    fleet = cl.fleet()
    stores = {c: cl.store(c, max_inflight=0) for c in range(n_clients)}
    from repro.core import ClientCrashed
    for i, (c, op) in enumerate(ops):
        try:
            stores[c].submit(op)
        except ClientCrashed:
            pass
        if i % batch == batch - 1:
            fleet.run()
    fleet.run()
    if cl.migrator.busy:
        cl.migrator.drive()
        fleet.run()
    return fleet


def _zipf_ops(cl, n_clients, n_keys, n_ops, *, theta=0.99, preload=True):
    ops = []
    if preload:
        ops += [(k % n_clients, Op.insert(k, [k])) for k in range(n_keys)]
    wl = cl.rng.stream("workload")
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-theta)
    p /= p.sum()
    keys = wl.choice(n_keys, size=n_ops, p=p)
    for i, k in enumerate(keys):
        op = Op.update(int(k), [i]) if i % 2 else Op.get(int(k))
        ops.append((i % n_clients, op))
    return ops


def _assert_conserved(ss):
    """The exact per-op identity, checked op by op (not just in sum)."""
    o = ss.ops
    settled = o["rtts"] >= 0
    assert (o["fg_spans"][settled] + o["untraced"][settled]
            == o["rtts"][settled]).all()
    assert (o["untraced"][settled] >= 0).all(), "over-attribution"


# -------------------------------------------------- conservation under load
def test_rtt_conservation_ycsba_storm_256_clients():
    """The acceptance property: a seeded 256-client YCSB-A-shaped run
    through a crash/recover/add-MN storm conserves RTTs exactly — every
    settled op's foreground spans + untraced residual == its measured
    total, with zero over-attribution."""
    n_clients, n_keys = 256, 512
    cl = FuseeCluster(DMConfig(num_mns=5, replication=3, index_shards=4,
                               region_words=1 << 15, regions_per_mn=16),
                      num_clients=n_clients, seed=42)
    cl.attach_tracer(capacity=1 << 18)
    plan = FaultPlan.storm(cl.rng.stream("faults"),
                           clients=range(n_clients), mns=5,
                           replication=3, n_client_crashes=2,
                           n_mn_crashes=1, n_add_mns=1, remove_added=False,
                           first_op=100, spacing=120, recover_delay=10)
    cl.inject(plan)
    _drive(cl, n_clients, _zipf_ops(cl, n_clients, n_keys, 1500))
    prof = cl.profile()
    c = prof["conservation"]
    assert c["ok"], c
    assert c["violations"] == 0
    assert c["attributed_rtts"] + c["untraced_rtts"] == c["total_rtts"]
    assert c["ops"] > 1000
    _assert_conserved(prof["spans"])
    # the storm produced typed retry causes, not just clean phases
    causes = {r["cause"] for r in prof["rows"]}
    assert causes - {""}, "no retry causes attributed under a storm"


def test_mid_flight_crash_flags_not_misattributed():
    """Ops in flight when their client crash-stops settle CRASHED (flag
    carried on the op row) or stay open (FLAG_OPEN, excluded from
    conservation); either way the settled population still conserves."""
    n_clients = 4
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3),
                      num_clients=n_clients, seed=13)
    cl.attach_tracer()
    cl.inject(FaultPlan().crash_mn(2, after_ops=30)
              .crash_client(0, after_ops=40))
    ops = [(i % n_clients, Op.put(i, [i])) for i in range(120)]
    _drive(cl, n_clients, ops, batch=16)
    ss = spans_from_cluster(cl)
    _assert_conserved(ss)
    o = ss.ops
    crashed = (o["flags"] & FLAG_CRASHED) > 0
    assert crashed.any(), "client crash produced no CRASHED ops"
    # crashed ops settled: they participate in (and pass) conservation
    assert (o["rtts"][crashed] >= 0).all()
    rep = critical_path_report(ss)
    assert rep["conservation"]["ok"], rep["conservation"]


def test_retries_across_add_mn_cutover_conserve():
    """A migration window mid-run: dual-write spans and stale-epoch /
    cas-lost retries must fold into the report without breaking the
    conservation identity."""
    n_clients = 8
    cl = FuseeCluster(DMConfig(num_mns=2, replication=2, index_shards=8,
                               region_words=1 << 15, regions_per_mn=8),
                      num_clients=n_clients, seed=3)
    cl.attach_tracer(capacity=1 << 17)
    fleet = cl.fleet()
    sched = cl.scheduler
    backends = [cl.store(c, max_inflight=0).backend
                for c in range(n_clients)]
    k, added = 0, False
    while k < 300 or cl.migrator.busy or sched.has_work():
        for c in range(n_clients):
            if k < 300 and sched.inflight(c) < 4:
                backends[c].submit_many([Op.put(k, [k])])
                k += 1
        if k >= 100 and not added:
            cl.add_mn(wait=False)
            added = True
        fleet.tick()
    ss = spans_from_cluster(cl)
    _assert_conserved(ss)
    rep = critical_path_report(ss)
    assert rep["conservation"]["ok"], rep["conservation"]
    labels = {(r["phase"], r["cause"]) for r in rep["rows"]}
    assert any(c == "mig_dual_write" for _p, c in labels), \
        "cutover window left no dual-write attribution"


def test_wrapped_verb_ring_partial_but_flagged():
    """A verb ring too small for the run: span trees are partial, and the
    profiler says so (FLAG_PARTIAL, partial_ops) instead of silently
    mis-attributing — untraced residuals stay exact and non-negative."""
    n_clients = 4
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3),
                      num_clients=n_clients, seed=17)
    tr = cl.attach_tracer(capacity=256)          # will wrap many times
    ops = [(i % n_clients, Op.put(i, [i])) for i in range(200)]
    ops += [(i % n_clients, Op.get(i % 200)) for i in range(200)]
    _drive(cl, n_clients, ops, batch=16)
    assert tr.dropped > 0
    ss = spans_from_cluster(cl)
    assert ss.trace_dropped > 0
    _assert_conserved(ss)
    o = ss.ops
    partial = (o["flags"] & FLAG_PARTIAL) > 0
    assert partial.any(), "wrapped ring produced no FLAG_PARTIAL ops"
    rep = critical_path_report(ss)
    assert rep["conservation"]["partial_ops"] == int(partial.sum())
    assert rep["conservation"]["ok"], rep["conservation"]
    assert rep["totals"]["trace_dropped"] == ss.trace_dropped


def test_same_seed_profiles_bit_identical():
    def one():
        cl = FuseeCluster(DMConfig(num_mns=4, replication=3, index_shards=4),
                          num_clients=8, seed=29)
        cl.attach_tracer(capacity=1 << 16)
        cl.inject(FaultPlan().crash_mn(3, after_ops=60))
        _drive(cl, 8, _zipf_ops(cl, 8, 128, 400))
        prof = cl.profile()
        prof.pop("spans")                       # arrays: compared via rows
        prof.pop("tick_phases", None)           # wall clock: never compared
        return json.dumps(prof, sort_keys=True)
    assert one() == one()


# ------------------------------------------------------------- span units
def test_build_spans_empty_trace_all_untraced():
    """No tracer rows at all: every settled op is one untraced residual;
    conservation still holds by construction."""
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=1)
    kv = cl.store(0)
    for i in range(10):
        kv.put(i, [i])
    kv.drain()
    obs = cl.obs
    ev = obs.flight_events()
    ss = build_spans({f: np.zeros(0, np.int64)
                      for f in ("seq", "tick", "cid", "op_id", "phase",
                                "label", "cause", "bg", "ok")},
                     [], ev, obs.labels())
    assert ss.n_spans == 0 and ss.n_ops == 10
    _assert_conserved(ss)
    assert (ss.ops["untraced"] == ss.ops["rtts"]).all()
    rep = critical_path_report(ss)
    assert rep["conservation"]["ok"]
    assert all(r["phase"] == "(untraced)" for r in rep["rows"])


def test_open_ops_flagged_and_excluded():
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=2)
    cl.attach_tracer()
    kv = cl.store(0)
    for i in range(6):
        kv.put(i, [i])
    kv.drain()
    # leave one op genuinely in flight (submitted, never drained)
    cl.store(1).submit(Op.put(99, [99]))
    for _ in range(2):                          # a couple of beats only
        cl.scheduler.step(1)
    ss = spans_from_cluster(cl)
    o = ss.ops
    open_ops = (o["flags"] & FLAG_OPEN) > 0
    assert open_ops.sum() == 1
    assert (o["rtts"][open_ops] == -1).all()
    rep = critical_path_report(ss)
    assert rep["totals"]["open_ops"] == 1
    assert rep["conservation"]["ops"] == int((~open_ops).sum())
    tree = ss.op_tree(1, int(o["op_id"][open_ops][0]))
    assert tree is not None and tree["rtts"] == -1


def test_op_tree_shape_and_format_report():
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=5)
    cl.attach_tracer()
    kv = cl.store(0)
    kv.insert(7, [7])
    kv.get(7)
    kv.drain()
    ss = spans_from_cluster(cl)
    o = ss.ops
    row = int(np.flatnonzero(o["rtts"] >= 0)[0])
    tree = ss.op_tree(int(o["cid"][row]), int(o["op_id"][row]))
    assert tree["spans"], "settled op reconstructed with no spans"
    phases = [s["phase"] for s in tree["spans"]]
    assert phases == sorted(phases), "spans not in phase order"
    assert all(s["verbs"] >= 1 for s in tree["spans"])
    txt = format_report(critical_path_report(ss), top=3)
    assert "conservation: OK" in txt
    assert txt.count("\n") <= 3 + 2 + 1        # header + rule + rows + tail


def test_spans_nest_in_perfetto_export(tmp_path):
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=8)
    cl.attach_tracer()
    kv = cl.store(0)
    for i in range(12):
        kv.put(i, [i])
    kv.drain()
    ss = spans_from_cluster(cl)
    obs = cl.obs
    trace = flight_to_perfetto({"labels": obs.labels(),
                                **obs.flight_events(),
                                "dropped": obs.flight.dropped},
                               str(tmp_path / "t.json"), spans=ss)
    evs = trace["traceEvents"]
    phase_spans = [e for e in evs if e.get("cat") == "phase"
                   and e.get("ph") == "X"]
    op_spans = {(e["tid"], e["args"]["op_id"]): e for e in evs
                if e.get("cat") == "op" and "op_id" in e.get("args", {})}
    assert phase_spans
    for e in phase_spans:
        parent = op_spans.get((e["tid"], e["args"]["op_id"]))
        assert parent is not None
        # nested: strictly inside the parent slice (time containment)
        assert parent["ts"] <= e["ts"]
        assert e["ts"] + e["dur"] <= parent["ts"] + parent["dur"] + 1e-9


# --------------------------------------------------------------- hotspot
def test_space_saving_exact_under_capacity():
    s = SpaceSaving(capacity=16)
    keys = [1] * 5 + [2] * 3 + [3] * 2 + [4]
    s.update(np.array(keys))
    s.update(np.array([1, 1, 5]))
    top = s.top(3)
    assert top[0] == (1, 7, 0)
    assert top[1] == (2, 3, 0)
    assert s.n_seen == len(keys) + 3


def test_space_saving_eviction_error_bound():
    s = SpaceSaving(capacity=4)
    rng = np.random.default_rng(0)
    true = {k: 0 for k in range(64)}
    # heavy head + noise tail, streamed in batches like the flush cadence
    for _ in range(30):
        batch = np.concatenate([np.repeat([0, 1], 10),
                                rng.integers(2, 64, size=8)])
        for k in batch:
            true[int(k)] += 1
        s.update(batch)
    top = dict((k, c) for k, c, _e in s.top(2))
    assert set(top) == {0, 1}                   # heavy hitters survive
    for k, c, e in s.top():
        assert true[k] <= c <= true[k] + e      # the space-saving bound


def test_space_saving_deterministic():
    def run():
        s = SpaceSaving(capacity=8)
        rng = np.random.default_rng(7)
        for _ in range(20):
            s.update(rng.integers(0, 40, size=32))
        return s.top()
    assert run() == run()


def test_zipf_theta_estimator_contract():
    ranks = np.arange(1, 129, dtype=np.float64)
    counts = np.round(1e6 * ranks ** -0.99)
    assert abs(zipf_theta(counts) - 0.99) < 0.05
    assert zipf_theta(np.full(128, 50.0)) == pytest.approx(0.0, abs=0.05)
    assert zipf_theta([9, 5, 3]) == 0.0          # unsaturated head: no fit
    assert zipf_theta(np.zeros(20)) == 0.0


def test_hotkey_monitor_regime_hysteresis():
    m = HotKeyMonitor(top_k=8, capacity=32, theta_hi=0.6,
                      imb_hi=2.0, imb_lo=1.4)
    assert m.evaluate() is None and m.regime == "uniform"
    # skewed stream -> one transition, then stable (no flapping)
    rng = np.random.default_rng(1)
    ranks = np.arange(1, 65, dtype=np.float64)
    p = ranks ** -1.2
    p /= p.sum()
    ev = None
    for _ in range(12):
        m.observe_keys(rng.choice(64, size=256, p=p))
        e = m.evaluate()
        ev = ev or e
    assert ev is not None and ev["regime"] == "skewed"
    assert m.regime == "skewed" and m.flips == 1
    assert m.evaluate() is None                  # no repeat event
    snap = m.snapshot()
    assert snap["regime"] == "skewed" and snap["regime_flips"] == 1
    json.dumps(snap)                             # JSON-pure


def test_hotkey_monitor_imbalance_ewma():
    m = HotKeyMonitor(alpha=0.5)
    for _ in range(6):
        m.observe_load(np.array([0, 0, 0, 1]), np.array([2, 2, 2, 2]))
    assert m.shard_imbalance > 1.4              # 3:1 shard split
    assert m.mn_imbalance == 1.0                # single live MN dim
    m2 = HotKeyMonitor()
    assert m2.shard_imbalance == 1.0            # no data: balanced


def test_planted_zipf_top32_recovered_within_2k_ticks():
    """The acceptance bound: >=90% of the true top-32 keys of a planted
    zipf(0.99) stream are in the monitor's top-32 within 2k ticks."""
    n_clients, n_keys = 16, 4096
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3, index_shards=4,
                               region_words=1 << 16, regions_per_mn=16),
                      num_clients=n_clients, seed=23)
    cl.enable_hotspot()
    fleet = cl.fleet()
    sched = cl.scheduler
    for k in range(64):                          # small warm set
        sched.submit(k % n_clients, "insert", k, [k])
    fleet.run()
    wl = cl.rng.stream("workload")
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-0.99)
    p /= p.sum()
    tick0 = sched.tick
    while sched.tick - tick0 < 2000:
        keys = wl.choice(n_keys, size=n_clients, p=p)
        for c, k in enumerate(keys):
            sched.submit(c, "search", int(k), None)
        fleet.run()
    cl.obs.flush()
    got = {k for k, _c, _e in cl.obs.hotspot.sketch.top(32)}
    true_top = set(range(32))                   # fold32(k) == k for small k
    recovered = len(got & true_top) / 32
    assert recovered >= 0.90, f"only {recovered:.0%} of top-32 recovered"
    # head-only θ under merge-floored tail counts underestimates the
    # planted 0.99, but must still be far from a uniform stream's ~0
    assert cl.metrics()["hotspot"]["theta_milli"] > 350


def test_regime_event_lands_in_flight_ring():
    n_clients = 8
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3),
                      num_clients=n_clients, seed=31)
    cl.enable_hotspot(theta_hi=0.3, imb_hi=1.5)  # eager thresholds
    _drive(cl, n_clients, _zipf_ops(cl, n_clients, 128, 600, theta=1.2))
    ev = cl.obs.flight_events()
    regimes = ev["etype"] == EV_REGIME
    assert regimes.any(), "no regime event recorded"
    labels = cl.obs.labels()
    kinds = {labels[int(k)] for k in ev["kind"][regimes]}
    assert "skewed" in kinds
    m = cl.metrics()
    assert m["gauges"]["hot.regime"] == 1
    assert m["counters"]["hot.regime_flips"] >= 1
    # exported as instants on the cluster lane
    trace = flight_to_perfetto({"labels": labels, **ev, "dropped": 0})
    regs = [e for e in trace["traceEvents"] if e.get("cat") == "regime"]
    assert regs and all(e["ph"] == "i" for e in regs)
    assert all("theta_milli" in e["args"] for e in regs)


def test_hotspot_off_keeps_snapshots_identical():
    """The monitor is opt-in: a run with it never enabled produces the
    same metrics JSON as before the feature existed (no hot.* keys)."""
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=3,
                      seed=11)
    kv = cl.store(0)
    for i in range(30):
        kv.put(f"k{i}", f"v{i}")
    kv.drain()
    m = cl.metrics()
    assert "hotspot" not in m
    assert not any(k.startswith("hot.") for k in m["counters"])
    assert not any(k.startswith("hot.") for k in m["gauges"])


# ------------------------------------------------- obs-hub flush hardening
def test_pending_heat_and_events_survive_detach():
    """The flush-hardening regression: scalar heat touches and op events
    buffered between flush cadences must land in the sketch / ring when
    the hub detaches or a profile is read — never silently dropped."""
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=4)
    obs = cl.obs
    for i in range(10):                          # < flush_every: buffered
        obs.heat_key64(i)
    assert obs._heat_pend
    cl.enable_hotspot()
    cl.detach_obs()                              # must drain, not drop
    assert not obs._heat_pend
    assert sum(cl.metrics()["heat"]["cache.heat"]) >= 10
    assert obs.hotspot.sketch.n_seen == 10


def test_cluster_events_flush_at_threshold():
    """fault/recovery/migration appends respect the flush cadence: the
    tuple buffer never grows beyond flush_every rows."""
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=6)
    obs = cl.obs
    obs.flush()
    for i in range(obs.flush_every + 5):
        obs.fault("synthetic", i, tick=i)
    assert len(obs._pend) < obs.flush_every
    ev = obs.flight_events()
    assert (ev["etype"] == 2).sum() == obs.flush_every + 5  # EV_FAULT


def test_flight_events_accessor_sees_buffered_tail():
    cl = FuseeCluster(DMConfig(num_mns=3, replication=2), num_clients=2,
                      seed=9)
    kv = cl.store(0)
    for i in range(5):
        kv.put(i, [i])
    kv.drain()
    obs = cl.obs
    assert obs._pend                             # tail still buffered
    raw = obs.flight.events()["etype"]
    via = obs.flight_events()["etype"]
    assert len(via) > len(raw)                   # accessor flushed first
