"""Edge cases for the Wing&Gong checker in core/linearize.py.

The property/storm suites exercise ``check_linearizable`` on generated
histories; these tests pin the tricky corners directly: duplicate written
values, ABSENT-key transitions (insert-upsert / update-NOT_FOUND /
blind-delete), a known non-linearizable counterexample, and the
``records_to_hops`` filtering contract.
"""
from repro.core.events import OpResult
from repro.core.linearize import HOp, check_linearizable, records_to_hops
from repro.core.sim import OpRecord


def _ins(i, inv, resp, v, status="OK"):
    return HOp(i, "insert", inv, resp, wrote=v, read=None, status=status)


def _upd(i, inv, resp, v, status="OK"):
    return HOp(i, "update", inv, resp, wrote=v, read=None, status=status)


def _srch(i, inv, resp, v, status="OK"):
    return HOp(i, "search", inv, resp, wrote=None, read=v, status=status)


def _del(i, inv, resp, status="OK"):
    return HOp(i, "delete", inv, resp, wrote=None, read=None, status=status)


# ------------------------------------------------------- duplicate values
def test_duplicate_written_values_sequential():
    # insert(7) twice (our INSERT upserts), then a search reading 7
    h = [_ins(0, 0, 1, (7,)), _ins(1, 2, 3, (7,)), _srch(2, 4, 5, (7,))]
    assert check_linearizable(h)


def test_duplicate_written_values_concurrent_reads_interleave():
    # two concurrent inserts of the SAME value: any serialization leaves
    # the register at (5,), so interleaved reads of (5,) always linearize
    h = [_ins(0, 0, 10, (5,)), _ins(1, 0, 10, (5,)),
         _srch(2, 11, 12, (5,)), _srch(3, 13, 14, (5,))]
    assert check_linearizable(h)


def test_duplicate_values_do_not_mask_stale_read():
    # both writers wrote (5,), a later search still cannot observe ABSENT
    h = [_ins(0, 0, 1, (5,)), _ins(1, 2, 3, (5,)),
         _srch(2, 4, 5, None, status="NOT_FOUND")]
    assert not check_linearizable(h)


# --------------------------------------------------- ABSENT transitions
def test_update_on_absent_key_not_found():
    assert check_linearizable([_upd(0, 0, 1, (9,), status="NOT_FOUND")])


def test_update_on_absent_key_cannot_ack_ok():
    assert not check_linearizable([_upd(0, 0, 1, (9,), status="OK")])


def test_update_not_found_concurrent_with_insert():
    # update may linearize before the concurrent insert's effect point
    h = [_ins(0, 0, 10, (1,)), _upd(1, 0, 10, (2,), status="NOT_FOUND")]
    assert check_linearizable(h)
    # ...but not after the insert has completed in real time
    h2 = [_ins(0, 0, 1, (1,)), _upd(1, 2, 3, (2,), status="NOT_FOUND")]
    assert not check_linearizable(h2)


def test_delete_not_found_requires_observed_absence():
    assert check_linearizable([_del(0, 0, 1, status="NOT_FOUND")])
    h = [_ins(0, 0, 1, (3,)), _del(1, 2, 3, status="NOT_FOUND")]
    assert not check_linearizable(h)


def test_delete_ok_is_a_blind_write():
    # concurrent deleters may BOTH report OK (all-writers-write-NULL: the
    # paper's uniqueness argument doesn't apply; see module docstring)
    h = [_ins(0, 0, 1, (4,)), _del(1, 2, 8, status="OK"),
         _del(2, 2, 8, status="OK"),
         _srch(3, 9, 10, None, status="NOT_FOUND")]
    assert check_linearizable(h)
    # delete-OK even on an absent key: still just a write of ABSENT
    assert check_linearizable([_del(0, 0, 1, status="OK")])


def test_insert_after_delete_restores_value():
    h = [_ins(0, 0, 1, (6,)), _del(1, 2, 3), _ins(2, 4, 5, (7,)),
         _srch(3, 6, 7, (7,))]
    assert check_linearizable(h)


# ------------------------------------------- non-linearizable witnesses
def test_counterexample_stale_read_after_overwrite():
    """The classic: w1 and w2 complete in real-time order, then two
    sequential reads observe v2 *then* v1 — no remaining write can move
    the register back, so no linearization exists."""
    h = [_ins(0, 0, 1, (1,)), _ins(1, 2, 3, (2,)),
         _srch(2, 4, 5, (2,)), _srch(3, 6, 7, (1,))]
    assert not check_linearizable(h)


def test_counterexample_read_of_never_written_value():
    h = [_ins(0, 0, 1, (1,)), _srch(1, 2, 3, (99,))]
    assert not check_linearizable(h)


def test_concurrent_reads_may_disagree_on_order():
    # same shape as the stale-read case but the READS are concurrent with
    # the second write — now both observations are legal
    h = [_ins(0, 0, 1, (1,)), _ins(1, 2, 9, (2,)),
         _srch(2, 3, 9, (2,)), _srch(3, 3, 9, (1,))]
    assert check_linearizable(h)


# ------------------------------------------------- records_to_hops -----
def _rec(op_id, kind, key, value=None, *, status="OK", rvalue=None,
         result=True, inv=0, resp=1):
    return OpRecord(cid=0, op_id=op_id, kind=kind, key=key, value=value,
                    inv_tick=inv, resp_tick=resp,
                    result=OpResult(status, value=rvalue) if result else None)


def test_records_to_hops_filters():
    recs = [
        _rec(0, "insert", 42, [1, 2]),                      # kept
        _rec(1, "insert", 43, [3]),                         # other key
        _rec(2, "search", 42, rvalue=[1, 2]),               # kept, read set
        _rec(3, "insert", 42, [9], result=False),           # still in flight
        _rec(4, "scan", 42),                                # not a register op
        _rec(5, "insert", 42, [9], status="FULL"),          # excluded status
        _rec(6, "update", 42, [5], status="NOT_FOUND"),     # kept
        _rec(7, "delete", 42),                              # kept
    ]
    hops = sorted(records_to_hops(recs, 42), key=lambda o: o.op_id)
    assert [o.op_id for o in hops] == [0, 2, 6, 7]
    assert hops[0].wrote == (1, 2)
    assert hops[1].kind == "search" and hops[1].read == (1, 2)
    assert hops[2].status == "NOT_FOUND"
    assert hops[3].kind == "delete" and hops[3].wrote is None
    # and the surviving history is a consistent one
    assert check_linearizable(hops)


def test_records_to_hops_encodes_public_keys():
    from repro.core.codec import encode_key
    ik = encode_key(b"user:7")
    recs = [_rec(0, "insert", ik, [8]), _rec(1, "insert", 999, [9])]
    hops = records_to_hops(recs, b"user:7")
    assert [o.op_id for o in hops] == [0]
    # absent search reads map to None (ABSENT), not a tuple
    recs2 = [_rec(0, "search", ik, status="NOT_FOUND")]
    (h,) = records_to_hops(recs2, b"user:7")
    assert h.read is None and h.status == "NOT_FOUND"
