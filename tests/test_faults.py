"""Tests for the first-class cluster failure surface (core/faults.py +
core/store.py + the scheduler's failure semantics).

Covers: typed ``ClientCrashed`` / ``SchedulerStalled`` errors, in-flight
futures of a crashed client resolving to the retriable ``CRASHED``
outcome (including the batched-SEARCH fast path and its per-key fallback
resubmits), dynamic membership with lease-epoch propagation, declarative
``FaultPlan`` injection at tick/op boundaries, automatic MN-crash
detection inside the scheduler loop, and the ``health()`` snapshot."""
import numpy as np
import pytest

from repro.core import (CRASHED, OK, ClientCrashed, DMConfig, FaultEvent,
                        FaultPlan, FuseeCluster, KVFuture, Op,
                        SchedulerStalled)


def _cluster(**kw):
    kw.setdefault("num_clients", 3)
    return FuseeCluster(DMConfig(num_mns=4, replication=3), **kw)


# ------------------------------------------------------- crashed futures ----
def test_inflight_futures_resolve_crashed_retriable():
    """The acceptance bar: in-flight futures of a crashed client resolve
    with a typed retriable status instead of hanging or raising."""
    cl = _cluster()
    kv = cl.store(0)
    futs = kv.submit_batch([Op.put(i, [i]) for i in range(6)])
    assert cl.scheduler.inflight(0) > 0
    cl.crash_client(0)
    res = [f.result() for f in futs]           # must not raise
    assert all(f.done() for f in futs)
    assert {r.status for r in res} == {CRASHED}
    assert all(r.retriable for r in res)
    # the ops are retriable on a live client
    kv1 = cl.store(1)
    assert all(kv1.put(i, [i]).status == OK for i in range(6))


def test_submit_on_crashed_client_raises_typed():
    cl = _cluster()
    kv = cl.store(0)
    kv.put(1, [1])
    cl.crash_client(0)
    with pytest.raises(ClientCrashed) as ei:
        kv.put(2, [2])
    assert ei.value.cid == 0 and ei.value.reason == "crashed"
    # the raw scheduler surface raises the same typed error (no bare assert)
    with pytest.raises(ClientCrashed):
        cl.scheduler.submit(0, "insert", 3, [3])
    with pytest.raises(ClientCrashed):
        cl.scheduler.submit(999, "insert", 3, [3])


def test_crash_mid_batch_settles_remaining_futures():
    """A client dying while a pipelined batch is still being submitted
    (fault injection during the backpressure pump) settles every accepted
    future as CRASHED instead of leaving futures dangling."""
    cl = _cluster()
    kv = cl.store(0, max_inflight=4)
    cl.inject(FaultPlan().crash_client(0, after_ops=8))
    futs = kv.submit_batch([Op.put(i, [i]) for i in range(32)])
    assert len(futs) == 32
    res = [f.result() for f in futs]
    assert all(f.done() for f in futs)
    n_ok = sum(r.status == OK for r in res)
    assert n_ok >= 8 and sum(r.status == CRASHED for r in res) == 32 - n_ok


# --------------------------------------- batched SEARCH fast path crash ----
def test_batch_search_fused_crash_resolves_all_futures():
    """Client crashes while a fused multi-key SEARCH is in flight: the
    fused op's on_done expansion resolves every per-key future CRASHED —
    nothing leaks, nothing raises."""
    cl = _cluster()
    kv = cl.store(0)
    for i in range(8):
        assert kv.put(i, [i * 3]).status == OK
        kv.get(i)                               # warm the adaptive cache
    futs = kv.submit_batch([Op.get(i) for i in range(8)])
    fused = [r for r in cl.scheduler.history if r.kind == "search_batch"]
    assert len(fused) == 1 and fused[0].result is None   # fused op in flight
    cl.crash_client(0)
    assert all(f.done() for f in futs)          # resolved by crash, no drive
    res = [f.result() for f in futs]
    assert {r.status for r in res} == {CRASHED}
    assert all(r.retriable for r in res)
    assert fused[0].result.status == CRASHED
    assert fused[0].on_done is None             # expansion hook fired+cleared


def test_batch_search_fallback_resubmits_crash_mid_flight():
    """Crash lands AFTER the fused op expanded but while per-key fallback
    resubmits (stale cache entries) are still in flight: fast-path hits
    stay OK, fallbacks report CRASHED, no future is left unresolved."""
    cl = _cluster()
    kv0, kv1 = cl.store(0), cl.store(1)
    for i in range(8):
        assert kv0.put(i, [i]).status == OK
        kv0.get(i)
    for i in range(0, 8, 2):                    # stale half of client 0 cache
        assert kv1.update(i, [100 + i]).status == OK
    futs = kv0.submit_batch([Op.get(i) for i in range(8)])
    sched = cl.scheduler
    # drive client 0 until the fused parent responds (fallbacks resubmitted
    # at that tick), then crash before the fallbacks can finish
    fused = next(r for r in sched.history if r.kind == "search_batch")
    guard = 0
    while fused.result is None:
        assert sched.step(0) and (guard := guard + 1) < 10**5
    assert sched.inflight(0) > 0                # fallback searches in flight
    cl.crash_client(0)
    assert all(f.done() for f in futs)
    res = [f.result() for f in futs]
    statuses = {r.status for r in res}
    assert statuses <= {OK, CRASHED} and CRASHED in statuses
    assert [r.value for r in res if r.status == OK] == \
        [[i] for i in range(1, 8, 2)]           # fast-path hits kept their value


# ------------------------------------------------------------ typed stall ---
def test_scheduler_stalled_is_typed():
    cl = _cluster()
    be = cl.store(0).backend
    orphan = KVFuture(be)                       # future with no record
    with pytest.raises(SchedulerStalled):
        be.drive(orphan)


# ------------------------------------------------------ dynamic membership --
def test_add_client_at_runtime_propagates_epoch():
    cl = _cluster(num_clients=2)
    kv = cl.store(0)
    for i in range(10):
        kv.put(i, [i])
    epoch0 = cl.pool.epoch
    cid = cl.add_client()
    assert cid == 2
    assert cl.pool.epoch == epoch0 + 1
    # every live client observed the new lease epoch (prepare committed)
    assert all(c.epoch == cl.pool.epoch and not c.notified_prepare
               for c in cl.clients.values())
    # the joiner serves reads immediately
    assert all(cl.store(cid).get(i) == [i] for i in range(10))
    # and writes through the same pipelined surface
    assert cl.store(cid).put(b"new", b"v").status == OK
    assert kv.get(b"new") == b"v"


def test_remove_client_drains_then_rejects():
    cl = _cluster()
    kv = cl.store(1)
    futs = kv.submit_batch([Op.put(i, [i]) for i in range(12)])
    epoch0 = cl.pool.epoch
    cl.remove_client(1)                         # drains in-flight ops first
    assert all(f.done() for f in futs)
    assert all(f.result().status == OK for f in futs)
    assert cl.pool.epoch == epoch0 + 1
    with pytest.raises(ClientCrashed) as ei:
        cl.store(1)
    assert ei.value.reason == "removed"
    with pytest.raises(ClientCrashed) as ei:
        cl.scheduler.submit(1, "insert", 99, [1])
    assert ei.value.reason == "removed"
    # the data it wrote survives; health reports the removal
    assert all(cl.store(0).get(i) == [i] for i in range(12))
    h = cl.health()
    assert [c.status for c in h.clients if c.cid == 1] == ["removed"]


def test_stale_store_handle_after_removal_raises():
    """A KVStore bound before remove_client must reject submits with the
    typed error — never silently settle CRASHED or run on a reused cid."""
    cl = _cluster()
    kv = cl.store(1)
    kv.put(5, [5])
    cl.remove_client(1)
    with pytest.raises(ClientCrashed) as ei:
        kv.put(6, [6])
    assert ei.value.reason == "removed"
    with pytest.raises(ClientCrashed):
        kv.get(5)
    # the cid is reused by a later join; the stale handle still rejects
    assert cl.add_client() == 1
    with pytest.raises(ClientCrashed) as ei:
        kv.put(7, [7])
    assert ei.value.reason == "replaced"
    assert cl.store(1).get(5) == [5]            # fresh binding works


def test_removed_cid_reused_without_inheritance():
    """add/remove churn reuses cids; the reused cid inherits neither the
    leaver's meta list heads nor its blocks, and the leaver's data stays
    reachable through the index."""
    from repro.core.heap import FIRST_DATA_REGION
    cl = _cluster(num_clients=2)
    kv1 = cl.store(1)
    for i in range(8):
        assert kv1.put(i, [i]).status == OK
    cl.remove_client(1)
    # no BAT entry still names the departed client
    for g in range(FIRST_DATA_REGION, cl.pool.num_regions):
        bat = cl.pool.mns[cl.pool.primary_mn(g)].regions[g]
        assert not any(int(bat[b]) == 2
                       for b in range(cl.pool.cfg.blocks_per_region))
    for _ in range(3):                          # churn: never exhausts cids
        cid = cl.add_client()
        assert cid == 1
        kv = cl.store(cid)
        assert all(kv.get(i) == [i] for i in range(8))   # data survived
        assert kv.put(100, [100]).status == OK           # fresh allocations
        cl.remove_client(cid)
    assert cl._next_cid == 2                    # no meta-region creep


def test_crash_unknown_or_removed_cid_typed():
    cl = _cluster()
    with pytest.raises(ClientCrashed) as ei:
        cl.crash_client(99)
    assert ei.value.reason == "unknown"
    cl.remove_client(2)
    with pytest.raises(ClientCrashed) as ei:
        cl.crash_client(2)
    assert ei.value.reason == "removed"


def test_remove_client_drains_amid_other_clients_work():
    cl = _cluster()
    kv0, kv1 = cl.store(0), cl.store(1)
    f0 = kv0.submit_batch([Op.put(i, [i]) for i in range(8)])
    f1 = kv1.submit_batch([Op.put(10 + i, [i]) for i in range(8)])
    cl.remove_client(0)          # drain round-robins the whole cluster
    assert all(f.done() and f.result().status == OK for f in f0)
    [f.result() for f in f1]     # the survivor's pipeline is unharmed
    assert all(cl.store(1).get(i) == [i] for i in range(8))


# --------------------------------------------------------- fault injection --
def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultEvent("explode", 0, at_tick=1)
    with pytest.raises(ValueError):
        FaultEvent("crash_mn", 0)               # no trigger
    with pytest.raises(ValueError):
        FaultEvent("crash_mn", 0, at_tick=1, after_ops=1)
    plan = FaultPlan().crash_mn(1, at_tick=5).crash_client(0, after_ops=3)
    assert len(plan) == 2


def test_injector_fires_at_boundaries_and_auto_recovers_mn():
    """crash_mn fires mid-workload from the plan; the scheduler detects the
    dead MN itself (no master.maybe_recover_mns() anywhere) and the
    workload completes; crash_client fires later at an op boundary."""
    cl = _cluster(num_clients=2)
    kv = cl.store(0)
    inj = cl.inject(FaultPlan()
                    .crash_mn(2, after_ops=10)
                    .crash_client(0, after_ops=20))
    statuses = []
    for i in range(40):
        try:
            statuses.append(kv.put(i, [i]).status)
        except ClientCrashed:
            statuses.append("REJECTED")
    assert inj.done and len(inj.fired) == 2
    assert inj.poll not in cl.scheduler._tick_hooks   # spent hook pruned
    assert cl.scheduler.mn_recoveries == 1      # auto-detected, Alg-3 ran
    assert not cl.pool.mns[2].alive
    n_ok = statuses.count(OK)
    assert n_ok >= 20                           # survived the MN crash
    assert statuses.count("REJECTED") == 40 - n_ok - statuses.count(CRASHED)
    # every OK'd key is readable on the surviving client despite both faults
    kv1 = cl.store(1)
    assert all(kv1.get(i) == [i]
               for i, s in enumerate(statuses) if s == OK)


def test_mn_detect_delay_defers_recovery():
    cl = FuseeCluster(DMConfig(num_mns=4, replication=3), num_clients=1,
                      mn_detect_delay=10_000)
    kv = cl.store(0)
    for i in range(4):
        kv.put(i, [i])
    cl.crash_mn(1)
    kv.get(0)                                   # ops run inside the window
    assert cl.scheduler.mn_recoveries == 0      # lease not yet expired
    assert cl.health().alive_mns == 3


# ------------------------------------------------------------------ health --
def test_health_snapshot_contents():
    cl = _cluster()
    kv = cl.store(0)
    for i in range(6):
        kv.put(i, [i])
    futs = kv.submit_batch([Op.put(10 + i, [i]) for i in range(4)])
    cl.crash_client(0)
    [f.result() for f in futs]
    cl.recover_client(0, reassign_to_cid=1)
    cl.crash_mn(3)
    cl.store(1).get(0)                          # a step -> MN auto-recovery
    h = cl.health()
    assert h.epoch == cl.pool.epoch and h.tick == cl.scheduler.tick
    assert h.alive_mns == 3 and len(h.mns) == 4
    assert not h.mns[3].alive
    assert sum(m.primary_regions for m in h.mns if m.alive) == \
        cl.pool.num_regions
    by_cid = {c.cid: c for c in h.clients}
    assert by_cid[0].status == "crashed" and by_cid[1].status == "live"
    assert by_cid[0].completed_ops == 6 and by_cid[0].crashed_ops == 4
    assert h.crashed_ops == 4
    assert h.client_recoveries == 1 and h.mn_recoveries == 1
    assert h.recovery.reconnect_ms > 0          # cumulative RecoveryStats
    assert "epoch=" in h.summary()


def test_stats_reports_failure_state():
    cl = _cluster()
    kv = cl.store(0)
    kv.put(1, [1])
    cl.crash_mn(1)
    kv.get(1)
    st = kv.stats()
    assert st["mns_alive"] == 3 and st["crashed"] is False
    assert st["epoch"] == cl.pool.epoch


def test_scan_stats_deprecated_alias_warns():
    import warnings
    kv = _cluster().store(0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert kv.scan_stats() == kv.stats()
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


# ------------------------------------------------------------ device twin ---
def test_device_backend_crashed_worker_raises_typed():
    from repro.serving import DeviceBackend, PoolConfig
    from repro.core.api import KVStore
    be = DeviceBackend(PoolConfig(n_pages=64, n_buckets=32,
                                  slots_per_bucket=4, replicas=2))
    store = KVStore(be)
    assert store.put(b"k", b"v").status == OK
    be.pool.crash_client(be.cid)
    be.crashed = True                           # ServeEngine.crash_worker path
    with pytest.raises(ClientCrashed) as ei:
        store.put(b"k2", b"v2")
    assert ei.value.cid == be.cid
    assert store.stats()["crashed"] is True
    be.pool.recover_client(be.cid)
    be.crashed = False                          # ServeEngine.recover_worker path
    assert store.put(b"k2", b"v2").status == OK
