"""Systematic schedule exploration: a DPOR model checker over the sim.

Random storms (races.py `_storm_run`) *sample* interleavings; this module
*enumerates* them for small scopes — 2–3 clients, 1–2 MNs, a few colliding
keys — and asserts the full FUSEE correctness contract on every maximal
schedule: per-key linearizability, race-detector-clean, heap-audit-clean,
and no acked-write-loss.  The churn-cutover acked-write-loss bug (storm
seed 7, PR 6's strict xfail) is the first paying customer: the minimized
counterexample from the `cutover` scope is the root-cause artifact.

Choice-point contract (core/sim.py): every nondeterministic decision of a
step-mode run is one `Choice` — which (client, MN) QP lane fires its head
verb, when a pending master call dispatches, and when an armed boundary
event (client/MN crash, MN-failure detection, migration chunk / cutover
commit) triggers.  `Scheduler.choices()` enumerates the enabled set in a
deterministic order; `Scheduler.fire(ch)` executes exactly one.  A state
is therefore reproducible as the `Choice` sequence that reached it.

Exploration = stateless depth-first search by re-execution: a branch is
(prefix choices) + (one backtracked choice) + leftmost deterministic
continuation to a *maximal* (drained) state.  Two reductions prune the
tree:

  * dynamic partial-order reduction — per fired transition the attached
    `VerbTracer` yields its word-level footprint; only transitions whose
    footprints conflict (same region words, at least one writer, from
    different processes) schedule a backtrack point.  Boundary events get
    a conservative global footprint (they reorder against everything).
  * state-hash dedup — a blake2b digest over (pool region bytes, placement
    + epoch, QP/master queue contents, per-client delivery digests, armed
    events, completed-op results).  Client-internal state (allocator
    cursors, caches, generator frames) is a pure function of the client's
    delivery history, which `Scheduler.client_digest` folds per delivery,
    so equal digests imply equal continuations.  Reaching a visited state
    cuts the branch: its (deterministic leftmost) continuation — and the
    invariant verdict at its maximal state — was already covered.

On violation the full schedule is delta-debugged (ddmin) down to a minimal
choice prefix whose leftmost continuation still violates, and saved as a
pickle-free `.npz` counterexample:

    python -m repro.analysis.explore --scope cutover \
        --unsafe client.UNSAFE_FREE_OWN_ON_RETRY --out ce/
    python -m repro.analysis.explore --repro ce/cutover.npz
"""
from __future__ import annotations

import argparse
import hashlib
import sys
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core import client as client_mod
from ..core import master as master_mod
from ..core import race as race_mod
from ..core import sim as sim_mod
from ..core.heap import DMConfig
from ..core.layout import fingerprint
from ..core.linearize import HOp, check_linearizable, records_to_hops
from ..core.sim import Choice
from ..core.store import FuseeCluster
from . import heapcheck, races
from .trace import CAS, FAA, READ, WRITE

__all__ = ["Explorer", "ExploreResult", "Violation", "Scope", "SCOPES",
           "save_counterexample", "load_counterexample", "replay", "main"]

# crash probes never ack, so a landed-or-not crashed write is modeled as a
# maybe-op during linearization; cap the subset blow-up (events per scope
# arm at most one or two crashes)
_MAX_CRASHED_SUBSET = 6
_FAR_FUTURE = 1 << 60


# --------------------------------------------------------------------- flags
# the test-only protocol-hole switches a scope may re-enable, addressed as
# "module.ATTRIBUTE" (the same names the regression tests flip)
_FLAG_MODULES = {"client": client_mod, "master": master_mod,
                 "sim": sim_mod}


def _flag_items(flags: Optional[Dict[str, bool]]) -> List[Tuple[str, bool]]:
    return sorted((flags or {}).items())


class _FlagGuard:
    """Apply test-only UNSAFE_* module flags for the guard's lifetime."""

    def __init__(self, flags: Optional[Dict[str, bool]]):
        self.flags = _flag_items(flags)
        self._saved: List[Tuple[object, str, bool]] = []

    def __enter__(self):
        for spec, val in self.flags:
            modname, attr = spec.split(".", 1)
            mod = _FLAG_MODULES[modname]
            if not attr.startswith("UNSAFE_") or not hasattr(mod, attr):
                raise ValueError(f"unknown test-only flag {spec!r}")
            self._saved.append((mod, attr, getattr(mod, attr)))
            setattr(mod, attr, val)
        return self

    def __exit__(self, *exc):
        while self._saved:
            mod, attr, old = self._saved.pop()
            setattr(mod, attr, old)
        return False


# -------------------------------------------------------------------- scopes
@dataclass
class ScopeSetup:
    """One fresh, fully-submitted exploration instance."""
    cluster: FuseeCluster
    keys: Tuple[int, ...]              # keys under linearizability check
    tracer: object                     # attached VerbTracer


@dataclass(frozen=True)
class Scope:
    name: str
    doc: str
    build: Callable[[], ScopeSetup]


def _small_cfg(*, num_mns=1, replication=1, regions_per_mn=4,
               index_buckets=4) -> DMConfig:
    return DMConfig(num_mns=num_mns, replication=replication,
                    region_words=1 << 9, block_words=1 << 7,
                    regions_per_mn=regions_per_mn,
                    index_buckets=index_buckets, slots_per_bucket=7,
                    size_classes=4, index_shards=1)


def _mk_cluster(cfg: DMConfig, num_clients: int) -> FuseeCluster:
    cl = FuseeCluster(cfg, num_clients=num_clients, seed=0,
                      enable_cache=False)
    cl.scheduler.manual_boundaries = True
    cl.scheduler.track_digests = True
    return cl


def _setup(cl: FuseeCluster, keys) -> ScopeSetup:
    tr = cl.attach_tracer(capacity=1 << 16)
    return ScopeSetup(cluster=cl, keys=tuple(keys), tracer=tr)


def colliding_keys(n_buckets: int, count: int = 2,
                   start: int = 1) -> List[int]:
    """``count`` distinct keys sharing one RACE bucket pair (so their
    inserts race on the same empty slot word) with pairwise distinct
    fingerprints (so a lost insert is *detectable* as a foreign value)."""
    base = race_mod.bucket_pair(start, n_buckets)
    keys, fps, k = [start], {fingerprint(start)}, start + 1
    while len(keys) < count:
        if race_mod.bucket_pair(k, n_buckets) == base \
                and fingerprint(k) not in fps:
            keys.append(k)
            fps.add(fingerprint(k))
        k += 1
    return keys


def _scope_insert_race() -> ScopeSetup:
    cl = _mk_cluster(_small_cfg(), num_clients=2)
    for cid in (0, 1):
        cl.scheduler.submit(cid, "insert", 1, [cid + 1, 7])
        cl.scheduler.submit(cid, "update", 1, [cid + 1, 8])
    return _setup(cl, [1])


def _scope_no_fault() -> ScopeSetup:
    cl = _mk_cluster(_small_cfg(), num_clients=2)
    k1, k2 = colliding_keys(cl.cfg.index_buckets, 2)
    cl.scheduler.submit(0, "insert", k1, [10, 1])
    cl.scheduler.submit(0, "update", k1, [11, 1])
    cl.scheduler.submit(1, "insert", k2, [20, 1])
    return _setup(cl, [k1, k2])


def _scope_lost_ack() -> ScopeSetup:
    cl = _mk_cluster(_small_cfg(), num_clients=2)
    k1, k2 = colliding_keys(cl.cfg.index_buckets, 2)
    cl.scheduler.submit(0, "insert", k1, [10, 1])
    cl.scheduler.submit(1, "insert", k2, [20, 1])
    return _setup(cl, [k1, k2])


def _scope_crash() -> ScopeSetup:
    cl = _mk_cluster(_small_cfg(), num_clients=2)
    cl.scheduler.submit(0, "insert", 1, [10, 1])
    cl.scheduler.submit(1, "insert", 1, [20, 1])
    # unconditionally enabled: the event reaches the *initial* execution
    # (at its tail), and event-vs-verb conflicts then walk it backwards to
    # every verb boundary — systematic crash-point enumeration
    cl.scheduler.arm_event("crash_client:1", lambda sc: sc.crash_client(1),
                           once=True)
    return _setup(cl, [1])


def _scope_stale_epoch() -> ScopeSetup:
    cl = _mk_cluster(_small_cfg(num_mns=2, replication=2, regions_per_mn=2),
                     num_clients=1)
    cl.scheduler.submit(0, "insert", 1, [10, 1])
    cl.scheduler.submit(0, "update", 1, [11, 1])
    cl.scheduler.arm_event("crash_mn:1", lambda sc: sc.crash_mn(1),
                           once=True)
    return _setup(cl, [1])


def _scope_cutover() -> ScopeSetup:
    # r=3 so the round's backup-CAS evidence can SPLIT: one backup CAS
    # lands, the membership bump bounces the other -> fail_query sees
    # backups [v_new, 0], its majority tie-break decides "not applied,
    # retry" while the evidence sits on backup 1 — which the cutover's
    # Alg-3 repair then adopts into every replica.  The retry re-reads
    # its own half-installed value as v_old: the seed-7 shape.
    cl = _mk_cluster(_small_cfg(num_mns=3, replication=3, regions_per_mn=2),
                     num_clients=1)
    cl.scheduler.submit(0, "insert", 1, [10, 1])
    cl.migrator.chunk_words = cl.cfg.region_words // 2   # 2-fire copy window
    # TWO separately-placed boundaries: the add_mn membership bump
    # (bounces a mid-round verb -> master arbitration can answer RETRY
    # off the unrepaired primary) and the later cutover commit (whose
    # repair spreads the backup-CAS evidence) — so scale-out itself is
    # an enumerated event, and the migration rides the migrate event
    cl.arm_migration_event()         # cutover boundary = enumerated choice
    cl.scheduler.arm_event("add_mn", lambda sc: cl.add_mn(wait=False),
                           once=True)
    return _setup(cl, [1])


def _owned_primary_mn(sc, cid: int):
    """The MN holding replica 0 of the first data region whose BAT records
    a block owned by ``cid`` (None until the client has allocated)."""
    pool = sc.pool
    for g in pool.data_regions:
        mem = pool.mns[pool.primary_mn(g)].regions.get(g)
        if mem is None:
            continue
        for b in range(pool.cfg.blocks_per_region):
            if int(mem[b]) == cid + 1:
                return pool.primary_mn(g)
    return None


def _scope_loser_reset() -> ScopeSetup:
    # the storm seeds-8/15 shape, minimized: client 1 dies mid-insert
    # with its KV object landed on the primary replica only (the crash
    # drops the backup-write lane), §5.3 recovery REDOES the logged op —
    # installing the index slot and committing the log off the one good
    # copy — and then the MN holding that copy dies too.  Alg-3 re-homes
    # the region onto the surviving (all-zero at the object) replica:
    # the slot now references garbage, which the heap audit reports as a
    # slot surviving a loser reset.  master.UNSAFE_REDO_NO_CONVERGE
    # re-opens the hole; the fix converges the object replicas before
    # the redo makes the object reachable.
    cl = _mk_cluster(_small_cfg(num_mns=2, replication=2, regions_per_mn=2),
                     num_clients=2)
    k1, k2 = colliding_keys(cl.cfg.index_buckets, 2)
    cl.scheduler.submit(0, "insert", k1, [10, 1])
    cl.scheduler.submit(1, "insert", k2, [20, 1])
    cl.scheduler.arm_event("crash_client:1", lambda sc: sc.crash_client(1),
                           once=True)
    cl.scheduler.arm_event(
        "recover_client:1", lambda sc: cl.recover_client(1),
        enabled=lambda sc: cl.clients[1].crashed, once=True)
    # crash the MN holding the primary copy of the crashed client's data
    # (resolved per-state: placement is deterministic but allocation-time)
    cl.scheduler.arm_event(
        "crash_mn_primary", lambda sc: sc.crash_mn(_owned_primary_mn(sc, 1)),
        enabled=lambda sc: (cl.clients[1].crashed
                            and _owned_primary_mn(sc, 1) is not None),
        once=True)
    return _setup(cl, [k1, k2])


SCOPES: Dict[str, Scope] = {s.name: s for s in (
    Scope("insert_race", "2 clients insert the same key (1 MN, r=1) — the "
          "DPOR reduction benchmark scope", _scope_insert_race),
    Scope("no_fault", "2 clients, 3 ops over 2 bucket-colliding keys; no "
          "events armed", _scope_no_fault),
    Scope("lost_ack", "2 clients insert bucket-colliding keys; the PR-3 "
          "empty-slot-CAS lost-ack scope (client.UNSAFE_ACK_LOST_EMPTY_CAS)",
          _scope_lost_ack),
    Scope("crash", "insert race plus a client-crash boundary event at every "
          "verb boundary", _scope_crash),
    Scope("stale_epoch", "1 client, 2 MNs r=2, MN-crash + detection events; "
          "the PR-3 stale-epoch scope (sim.UNSAFE_EXEC_STALE_EPOCH)",
          _scope_stale_epoch),
    Scope("cutover", "1 client upserting across a live add_mn index "
          "migration; the churn-cutover acked-write-loss scope "
          "(client.UNSAFE_FREE_OWN_ON_RETRY)", _scope_cutover),
    Scope("loser_reset", "2 clients over colliding keys; client 1 crashes "
          "mid-insert, is recovered (§5.3 redo), then the MN holding its "
          "object's primary copy crashes — the storm seeds-8/15 torn-redo "
          "scope (master.UNSAFE_REDO_NO_CONVERGE)", _scope_loser_reset),
)}


# --------------------------------------------------------------- state hash
def _hash_bytes(parts: List[bytes]) -> int:
    return int.from_bytes(
        hashlib.blake2b(b"\x00".join(parts), digest_size=16).digest(),
        "little")


def state_hash(cl: FuseeCluster) -> int:
    """Digest of everything the continuation of a run can depend on:
    pool bytes (index words, BAT, bitmaps, objects, embedded logs — log
    heads live in pool words), placement + epoch, migration progress,
    scheduler queue contents, per-client delivery digests, armed events,
    and completed-op results.  Tick counters are deliberately excluded:
    two schedules reaching the same state at different ticks are the
    same state."""
    pool, sched = cl.pool, cl.scheduler
    parts: List[bytes] = [int(pool.epoch).to_bytes(8, "little")]
    for mn in pool.mns:
        parts.append(b"M%d:%d:%d" % (mn.mid, mn.alive, mn.retired))
        for g in sorted(mn.regions):
            parts.append(b"g%d" % g)
            parts.append(mn.regions[g].tobytes())
        parts.append(repr(sorted(mn.alloc_cursor.items())).encode())
    parts.append(repr(sorted((g, tuple(r))
                             for g, r in pool.placement.items())).encode())
    parts.append(repr(sorted(pool.migrations)).encode())
    parts.append(repr(cl.migrator.status()).encode())
    for cid in sorted(sched.pipes):
        pipe = sched.pipes[cid]
        parts.append(b"c%d" % cid)
        parts.append(
            sched.client_digest.get(cid, 0).to_bytes(16, "little"))
        for op_id in sorted(pipe.runs):
            run = pipe.runs[op_id]
            parts.append(b"r%d:%d:%s:%d" % (op_id, run.phase_no,
                                            run.phase_label.encode(),
                                            run.pending))
        for mn_id in sorted(pipe.qp):
            for run, idx, v in pipe.qp[mn_id]:
                parts.append(b"q%d:%d:%d:%s:%d:%d:%d:%d" % (
                    mn_id, run.record.op_id, idx, v.kind.encode(),
                    v.region, v.replica, int(v.off), v.epoch))
        for run in pipe.master_q:
            call = run.master_call
            parts.append(b"mc%d:%s" % (run.record.op_id,
                                       repr((call.kind if call else None,
                                             call.payload if call else None))
                                       .encode()))
    for c in cl.clients.values():
        parts.append(b"ce%d:%d:%d" % (c.cid, c.epoch, c.crashed))
    parts.append(repr(sorted(sched._events)).encode())
    parts.append(b"det%d" % (sched._mn_detect_at is not None))
    for rec in sched.history:
        if rec.result is not None:
            parts.append(b"h%d:%s:%s" % (
                rec.op_id, str(rec.result.status).encode(),
                repr(rec.result.value).encode()))
    return _hash_bytes(parts)


# --------------------------------------------------------------- footprints
# a footprint is a list of (region, lo, hi, is_write) word intervals; None
# means "conflicts with everything" (boundary events, alloc/free verbs)
Footprint = Optional[List[Tuple[int, int, int, bool]]]


def _footprint_from(tracer, n0: int, n1: int) -> List:
    fp = []
    buf = tracer.buf
    cap = tracer.capacity
    for i in range(n0, n1):
        j = i % cap
        off, n = int(buf["off"][j]), max(1, int(buf["n"][j]))
        fp.append((int(buf["region"][j]), off, off + n,
                   int(buf["verb"][j]) != READ))
    return fp


def _conflict(a: Footprint, b: Footprint) -> bool:
    if a is None or b is None:
        return True
    for ra, lo_a, hi_a, wa in a:
        for rb, lo_b, hi_b, wb in b:
            if ra == rb and (wa or wb) and lo_a < hi_b and lo_b < hi_a:
                return True
    return False


def _dependent(ca: Choice, fa: Footprint, cb: Choice, fb: Footprint) -> bool:
    """Dependence relation shared by the race scan and the sleep sets —
    the two MUST agree or sleep pruning can starve a scheduled backtrack.
    Same-cid master-vs-lane pairs are order-forced (master-call priority)
    and therefore dependent regardless of footprints."""
    forced = (ca.kind != "event" and cb.kind != "event"
              and ca.cid == cb.cid and "master" in (ca.kind, cb.kind))
    return forced or _conflict(fa, fb)


def _proc_of(ch: Choice) -> str:
    """DPOR process id: a unit whose transitions are totally ordered.

    A client's QP lanes are INDEPENDENT FIFO streams (a doorbell batch
    fans out per MN), so each (cid, mn) lane is its own process — only
    same-lane verbs are program-ordered, and a membership bump CAN land
    between two lanes of one phase (the seed-7 shape needs exactly that
    reorder).  Master-call dispatch is one sequenced stream per client;
    each armed event is a singleton process."""
    if ch.kind == "event":
        return f"e:{ch.name}"
    if ch.kind == "master":
        return f"m:{ch.cid}"
    return f"c:{ch.cid}:{ch.mn}"


# --------------------------------------------------------------- invariants
@dataclass
class Violation:
    kind: str                          # linearizability | acked_write_lost |
    detail: str                        # race:<rule> | heap_audit | exception
    schedule: Tuple[Choice, ...]       # full schedule that reached it
    minimized: Optional[Tuple[Choice, ...]] = None

    def __str__(self) -> str:
        sched = self.minimized if self.minimized is not None \
            else self.schedule
        return (f"{self.kind}: {self.detail}\n  schedule "
                f"({len(sched)} choice points):\n" +
                "\n".join(f"    {i:3d}. {c}" for i, c in enumerate(sched)))


def _lin_with_crashes(hops: List[HOp], crashed: List[HOp]) -> bool:
    """A crashed write may or may not have taken effect; linearizable iff
    some landed-subset makes the definite history linearizable."""
    crashed = crashed[:_MAX_CRASHED_SUBSET]
    for mask in range(1 << len(crashed)):
        trial = list(hops)
        for i, h in enumerate(crashed):
            if mask >> i & 1:
                trial.append(h)
        if check_linearizable(trial):
            return True
    return False


def check_invariants(setup: ScopeSetup) -> List[Violation]:
    """Run the full contract on a drained (maximal) state.  Returns bare
    violations; the caller attaches schedules."""
    cl, keys, tracer = setup.cluster, setup.keys, setup.tracer
    out: List[Violation] = []
    sched = cl.scheduler
    if tracer.dropped:
        out.append(Violation("exception",
                             f"tracer ring wrapped ({tracer.dropped} "
                             "dropped) — raise capacity", ()))

    # final-read probes: one search per key from a live client makes acked
    # losses visible to the linearizability check below
    probe_cids = [c.cid for c in cl.clients.values() if not c.crashed]
    finals: Dict[int, Optional[tuple]] = {}
    if probe_cids:
        pc = min(probe_cids)
        for k in keys:
            rec = sched.submit(pc, "search", k)
            while sched.eligible(pc):
                fired = False
                for ch in sched.choices():
                    if ch.kind != "event":
                        sched.fire(ch)
                        fired = True
                        break
                if not fired:
                    break
            res = rec.result
            finals[k] = (tuple(res.value)
                         if res is not None and res.value is not None
                         else None)

    for k in keys:
        hops = records_to_hops(sched.history, k)
        crashed = [HOp(op_id=r.op_id, kind=r.kind, inv=r.inv_tick,
                       resp=_FAR_FUTURE, wrote=tuple(r.value),
                       read=None, status="OK")
                   for r in sched.history
                   if r.key == k and r.result is not None
                   and r.result.status not in ("OK", "NOT_FOUND")
                   and r.kind in ("insert", "update") and r.value is not None]
        if not _lin_with_crashes(hops, crashed):
            out.append(Violation(
                "linearizability",
                f"key {k}: history of {len(hops)} ops "
                f"(+{len(crashed)} crashed writes) not linearizable; "
                f"final read = {finals.get(k)}", ()))
            continue
        # direct acked-write-loss statement (subsumed by linearizability
        # with the probe appended, but reported with a sharper kind)
        acked = [tuple(r.value) for r in sched.history
                 if r.key == k and r.kind in ("insert", "update")
                 and r.result is not None and r.result.status == "OK"]
        maybe = acked + [tuple(h.wrote) for h in crashed]
        deletes = any(r.key == k and r.kind == "delete"
                      and r.result is not None
                      and r.result.status not in ("NOT_FOUND",)
                      for r in sched.history)
        fin = finals.get(k)
        if fin is not None and list(fin) not in [list(v) for v in maybe]:
            out.append(Violation(
                "acked_write_lost",
                f"key {k}: final value {fin} was never written "
                f"(acked {acked})", ()))
        elif fin is None and acked and not deletes and not crashed:
            out.append(Violation(
                "acked_write_lost",
                f"key {k}: {len(acked)} acked writes but the key reads "
                "ABSENT with no delete in history", ()))

    for f in races.detect(tracer, scheduler=sched):
        out.append(Violation(f"race:{f.rule}", f.detail, ()))
    rep = heapcheck.audit(cl)
    if not rep.ok:
        out.append(Violation("heap_audit", "; ".join(rep.errors[:4]), ()))
    return out


# ----------------------------------------------------------------- explorer
@dataclass
class _Node:
    """One state on the current DFS path + the transition taken from it."""
    enabled: Tuple[Choice, ...]
    chosen: Choice
    proc: str
    footprint: Footprint
    hash_after: int
    done: Set[Choice] = field(default_factory=set)
    backtrack: Set[Choice] = field(default_factory=set)
    # happens-before bookkeeping (filled by _update_backtracks): this
    # transition's index within its proc (1-based) and its vector clock —
    # proc -> highest pidx of that proc that happens-before this node
    pidx: int = 0
    vc: Dict[str, int] = field(default_factory=dict)
    # sleep-set bookkeeping: `sleep` is the set in force ON ARRIVAL at
    # this state (choice -> the footprint it had when put to sleep —
    # still valid because only dependent transitions wake it, and a
    # lane's head verb cannot change while the lane is asleep);
    # `slept` records each fully-explored branch choice with its
    # footprint, so later branches from this node put it to sleep
    sleep: Dict[Choice, Footprint] = field(default_factory=dict)
    slept: Dict[Choice, Footprint] = field(default_factory=dict)


@dataclass
class ExploreResult:
    scope: str
    naive: bool
    states: int = 0                    # distinct states visited
    executions: int = 0                # maximal (or cut) executions run
    transitions: int = 0               # newly recorded transitions
    replay_fires: int = 0              # prefix re-execution transitions
    dedup_cuts: int = 0
    sleep_blocks: int = 0              # executions pruned by sleep sets
    complete: bool = True              # budget not exhausted
    violations: List[Violation] = field(default_factory=list)
    visit_digest: str = ""             # order-sensitive digest of new states
    wall_s: float = 0.0

    def summary(self) -> str:
        v = (f"{len(self.violations)} VIOLATION(S): "
             + ", ".join(sorted({x.kind for x in self.violations}))
             if self.violations else "no violations")
        return (f"[{self.scope}{' naive' if self.naive else ''}] "
                f"{self.states} states, {self.executions} executions, "
                f"{self.transitions} transitions "
                f"({self.dedup_cuts} dedup cuts, {self.sleep_blocks} sleep "
                f"blocks, {self.replay_fires} replay fires) "
                f"in {self.wall_s:.2f}s — "
                f"{'complete' if self.complete else 'budget-capped'}; {v}")


class Explorer:
    """DFS + DPOR + state-hash dedup over one scope (see module doc)."""

    def __init__(self, scope, *, flags: Optional[Dict[str, bool]] = None,
                 naive: bool = False, max_states: int = 200_000,
                 max_depth: int = 3000, stop_on_violation: bool = True):
        self.scope = SCOPES[scope] if isinstance(scope, str) else scope
        self.flags = dict(flags or {})
        self.naive = naive
        self.max_states = max_states
        self.max_depth = max_depth
        self.stop_on_violation = stop_on_violation
        self.visited: Set[int] = set()
        self._visit_hash = hashlib.blake2b(digest_size=16)
        self.result = ExploreResult(scope=self.scope.name, naive=naive)

    # ------------------------------------------------------------ execution
    def _execute(self, prefix: Sequence[Choice], record_from: int,
                 sleep0: Optional[Dict[Choice, Footprint]] = None):
        """Fire ``prefix`` then extend leftmost to a maximal state.
        Steps >= ``record_from`` are recorded as fresh `_Node`s; the
        prefix below it replays without hashing (its nodes persist on the
        caller's stack).  ``sleep0`` is the sleep set in force at the
        branch state: a sleeping choice leads to a subtree already fully
        explored from an earlier sibling, so it is never fired until a
        dependent transition wakes it; an execution whose every enabled
        choice sleeps is a redundant interleaving and stops early.
        Returns (new_nodes, setup, cut, exc)."""
        setup = self.scope.build()
        cl = setup.cluster
        tracer = setup.tracer
        nodes: List[_Node] = []
        sleep: Dict[Choice, Footprint] = dict(sleep0 or {})
        cut = False
        depth = 0

        def fire_one(ch: Choice, enabled: Tuple[Choice, ...]) -> bool:
            nonlocal cut, sleep
            n0 = tracer.n
            glob = False
            if ch.kind == "event":
                glob = True                      # crash/cutover: reorder
            elif ch.kind == "lane":              # against everything
                q = cl.scheduler.pipes[ch.cid].qp.get(ch.mn)
                if q and q[0][2].kind in ("alloc", "free"):
                    glob = True                  # untraced BAT traffic
            at_state_sleep = dict(sleep)
            if not cl.fire(ch):
                raise RuntimeError(f"schedule replay diverged: {ch} "
                                   f"not enabled at depth {depth}")
            fp = None if glob else _footprint_from(tracer, n0, tracer.n)
            sleep = {c: f for c, f in sleep.items()
                     if not _dependent(c, f, ch, fp)}   # wake dependents
            h = state_hash(cl)
            fresh = h not in self.visited
            if fresh:
                self.visited.add(h)
                self.result.states += 1
                self._visit_hash.update(h.to_bytes(16, "little"))
            nodes.append(_Node(enabled=enabled, chosen=ch,
                               proc=_proc_of(ch), footprint=fp,
                               hash_after=h, done={ch},
                               sleep=at_state_sleep))
            self.result.transitions += 1
            if not fresh and self.naive:
                # naive explores every choice from a state's first visit,
                # so revisits are fully covered and the branch can stop.
                # Under DPOR a cut here would discard the continuation
                # whose race analysis schedules the missing backtracks
                # (the classic DPOR x state-caching unsoundness), so DPOR
                # runs every execution to a maximal state and uses the
                # visited set for metrics only.
                self.result.dedup_cuts += 1
                cut = True
            return not cut

        exc: Optional[str] = None
        try:
            for i, ch in enumerate(prefix):
                if i < record_from:
                    if not cl.fire(ch):
                        raise RuntimeError(f"schedule replay diverged: {ch} "
                                           f"not enabled at depth {i}")
                    self.result.replay_fires += 1
                    depth += 1
                    continue
                fire_one(ch, tuple(cl.choices()))
                depth += 1
            while not cut and depth < self.max_depth:
                cs = tuple(cl.choices())
                if not cs:
                    break
                awake = [c for c in cs if c not in sleep]
                if not awake:
                    # every enabled choice sleeps: any continuation from
                    # here permutes independent transitions of a subtree
                    # an earlier sibling already covered — prune (the
                    # prefix still feeds the race scan; invariants were
                    # checked on the equivalent execution)
                    self.result.sleep_blocks += 1
                    cut = True
                    break
                fire_one(awake[0], cs)
                depth += 1
            if depth >= self.max_depth:
                raise RuntimeError(
                    f"max_depth {self.max_depth} exceeded — livelock or "
                    "scope too large")
        except RuntimeError:
            raise                    # checker errors, not protocol findings
        except Exception as e:       # a schedule CRASHING the sim is itself
            exc = f"{type(e).__name__}: {e}"      # a reportable violation
        self.result.executions += 1
        return nodes, setup, cut, exc

    # ---------------------------------------------------------------- DPOR
    def _update_backtracks(self, stack: List[_Node], new_from: int):
        if self.naive:
            for node in stack[new_from:]:
                node.backtrack |= set(node.enabled)
            return
        # Vector-clock happens-before over lane-granular procs.  HB is
        # generated by program order (same proc) plus every conflicting
        # pair, transitively: firing j merges the clock of each earlier
        # conflicting transition.  A pair (i, j) is a *race* — a reorder
        # the DFS must try — iff it conflicts and i is NOT already
        # ordered before j through j's program predecessor (nj's
        # inherited clock).  Without this, a global-footprint event
        # would re-race with every later transition on every execution
        # and the backtrack sets never converge.  Prefix nodes keep the
        # clocks computed on earlier calls (the prefix is unchanged);
        # only nodes from new_from on are stamped here.
        counters: Dict[str, int] = {}
        last_vc: Dict[str, Dict[str, int]] = {}
        for n in stack[:new_from]:
            counters[n.proc] = n.pidx
            last_vc[n.proc] = n.vc
        for j in range(new_from, len(stack)):
            nj = stack[j]
            vc = dict(last_vc.get(nj.proc, {}))   # program-order inheritance
            raced = False
            for i in range(j - 1, -1, -1):
                ni = stack[i]
                if ni.proc == nj.proc:
                    continue
                if ni.pidx <= vc.get(ni.proc, 0):
                    continue   # already happens-before j (transitively —
                    # the descending scan merges nearer clocks first)
                ci, cj = ni.chosen, nj.chosen
                forced = (ci.kind != "event" and cj.kind != "event"
                          and ci.cid == cj.cid
                          and "master" in (ci.kind, cj.kind))
                # ^ a client's master dispatch is never co-enabled with its
                #   own lanes (master-call priority): order forced, not a race
                if not forced and not _conflict(ni.footprint, nj.footprint):
                    continue
                if not forced and not raced:
                    # the LATEST conflicting, not-yet-ordered transition is
                    # j's race partner (Flanagan-Godefroid): reverse there;
                    # earlier races surface recursively on the reversed
                    # execution.  Racing every proc instead multiplies
                    # executions without widening coverage.
                    raced = True
                    if nj.chosen in ni.enabled:
                        ni.backtrack.add(nj.chosen)
                    elif nj.chosen.kind != "event":
                        # a lane/master choice absent from i's enabled set
                        # was either created after i or hidden by master-
                        # call priority — over-approximate with i's full
                        # enabled set (sound; events are always enabled
                        # while armed, so they never take this path)
                        ni.backtrack |= set(ni.enabled)
                # conflicting or forced-ordered: i happens-before j —
                # merge its clock so earlier coverage checks see it
                for p, c in ni.vc.items():
                    if c > vc.get(p, 0):
                        vc[p] = c
                vc[ni.proc] = ni.pidx
            counters[nj.proc] = counters.get(nj.proc, 0) + 1
            nj.pidx = counters[nj.proc]
            vc[nj.proc] = nj.pidx
            nj.vc = vc
            last_vc[nj.proc] = vc

    # ----------------------------------------------------------------- run
    def run(self) -> ExploreResult:
        t0 = time.perf_counter()
        with _FlagGuard(self.flags):
            self._run_locked()
        self.result.visit_digest = self._visit_hash.hexdigest()
        self.result.wall_s = time.perf_counter() - t0
        return self.result

    def _run_locked(self):
        stack, setup, cut, exc = self._execute([], 0)
        new_from = 0
        while True:
            self._update_backtracks(stack, new_from)
            if exc is not None:
                v = Violation("exception", exc,
                              tuple(n.chosen for n in stack))
                self.result.violations.append(v)
            elif not cut:
                for v in check_invariants(setup):
                    v.schedule = tuple(n.chosen for n in stack)
                    self.result.violations.append(v)
            if self.result.violations and self.stop_on_violation:
                return
            if self.result.states >= self.max_states:
                self.result.complete = False
                return

            def _avail(n: _Node) -> Set[Choice]:
                # a backtrack choice that sleeps at this state is covered
                # by an earlier sibling's subtree — skipping it is the
                # whole point of the sleep set (naive keeps none)
                return n.backtrack - n.done - set(n.sleep)

            while stack and not _avail(stack[-1]):
                stack.pop()
            if not stack:
                return
            node = stack[-1]
            ch = min(_avail(node))
            node.done.add(ch)
            # the branch just abandoned goes to sleep for later siblings
            node.slept[node.chosen] = node.footprint
            sleep0 = dict(node.sleep)
            sleep0.update(node.slept)
            sleep0.pop(ch, None)
            prefix = [n.chosen for n in stack[:-1]] + [ch]
            new_from = len(stack) - 1
            new_nodes, setup, cut, exc = self._execute(
                prefix, new_from, sleep0 if not self.naive else None)
            if new_nodes:
                # the branch state re-recorded as new_nodes[0]: it keeps
                # the accumulated bookkeeping of the node it replaces
                new_nodes[0].done = node.done
                new_nodes[0].backtrack = node.backtrack
                new_nodes[0].sleep = node.sleep
                new_nodes[0].slept = node.slept
            stack = stack[:-1] + new_nodes

    # --------------------------------------------------------- minimization
    def _violates_like(self, schedule: Sequence[Choice],
                       kind: str) -> bool:
        setup = self.scope.build()
        cl = setup.cluster
        try:
            depth = 0
            for ch in schedule:
                if cl.fire(ch):
                    depth += 1
            while depth < self.max_depth:
                cs = cl.choices()
                if not cs:
                    break
                cl.fire(cs[0])
                depth += 1
            if depth >= self.max_depth:
                return False
            found = check_invariants(setup)
        except Exception:
            return kind == "exception"
        return any(v.kind == kind for v in found)

    def minimize(self, violation: Violation) -> Violation:
        """ddmin the schedule to a minimal choice prefix whose leftmost
        continuation still produces a violation of the same kind.  Skipped
        (disabled) choices drop out for free during replay."""
        kind = violation.kind
        with _FlagGuard(self.flags):
            sched = list(violation.schedule)
            # the deterministic tail is free: binary-search the shortest
            # violating prefix first, then ddmin the remainder
            lo, hi = 0, len(sched)
            while lo < hi:
                mid = (lo + hi) // 2
                if self._violates_like(sched[:mid], kind):
                    hi = mid
                else:
                    lo = mid + 1
            sched = sched[:hi]
            n = 2
            while len(sched) >= 2 and n <= len(sched):
                chunk = len(sched) // n
                reduced = False
                for i in range(n):
                    trial = sched[:i * chunk] + sched[(i + 1) * chunk:] \
                        if i < n - 1 else sched[:i * chunk]
                    if trial != sched and self._violates_like(trial, kind):
                        sched, n, reduced = trial, max(n - 1, 2), True
                        break
                if not reduced:
                    if n >= len(sched):
                        break
                    n = min(n * 2, len(sched))
            # final pass: drop single choices
            i = 0
            while i < len(sched):
                trial = sched[:i] + sched[i + 1:]
                if self._violates_like(trial, kind):
                    sched = trial
                else:
                    i += 1
        violation.minimized = tuple(sched)
        return violation


# ------------------------------------------------------------ npz round-trip
_KIND_CODE = {"lane": 0, "master": 1, "event": 2}
_CODE_KIND = {v: k for k, v in _KIND_CODE.items()}


def save_counterexample(path: str, scope_name: str, violation: Violation,
                        flags: Optional[Dict[str, bool]] = None):
    """Pickle-free replayable counterexample: the minimized (or full)
    schedule + the scope + the UNSAFE flags it ran under."""
    sched = violation.minimized if violation.minimized is not None \
        else violation.schedule
    np.savez(path,
             schema=np.int64(1),
             scope=np.array(scope_name, dtype="U64"),
             kind=np.array(violation.kind, dtype="U64"),
             detail=np.array(violation.detail[:512], dtype="U512"),
             ckind=np.array([_KIND_CODE[c.kind] for c in sched], np.int8),
             cid=np.array([c.cid for c in sched], np.int32),
             mn=np.array([c.mn for c in sched], np.int32),
             name=np.array([c.name for c in sched], dtype="U64"),
             flags=np.array([f"{k}={int(v)}"
                             for k, v in _flag_items(flags)], dtype="U96"))


def load_counterexample(path: str):
    z = np.load(path, allow_pickle=False)
    sched = tuple(Choice(kind=_CODE_KIND[int(k)], cid=int(c), mn=int(m),
                         name=str(n))
                  for k, c, m, n in zip(z["ckind"], z["cid"], z["mn"],
                                        z["name"]))
    flags = {}
    for item in z["flags"]:
        k, _, v = str(item).partition("=")
        flags[k] = bool(int(v))
    return (str(z["scope"]), str(z["kind"]), str(z["detail"]), sched, flags)


def replay(path: str, *, out=print) -> bool:
    """Re-execute a saved counterexample; True iff the violation (any
    violation, in fact) reproduces."""
    scope_name, kind, detail, sched, flags = load_counterexample(path)
    out(f"replaying {path}: scope={scope_name} expected={kind}")
    out(f"  recorded detail: {detail}")
    if flags:
        out(f"  flags: {flags}")
    with _FlagGuard(flags):
        setup = SCOPES[scope_name].build()
        cl = setup.cluster
        for i, ch in enumerate(sched):
            fired = cl.fire(ch)
            out(f"  {i:3d}. {ch}{'' if fired else '  (skipped: disabled)'}")
        steps = 0
        while steps < 10_000:
            cs = cl.choices()
            if not cs:
                break
            cl.fire(cs[0])
            steps += 1
        out(f"  leftmost continuation: {steps} transitions to drain")
        found = check_invariants(setup)
    for v in found:
        out(f"  VIOLATION {v.kind}: {v.detail}")
    if not found:
        out("  no violation reproduced")
    return bool(found)


# -------------------------------------------------------------------- CLI
def explore(scope: str, *, flags=None, naive=False, max_states=200_000,
            max_depth=3000, minimize=True,
            stop_on_violation=True) -> ExploreResult:
    ex = Explorer(scope, flags=flags, naive=naive, max_states=max_states,
                  max_depth=max_depth, stop_on_violation=stop_on_violation)
    res = ex.run()
    if minimize:
        for v in res.violations:
            ex.minimize(v)
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.explore",
        description="systematic schedule exploration (DPOR model checker)")
    ap.add_argument("--scope", choices=sorted(SCOPES), help="scope to explore")
    ap.add_argument("--list", action="store_true", help="list scopes")
    ap.add_argument("--max-states", type=int, default=200_000)
    ap.add_argument("--max-depth", type=int, default=3000)
    ap.add_argument("--naive", action="store_true",
                    help="disable DPOR (full enumeration modulo dedup)")
    ap.add_argument("--unsafe", action="append", default=[],
                    metavar="MODULE.FLAG",
                    help="enable a test-only UNSAFE_* protocol-hole flag "
                         "(e.g. client.UNSAFE_FREE_OWN_ON_RETRY)")
    ap.add_argument("--out", default=None,
                    help="directory for counterexample .npz artifacts")
    ap.add_argument("--repro", default=None, metavar="FILE.npz",
                    help="replay a saved counterexample instead of exploring")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(SCOPES):
            print(f"{name:12s} {SCOPES[name].doc}")
        return 0
    if args.repro:
        return 1 if replay(args.repro) else 0
    if not args.scope:
        ap.error("--scope, --repro or --list required")

    flags = {spec: True for spec in args.unsafe}
    res = explore(args.scope, flags=flags, naive=args.naive,
                  max_states=args.max_states, max_depth=args.max_depth)
    print(res.summary())
    print(f"  visit digest: {res.visit_digest}")
    for i, v in enumerate(res.violations):
        print(str(v))
        if args.out:
            import os
            os.makedirs(args.out, exist_ok=True)
            suffix = f"-{i}" if len(res.violations) > 1 else ""
            path = os.path.join(args.out, f"{args.scope}{suffix}.npz")
            save_counterexample(path, args.scope, v, flags)
            print(f"  saved counterexample: {path} "
                  f"(replay: python -m repro.analysis.explore --repro {path})")
    return 1 if res.violations else 0


if __name__ == "__main__":
    sys.exit(main())
