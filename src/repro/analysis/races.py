"""Happens-before race analysis over a recorded verb trace.

The FUSEE protocol *embraces* data races: index slots are raced by CAS and
arbitrated by SNAPSHOT rules, object reads race used-bit resets and are
validated by CRC, the ordered keydir plain-writes unreachable fresh leaves
before linking them.  A naive conflict detector would drown in legal races,
so every rule here is scoped by the protocol's own legalization argument —
a finding is a conflict the protocol has **no** story for:

``stale_epoch``
    A mutation executed under a lease epoch older than the pool epoch.
    The §5.2 membership model requires such verbs to bounce (MR invalid);
    one reaching memory means a guard is missing (the PR-3 stale-epoch
    redirection bug class).
``lost_cas_ack``
    An op acked OK after *losing* an empty-slot index CAS (expected 0,
    found a different key's slot value) with no later successful index
    mutation installing its value and no master arbitration
    (``MASTER_WIN``).  The acknowledged write is nowhere in the index —
    the PR-3 lost-write bug class.
``ww_race``
    Plain WRITEs from two different clients to the same DM word, with
    op intervals overlapping in real time, writing different values,
    where *neither* writer holds a CAS claim nearby (same region within
    16 words, won earlier in the same op).  QP FIFO never orders verbs
    of different clients, so nothing serializes these.  CAS-guarded
    completion writes (ordered-keydir backup broadcasts after a won
    claim) are excluded — the claim CAS is the serialization point.
``index_plain_write``
    A client-context plain WRITE or FAA to a RACE index shard.  Clients
    mutate index slots exclusively through CAS (Alg 1); a plain write
    cannot lose a race and is unconditionally wrong (read/write conflict
    scoping: data-region reads are CRC-validated, so only the index —
    where a torn or blind write is never validated — is flagged).
``clear_order``
    Within one op, a word cleared to 0 on the primary replica in a
    strictly earlier phase than on some backup.  Delete/clear paths must
    clear backups first (primary last), mirroring SNAPSHOT phase order —
    otherwise a crash between the phases resurrects the value from a
    backup after the primary already acked it gone.
``torn_read``
    A READ of an index/keydir word interleaved (by execution order)
    between two mutations of one other-client phase (doorbell batch)
    touching its range — a multi-verb mutation observed mid-flight where
    no validation catches it.  Data-region torn reads are legal (CRC +
    retry) and not flagged.

The pass is numpy-vectorized: word ranges are expanded with repeat/cumsum,
conflicts are localized by a lexsort over (word, seq), and only words with
cross-client activity fall back to per-word Python (a handful even in
storm traces), so million-verb traces analyze in seconds.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .trace import CAS, FAA, READ, VERB_NAMES, WRITE

__all__ = ["Finding", "detect", "report", "ALL_RULES",
           "TruncatedTraceWarning", "TruncatedTraceError"]

ALL_RULES = ("stale_epoch", "lost_cas_ack", "ww_race", "index_plain_write",
             "clear_order", "torn_read")

# a plain write within this many words of an earlier same-op CAS win (same
# region) counts as that claim's replication-completion write
CAS_GUARD_WINDOW = 16
# cap on per-word pairwise work: a word with pathological event counts is
# truncated (and the truncation reported) instead of going quadratic
MAX_EVENTS_PER_WORD = 256


@dataclass(frozen=True)
class Finding:
    rule: str
    region: int
    replica: int
    off: int                       # offending word address
    cids: Tuple[int, ...]
    verbs: Tuple[str, ...]
    op_ids: Tuple[int, ...]
    seqs: Tuple[int, ...]
    detail: str

    def __str__(self) -> str:
        return (f"[{self.rule}] region {self.region} replica {self.replica}"
                f" word {self.off}: cids {list(self.cids)} verbs"
                f" {list(self.verbs)} ops {list(self.op_ids)} — {self.detail}")


@dataclass
class _OpInfo:
    cid: int
    inv: int
    resp: int
    status: Optional[str] = None
    rule: Optional[str] = None


def _op_table(scheduler) -> Dict[int, _OpInfo]:
    ops: Dict[int, _OpInfo] = {}
    if scheduler is None:
        return ops
    horizon = scheduler.tick + 1
    for rec in scheduler.history:
        resp = rec.resp_tick if rec.resp_tick >= 0 else horizon
        info = _OpInfo(cid=rec.cid, inv=rec.inv_tick, resp=resp)
        if rec.result is not None:
            info.status = rec.result.status
            info.rule = rec.result.rule
        ops[rec.op_id] = info
    return ops


class TruncatedTraceWarning(UserWarning):
    """The tracer ring wrapped: the analysis covers a truncated window."""


class TruncatedTraceError(RuntimeError):
    """Raised by ``detect(..., on_truncated="fail")`` on a wrapped ring."""


def detect(tracer, scheduler=None, rules=None,
           on_truncated: str = "warn") -> List[Finding]:
    """Run the race rules over ``tracer``'s retained window.

    ``scheduler`` supplies op real-time intervals and outcomes (required
    for ``lost_cas_ack`` and the concurrency test of ``ww_race``; without
    it those rules degrade conservatively to seq-order only).

    A saturated ring silently weakens every rule — happens-before edges
    and CAS guards anchored in dropped records are invisible, so both
    false negatives AND false positives (an unguarded-looking write whose
    guard fell off) are possible.  ``on_truncated`` decides what a wrapped
    ring does: ``"warn"`` (default) emits a ``TruncatedTraceWarning``,
    ``"fail"`` raises ``TruncatedTraceError`` (CI mode), ``"ignore"``
    analyzes silently.
    """
    if on_truncated not in ("warn", "fail", "ignore"):
        raise ValueError(f"on_truncated={on_truncated!r}: expected "
                         "'warn', 'fail' or 'ignore'")
    pool = tracer.pool
    if pool is None:
        raise ValueError("tracer is not attached to a pool")
    if tracer.dropped:
        msg = (f"tracer ring wrapped: {tracer.dropped} oldest record(s) "
               f"dropped (capacity {tracer.capacity}, {tracer.n} emitted) — "
               "race analysis covers the retained window only")
        if on_truncated == "fail":
            raise TruncatedTraceError(msg)
        if on_truncated == "warn":
            warnings.warn(msg, TruncatedTraceWarning, stacklevel=2)
    return detect_events(tracer.events(), tracer.labels,
                         index_regions=set(pool.index_region_set),
                         ordered_regions=set(pool.ordered_region_set),
                         ops=_op_table(scheduler), rules=rules)


def detect_events(ev, labels, *, index_regions, ordered_regions,
                  ops: Dict[int, _OpInfo], rules=None) -> List[Finding]:
    rules = set(ALL_RULES if rules is None else rules)
    findings: List[Finding] = []
    if len(ev["seq"]) == 0:
        return findings
    ctx = _Ctx(ev, labels, index_regions, ordered_regions, ops)
    if "stale_epoch" in rules:
        findings += _rule_stale_epoch(ctx)
    if "lost_cas_ack" in rules:
        findings += _rule_lost_cas_ack(ctx)
    if "index_plain_write" in rules:
        findings += _rule_index_plain_write(ctx)
    if "clear_order" in rules:
        findings += _rule_clear_order(ctx)
    if "ww_race" in rules or "torn_read" in rules:
        findings += _word_conflict_rules(ctx, rules)
    findings.sort(key=lambda f: (f.rule, f.seqs))
    return findings


def report(findings: List[Finding], tracer=None) -> str:
    """Human-readable race report (one block per finding)."""
    dropped = tracer.dropped if tracer is not None else 0
    if not findings:
        if dropped:
            # "clean" over a truncated window is NOT a clean verdict
            return (f"race detector: no findings in retained window — "
                    f"NOT clean: ring wrapped, oldest {dropped} "
                    "record(s) dropped\n")
        return "race detector: clean (0 findings)\n"
    lines = [f"race detector: {len(findings)} finding(s)"]
    if dropped:
        lines.append(f"  (ring wrapped: oldest {dropped} events "
                     "dropped — findings cover the retained window)")
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    lines.append("  " + ", ".join(f"{r}: {n}"
                                  for r, n in sorted(by_rule.items())))
    for i, f in enumerate(findings, 1):
        lines.append(f"--- finding {i} ---")
        lines.append(str(f))
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
@dataclass
class _Ctx:
    ev: dict
    labels: list
    index_regions: set
    ordered_regions: set
    ops: Dict[int, _OpInfo]
    masks: dict = field(default_factory=dict)

    def __post_init__(self):
        ev = self.ev
        verb, ok = ev["verb"], ev["ok"].astype(bool)
        client = ev["cid"] >= 0
        hit = (verb == CAS) & ok & (ev["old"] == ev["arg"])
        mut = ok & ((verb == WRITE) | (verb == FAA) | hit)
        in_index = np.isin(ev["region"], sorted(self.index_regions)) \
            if self.index_regions else np.zeros(len(verb), bool)
        in_ordered = np.isin(ev["region"], sorted(self.ordered_regions)) \
            if self.ordered_regions else np.zeros(len(verb), bool)
        self.masks = dict(ok=ok, client=client, hit=hit, mut=mut,
                          in_index=in_index, in_ordered=in_ordered)

    def label_of(self, i: int) -> str:
        lid = int(self.ev["label"][i])
        return self.labels[lid] if 0 <= lid < len(self.labels) else "?"

    def concurrent(self, op_a: int, op_b: int) -> bool:
        """Real-time overlap of two op intervals; unknown ops are treated
        as concurrent (conservative)."""
        a, b = self.ops.get(op_a), self.ops.get(op_b)
        if a is None or b is None:
            return True
        return a.inv <= b.resp and b.inv <= a.resp


def _mk(ctx: _Ctx, rule: str, idxs, detail: str) -> Finding:
    ev = ctx.ev
    idxs = [int(i) for i in idxs]
    i0 = idxs[0]
    return Finding(
        rule=rule, region=int(ev["region"][i0]),
        replica=int(ev["replica"][i0]), off=int(ev["off"][i0]),
        cids=tuple(int(ev["cid"][i]) for i in idxs),
        verbs=tuple(VERB_NAMES[int(ev["verb"][i])] for i in idxs),
        op_ids=tuple(int(ev["op_id"][i]) for i in idxs),
        seqs=tuple(int(ev["seq"][i]) for i in idxs),
        detail=detail)


# ----------------------------------------------------------- scalar rules
def _rule_stale_epoch(ctx: _Ctx) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    cand = (m["client"] & m["ok"] & (ev["verb"] != READ)
            & (ev["epoch_issue"] >= 0)
            & (ev["epoch_issue"] != ev["epoch_exec"]))
    out = []
    for i in np.nonzero(cand)[0]:
        out.append(_mk(
            ctx, "stale_epoch", [i],
            f"mutation issued under lease epoch {int(ev['epoch_issue'][i])} "
            f"executed at pool epoch {int(ev['epoch_exec'][i])} "
            f"(phase '{ctx.label_of(i)}', tick {int(ev['tick'][i])}) — "
            "stale verbs must bounce, not land on re-homed placement"))
    return out


def _rule_lost_cas_ack(ctx: _Ctx) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    lost_empty = (m["client"] & m["in_index"] & (ev["verb"] == CAS)
                  & m["ok"] & (ev["arg"] == 0) & (ev["old"] != 0)
                  & (ev["old"] != ev["val"]))
    if not lost_empty.any():
        return []
    # value a mutation installs: write payload first word / cas new value
    installed = np.where(ev["verb"] == WRITE, ev["arg"], ev["val"])
    install = m["client"] & m["in_index"] & m["mut"] & (ev["verb"] != FAA)
    out = []
    seen_ops = set()
    for i in np.nonzero(lost_empty)[0]:
        op = int(ev["op_id"][i])
        if op in seen_ops:
            continue
        info = ctx.ops.get(op)
        if info is None or info.status != "OK":
            continue   # op retried / failed / still open: protocol handled it
        if info.rule == "MASTER_WIN":
            continue   # master arbitration installed the value (Alg 4)
        v_new = int(ev["val"][i])
        old_u = int(ev["old"][i]) & 0xFFFFFFFFFFFFFFFF
        if (old_u >> 56) == ((v_new & 0xFFFFFFFFFFFFFFFF) >> 56):
            continue   # same-fingerprint winner: a same-key racer upserted
                       # the slot, so losing + acking OK is last-writer-wins
                       # (the loser's value linearizes just before the
                       # winner's).  A true lost write to a DIFFERENT key
                       # matches fps only 1/255 of the time.
        later_ok = (install & (ev["op_id"] == op)
                    & (installed == v_new) & (ev["seq"] > ev["seq"][i]))
        if later_ok.any():
            continue   # the op retried and its value did land
        seen_ops.add(op)
        out.append(_mk(
            ctx, "lost_cas_ack", [i],
            f"op {op} (cid {int(ev['cid'][i])}) acked OK "
            f"(rule {info.rule}) after losing an empty-slot CAS: expected "
            f"0, found {int(ev['old'][i]) & 0xFFFFFFFFFFFFFFFF:#x}, wanted "
            f"{v_new & 0xFFFFFFFFFFFFFFFF:#x} — acknowledged write is "
            "nowhere in the index"))
    return out


def _rule_index_plain_write(ctx: _Ctx) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    cand = (m["client"] & m["in_index"] & m["ok"]
            & ((ev["verb"] == WRITE) | (ev["verb"] == FAA)))
    out = []
    for i in np.nonzero(cand)[0]:
        out.append(_mk(
            ctx, "index_plain_write", [i],
            f"client {int(ev['cid'][i])} mutated an index shard with a "
            f"plain {VERB_NAMES[int(ev['verb'][i])].upper()} (phase "
            f"'{ctx.label_of(i)}') — index slots may only be CASed"))
    return out


def _rule_clear_order(ctx: _Ctx) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    # scope: index/keydir words only, where a zero IS the authoritative
    # state.  Data-region clears (used-bit resets, delete cleanup) may
    # legally touch replicas across phases in either order — readers
    # validate objects by CRC + used bit, so a half-cleared object can
    # never resurrect an acked-gone value.
    zero = m["client"] & m["ok"] & (m["in_index"] | m["in_ordered"]) & (
        ((ev["verb"] == WRITE) & (ev["n"] == 1) & (ev["arg"] == 0))
        | (m["hit"] & (ev["val"] == 0)))
    idxs = np.nonzero(zero)[0]
    if len(idxs) == 0:
        return []
    key = np.stack([ev["op_id"][idxs], ev["region"][idxs],
                    ev["off"][idxs]], axis=1)
    _, inverse = np.unique(key, axis=0, return_inverse=True)
    groups: Dict[int, list] = {}
    for pos, g in zip(idxs, inverse):
        groups.setdefault(int(g), []).append(int(pos))
    out = []
    for members in groups.values():
        prim = [i for i in members if ev["replica"][i] == 0]
        back = [i for i in members if ev["replica"][i] > 0]
        if not prim or not back:
            continue
        p = min(prim, key=lambda i: int(ev["phase"][i]))
        b = max(back, key=lambda i: int(ev["phase"][i]))
        if int(ev["phase"][p]) < int(ev["phase"][b]):
            out.append(_mk(
                ctx, "clear_order", [p, b],
                f"op {int(ev['op_id'][p])} cleared primary replica 0 at "
                f"phase {int(ev['phase'][p])} ('{ctx.label_of(p)}') before "
                f"backup replica {int(ev['replica'][b])} at phase "
                f"{int(ev['phase'][b])} ('{ctx.label_of(b)}') — clears "
                "must land on backups first"))
    return out


# ----------------------------------------------------- per-word conflicts
def _expand_words(ev, idxs):
    """Per-word rows for events ``idxs``: (event_row, word) arrays."""
    lens = ev["n"][idxs]
    lens = np.maximum(lens, 0)
    rows = np.repeat(idxs, lens)
    starts = np.repeat(np.cumsum(lens) - lens, lens)
    word = ev["off"][rows] + (np.arange(int(lens.sum())) - starts)
    return rows, word


def _word_conflict_rules(ctx: _Ctx, rules) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    out: List[Finding] = []
    # candidate events: client plain writes everywhere (ww_race) plus
    # reads + mutations in the index/keydir scope (torn_read)
    ww_mask = m["client"] & (ev["verb"] == WRITE) & m["ok"] \
        if "ww_race" in rules else np.zeros(len(ev["seq"]), bool)
    torn_scope = m["in_index"] | m["in_ordered"]
    torn_mask = m["client"] & torn_scope & (m["mut"] | (ev["verb"] == READ)) \
        if "torn_read" in rules else np.zeros(len(ev["seq"]), bool)
    cand = ww_mask | torn_mask
    idxs = np.nonzero(cand)[0]
    if len(idxs) == 0:
        return out
    rows, word = _expand_words(ev, idxs)
    key = ((ev["region"][rows].astype(np.int64) << 40)
           | (ev["replica"][rows].astype(np.int64) << 36) | word)
    # words touched by >= 2 distinct client cids
    pairs = np.unique(np.stack([key, ev["cid"][rows]], axis=1), axis=0)
    wkeys, counts = np.unique(pairs[:, 0], return_counts=True)
    hot = set(wkeys[counts >= 2].tolist())
    if not hot:
        return out
    sel = np.isin(key, np.fromiter(hot, np.int64, len(hot)))
    per_word: Dict[int, list] = {}
    for r, k in zip(rows[sel], key[sel]):
        per_word.setdefault(int(k), []).append(int(r))
    guards = _cas_guards(ctx)
    for k, members in per_word.items():
        members = sorted(set(members), key=lambda i: int(ev["seq"][i]))
        if len(members) > MAX_EVENTS_PER_WORD:
            members = members[:MAX_EVENTS_PER_WORD]
        w = k & ((1 << 36) - 1)
        if "ww_race" in rules:
            out += _ww_pairs(ctx, [i for i in members if ww_mask[i]],
                             w, guards)
        if "torn_read" in rules:
            out += _torn_reads(ctx, [i for i in members if torn_mask[i]], w)
    return out


def _cas_guards(ctx: _Ctx) -> Dict[Tuple[int, int], list]:
    """(cid, op) -> [(seq, region, off)] of successful CAS claims."""
    ev, m = ctx.ev, ctx.masks
    guards: Dict[Tuple[int, int], list] = {}
    for i in np.nonzero(m["client"] & m["hit"])[0]:
        guards.setdefault(
            (int(ev["cid"][i]), int(ev["op_id"][i])), []).append(
            (int(ev["seq"][i]), int(ev["region"][i]), int(ev["off"][i])))
    return guards


def _is_guarded(ev, i, guards) -> bool:
    lst = guards.get((int(ev["cid"][i]), int(ev["op_id"][i])))
    if not lst:
        return False
    seq, region, off = int(ev["seq"][i]), int(ev["region"][i]), \
        int(ev["off"][i])
    return any(s < seq and r == region and abs(o - off) <= CAS_GUARD_WINDOW
               for s, r, o in lst)


def _ww_pairs(ctx: _Ctx, writes, word, guards) -> List[Finding]:
    ev = ctx.ev
    out = []
    for a_pos in range(len(writes)):
        for b_pos in range(a_pos + 1, len(writes)):
            a, b = writes[a_pos], writes[b_pos]
            if ev["cid"][a] == ev["cid"][b]:
                continue    # same client: QP FIFO / program order
            if not ctx.concurrent(int(ev["op_id"][a]), int(ev["op_id"][b])):
                continue    # real-time ordered: last writer legitimately wins
            same_shape = (ev["off"][a] == ev["off"][b]
                          and ev["n"][a] == ev["n"][b])
            same_value = (same_shape and ev["arg"][a] == ev["arg"][b]
                          and ev["val"][a] == ev["val"][b])
            if same_value:
                continue    # idempotent double-write (e.g. keydir ensure)
            if _is_guarded(ev, a, guards) or _is_guarded(ev, b, guards):
                continue    # replication completion of a won CAS claim
            out.append(_mk(
                ctx, "ww_race", [a, b],
                f"unordered plain writes from cids {int(ev['cid'][a])} and "
                f"{int(ev['cid'][b])} to word {word} with different values "
                f"(phases '{ctx.label_of(a)}' / '{ctx.label_of(b)}'): no "
                "QP FIFO edge, no CAS claim — outcome is timing-dependent"))
            return out   # one finding per word is enough signal
    return out


def _torn_reads(ctx: _Ctx, members, word) -> List[Finding]:
    ev, m = ctx.ev, ctx.masks
    reads = [i for i in members if ev["verb"][i] == READ]
    muts = [i for i in members if m["mut"][i]]
    if not reads or len(muts) < 2:
        return []
    out = []
    # mutation groups: one (cid, op, phase) doorbell batch
    groups: Dict[Tuple[int, int, int], list] = {}
    for i in muts:
        groups.setdefault((int(ev["cid"][i]), int(ev["op_id"][i]),
                           int(ev["phase"][i])), []).append(i)
    for r in reads:
        rs = int(ev["seq"][r])
        for (gcid, gop, _), g in groups.items():
            if gcid == int(ev["cid"][r]) or len(g) < 2:
                continue
            seqs = [int(ev["seq"][i]) for i in g]
            if min(seqs) < rs < max(seqs):
                first = min(g, key=lambda i: int(ev["seq"][i]))
                out.append(_mk(
                    ctx, "torn_read", [r, first],
                    f"cid {int(ev['cid'][r])} read word {word} between "
                    f"verbs of cid {gcid} op {gop}'s multi-verb mutation "
                    f"phase ('{ctx.label_of(first)}') — observed a torn "
                    "write in un-validated metadata"))
                return out
    return out


# =============================================================== CLI =======
# ``python -m repro.analysis.races --storm-seed N`` — run the seeded fault
# storm (same shape as tests/test_fault_storm.py) under an attached tracer,
# then run the race pass and the heap/epoch auditor over the result.  Exits
# nonzero on any race finding or heap error; ``--out DIR`` saves the raw
# trace as an .npz artifact (what the CI analysis job uploads).

def _storm_run(seed: int, *, churn: bool = False, total_ops: int = 160,
               capacity: int = 1 << 16):
    from ..core import (ClientCrashed, DMConfig, FaultPlan, FuseeCluster,
                        Op)

    n_clients, n_mns, repl = 6, 5, 3
    cl = FuseeCluster(DMConfig(num_mns=n_mns, replication=repl,
                               region_words=1 << 15, regions_per_mn=16,
                               index_shards=4 if churn else 1),
                      num_clients=n_clients, seed=seed)
    tr = cl.attach_tracer(capacity=capacity)
    storm_kw = dict(clients=range(n_clients), mns=n_mns, replication=repl,
                    n_client_crashes=2, n_mn_crashes=2, first_op=10,
                    spacing=14, recover_delay=8)
    if churn:
        storm_kw.update(n_add_mns=1, remove_added=True,
                        crash_during_migration=True, n_mn_crashes=1)
    plan = FaultPlan.storm(cl.rng.stream("faults"), **storm_kw)
    injector = cl.inject(plan)
    fleet = cl.fleet()
    stores = {c: cl.store(c, max_inflight=0) for c in range(n_clients)}
    submitted = 0
    while submitted < total_ops:
        for c in range(n_clients):
            if submitted >= total_ops:
                break
            k = submitted
            submitted += 1
            try:
                stores[c].submit(Op.put(k, [k, c]))
            except ClientCrashed:
                pass                   # typed rejection: op never entered
        for _ in range(4):
            if cl.scheduler.has_work():
                fleet.tick()
    fleet.run()
    if cl.migrator.busy:
        cl.migrator.drive()
    if not injector.done:
        raise RuntimeError(f"storm plan did not fully fire (seed {seed})")
    return cl, tr


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import os

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.races",
        description="Run a seeded fault storm under the verb tracer, then "
                    "the race detector and heap auditor; exit 1 on findings.")
    ap.add_argument("--storm-seed", type=int, default=0, metavar="N",
                    help="SimRng seed for the storm run (default 0)")
    ap.add_argument("--churn", action="store_true",
                    help="add membership churn (MN scale-out + live "
                    "migration + mid-migration crash) to the storm")
    ap.add_argument("--ops", type=int, default=160,
                    help="ops to submit (default 160)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated race rules (default: all)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="save the raw verb trace as DIR/trace-seed<N>.npz")
    ap.add_argument("--no-heapcheck", action="store_true",
                    help="skip the post-drain heap/epoch audit")
    args = ap.parse_args(argv)

    cl, tr = _storm_run(args.storm_seed, churn=args.churn,
                        total_ops=args.ops)
    rules = tuple(args.rules.split(",")) if args.rules else None
    findings = detect(tr, scheduler=cl.scheduler, rules=rules)
    print(f"[races] seed={args.storm_seed} churn={args.churn} "
          f"events={tr.n} findings={len(findings)}")
    print(report(findings, tr))

    heap_bad = False
    if not args.no_heapcheck:
        from .heapcheck import audit
        rep = audit(cl)
        heap_bad = not rep.ok
        print(f"[heapcheck] {rep}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(
            args.out, f"trace-seed{args.storm_seed}"
                      f"{'-churn' if args.churn else ''}.npz")
        tr.save(path)
        print(f"[races] trace saved to {path}")
    return 1 if (findings or heap_bad) else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
