"""Verb-trace recorder for the one-sided verb race detector.

``VerbTracer.attach(pool)`` wraps the eight ``DMPool`` verb entry points
(``read/write/cas/faa`` and their ``*_batch`` twins) with thin recording
closures installed as *instance* attributes.  A pool that never attaches a
tracer executes the original class methods untouched — the disabled mode
is structurally zero-cost, which is what the fleet-tick overhead claim in
``benchmarks/run.py`` measures.  ``pause()`` keeps the wrappers installed
but skips recording (the residual wrapper-dispatch cost, the honest
"hooks compiled in but disabled" number).

Each recorded event is one row across parallel int64 ring-buffer columns:

    seq          global execution order (monotone; survives ring wrap)
    tick         scheduler tick at execution
    cid          issuing client (-1 = master / recovery / migration traffic)
    op_id        scheduler op id (-1 when not attributable to an op)
    phase        op phase ordinal at issue time (rtts + bg_rtts)
    label        interned phase label (see ``labels``)
    verb         0=read 1=write 2=cas 3=faa
    region / replica / off / n
    epoch_issue  lease epoch stamped when the doorbell batch was posted
    epoch_exec   pool epoch when the verb actually executed
    ok           verb completed at the MN (False = crash-stop FAIL)
    arg          cas: expected value; faa: delta; write: first word
    val          cas: new value; write: crc32 of the full payload
    old          cas/faa: value found at the word (bit pattern)
    cause        interned retry/stall cause of the issuing phase (see
                 core/events.py CAUSES; -1 = no cause).  Verbs executed
                 inside a live-migration dual-write window that carry no
                 issue-side cause are stamped ``mig_dual_write`` at
                 execution time (deterministic: migration state is a
                 protocol event).
    bg           1 when the issuing phase is background (off the op's
                 latency critical path); the span profiler separates
                 foreground RTT attribution on this bit, not on label
                 string conventions

Execution context (tick / cid / op / phase / issue epoch) is not visible
at the pool layer, so the scheduler (sim.py) and the fleet engine
(fleet.py) push it just before dispatching each verb — scalar context via
``set_ctx``, one-tick batch context via ``set_batch_ctx``.  Pool traffic
issued outside any client op (master recovery, Alg-3, migration bulk
copies) runs under the master context set at ``begin_tick``.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional

import numpy as np

__all__ = ["VerbTracer", "READ", "WRITE", "CAS", "FAA", "VERB_NAMES"]

READ, WRITE, CAS, FAA = 0, 1, 2, 3
VERB_NAMES = ("read", "write", "cas", "faa")
MASTER_CID = -1

_MASK = 0xFFFF_FFFF_FFFF_FFFF

FIELDS = (
    "seq", "tick", "cid", "op_id", "phase", "label", "verb", "region",
    "replica", "off", "n", "epoch_issue", "epoch_exec", "ok", "arg",
    "val", "old", "cause", "bg",
)

_WRAPPED = ("read", "write", "cas", "faa",
            "read_batch", "write_batch", "cas_batch", "faa_batch")


def _i64(v) -> int:
    """The int64 bit pattern of a (possibly >= 2**63) unsigned word."""
    v = int(v) & _MASK
    return v - (1 << 64) if v >= (1 << 63) else v


def _u64_view(values) -> np.ndarray:
    return np.asarray([int(v) & _MASK for v in values],
                      dtype=np.uint64).view(np.int64)


class VerbTracer:
    """Ring-buffer recorder; see module docstring."""

    def __init__(self, capacity: int = 1 << 20):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self.buf: Dict[str, np.ndarray] = {
            f: np.zeros(self.capacity, np.int64) for f in FIELDS}
        self.n = 0                      # events emitted ever (ring may wrap)
        self.paused = False
        self.pool = None
        self._labels: List[str] = ["master"]
        self._label_ids: Dict[str, int] = {"master": 0}
        # scalar execution context (master defaults)
        self._tick = 0
        self._cid = MASTER_CID
        self._op = -1
        self._phase = -1
        self._label = 0
        self._epoch = -1
        self._cause = -1
        self._bg = 0
        self._mig_cause = self.intern("mig_dual_write")
        self._bc = None                 # one-shot batch context

    # ------------------------------------------------------------- context
    def intern(self, label: str) -> int:
        lid = self._label_ids.get(label)
        if lid is None:
            lid = self._label_ids[label] = len(self._labels)
            self._labels.append(label)
        return lid

    @property
    def labels(self) -> List[str]:
        return list(self._labels)

    def set_ctx(self, tick, cid, op_id, phase, label_id, epoch,
                cause_id=-1, bg=0):
        self._tick = tick
        self._cid = cid
        self._op = op_id
        self._phase = phase
        self._label = label_id
        self._epoch = epoch
        self._cause = cause_id
        self._bg = bg

    def set_master_ctx(self, tick):
        self.set_ctx(tick, MASTER_CID, -1, -1, 0, -1)

    def set_batch_ctx(self, tick, cids, op_ids, phases, label_ids, epochs,
                      causes=None, bgs=None):
        """Per-verb context for the next ``*_batch`` pool call (fleet tick).
        Consumed by exactly one batch; cleared afterwards."""
        self._tick = tick
        n = len(np.asarray(cids, np.int64))
        self._bc = (np.asarray(cids, np.int64),
                    np.asarray(op_ids, np.int64),
                    np.asarray(phases, np.int64),
                    np.asarray(label_ids, np.int64),
                    np.asarray(epochs, np.int64),
                    np.full(n, -1, np.int64) if causes is None
                    else np.asarray(causes, np.int64),
                    np.zeros(n, np.int64) if bgs is None
                    else np.asarray(bgs, np.int64))

    def pause(self):
        self.paused = True

    def resume(self):
        self.paused = False

    # ------------------------------------------------------------ attaching
    def attach(self, pool) -> "VerbTracer":
        if self.pool is not None:
            raise RuntimeError("tracer already attached")
        if getattr(pool, "_tracer", None) is not None:
            raise RuntimeError("pool already has a tracer attached")
        self.pool = pool
        for name in _WRAPPED:
            setattr(pool, name, self._wrapper(pool, name))
        pool._tracer = self
        return self

    def detach(self):
        pool, self.pool = self.pool, None
        if pool is None:
            return
        for name in _WRAPPED:
            # drop the instance attribute -> calls fall back to the class
            # method, restoring the structurally zero-cost path
            pool.__dict__.pop(name, None)
        pool._tracer = None

    def _wrapper(self, pool, name):
        inner = getattr(type(pool), name).__get__(pool)
        tr = self
        if name == "read":
            def read(region, replica, off, n):
                out = inner(region, replica, off, n)
                if not tr.paused:
                    tr._emit(READ, region, replica, off, n,
                             out is not None, 0, 0, 0)
                return out
            return read
        if name == "write":
            def write(region, replica, off, words):
                ok = inner(region, replica, off, words)
                if not tr.paused:
                    w = [int(x) & _MASK for x in words]
                    tr._emit(WRITE, region, replica, off, len(w), bool(ok),
                             w[0] if w else 0, _payload_sig(w), 0)
                return ok
            return write
        if name == "cas":
            def cas(region, replica, off, exp, new):
                old = inner(region, replica, off, exp, new)
                if not tr.paused:
                    tr._emit(CAS, region, replica, off, 1, old is not None,
                             exp, new, 0 if old is None else old)
                return old
            return cas
        if name == "faa":
            def faa(region, replica, off, delta):
                old = inner(region, replica, off, delta)
                if not tr.paused:
                    tr._emit(FAA, region, replica, off, 1, old is not None,
                             delta, 0, 0 if old is None else old)
                return old
            return faa
        if name == "read_batch":
            def read_batch(regions, replicas, offs, ns):
                out = inner(regions, replicas, offs, ns)
                if not tr.paused:
                    oks = np.asarray([r is not None for r in out], np.int64)
                    tr._emit_vec(READ, regions, replicas, offs,
                                 np.asarray(ns, np.int64), oks,
                                 None, None, None)
                else:
                    tr._bc = None
                return out
            return read_batch
        if name == "write_batch":
            def write_batch(regions, replicas, offs, words_list):
                out = inner(regions, replicas, offs, words_list)
                if not tr.paused:
                    clean = [[int(x) & _MASK for x in w] for w in words_list]
                    tr._emit_vec(
                        WRITE, regions, replicas, offs,
                        np.asarray([len(w) for w in clean], np.int64),
                        np.asarray(out, np.int64),
                        _u64_view([w[0] if w else 0 for w in clean]),
                        np.asarray([_payload_sig(w) for w in clean],
                                   np.int64),
                        None)
                else:
                    tr._bc = None
                return out
            return write_batch
        if name == "cas_batch":
            def cas_batch(regions, replicas, offs, exps, news):
                out = inner(regions, replicas, offs, exps, news)
                if not tr.paused:
                    tr._emit_vec(
                        CAS, regions, replicas, offs,
                        np.ones(len(out), np.int64),
                        np.asarray([v is not None for v in out], np.int64),
                        _u64_view(exps), _u64_view(news),
                        _u64_view([0 if v is None else v for v in out]))
                else:
                    tr._bc = None
                return out
            return cas_batch
        if name == "faa_batch":
            def faa_batch(regions, replicas, offs, deltas):
                out = inner(regions, replicas, offs, deltas)
                if not tr.paused:
                    tr._emit_vec(
                        FAA, regions, replicas, offs,
                        np.ones(len(out), np.int64),
                        np.asarray([v is not None for v in out], np.int64),
                        _u64_view(deltas), None,
                        _u64_view([0 if v is None else v for v in out]))
                else:
                    tr._bc = None
                return out
            return faa_batch
        raise ValueError(name)

    # ------------------------------------------------------------ recording
    def _emit(self, verb, region, replica, off, n, ok, arg, val, old):
        b = self.buf
        i = self.n % self.capacity
        b["seq"][i] = self.n
        b["tick"][i] = self._tick
        b["cid"][i] = self._cid
        b["op_id"][i] = self._op
        b["phase"][i] = self._phase
        b["label"][i] = self._label
        b["verb"][i] = verb
        b["region"][i] = region
        b["replica"][i] = replica
        b["off"][i] = off
        b["n"][i] = n
        b["epoch_issue"][i] = self._epoch
        b["epoch_exec"][i] = self.pool.epoch
        b["ok"][i] = 1 if ok else 0
        b["arg"][i] = _i64(arg)
        b["val"][i] = _i64(val)
        b["old"][i] = _i64(old)
        c = self._cause
        if c < 0 and self.pool.migrations:
            c = self._mig_cause
        b["cause"][i] = c
        b["bg"][i] = self._bg
        self.n += 1

    def _emit_vec(self, verb, regions, replicas, offs, ns, oks,
                  arg, val, old):
        m = len(ns)
        bc, self._bc = self._bc, None
        if m == 0:
            return
        b = self.buf
        idx = (self.n + np.arange(m)) % self.capacity
        b["seq"][idx] = self.n + np.arange(m)
        b["tick"][idx] = self._tick
        if bc is not None and len(bc[0]) == m:
            cids, op_ids, phases, label_ids, epochs, causes, bgs = bc
        else:   # un-attributed batch traffic (e.g. migration bulk copy)
            cids = op_ids = phases = -1
            label_ids, epochs = 0, -1
            causes, bgs = -1, 0
        if self.pool.migrations:
            # dual-write window: stamp verbs that carry no issue-side cause
            causes = np.where(np.asarray(causes, np.int64) < 0,
                              self._mig_cause, causes)
        b["cid"][idx] = cids
        b["op_id"][idx] = op_ids
        b["phase"][idx] = phases
        b["label"][idx] = label_ids
        b["cause"][idx] = causes
        b["bg"][idx] = bgs
        b["verb"][idx] = verb
        b["region"][idx] = np.asarray(regions, np.int64)
        b["replica"][idx] = np.asarray(replicas, np.int64)
        b["off"][idx] = np.asarray(offs, np.int64)
        b["n"][idx] = ns
        b["epoch_issue"][idx] = epochs
        b["epoch_exec"][idx] = self.pool.epoch
        b["ok"][idx] = oks
        b["arg"][idx] = 0 if arg is None else arg
        b["val"][idx] = 0 if val is None else val
        b["old"][idx] = 0 if old is None else old
        self.n += m

    # ------------------------------------------------------------- reading
    @property
    def dropped(self) -> int:
        """Events that fell off the ring (oldest-first)."""
        return max(0, self.n - self.capacity)

    def events(self) -> Dict[str, np.ndarray]:
        """The retained trace window as seq-ascending column arrays."""
        if self.n <= self.capacity:
            return {f: a[:self.n].copy() for f, a in self.buf.items()}
        c = self.n % self.capacity
        return {f: np.concatenate([a[c:], a[:c]])
                for f, a in self.buf.items()}

    def save(self, path):
        """Persist the trace window (+ label table) as an ``.npz`` — the
        artifact format the CI analysis job uploads for flagged runs."""
        np.savez_compressed(
            path, **self.events(),
            _labels=np.asarray(self._labels, dtype=object),
            _dropped=np.asarray([self.dropped], np.int64))

    @staticmethod
    def load(path):
        """Load a saved trace -> (events dict, labels list)."""
        with np.load(path, allow_pickle=True) as z:
            n = len(z["seq"])
            # traces saved before the cause/bg columns load with defaults
            ev = {f: z[f] if f in z.files
                  else np.full(n, -1 if f == "cause" else 0, np.int64)
                  for f in FIELDS}
            labels = [str(x) for x in z["_labels"]]
        return ev, labels


def _payload_sig(words) -> int:
    """Order-sensitive signature of a write payload (value comparison for
    the write/write race rule without retaining full payloads)."""
    return zlib.crc32(np.asarray(words, np.uint64).tobytes())
