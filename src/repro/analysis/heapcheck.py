"""Post-drain DM heap / placement-epoch auditor.

``audit(cluster)`` walks the *quiescent* state of a ``FuseeCluster`` (call
it after ``drain()``) and cross-checks the four ownership surfaces of the
disaggregated heap against each other:

* the **RACE index shards** — every nonzero slot must point at a parseable,
  CRC-valid, used, non-invalidated object whose fingerprint and shard
  routing match the slot; no two slots may share a pointer or a key; a
  referenced object must not carry a set free bit (use-after-free);
* the **block allocation tables** (BAT) — owners must be 0 / a known cid+1
  / ``BAT_ORPHAN``, replicas must agree, and every allocated block must be
  *reachable*: owned by a live client that tracks it in its slab, or
  containing at least one index-referenced object (anything else is
  leaked garbage, reported);
* the **free surfaces** — per-block free bitmaps (bits only at offsets the
  block's size class can carve — a misaligned bit is the double-free FAA
  overflow signature) and the in-process slab free lists;
* the **placement ring** — live clients hold the pool lease epoch, no
  migration is still open, membership contains only live non-retired MNs,
  every placement replica is alive and hosts its region, retired MNs host
  nothing.

The leak rule, per object carved from a live client's block::

    used && !free_bit && !slot_referenced && !in_owner_free_list  ->  leak

(losers reset ``used``; overwritten objects get their free bit FAAed; a
reachable committed object is slot-referenced; everything else must be on
the owner's reclaim path).

Findings are split into ``errors`` (invariant violations — a protocol or
harness bug) and ``warnings`` (legal-but-lossy states: orphaned garbage
blocks surrendered by removed clients, keydir entries dropped under ORD
FULL back-pressure, blocks stranded by unrecovered client crashes).
Crashed-but-unrecovered clients are skipped (their heap state is
*supposed* to dangle until §5.3 recovery) and counted in ``stats``.

Runs that experienced **client crashes** audit in *lenient* mode: leaks
and index-replica divergence demote to warnings there, because both are
documented §5.3 residue rather than bugs — recovery repairs only the
at-most-one in-flight *tail* log entry per size-class list (a pipelined
client that crashed with several in-flight ops legally strands the
non-tail objects), and a crash between a round's backup and primary
CASes leaves backup divergence that the next round on the slot, Alg-3,
or a migration cutover repairs lazily.  A crash-free run holds the
strict line: any leak or divergence is an error.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

import numpy as np

from ..core import layout as L
from ..core import ordered
from ..core.heap import BAT_ORPHAN

__all__ = ["HeapReport", "audit"]


@dataclass
class HeapReport:
    errors: List[str] = field(default_factory=list)
    warnings: List[str] = field(default_factory=list)
    stats: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.errors

    def __str__(self) -> str:
        lines = [f"heap audit: {'clean' if self.ok else 'FAILED'} "
                 f"({len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s))"]
        lines += [f"  ERROR: {e}" for e in self.errors]
        lines += [f"  warn:  {w}" for w in self.warnings]
        lines.append("  stats: " + ", ".join(
            f"{k}={v}" for k, v in sorted(self.stats.items())))
        return "\n".join(lines)


def _primary_mem(pool, region):
    """First alive replica array hosting ``region`` (master idiom)."""
    for mid in pool.placement.get(region, []):
        mn = pool.mns[mid]
        if mn.alive and region in mn.regions:
            return mn.regions[region]
    return None


def _alive_arrays(pool, region):
    out = []
    for i, mid in enumerate(pool.placement.get(region, [])):
        mn = pool.mns[mid]
        if mn.alive and region in mn.regions:
            out.append((i, mid, mn.regions[region]))
    return out


def audit(cluster) -> HeapReport:
    """Audit a quiescent cluster; see module docstring."""
    rep = HeapReport()
    pool = cluster.pool
    cfg = pool.cfg
    clients = cluster.clients
    live = {cid: c for cid, c in clients.items() if not c.crashed}
    crashed = {cid for cid, c in clients.items() if c.crashed}
    rep.stats["clients_live"] = len(live)
    rep.stats["clients_crashed_skipped"] = len(crashed)
    # lenient mode: the run saw client crashes, so §5.3 residue (stranded
    # non-tail objects, mid-round backup divergence) is expected — see
    # module docstring
    lenient = bool(crashed) or cluster.client_recoveries > 0 \
        or cluster.scheduler.crashed_ops > 0
    rep.stats["lenient"] = int(lenient)
    tracer = getattr(pool, "_tracer", None)
    if tracer is not None:
        # the heap audit itself reads pool state, not the trace — but a
        # wrapped ring means any race/trace analysis paired with this
        # audit ran on a truncated window, so surface it here too
        rep.stats["trace_dropped"] = tracer.dropped
        if tracer.dropped:
            rep.warnings.append(
                f"verb-trace ring wrapped: {tracer.dropped} oldest "
                f"record(s) dropped (capacity {tracer.capacity}) — "
                "trace-based analyses cover a truncated window")

    _audit_ring(rep, pool, live)
    refs = _audit_index(rep, pool, lenient)
    _audit_bats(rep, pool, live, refs)
    _audit_blocks(rep, pool, live, refs, lenient)
    if cfg.ordered_index:
        _audit_keydir(rep, pool, live, crashed, refs, lenient)
    return rep


# ------------------------------------------------------------ placement ring
def _audit_ring(rep: HeapReport, pool, live):
    for cid, c in sorted(live.items()):
        if c.epoch != pool.epoch:
            rep.errors.append(
                f"epoch: live client {cid} holds lease epoch {c.epoch} "
                f"but pool epoch is {pool.epoch} (membership commit "
                "did not propagate)")
    if pool.migrations:
        rep.errors.append(
            f"epoch: {len(pool.migrations)} migration dual-write window(s) "
            f"still open for regions {sorted(pool.migrations)} — the "
            "cluster is not quiescent")
    for mid in pool.directory.members:
        mn = pool.mns[mid]
        if not mn.alive or mn.retired:
            rep.errors.append(
                f"ring: MN {mid} is in the committed membership but "
                f"{'retired' if mn.retired else 'dead'} — crash undetected "
                "or retirement incomplete")
    for mn in pool.mns:
        if mn.retired and mn.regions:
            rep.errors.append(
                f"ring: retired MN {mn.mid} still hosts regions "
                f"{sorted(mn.regions)}")
    for region, reps in sorted(pool.placement.items()):
        if not reps:
            rep.errors.append(f"ring: region {region} has an empty "
                              "replica set")
            continue
        if len(set(reps)) != len(reps):
            rep.errors.append(
                f"ring: region {region} lists a duplicate replica: {reps}")
        for mid in reps:
            mn = pool.mns[mid]
            if not mn.alive:
                rep.errors.append(
                    f"ring: region {region} placed on dead MN {mid} "
                    "(Alg-3 re-home missing)")
            elif region not in mn.regions:
                rep.errors.append(
                    f"ring: region {region} placed on MN {mid} which does "
                    "not host a copy")


# ------------------------------------------------------------ index shards
@dataclass
class _Ref:
    """One nonzero index slot and the object it claims."""
    shard: int
    slot_off: int
    fp: int
    sc: int
    ptr: int
    key: int = -1          # parsed object key (-1 = unparseable)


def _audit_index(rep: HeapReport, pool, lenient: bool = False
                 ) -> List[_Ref]:
    cfg = pool.cfg
    refs: List[_Ref] = []
    by_ptr: Dict[int, Tuple[int, int]] = {}
    by_key: Dict[int, Tuple[int, int]] = {}
    data_region_set = set(pool.data_regions)
    for g in pool.index_regions:
        arrays = _alive_arrays(pool, g)
        if not arrays:
            rep.errors.append(f"index: shard region {g} has no alive "
                              "replica")
            continue
        n = cfg.index_words
        base = arrays[0][2][:n]
        for _, mid, arr in arrays[1:]:
            if not np.array_equal(arr[:n], base):
                diff = int(np.nonzero(arr[:n] != base)[0][0])
                # a client crash between a round's backup and primary
                # CASes legally strands backup divergence (repaired by
                # the next round / Alg-3 / cutover) — lenient demotes
                sink = rep.warnings if lenient else rep.errors
                sink.append(
                    f"index: shard {g} replicas diverge at slot word "
                    f"{diff} (MN {arrays[0][1]} vs MN {mid}) after drain — "
                    + ("mid-round crash residue" if lenient else
                       "an uncommitted SNAPSHOT round survived"))
        for off in np.nonzero(base)[0]:
            slot = int(base[int(off)])
            r = _Ref(shard=g, slot_off=int(off), fp=L.slot_fp(slot),
                     sc=L.slot_size_class(slot), ptr=L.slot_ptr(slot))
            refs.append(r)
            dup = by_ptr.get(r.ptr)
            if dup is not None:
                rep.errors.append(
                    f"index: pointer {r.ptr:#x} referenced by two slots: "
                    f"shard {dup[0]} word {dup[1]} and shard {g} word "
                    f"{r.slot_off} (double reference)")
            else:
                by_ptr[r.ptr] = (g, r.slot_off)
            _check_ref_object(rep, pool, r, data_region_set)
            if r.key >= 0:
                dupk = by_key.get(r.key)
                if dupk is not None:
                    rep.errors.append(
                        f"index: key {r.key:#x} present in two slots: "
                        f"shard {dupk[0]} word {dupk[1]} and shard {g} "
                        f"word {r.slot_off}")
                else:
                    by_key[r.key] = (g, r.slot_off)
    rep.stats["index_slots_used"] = len(refs)
    return refs


def _check_ref_object(rep: HeapReport, pool, r: _Ref, data_region_set):
    cfg = pool.cfg
    region, off = L.ptr_region(r.ptr), L.ptr_offset(r.ptr)
    where = f"shard {r.shard} word {r.slot_off} -> ptr {r.ptr:#x}"
    if region not in data_region_set:
        rep.errors.append(f"index: {where} points outside the data "
                          f"regions (region {region})")
        return
    blk = (off - cfg.bat_words) // cfg.block_words
    base = pool.block_base(blk)
    scw = L.size_class_words(r.sc)
    if not (0 <= blk < cfg.blocks_per_region) or off < base \
            or (off - base) % L.MIN_OBJ_WORDS != 0 \
            or off + scw > pool.block_base(blk) + cfg.block_payload_words:
        rep.errors.append(f"index: {where} is not a carvable object "
                          f"offset (block {blk}, sc {r.sc})")
        return
    mem = _primary_mem(pool, region)
    if mem is None:
        rep.errors.append(f"index: {where} targets region {region} with "
                          "no alive replica")
        return
    if int(mem[blk]) == 0:       # BAT word of this block
        rep.errors.append(f"index: {where} lands in UNALLOCATED block "
                          f"{blk} of region {region} (dangling reference)")
        return
    obj_idx = (off - base) // L.MIN_OBJ_WORDS
    bm_word = int(mem[pool.bitmap_base(blk) + obj_idx // 64])
    if (bm_word >> (obj_idx % 64)) & 1:
        rep.errors.append(
            f"index: {where} references an object whose free bit is set "
            f"(region {region} block {blk} obj {obj_idx}) — use after free")
    o = L.parse_object(mem[off:off + scw])
    r.key = int(o["key"])
    if not o["crc_ok"]:
        rep.errors.append(f"index: {where} object fails CRC (torn or "
                          "mis-sized commit)")
    if not o["used"]:
        rep.errors.append(f"index: {where} object has used=0 (slot "
                          "survived a loser reset)")
    if o["invalid"]:
        rep.errors.append(f"index: {where} object is invalidated but "
                          "still referenced")
    if L.fingerprint(r.key) != r.fp:
        rep.errors.append(
            f"index: {where} fingerprint mismatch: slot fp {r.fp}, object "
            f"key {r.key:#x} -> fp {L.fingerprint(r.key)}")
    if pool.index_region_of(r.key) != r.shard:
        rep.errors.append(
            f"index: key {r.key:#x} stored in shard {r.shard} but routes "
            f"to shard {pool.index_region_of(r.key)} (mis-sharded slot)")


# --------------------------------------------------------------------- BAT
def _audit_bats(rep: HeapReport, pool, live, refs: List[_Ref]):
    cfg = pool.cfg
    max_owner = pool.num_clients       # owners are cid+1
    allocated = 0
    orphans = 0
    for region in pool.data_regions:
        arrays = _alive_arrays(pool, region)
        if not arrays:
            continue                   # flagged by the ring audit already
        n = cfg.bat_words
        base = arrays[0][2][:n]
        for _, mid, arr in arrays[1:]:
            if not np.array_equal(arr[:n], base):
                blk = int(np.nonzero(arr[:n] != base)[0][0])
                rep.errors.append(
                    f"bat: region {region} BAT diverges at block {blk} "
                    f"(MN {arrays[0][1]} vs MN {mid})")
        for blk in np.nonzero(base)[0]:
            owner = int(base[int(blk)])
            allocated += 1
            if owner == BAT_ORPHAN:
                orphans += 1
            elif not (1 <= owner <= max_owner):
                rep.errors.append(
                    f"bat: region {region} block {int(blk)} owned by "
                    f"unknown tag {owner:#x} (not 0 / cid+1 / ORPHAN)")
    rep.stats["blocks_allocated"] = allocated
    rep.stats["blocks_orphan"] = orphans


# -------------------------------------------------- block / object surfaces
def _audit_blocks(rep: HeapReport, pool, live, refs: List[_Ref],
                  lenient: bool = False):
    cfg = pool.cfg
    ref_ptrs: Set[int] = {r.ptr for r in refs}
    ref_blocks: Set[Tuple[int, int]] = {
        (L.ptr_region(r.ptr),
         (L.ptr_offset(r.ptr) - cfg.bat_words) // cfg.block_words)
        for r in refs}
    slab_blocks: Set[Tuple[int, int]] = set()
    objects_live = 0
    objects_freed = 0
    leaks = 0
    for cid, c in sorted(live.items()):
        for sc, st in sorted(c.slab.items()):
            scw = L.size_class_words(sc)
            stride = scw // L.MIN_OBJ_WORDS
            free_set = {int(p) for p in st.free}
            for (region, blk) in st.blocks:
                slab_blocks.add((region, blk))
                mem = _primary_mem(pool, region)
                if mem is None:
                    continue
                owner = int(mem[blk])
                if owner != cid + 1:
                    rep.warnings.append(
                        f"block: region {region} block {blk} is in client "
                        f"{cid}'s slab but BAT says owner tag {owner:#x} "
                        "(reassigned by recovery or disowned)")
                base = pool.block_base(blk)
                n_objs = cfg.block_payload_words // scw
                bm_off = pool.bitmap_base(blk)
                bm = [int(w) for w in
                      mem[bm_off:bm_off + cfg.bitmap_words]]
                for w_i, w in enumerate(bm):
                    while w:
                        bit = (w & -w).bit_length() - 1
                        w &= w - 1
                        if (w_i * 64 + bit) % stride != 0:
                            rep.errors.append(
                                f"block: region {region} block {blk} free "
                                f"bitmap bit {w_i * 64 + bit} is not on "
                                f"the sc-{sc} carve grid — double-free "
                                "FAA overflow")
                for i in range(n_objs):
                    off = base + i * scw
                    ptr = L.pack_ptr(region, off)
                    tail = int(mem[off + scw - 1])
                    used = bool(tail & L.USED_BIT)
                    obj_idx = (off - base) // L.MIN_OBJ_WORDS
                    freed = bool(bm[obj_idx // 64] >> (obj_idx % 64) & 1)
                    if used and not freed:
                        objects_live += 1
                    if freed:
                        objects_freed += 1
                    if used and not freed and ptr not in ref_ptrs \
                            and ptr not in free_set:
                        leaks += 1
                        # a client that crashed with a pipeline of in-flight
                        # ops strands the non-tail ones (§5.3 repairs only
                        # the tail log entry per list) — lenient demotes
                        sink = rep.warnings if lenient else rep.errors
                        sink.append(
                            f"leak: region {region} block {blk} word {off} "
                            f"(client {cid}, sc {sc}): used object with no "
                            "index reference, no free bit, and not on the "
                            "owner's free list — unreachable"
                            + (" (crashed-op residue)" if lenient else
                               " forever"))
    # reachability of allocated blocks that no live client's slab tracks
    for region in pool.data_regions:
        mem = _primary_mem(pool, region)
        if mem is None:
            continue
        for blk in np.nonzero(mem[:cfg.bat_words])[0]:
            blk = int(blk)
            if (region, blk) in slab_blocks or (region, blk) in ref_blocks:
                continue
            owner = int(mem[blk])
            who = "ORPHAN" if owner == BAT_ORPHAN else f"tag {owner:#x}"
            rep.warnings.append(
                f"block: region {region} block {blk} ({who}) is allocated "
                "but unreachable: no slab tracks it and no index slot "
                "references into it (garbage until reclaimed)")
    rep.stats["objects_live"] = objects_live
    rep.stats["objects_freed_pending"] = objects_freed
    rep.stats["leaks"] = leaks


# ------------------------------------------------------------------ keydir
def _audit_keydir(rep: HeapReport, pool, live, crashed, refs: List[_Ref],
                  lenient: bool = False):
    race_keys = {r.key for r in refs if r.key >= 0}
    ord_keys = set(ordered.ordered_keys_direct(pool))
    rep.stats["keydir_keys"] = len(ord_keys)
    # ORD FULL back-pressure and client crashes legally desync the keydir
    # from the RACE truth — demote to warnings in those runs
    drops = sum(c.ord_full_drops for c in live.values())
    lenient = lenient or drops > 0
    sink = rep.warnings if lenient else rep.errors
    why = (f" (lenient: {drops} ORD-FULL drop(s), "
           f"{len(crashed)} unrecovered crash(es))" if lenient else "")
    missing = sorted(race_keys - ord_keys)
    extra = sorted(ord_keys - race_keys)
    if missing:
        sink.append(
            f"keydir: {len(missing)} committed key(s) invisible to scans, "
            f"e.g. {missing[0]:#x}{why}")
    if extra:
        sink.append(
            f"keydir: {len(extra)} key(s) in the ordered keydir with no "
            f"RACE entry, e.g. {extra[0]:#x}{why}")
    for region in pool.ordered_regions:
        arrays = _alive_arrays(pool, region)
        if len(arrays) >= 2:
            base = arrays[0][2]
            for _, mid, arr in arrays[1:]:
                if not np.array_equal(arr, base):
                    diff = int(np.nonzero(arr != base)[0][0])
                    rep.warnings.append(
                        f"keydir: region {region} replicas diverge at word "
                        f"{diff} (MN {arrays[0][1]} vs MN {mid}) — "
                        "claim round not completed (repair_ordered due)")
                    break
