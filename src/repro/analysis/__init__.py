"""Protocol sanitizer suite (static + dynamic analyses).

Three legs, per the sanitizer design:

* ``trace``  — a ring-buffer recorder for one-sided verbs (READ / WRITE /
  CAS / FAA and their fleet-mode batch twins), attached to a ``DMPool`` by
  instance-method wrapping so the un-attached pool pays zero cost;
* ``races``  — a vectorized happens-before pass over a recorded trace that
  flags cross-client conflicts the FUSEE protocol does *not* legalize
  (stale-epoch mutations, acked lost empty-slot CASes, unguarded
  write/write conflicts, primary-before-backup clears, torn reads);
* ``lint``   — AST protocol lints (L001-L005), runnable as
  ``python -m repro.analysis.lint``;
* ``heapcheck`` — a post-drain DM heap / placement-epoch auditor
  (leaks, double references, BAT ownership, replica divergence).
"""
from .trace import VerbTracer  # noqa: F401
from .races import Finding, detect, report  # noqa: F401
from .heapcheck import HeapReport, audit  # noqa: F401
