"""AST protocol lints for the FUSEE reproduction (L001-L009).

Run as ``python -m repro.analysis.lint [paths...]`` (defaults to the
``repro`` package plus the repo's ``tests/`` and ``benchmarks/`` trees);
exits nonzero on any finding, which is what the CI ``analysis`` job
enforces.  Rules encode protocol contracts that type checkers cannot
see:

L001  **epoch-threaded verbs** — a direct ``pool.read/write/cas/faa``
      (or ``*_batch``) call site must sit in a function that compares a
      lease ``epoch`` (the §5.2 stale-verb guard), unless the module runs
      under master authority (``master.py``, ``migrate.py``, ``heap.py``
      itself).  The PR-3 stale-epoch redirection bug class: a verb that
      executes against re-homed placement without an issue-time epoch
      check.
L002  **nondeterminism** — ``random.*``, ``time.time()``, and global
      ``np.random.*`` draws are banned outside ``core/rng.py``: every
      random decision must derive from a named ``SimRng`` substream or
      an explicit seed, or the replay contract breaks.  Seed-taking
      constructors (``default_rng(seed)``, ``SeedSequence(seed)``,
      ``random.Random(seed)``) called WITH arguments are deterministic
      functions of their inputs and exempt; the argless forms draw OS
      entropy and are flagged.  (Explicitly-keyed ``jax.random`` is
      deterministic and exempt.)
L003  **pool-array mutation** — only ``DMPool`` (and the master-authority
      modules) may store into MN region arrays (``*.regions[...]`` or
      names derived from them).  Everyone else goes through verbs, which
      the tracer, netmodel, and crash-stop logic can see.
L004  **scalar loops in batch paths** — ``fleet.py`` functions and
      ``heap.py`` ``*_batch`` methods must not issue scalar verbs from a
      Python ``for``/``while`` (the fleet tick's whole point is one array
      call per verb kind; a per-client loop silently reverts to O(N)
      Python).
L005  **bare assert in protocol code** — ``core/*.py`` must raise typed
      ``faults`` errors carrying reproducing context instead of ``assert``
      (asserts vanish under ``python -O`` and carry no seed/cid/tick).
L006  **pragma hygiene** — every suppression pragma must carry a
      parenthesized justification, and must actually suppress a finding:
      a pragma whose rule no longer fires on its line is *stale* and gets
      reported (a leftover license would silently cover a future
      regression on that line).
L007  **Python loops in the fused tick path** — ``*fused*`` functions in
      ``fleet.py``/``heap.py`` are the megakernel: one array dispatch
      over the whole fleet's lanes.  Any statement-level ``for``/
      ``while`` there is a per-lane O(N) regression waiting to scale, so
      each one must either vanish into array ops or carry an explicit
      ``allow-fused-loop`` pragma arguing why it is not per-lane work
      (LUT rebuilds on topology changes, per-verb result unpack at the
      generator API boundary, inherently sequential same-word races).
L008  **bare counters-dict mutation** — protocol/fleet code must not
      write through ad-hoc ``counters`` dicts (``self.counters[k] += 1``
      or rebinding ``.counters`` to a dict literal): metrics live in the
      typed registry (``repro.obs.registry``) under stable dotted names,
      where snapshots are deterministic, mergeable, and covered by the
      fused-vs-oracle differential gate.  The surviving ``counters``
      attributes are read-only deprecation views.
L009  **Python loops in obs hot paths** — the observability package's
      cost contract (obs/flight.py docstring, claims-checked by the
      ``obs_overhead`` bench) is tuple-append per op and array passes per
      flush.  A statement-level ``for``/``while`` inside an ``obs/``
      flush/update/observe/fold/build-family function is a per-element
      regression waiting to scale exactly like L004/L007; vectorize it,
      or carry an ``allow-obs-loop`` pragma arguing why the loop is not
      per-element work (taxonomy-bounded group walks, export paths).

Suppression: a trailing ``# lint: allow-<name> (<why>)`` pragma on the
offending line, or on the enclosing ``def``/``class`` line to cover the
whole body.  ``<name>`` is the rule id (``L003``) or its alias:
``assert`` (L005), ``epoch`` (L001), ``nondet`` (L002), ``pool-mutation``
(L003), ``scalar-loop`` (L004), ``fused-loop`` (L007), ``counters``
(L008), ``obs-loop`` (L009).  Pragmas are deliberate, documented
exemptions — the lint keeps them honest by flagging unknown names,
missing justifications, and stale sites (L006 itself is exempt from
suppression: delete the pragma instead).
"""
from __future__ import annotations

import argparse
import ast
import io
import os
import re
import sys
import tokenize
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["LintFinding", "lint_source", "lint_paths", "main", "RULES"]

RULES = {
    "L001": "verb site lacks a lease-epoch guard",
    "L002": "nondeterministic source outside core/rng.py",
    "L003": "direct mutation of pool region arrays outside DMPool",
    "L004": "scalar verb loop inside a batch path",
    "L005": "bare assert in protocol code",
    "L006": "lint pragma without justification, or stale (suppresses "
            "nothing)",
    "L007": "Python loop inside a fused tick path",
    "L008": "write through a bare counters dict in protocol code",
    "L009": "Python loop inside an obs hot path",
}

_ALIASES = {
    "epoch": "L001", "nondet": "L002", "pool-mutation": "L003",
    "scalar-loop": "L004", "assert": "L005", "fused-loop": "L007",
    "counters": "L008", "obs-loop": "L009",
}

# L009 scope: function-name prefixes (leading underscores stripped) of
# the obs/ batch entry points — per-flush / per-wave code where a
# per-element Python loop silently reverts the vectorized cost contract
_OBS_HOT_PREFIXES = (
    "flush", "update", "observe", "touch", "heat", "push", "emit",
    "fold", "build", "evaluate", "top", "group", "critical",
    "spans_to", "op_begin", "op_settled", "append")

VERBS = ("read", "write", "cas", "faa")

# RNG constructors that take an explicit seed: called WITH arguments they
# are deterministic functions of their inputs and replay-safe; only the
# argless forms (OS entropy) and module-level draws are nondeterministic
_SEEDED_CTORS = ("np.random.default_rng", "numpy.random.default_rng",
                 "np.random.SeedSequence", "numpy.random.SeedSequence",
                 "random.Random")
BATCH_VERBS = tuple(v + "_batch" for v in VERBS)

# modules that legitimately run under master authority (recovery,
# migration, the pool itself): direct array/verb access is their job
MASTER_AUTHORITY = {"master.py", "migrate.py", "heap.py"}

_PRAGMA_RE = re.compile(
    r"#\s*lint:\s*allow-([A-Za-z0-9_-]+)(?:\s*\(([^)]*)\))?")


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


# ------------------------------------------------------------------ helpers
def _dotted(node) -> str:
    """Best-effort dotted name of an expression ('pool.cas', 'np.random')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _comments(text: str) -> List[Tuple[int, str]]:
    """(line, comment-text) for every real comment token — pragmas are
    comments, and only comments: the pattern appearing inside a string
    literal (a lint message, a test fixture) is not a pragma."""
    out: List[Tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.string))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        out = list(enumerate(text.splitlines(), 1))   # best effort
    return out


def _pragmas(text: str) -> Dict[int, Set[str]]:
    """line -> set of rule ids allowed on that line."""
    out: Dict[int, Set[str]] = {}
    for i, line in _comments(text):
        for m in _PRAGMA_RE.finditer(line):
            name = m.group(1)
            rule = _ALIASES.get(name.lower(), name.upper())
            if rule not in RULES:
                out.setdefault(i, set()).add("?" + name)
            else:
                out.setdefault(i, set()).add(rule)
    return out


def _pragma_sites(text: str) -> List[Tuple[int, str, str, str]]:
    """Every pragma occurrence: (line, rule-or-?name, raw name,
    stripped justification text)."""
    out = []
    for i, line in _comments(text):
        for m in _PRAGMA_RE.finditer(line):
            name = m.group(1)
            rule = _ALIASES.get(name.lower(), name.upper())
            if rule not in RULES:
                rule = "?" + name
            out.append((i, rule, name, (m.group(2) or "").strip()))
    return out


def _contains_epoch_compare(fn: ast.AST) -> bool:
    """Does the function body compare anything called ``epoch``?"""
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            for part in [node.left] + list(node.comparators):
                for sub in ast.walk(part):
                    name = getattr(sub, "attr", None) or \
                        (sub.id if isinstance(sub, ast.Name) else None)
                    if name and "epoch" in name.lower():
                        return True
    return False


def _names_in_target(target) -> List[str]:
    """Names bound by an assignment/loop target (handles tuple unpack)."""
    out = []
    for node in ast.walk(target):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.append(node.id)
    return out


def _mentions_regions(node) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "regions"
               for n in ast.walk(node))


# ------------------------------------------------------------------- engine
class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, rel: str, text: str,
                 rules: Set[str]):
        self.path = path
        self.base = os.path.basename(rel)
        self.in_core = f"{os.sep}core{os.sep}" in rel or \
            rel.replace("/", os.sep).startswith(f"core{os.sep}")
        self.in_obs = f"{os.sep}obs{os.sep}" in rel or \
            rel.replace("/", os.sep).startswith(f"obs{os.sep}")
        self.is_rng = rel.replace(os.sep, "/").endswith("core/rng.py")
        self.rules = rules
        self.pragmas = _pragmas(text)
        self.used_pragmas: Set[Tuple[int, str]] = set()
        self.findings: List[LintFinding] = []
        self._fn_stack: List[ast.AST] = []   # enclosing function defs
        self._cls_stack: List[ast.ClassDef] = []
        self._tainted: List[Set[str]] = []   # per-function region-array names

    # ----------------------------------------------------------- reporting
    def _flag(self, rule: str, node: ast.AST, msg: str):
        if rule not in self.rules:
            return
        line = getattr(node, "lineno", 0)
        covered = [line] + \
            [f.lineno for f in self._fn_stack] + \
            [c.lineno for c in self._cls_stack]
        for ln in covered:
            if rule in self.pragmas.get(ln, ()):
                self.used_pragmas.add((ln, rule))  # L006 staleness proof
                return
        self.findings.append(
            LintFinding(self.path, line, rule, msg))

    # -------------------------------------------------------------- scopes
    def _visit_fn(self, node):
        self._fn_stack.append(node)
        self._tainted.append(set())
        self.generic_visit(node)
        self._tainted.pop()
        self._fn_stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_fn(node)

    def visit_AsyncFunctionDef(self, node):
        self._visit_fn(node)

    def visit_ClassDef(self, node):
        self._cls_stack.append(node)
        self.generic_visit(node)
        self._cls_stack.pop()

    # --------------------------------------------------------------- L005
    def visit_Assert(self, node):
        if self.in_core:
            self._flag(
                "L005", node,
                "bare assert in protocol code — raise a typed faults error "
                "(ProtocolViolation / RegionLost / ...) with reproducing "
                "context, or add `# lint: allow-assert (<why>)`")
        self.generic_visit(node)

    # --------------------------------------------------------------- calls
    def visit_Call(self, node):
        name = _dotted(node.func)
        self._check_L001(node, name)
        self._check_L002(node, name)
        self.generic_visit(node)

    def _check_L001(self, node, name):
        if self.base in MASTER_AUTHORITY or not self.in_core:
            return
        last = name.rsplit(".", 1)
        if len(last) != 2 or last[1] not in VERBS + BATCH_VERBS:
            return
        recv = last[0]
        # receivers that are (or hold) the pool — heuristic on naming
        if not (recv in ("pool", "p", "self.pool")
                or recv.endswith(".pool")):
            return
        if self._fn_stack and _contains_epoch_compare(self._fn_stack[-1]):
            return    # the §5.2 guard is present in this function
        self._flag(
            "L001", node,
            f"direct pool verb `{name}(...)` without a lease-epoch guard "
            "in the enclosing function — stale verbs must bounce (§5.2); "
            "compare the issue-time epoch or add "
            "`# lint: allow-epoch (<why>)`")

    def _check_L002(self, node, name):
        if self.is_rng:
            return
        if name in _SEEDED_CTORS and (node.args or node.keywords):
            return    # explicitly seeded: deterministic given its inputs
        bad = None
        if name.startswith(("np.random.", "numpy.random.")):
            bad = f"`{name}`"
        elif name == "time.time":
            bad = "`time.time()` (wall clock)"
        elif name.startswith("random.") and name.count(".") == 1:
            bad = f"stdlib `{name}`"
        if bad:
            self._flag(
                "L002", node,
                f"{bad} breaks seeded replay — draw from a named "
                "core/rng.py SimRng substream, or add "
                "`# lint: allow-nondet (<why>)`")

    # --------------------------------------------------------------- L003
    def visit_Assign(self, node):
        self._check_store_targets(node.targets, node)
        self._check_L008(node.targets, node, rebind=True)
        if self._tainted and _mentions_regions(node.value):
            for t in node.targets:
                self._tainted[-1].update(_names_in_target(t))
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_store_targets([node.target], node)
        self._check_L008([node.target], node, rebind=False)
        self.generic_visit(node)

    def visit_For(self, node):
        if self._tainted and _mentions_regions(node.iter):
            self._tainted[-1].update(_names_in_target(node.target))
        self._check_L004(node)
        self._check_L007(node)
        self._check_L009(node)
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_L004(node)
        self._check_L007(node)
        self._check_L009(node)
        self.generic_visit(node)

    def _check_store_targets(self, targets, node):
        if self.base in MASTER_AUTHORITY or not self.in_core:
            return
        for t in targets:
            if not isinstance(t, ast.Subscript):
                continue
            if _mentions_regions(t.value):
                self._flag(
                    "L003", node,
                    "direct store into an MN region array — only DMPool "
                    "(and master-authority modules) may bypass the verb "
                    "layer; issue verbs, or add "
                    "`# lint: allow-pool-mutation (<why>)`")
            elif isinstance(t.value, ast.Name) and self._tainted \
                    and t.value.id in self._tainted[-1]:
                self._flag(
                    "L003", node,
                    f"store into `{t.value.id}[...]`, which aliases an MN "
                    "region array — only DMPool (and master-authority "
                    "modules) may bypass the verb layer; issue verbs, or "
                    "add `# lint: allow-pool-mutation (<why>)`")

    # --------------------------------------------------------------- L008
    def _check_L008(self, targets, node, *, rebind: bool):
        """Writes through bare ``counters`` dicts in protocol code: the
        typed registry (repro.obs.registry) is the sanctioned metric
        store; the surviving ``counters`` attributes are read-only
        deprecation views."""
        if not self.in_core:
            return
        for t in targets:
            if isinstance(t, ast.Subscript):
                base = _dotted(t.value)
                if base == "counters" or base.endswith(".counters"):
                    self._flag(
                        "L008", node,
                        f"write through `{base}[...]` — metrics belong in "
                        "the typed registry (repro.obs.registry) under "
                        "dotted names, not ad-hoc counters dicts; bump a "
                        "registry handle, or add "
                        "`# lint: allow-counters (<why>)`")
            elif rebind and isinstance(t, ast.Attribute) \
                    and t.attr == "counters" \
                    and isinstance(node.value, (ast.Dict, ast.DictComp)):
                self._flag(
                    "L008", node,
                    "rebinding `.counters` to a dict literal — register "
                    "Counter/Gauge handles on the metrics registry "
                    "(repro.obs.registry) instead, or add "
                    "`# lint: allow-counters (<why>)`")

    # --------------------------------------------------------------- L004
    def _in_batch_scope(self) -> bool:
        if self.base == "fleet.py":
            return True
        if self.base == "heap.py" and self._fn_stack:
            fn = self._fn_stack[-1]
            return getattr(fn, "name", "").endswith("_batch")
        return False

    def _check_L004(self, node):
        if not self._in_batch_scope():
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = _dotted(sub.func)
                last = name.rsplit(".", 1)
                if len(last) == 2 and last[1] in VERBS \
                        and (last[0] in ("pool", "p", "self", "self.pool")
                             or last[0].endswith(".pool")):
                    self._flag(
                        "L004", node,
                        f"scalar verb `{name}(...)` inside a Python "
                        f"{'for' if isinstance(node, ast.For) else 'while'} "
                        "loop on a batch path — use the *_batch twins (one "
                        "array call per verb kind), or add "
                        "`# lint: allow-scalar-loop (<why>)`")
                    return

    # --------------------------------------------------------------- L007
    def _check_L007(self, node):
        if self.base not in ("fleet.py", "heap.py"):
            return
        if not any("fused" in getattr(fn, "name", "")
                   for fn in self._fn_stack):
            return
        kw = "for" if isinstance(node, ast.For) else "while"
        self._flag(
            "L007", node,
            f"Python `{kw}` loop inside a fused tick path — the megakernel "
            "contract is ONE array dispatch over all lanes; vectorize it, "
            "or add `# lint: allow-fused-loop (<why this is not per-lane "
            "work>)`")

    # --------------------------------------------------------------- L009
    def _check_L009(self, node):
        if not self.in_obs or not self._fn_stack:
            return
        name = getattr(self._fn_stack[-1], "name", "").lstrip("_")
        if not name.startswith(_OBS_HOT_PREFIXES):
            return
        kw = "for" if isinstance(node, ast.For) else "while"
        self._flag(
            "L009", node,
            f"Python `{kw}` loop inside an obs hot path "
            f"(`{self._fn_stack[-1].name}`) — the hub's cost contract is "
            "tuple-append per op and array passes per flush; vectorize "
            "it, or add `# lint: allow-obs-loop (<why this is not "
            "per-element work>)`")


# ---------------------------------------------------------------- frontends
def lint_source(text: str, path: str, *, rel: Optional[str] = None,
                rules: Optional[Set[str]] = None) -> List[LintFinding]:
    """Lint one module's source.  ``rel`` is the path relative to the
    package root (used for scoping rules); defaults to ``path``."""
    rel = rel if rel is not None else path
    rules = set(RULES) if rules is None else set(rules)
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, "E000",
                            f"syntax error: {e.msg}")]
    linter = _Linter(path, rel, text, rules)
    linter.visit(tree)
    # unknown pragma names are findings too — a typo'd pragma silently
    # suppressing nothing (or meant to suppress something) is a trap
    for line, names in sorted(linter.pragmas.items()):
        for n in sorted(names):
            if n.startswith("?"):
                linter.findings.append(LintFinding(
                    path, line, "E001",
                    f"unknown lint pragma `allow-{n[1:]}` (valid: "
                    f"{', '.join(sorted(_ALIASES))} or a rule id)"))
    # L006 pragma hygiene: every pragma must say WHY it is safe, and must
    # actually suppress something — a stale pragma is a license that
    # outlived its exemption and will silently cover a future regression
    if "L006" in rules:
        for line, rule, name, why in _pragma_sites(text):
            if rule.startswith("?"):
                continue                     # already an E001 above
            if not why:
                linter.findings.append(LintFinding(
                    path, line, "L006",
                    f"pragma `allow-{name}` lacks a justification — "
                    f"write `# lint: allow-{name} (<why this site is "
                    "exempt>)`"))
            elif rule in rules and (line, rule) not in linter.used_pragmas:
                linter.findings.append(LintFinding(
                    path, line, "L006",
                    f"stale pragma `allow-{name}`: {rule} no longer "
                    "fires on this line — delete the pragma (it would "
                    "silently cover a future regression)"))
    linter.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return linter.findings


def _package_root() -> str:
    """The installed ``repro`` package directory (default lint target)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(here)


def default_paths() -> List[str]:
    """The repro package plus the repo's ``tests/`` and ``benchmarks/``
    trees when present (a src-layout checkout) — pragma hygiene and the
    nondeterminism rule apply to test/bench code too."""
    pkg = _package_root()
    out = [pkg]
    repo = os.path.dirname(os.path.dirname(pkg))        # src/repro -> repo
    for extra in ("tests", "benchmarks"):
        d = os.path.join(repo, extra)
        if os.path.isdir(d):
            out.append(d)
    return out


def lint_paths(paths: List[str], *,
               rules: Optional[Set[str]] = None) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for root in paths:
        root = os.path.abspath(root)
        if os.path.isfile(root):
            files: List[Tuple[str, str]] = [(root, os.path.basename(root))]
        else:
            files = []
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        full = os.path.join(dirpath, fn)
                        files.append((full, os.path.relpath(full, root)))
        for full, rel in sorted(files):
            with open(full, "r", encoding="utf-8") as fh:
                text = fh.read()
            findings += lint_source(text, full, rel=rel, rules=rules)
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="FUSEE protocol lints (L001-L005); exit 1 on findings.")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: the repro package)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    args = ap.parse_args(argv)
    rules = set(args.rules.split(",")) if args.rules else None
    paths = args.paths or default_paths()
    findings = lint_paths(paths, rules=rules)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"protocol lint: {n} finding(s) in "
          f"{', '.join(os.path.relpath(p) if os.path.isabs(p) else p for p in paths)}"
          if n else "protocol lint: clean")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
