"""Distributed training loop: pjit train step, microbatch accumulation,
fault drill, straggler watchdog."""
from .trainer import (TrainConfig, Trainer, make_train_step,  # noqa: F401
                      pick_microbatches)
from .fault import SimulatedFailure, StragglerWatchdog  # noqa: F401
