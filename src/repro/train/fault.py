"""Failure injection + straggler detection for the training loop.

At 1000-node scale, two failure classes dominate:
* hard failures (preemption, HBM ECC, host loss) — handled by
  checkpoint/restart (Trainer.run_with_recovery; identical to a real
  preemption: state is rebuilt from the last *committed* checkpoint and the
  deterministic data pipeline is fast-forwarded by step number);
* stragglers (thermal throttling, failing ICI links) — detected here by
  per-step wall-time against a rolling median; the deployment hook is to
  evict the slow host and re-shard (in this repo: recorded + surfaced).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Tuple


class SimulatedFailure(RuntimeError):
    """Raised by the fault drill to emulate a node loss mid-run."""

    def __init__(self, step: int):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step


@dataclass
class StragglerWatchdog:
    factor: float = 3.0          # straggler = step > factor * rolling median
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged: List[Tuple[int, float, float]] = field(default_factory=list)

    def record(self, step: int, dt: float):
        med = statistics.median(self.times[-self.window:]) if self.times else dt
        self.times.append(dt)
        if len(self.times) > 8 and dt > self.factor * med:
            self.flagged.append((step, dt, med))

    @property
    def straggler_steps(self) -> List[int]:
        return [s for s, *_ in self.flagged]

    def summary(self) -> Dict:
        if not self.times:
            return {"steps": 0}
        return {
            "steps": len(self.times),
            "median_s": statistics.median(self.times),
            "p99_s": sorted(self.times)[int(0.99 * (len(self.times) - 1))],
            "stragglers": len(self.flagged),
        }
