"""The pjit training loop.

``make_train_step`` builds a single jitted step:

    batch (global_batch, seq) --reshape--> (n_micro, micro, seq)
      --lax.scan--> fp32 grad accumulation (remat inside the layer scan)
      --optional shard_map('pod')--> int8-compressed cross-pod grad merge
      --optimizer.update--> new params/state

Microbatch count is chosen so rematerialized activations fit HBM
(``pick_microbatches``); grads accumulate in fp32 sharded like the params.

The Trainer drives steps, checkpoints asynchronously every ``ckpt_every``,
detects stragglers, and recovers from (simulated) failures by restoring the
latest committed checkpoint — the restart path is identical to a real
preemption: rebuild state from disk, fast-forward the data pipeline cursor.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager, load_checkpoint
from repro.models.model import Model
from repro.optim import (OptConfig, Optimizer, init_error_feedback,
                         pod_compressed_mean)
from .fault import SimulatedFailure, StragglerWatchdog


@dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1                 # gradient-accumulation microbatches
    pod_compress: bool = False       # int8 cross-pod gradient merge
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0


def pick_microbatches(model: Model, global_batch: int, seq_len: int,
                      budget_bytes: float = 4e9) -> int:
    """Choose n_micro so stored layer inputs (scan remat) fit the budget."""
    cfg = model.cfg
    sizes = dict(zip(model.mesh.axis_names, model.mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    per_layer = seq_len * cfg.d_model * 2  # bf16 layer input per sample
    stored = cfg.n_layers * per_layer * (global_batch / dp)
    n_micro = 1
    while stored / n_micro > budget_bytes and n_micro < global_batch:
        n_micro *= 2
    # each microbatch must still shard over the full data-parallel extent
    # (the MoE shard_map maps the batch dim over ('pod','data'))
    n_micro = min(n_micro, max(global_batch // dp, 1))
    while global_batch % n_micro:
        n_micro //= 2
    return max(1, n_micro)


def make_train_step(model: Model, opt: Optimizer, *, n_micro: int = 1,
                    pod_compress: bool = False) -> Callable:
    """Returns step(state, batch) -> (state, metrics), jit-ready."""
    mesh = model.mesh
    has_pod = "pod" in mesh.axis_names

    def grads_of(params, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, grads

        def micro(batch):
            return jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (loss_acc + loss, g_acc), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(acc_step, (0.0, g0), micro(batch))
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    if pod_compress and has_pod:
        # map ONLY the pod axis; everything else stays auto-sharded.  Each
        # pod computes grads on its batch slice; the merge goes over the
        # wire int8 (optim/compress.py), with error feedback in the state.
        def step(state, batch):
            def pod_body(params, batch, err):
                loss, grads = grads_of(params, batch)
                grads, new_err = pod_compressed_mean(grads, err, axis="pod")
                loss = jax.lax.pmean(loss, "pod")
                return loss, grads, new_err

            pspecs = model.param_specs()
            smap = jax.shard_map(
                pod_body, mesh=mesh,
                in_specs=(jax.tree.map(lambda s: P(*s), pspecs),
                          jax.tree.map(lambda _: P("pod"), batch),
                          jax.tree.map(lambda s: P(*s), pspecs)),
                out_specs=(P(), jax.tree.map(lambda s: P(*s), pspecs),
                           jax.tree.map(lambda s: P(*s), pspecs)),
                check_vma=False, axis_names={"pod"})
            loss, grads, new_err = smap(state["params"], batch, state["err"])
            new_params, new_opt, metrics = opt.update(
                grads, state["opt"], state["params"])
            metrics["loss"] = loss
            return {"params": new_params, "opt": new_opt,
                    "err": new_err}, metrics
    else:
        def step(state, batch):
            loss, grads = grads_of(state["params"], batch)
            new_params, new_opt, metrics = opt.update(
                grads, state["opt"], state["params"])
            metrics["loss"] = loss
            return {"params": new_params, "opt": new_opt}, metrics

    return step


class Trainer:
    """Synchronous training driver with checkpoint/restart + watchdog."""

    def __init__(self, model: Model, opt_cfg: OptConfig,
                 tcfg: TrainConfig, dataset):
        self.model = model
        self.opt = Optimizer(opt_cfg)
        self.tcfg = tcfg
        self.dataset = dataset
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.watchdog = StragglerWatchdog(factor=tcfg.straggler_factor)
        self.step_fn = jax.jit(make_train_step(
            model, self.opt, n_micro=tcfg.n_micro,
            pod_compress=tcfg.pod_compress), donate_argnums=0)
        self.state = None
        self.step = 0
        self.history: list = []

    def init_state(self, key):
        params = self.model.init(key)
        state = {"params": params, "opt": self.opt.init(params)}
        if self.tcfg.pod_compress and "pod" in self.model.mesh.axis_names:
            state["err"] = init_error_feedback(params)
        self.state = state
        self.step = 0
        return state

    def restore(self) -> bool:
        """Restore latest checkpoint; returns True if one was found."""
        if self.ckpt.latest() is None:
            return False
        like = {"params": self.model.abstract_params(),
                "opt": self.opt.init(self.model.abstract_params())
                if False else None}
        # build abstract state via a throwaway init on shapes
        params_abs = self.model.abstract_params()
        state_abs = {"params": params_abs}
        opt_abs = jax.eval_shape(self.opt.init, params_abs)
        state_abs["opt"] = opt_abs
        if self.tcfg.pod_compress and "pod" in self.model.mesh.axis_names:
            state_abs["err"] = jax.eval_shape(init_error_feedback, params_abs)
        loaded, step, extra = load_checkpoint(
            self.tcfg.ckpt_dir, state_abs)
        self.state = loaded
        self.step = step
        return True

    def run(self, n_steps: int, *, fail_at: Optional[int] = None):
        """Train; optionally inject a failure at ``fail_at`` (fault drill)."""
        assert self.state is not None
        losses = []
        while self.step < n_steps:
            if fail_at is not None and self.step == fail_at:
                fail_at = None  # fire once
                raise SimulatedFailure(self.step)
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v)
                     for k, v in self.dataset.batch_at(self.step).items()}
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.watchdog.record(self.step, dt)
            losses.append(loss)
            self.history.append({"step": self.step, "loss": loss, "dt": dt})
            self.step += 1
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(self.step, self.state,
                                     extra={"data_step": self.step})
        self.ckpt.save_async(self.step, self.state,
                             extra={"data_step": self.step})
        self.ckpt.wait()
        return losses

    def run_with_recovery(self, n_steps: int, fail_at: Optional[int] = None):
        """The fault drill: crash at fail_at, restore, resume, finish."""
        try:
            return self.run(n_steps, fail_at=fail_at), False
        except SimulatedFailure:
            self.state = None
            restored = self.restore()
            if not restored:
                self.init_state(jax.random.key(0))
            return self.run(n_steps), True
