"""Online hot-key / skew monitor: the sensor layer for load-driven index
placement (ROADMAP item 1, FlexKV-style bucket migration).

Three streaming estimators, all batch-vectorized and fully deterministic
(integer counts + fixed-point milli gauges — same-seed runs produce
byte-identical registry snapshots):

* ``SpaceSaving`` — the Metwally et al. top-k heavy-hitter sketch over
  the fold32 key stream the heat sketch already sees (client cached-path
  touches + probe-wave keys).  Batched: one ``np.unique`` per flush,
  hits folded with one scatter-add, misses merged in mergeable-summaries
  form (candidate count = current min + batch count, err = min, keep the
  top-``capacity``), preserving the per-item algorithm's guarantee
  ``true_count <= count <= true_count + err`` even when one flush batch
  carries more distinct misses than the sketch has slots.  Deterministic
  tie-breaks: (count desc, key asc) for both survival and reporting.
* ``zipf_theta`` — an online zipf-θ estimate: least-squares slope of
  ``log(count)`` vs ``log(rank)`` over the monitor's top-k.  Contract:
  the estimate describes the **head** of the distribution (the monitored
  keys), needs a saturated monitor (>= 8 live counters) to report, and
  is exact only when the head really is zipfian — uniform workloads
  report ~0, planted zipf(0.99) converges to ~0.99 within a couple
  thousand ticks (acceptance-tested).
* ``HotKeyMonitor`` — glues both to an EWMA per-shard / per-MN imbalance
  score (max-share over mean-share of settled-op load) and a two-state
  regime machine (``uniform`` <-> ``skewed``) with hysteresis; crossings
  emit typed ``regime`` events into the flight ring (obs/flight.py
  ``EV_REGIME``) — the hook adaptive index offloading will consume.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SpaceSaving", "zipf_theta", "HotKeyMonitor"]


class SpaceSaving:
    """Batched space-saving top-k over an int key stream.

    Monitored set is kept key-sorted so batch membership is one
    ``searchsorted``; eviction keeps the per-item error bound by
    inheriting the evicted counter (err = evicted count).
    """

    def __init__(self, capacity: int = 128):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self.keys = np.zeros(0, np.int64)      # sorted ascending
        self.counts = np.zeros(0, np.int64)
        self.errs = np.zeros(0, np.int64)
        self.n_seen = 0                        # stream length folded so far

    def update(self, keys) -> None:
        """Fold a batch of keys (any int array) — one unique + one merge."""
        keys = np.asarray(keys)
        if keys.size == 0:
            return
        keys = keys.astype(np.int64, copy=False).ravel()
        self.n_seen += len(keys)
        uk, uc = np.unique(keys, return_counts=True)
        if len(self.keys):
            pos = np.searchsorted(self.keys, uk)
            posc = np.minimum(pos, len(self.keys) - 1)
            hit = self.keys[posc] == uk
            if hit.any():
                self.counts[posc[hit]] += uc[hit]
            uk, uc = uk[~hit], uc[~hit]
        if len(uk) == 0:
            return
        # largest incoming first; deterministic (count desc, key asc)
        order = np.lexsort((uk, -uc))
        uk, uc = uk[order], uc[order]
        free = self.capacity - len(self.keys)
        if free > 0:
            take = min(free, len(uk))
            self.keys = np.concatenate([self.keys, uk[:take]])
            self.counts = np.concatenate([self.counts, uc[:take]])
            self.errs = np.concatenate([self.errs,
                                        np.zeros(take, np.int64)])
            uk, uc = uk[take:], uc[take:]
        if len(uk):
            # Merge step (mergeable-summaries form of space-saving): every
            # miss enters with count = min + its batch count and err = min
            # (min is the inherited floor any evicted key could have had),
            # then only the top-``capacity`` by count survive.  Unlike
            # evicting ``len(uk)`` victims outright, this keeps the
            # guarantee for batches with more distinct misses than
            # capacity: an established heavy hitter can only be displaced
            # by a candidate whose (floor + batch) count actually beats it.
            minc = int(self.counts.min()) if len(self.counts) else 0
            cand_k = np.concatenate([self.keys, uk])
            cand_c = np.concatenate([self.counts, uc + minc])
            cand_e = np.concatenate([self.errs,
                                     np.full(len(uk), minc, np.int64)])
            keep = np.lexsort((cand_k, -cand_c))[:self.capacity]
            self.keys, self.counts, self.errs = \
                cand_k[keep], cand_c[keep], cand_e[keep]
        order = np.argsort(self.keys, kind="stable")
        self.keys = self.keys[order]
        self.counts = self.counts[order]
        self.errs = self.errs[order]

    def top(self, k: Optional[int] = None) -> List[Tuple[int, int, int]]:
        """``[(key, count, err), ...]`` by count descending (key asc ties)."""
        order = np.lexsort((self.keys, -self.counts))
        if k is not None:
            order = order[:k]
        return [(int(self.keys[i]), int(self.counts[i]), int(self.errs[i]))
                for i in order]


def zipf_theta(counts) -> float:
    """Least-squares zipf-θ over rank/count pairs (counts sorted desc).

    Returns 0.0 when fewer than 8 positive counts (an unsaturated head
    cannot be fit honestly).  θ is clamped to [0, 4] — beyond that the
    head is effectively a single key and the slope is noise."""
    c = np.asarray(counts, np.float64)
    c = c[c > 0]
    if len(c) < 8:
        return 0.0
    c = np.sort(c)[::-1]
    x = np.log(np.arange(1, len(c) + 1, dtype=np.float64))
    y = np.log(c)
    xm, ym = x.mean(), y.mean()
    denom = ((x - xm) ** 2).sum()
    if denom <= 0.0:
        return 0.0
    theta = -float(((x - xm) * (y - ym)).sum() / denom)
    return min(max(theta, 0.0), 4.0)


def _imbalance(ewma: np.ndarray) -> float:
    """max-share / mean-share over dimensions that have seen load."""
    live = ewma[ewma > 0]
    if len(live) < 2:
        return 1.0
    return float(live.max() / live.mean())


class HotKeyMonitor:
    """Streaming skew sensor; see module docstring.

    ``observe_keys`` takes fold32 keys (the heat-stream vocabulary);
    ``observe_load`` takes the per-settle shard/MN id arrays the obs hub
    already computes; ``evaluate`` refreshes θ/imbalance and returns a
    regime-transition dict (or None) for the caller to record.
    """

    def __init__(self, *, top_k: int = 32, capacity: int = 128,
                 alpha: float = 0.2, theta_hi: float = 0.6,
                 imb_hi: float = 2.0, imb_lo: float = 1.4):
        self.top_k = int(top_k)
        self.sketch = SpaceSaving(max(int(capacity), self.top_k))
        self.alpha = float(alpha)
        self.theta_hi = float(theta_hi)
        self.imb_hi = float(imb_hi)
        self.imb_lo = float(imb_lo)
        self._shard_ewma = np.zeros(0, np.float64)
        self._mn_ewma = np.zeros(0, np.float64)
        self.theta = 0.0
        self.regime = "uniform"
        self.flips = 0

    # ---------------------------------------------------------- ingest ---
    def observe_keys(self, keys32) -> None:
        self.sketch.update(keys32)

    def _fold_dim(self, ewma: np.ndarray, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return ewma
        hi = int(ids.max()) + 1
        if hi > len(ewma):
            ewma = np.concatenate([ewma, np.zeros(hi - len(ewma))])
        ewma *= (1.0 - self.alpha)
        cnt = np.bincount(ids, minlength=len(ewma)).astype(np.float64)
        ewma += self.alpha * cnt
        return ewma

    def observe_load(self, shards, mns) -> None:
        """One settle batch's shard/MN attribution (one EWMA step each)."""
        self._shard_ewma = self._fold_dim(self._shard_ewma, shards)
        self._mn_ewma = self._fold_dim(self._mn_ewma, mns)

    # -------------------------------------------------------- evaluate ---
    @property
    def shard_imbalance(self) -> float:
        return _imbalance(self._shard_ewma)

    @property
    def mn_imbalance(self) -> float:
        return _imbalance(self._mn_ewma)

    def evaluate(self) -> Optional[Dict]:
        """Refresh θ and the regime state machine.  Returns a transition
        event dict on a crossing (hysteresis: enter ``skewed`` above
        ``theta_hi`` OR ``imb_hi``, leave below BOTH ``theta_hi`` and
        ``imb_lo``), else None."""
        counts = np.sort(self.sketch.counts)[::-1][:self.sketch.capacity]
        self.theta = zipf_theta(counts)
        imb = max(self.shard_imbalance, self.mn_imbalance)
        new = self.regime
        if self.regime == "uniform":
            if self.theta > self.theta_hi or imb > self.imb_hi:
                new = "skewed"
        else:
            if self.theta <= self.theta_hi and imb < self.imb_lo:
                new = "uniform"
        if new == self.regime:
            return None
        self.regime = new
        self.flips += 1
        return {"regime": new, "theta_milli": int(round(self.theta * 1000)),
                "imbalance_milli": int(round(imb * 1000))}

    # -------------------------------------------------------- reporting --
    def snapshot(self) -> Dict:
        """Deterministic (int-valued) summary for ``cluster.metrics()`` /
        ``kv.stats()``; same-seed runs produce identical dicts."""
        return {
            "top": [list(t) for t in self.sketch.top(self.top_k)],
            "keys_seen": self.sketch.n_seen,
            "theta_milli": int(round(self.theta * 1000)),
            "shard_imbalance_milli":
                int(round(self.shard_imbalance * 1000)),
            "mn_imbalance_milli": int(round(self.mn_imbalance * 1000)),
            "regime": self.regime,
            "regime_flips": self.flips,
        }
