"""Typed metrics registry: counters, gauges, log-bucket histograms,
time-series rings, and heat sketches under stable dotted names.

FUSEE has no metadata server where load and latency naturally accumulate —
every client owns its own slice of the protocol — so the registry is the
single place a cluster's telemetry converges.  Design rules, in the spirit
of the rest of the repo:

* **Deterministic.**  Every metric derives from simulation state (ticks,
  RTTs, verb counts, bytes) — never wall-clock — so same-seed runs produce
  bit-identical snapshots and the fused fleet tick agrees with the
  per-kind oracle on every metric (tests/test_fleet_fused.py extends its
  differential signature over the registry).  The handful of metrics that
  legitimately depend on the execution *path* (``fleet.array_calls``,
  ``fleet.fused_ticks``, ...) are named in ``PATH_DEPENDENT`` and dropped
  by ``deterministic_view`` before any cross-path comparison.
* **Vectorized.**  Bulk-update entry points (``Histogram.observe_many``,
  ``Series.append_rows``, ``HeatSketch.update``) take whole numpy arrays
  so fleet paths record a tick's wave in one call — no per-client Python
  loops (L004/L007 hygiene).
* **Cheap.**  A ``Counter`` is one attribute increment; everything heavier
  is either buffered (see obs/flight.py) or windowed.

Naming contract: dotted, ``<component>.<metric>[.<dim>.<value>]`` —
``fleet.verbs``, ``api.batch_fast_hits``, ``migrate.cutovers``,
``op.lat_ticks.kind.insert``, ``op.lat_rtts.mn.3``, ``mn.load``.
Units ride the name: ``*_ticks`` are scheduler ticks, ``*_rtts`` are
verb round-trips, ``bytes`` are modeled DM bytes.

The old ad-hoc ``counters`` dicts (api/fleet/migrate) survive one release
as read-only deprecation aliases: ``LegacyCounters`` is a ``Mapping`` view
over registry handles under the historical key names.
"""
from __future__ import annotations

import warnings
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Series", "HeatSketch",
           "Registry", "LegacyCounters", "PATH_DEPENDENT",
           "deterministic_view", "snapshot_diff", "snapshot_merge"]

# Metrics whose value depends on HOW a run executed (fused vs oracle
# sweeps, shadow-index rebuild cadence, numpy dispatch counts) rather than
# WHAT the protocol did.  Fused-vs-oracle differential gates and
# cross-substrate comparisons must drop these; everything else in a
# snapshot is required to be bit-identical for the same seeded run.
PATH_DEPENDENT = frozenset({
    "fleet.array_calls", "fleet.fused_ticks", "fleet.fallback_ticks",
    "fleet.shadow_rebuilds", "api.shadow_rebuilds",
})

DEFAULT_HIST_BUCKETS = 28   # log2 buckets: {0}, {1}, [2,3], ... [2^26, 2^27)


class Counter:
    """Monotonic counter.  ``inc`` is the sanctioned mutation path (lint
    L008 flags writes to bare ``counters`` dicts in protocol code); hot
    loops may cache the handle and bump ``.value`` directly — the handle
    *is* the registry entry."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n


class Gauge:
    """Last-value (or running-max) gauge."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v):
        self.value = v

    def set_max(self, v):
        if v > self.value:
            self.value = v


class Histogram:
    """Fixed log2-bucket histogram of non-negative integers.

    Bucket 0 holds exactly {0}; bucket i >= 1 holds [2^(i-1), 2^i).  The
    last bucket absorbs overflow.  Buckets are a fixed-size int64 vector,
    so snapshot/diff/merge are elementwise and ``observe_many`` is one
    bincount — no per-sample Python."""
    __slots__ = ("name", "unit", "counts")

    def __init__(self, name: str, unit: str = "",
                 n_buckets: int = DEFAULT_HIST_BUCKETS):
        self.name = name
        self.unit = unit
        self.counts = np.zeros(n_buckets, np.int64)

    @staticmethod
    def bucket_of(vals: np.ndarray, n_buckets: int) -> np.ndarray:
        v = np.maximum(np.asarray(vals, np.int64), 0)
        with np.errstate(divide="ignore"):
            b = np.where(v > 0,
                         np.floor(np.log2(np.maximum(v, 1))).astype(np.int64)
                         + 1, 0)
        return np.minimum(b, n_buckets - 1)

    def observe(self, v: int):
        self.counts[int(self.bucket_of(np.asarray([v]), len(self.counts))[0])] += 1

    def observe_many(self, vals: np.ndarray):
        if len(vals) == 0:
            return
        b = self.bucket_of(vals, len(self.counts))
        self.counts += np.bincount(b, minlength=len(self.counts))

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def upper_edges(self) -> np.ndarray:
        """Inclusive upper edge per bucket: 0, 1, 3, 7, ... (2^i - 1)."""
        n = len(self.counts)
        e = (np.int64(1) << np.arange(n, dtype=np.int64)) - 1
        e[0] = 0
        return e

    def percentile(self, q: float) -> int:
        """Upper edge of the bucket containing the q-quantile rank (q in
        [0, 1]).  Conservative (rounds latency up to the bucket edge)."""
        total = self.total
        if total == 0:
            return 0
        rank = q * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        return int(self.upper_edges()[min(i, len(self.counts) - 1)])


class Series:
    """Fixed-capacity ring of float64 rows with named columns — the
    per-MN load time-series substrate.  Rows append in bulk (one 2-D
    scatter per wave); ``rows()`` returns them oldest-first, wrap-aware."""
    __slots__ = ("name", "fields", "capacity", "buf", "n")

    def __init__(self, name: str, fields: Tuple[str, ...],
                 capacity: int = 4096):
        self.name = name
        self.fields = tuple(fields)
        self.capacity = capacity
        self.buf = np.zeros((capacity, len(self.fields)), np.float64)
        self.n = 0

    def append_rows(self, rows: np.ndarray):
        rows = np.asarray(rows, np.float64)
        if rows.ndim == 1:
            rows = rows[None, :]
        k = len(rows)
        if k == 0:
            return
        clipped = 0
        if k > self.capacity:                  # keep only the newest tail
            clipped = k - self.capacity
            rows = rows[-self.capacity:]
            k = self.capacity
        # advance past the clipped rows too, so ``dropped`` and the ring
        # phase match the would-have-written-everything ordering
        idx = (self.n + clipped + np.arange(k)) % self.capacity
        self.buf[idx] = rows
        self.n += clipped + k

    def rows(self) -> np.ndarray:
        if self.n <= self.capacity:
            return self.buf[:self.n].copy()
        c = self.n % self.capacity
        return np.concatenate([self.buf[c:], self.buf[:c]])

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.capacity)


class HeatSketch:
    """Per-bucket access-heat counters — the FlexKV/rebalance input
    signal.  ``width`` counters indexed by a caller-supplied bucket hash
    (core/shadow.hash32_np over fold32 keys, i.e. the RACE first-choice
    bucket family), updated with one ``np.add.at`` per wave."""
    __slots__ = ("name", "width", "counts")

    def __init__(self, name: str, width: int = 1024):
        assert width & (width - 1) == 0, "heat width must be a power of 2"
        self.name = name
        self.width = width
        self.counts = np.zeros(width, np.int64)

    def update(self, bucket_idx: np.ndarray):
        if len(bucket_idx) == 0:
            return
        np.add.at(self.counts, np.asarray(bucket_idx, np.int64)
                  & (self.width - 1), 1)

    def touch(self, bucket: int):
        self.counts[bucket & (self.width - 1)] += 1

    def top(self, k: int = 8) -> List[Tuple[int, int]]:
        """Hottest buckets as (bucket, count), deterministic order."""
        idx = np.argsort(self.counts, kind="stable")[::-1][:k]
        return [(int(i), int(self.counts[i])) for i in idx
                if self.counts[i] > 0]


_TYPES = (Counter, Gauge, Histogram, Series, HeatSketch)


class Registry:
    """Flat name -> metric map with get-or-create typed accessors.

    One registry per cluster (hosted on the ``Scheduler``) carries the
    core protocol metrics; per-client ``SimBackend``s carry their own
    small registries (``api.*``) because backends are transient — merge
    snapshots with ``snapshot_merge`` when aggregating."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, unit: str = "",
                  n_buckets: int = DEFAULT_HIST_BUCKETS) -> Histogram:
        return self._get(name, Histogram, unit, n_buckets)

    def series(self, name: str, fields: Tuple[str, ...],
               capacity: int = 4096) -> Series:
        return self._get(name, Series, fields, capacity)

    def heat(self, name: str, width: int = 1024) -> HeatSketch:
        return self._get(name, HeatSketch, width)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict:
        """Pure-data snapshot (JSON-serializable; sorted names so equal
        registries produce byte-identical ``json.dumps``)."""
        out = {"counters": {}, "gauges": {}, "histograms": {},
               "series": {}, "heat": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = int(m.value)
            elif isinstance(m, Gauge):
                v = m.value
                out["gauges"][name] = float(v) if isinstance(v, float) \
                    else int(v)
            elif isinstance(m, Histogram):
                out["histograms"][name] = {
                    "unit": m.unit, "counts": m.counts.tolist()}
            elif isinstance(m, Series):
                out["series"][name] = {
                    "fields": list(m.fields), "dropped": m.dropped,
                    "rows": [[float(x) for x in r] for r in m.rows()]}
            elif isinstance(m, HeatSketch):
                out["heat"][name] = m.counts.tolist()
        return out


def deterministic_view(snap: Dict,
                       exclude: Iterable[str] = PATH_DEPENDENT) -> Dict:
    """Snapshot minus the path-dependent metrics — the form compared by
    the fused-vs-oracle differential gate and same-seed determinism
    tests."""
    ex = frozenset(exclude)
    return {sec: ({k: v for k, v in vals.items() if k not in ex}
                  if isinstance(vals, dict) else vals)
            for sec, vals in snap.items()}


def _zipped(a: Dict, b: Dict):
    for sec in ("counters", "gauges", "histograms", "series", "heat"):
        yield sec, a.get(sec, {}), b.get(sec, {})


def snapshot_diff(new: Dict, old: Dict) -> Dict:
    """``new - old`` for the additive sections (counters, histogram
    buckets, heat); gauges and series pass through from ``new``."""
    out: Dict = {}
    for sec, na, ob in _zipped(new, old):
        if sec == "counters":
            out[sec] = {k: v - ob.get(k, 0) for k, v in na.items()}
        elif sec == "histograms":
            out[sec] = {}
            for k, h in na.items():
                oc = ob.get(k, {}).get("counts")
                c = (np.asarray(h["counts"], np.int64)
                     - np.asarray(oc, np.int64)).tolist() \
                    if oc is not None else list(h["counts"])
                out[sec][k] = {"unit": h["unit"], "counts": c}
        elif sec == "heat":
            out[sec] = {k: (np.asarray(v, np.int64)
                            - np.asarray(ob[k], np.int64)).tolist()
                        if k in ob else list(v) for k, v in na.items()}
        else:
            out[sec] = {k: v for k, v in na.items()}
    return out


def snapshot_merge(a: Dict, b: Dict) -> Dict:
    """Aggregate two snapshots: counters/histograms/heat sum, gauges take
    the max, series concatenate rows (sorted by their first field, which
    is the sample tick by convention)."""
    out: Dict = {}
    for sec, sa, sb in _zipped(a, b):
        if sec == "counters":
            out[sec] = {k: sa.get(k, 0) + sb.get(k, 0)
                        for k in sorted(set(sa) | set(sb))}
        elif sec == "gauges":
            out[sec] = {k: max(sa.get(k, 0), sb.get(k, 0))
                        for k in sorted(set(sa) | set(sb))}
        elif sec == "histograms":
            out[sec] = {}
            for k in sorted(set(sa) | set(sb)):
                ha, hb = sa.get(k), sb.get(k)
                if ha is None or hb is None:
                    src = ha or hb
                    out[sec][k] = {"unit": src["unit"],
                                   "counts": list(src["counts"])}
                else:
                    out[sec][k] = {"unit": ha["unit"], "counts": (
                        np.asarray(ha["counts"], np.int64)
                        + np.asarray(hb["counts"], np.int64)).tolist()}
        elif sec == "heat":
            out[sec] = {}
            for k in sorted(set(sa) | set(sb)):
                va, vb = sa.get(k), sb.get(k)
                if va is None or vb is None:
                    out[sec][k] = list(va if va is not None else vb)
                else:
                    out[sec][k] = (np.asarray(va, np.int64)
                                   + np.asarray(vb, np.int64)).tolist()
        else:   # series
            out[sec] = {}
            for k in sorted(set(sa) | set(sb)):
                ra = sa.get(k, {}).get("rows", [])
                rb = sb.get(k, {}).get("rows", [])
                src = sa.get(k) or sb.get(k)
                out[sec][k] = {
                    "fields": list(src["fields"]),
                    "dropped": (sa.get(k, {}).get("dropped", 0)
                                + sb.get(k, {}).get("dropped", 0)),
                    "rows": sorted(ra + rb, key=lambda r: r[0])}
    return out


class LegacyCounters(Mapping):
    """Read-only dict-view over registry handles under the historical
    ``counters`` key names.  Deprecated — one release only; read the
    registry (``cluster.metrics()`` / ``kv.stats()``) instead.  Writes
    (``counters[k] += 1``) are not supported and flagged by lint L008."""

    __slots__ = ("_handles",)

    def __init__(self, handles: Dict[str, object]):
        # old key -> Counter/Gauge handle (read .value at access time)
        self._handles = handles

    def __getitem__(self, key: str):
        return self._handles[key].value

    def __iter__(self):
        return iter(self._handles)

    def __len__(self):
        return len(self._handles)

    def __repr__(self):
        return f"LegacyCounters({dict(self)!r})"


def legacy_counters_view(owner: str, handles: Dict[str, object]
                         ) -> LegacyCounters:
    """Build the deprecation alias for one component's old dict, warning
    on access (Python's default filter dedupes per call site)."""
    warnings.warn(
        f"{owner}.counters is deprecated; read the metrics registry "
        f"(cluster.metrics() / stats()) instead — the dict view will be "
        f"removed next release", DeprecationWarning, stacklevel=3)
    return LegacyCounters(handles)
