"""Op-level flight recorder + the cluster observability hub.

``FlightRecorder`` is a fixed ring of op **begin / settle / fault /
recovery / migration** events — orders of magnitude lighter than a
recording ``VerbTracer`` (a handful of events per *op* instead of a row
per *verb*), so it stays on for the life of a cluster.  Events buffer as
plain tuples on the hot path and land in the int64 ring in one vectorized
scatter per flush; ``save``/``load`` round-trip the ring through ``.npz``
exactly like the tracer's format.

``ClusterObs`` owns the recorder plus the derived telemetry that feeds
the metrics registry (obs/registry.py):

* op-latency histograms (submit->settle, in ticks and RTTs) per kind /
  per index shard / per primary MN, bulk-updated at flush;
* the per-MN load time-series (``mn.load``: bytes moved, verbs, queue
  depth, MN-CPU ops, cap-model utilization per tick window);
* the per-bucket heat sketch (``cache.heat``) fed by the client cache /
  probe-wave paths — the FlexKV/rebalance input signal.

Cost contract (claims-checked by ``benchmarks/run.py --only
obs_overhead``): a detached hub (``scheduler.obs is None``) costs the
fused fleet tick exactly one attribute load + ``is None`` test per hook
site; an attached hub records a 64-client YCSB tick for <5% — all per-op
work is one tuple append, everything array-shaped happens on the flush
cadence.

Auto-dump: when a fault fires, a heap audit fails, or a race finding
surfaces, the hub dumps the ring to ``dump_dir`` **once per reason
class** (``flight_<reason>_t<tick>.npz``).  Dumping is armed only when
``dump_dir`` is set (CI storms, drills, triage) so unit-test clusters
never litter the tree.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from .registry import Registry

__all__ = ["FlightRecorder", "ClusterObs",
           "EV_BEGIN", "EV_SETTLE", "EV_FAULT", "EV_RECOVERY", "EV_MIG",
           "EV_REGIME", "EV_NAMES", "FIELDS"]

EV_BEGIN, EV_SETTLE, EV_FAULT, EV_RECOVERY, EV_MIG, EV_REGIME = range(6)
EV_NAMES = ("begin", "settle", "fault", "recovery", "migration", "regime")

# ring columns (int64):
#   tick    scheduler tick of the event
#   etype   EV_* above
#   cid     client id (-1 for cluster-level events)
#   op_id   op id (-1 for non-op events)
#   kind    interned label: op kind / fault action / recovery / mig phase
#   key     op key (-1 when not an int key / not an op)
#   arg     event argument (fault target, migrated region, ...; -1 unused)
#   lat     settle: submit->settle ticks; recovery: RTT cost
#   rtts    settle: op RTTs (foreground)
#   status  interned result status (-1 when unsettled / not an op)
FIELDS = ("tick", "etype", "cid", "op_id", "kind", "key", "arg",
          "lat", "rtts", "status")
_NF = len(FIELDS)


class FlightRecorder:
    """Fixed ring of event rows; wrap drops the oldest (counted)."""

    def __init__(self, capacity: int = 1 << 15):
        self.capacity = capacity
        self.ring = np.zeros((capacity, _NF), np.int64)
        self.n = 0

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.capacity)

    def push_rows(self, rows: np.ndarray):
        k = len(rows)
        if k == 0:
            return
        clipped = 0
        if k > self.capacity:
            clipped = k - self.capacity
            rows = rows[-self.capacity:]
            k = self.capacity
        # advance past the clipped rows too, so ``dropped`` and the ring
        # phase match the would-have-written-everything ordering
        idx = (self.n + clipped + np.arange(k)) % self.capacity
        self.ring[idx] = rows
        self.n += clipped + k

    def events(self) -> Dict[str, np.ndarray]:
        """Columns oldest-first (wrap-aware) plus a global ``seq``."""
        if self.n <= self.capacity:
            rows = self.ring[:self.n]
        else:
            c = self.n % self.capacity
            rows = np.concatenate([self.ring[c:], self.ring[:c]])
        out = {f: rows[:, i].copy() for i, f in enumerate(FIELDS)}
        out["seq"] = np.arange(self.n - len(rows), self.n, dtype=np.int64)
        return out

    def save(self, path: str, labels: List[str]):
        ev = self.events()
        np.savez_compressed(
            path, **ev,
            _labels=np.asarray(labels, object),
            _fields=np.asarray(FIELDS, object),
            _dropped=np.asarray([self.dropped], np.int64))

    @staticmethod
    def load(path: str) -> Dict:
        """Load a dump: event columns + ``labels`` + ``dropped``."""
        with np.load(path, allow_pickle=True) as z:
            out = {k: z[k] for k in z.files if not k.startswith("_")}
            out["labels"] = [str(x) for x in z["_labels"]]
            out["dropped"] = int(z["_dropped"][0])
        return out


class ClusterObs:
    """The per-cluster observability hub (see module docstring).

    Wired by ``FuseeCluster``: ``scheduler.obs`` and ``pool._obs`` point
    here; ``cluster.detach_obs()`` sets both back to None, restoring the
    structurally-zero-cost hot path."""

    def __init__(self, sched, pool, *, kinds: Tuple[str, ...] = (),
                 window: int = 32, heat_width: int = 1024,
                 flight_capacity: int = 1 << 15, flush_every: int = 512,
                 link_bytes_per_tick: float = 14000.0,
                 dump_dir: Optional[str] = None):
        self.sched = sched
        self.pool = pool
        self.registry: Registry = sched.metrics
        self.flight = FlightRecorder(flight_capacity)
        self.window = window
        self.flush_every = flush_every
        self.link_bytes_per_tick = float(link_bytes_per_tick)
        self.dump_dir = dump_dir
        self.dumped: Dict[str, str] = {}      # reason class -> dump path
        # label interning (kinds first so ids are stable across runs)
        self._labels: List[str] = []
        self._label_ids: Dict[str, int] = {}
        for k in kinds:
            self._intern(k)
        # hot-path buffers: plain tuples, flushed vectorized
        self._pend: List[Tuple] = []
        self._heat_pend: List[int] = []   # fold32 keys from scalar paths
        # per-MN sampling state (first window measures from tick 0)
        self._last_sample = 0
        self._prev_bytes = np.zeros(0, np.float64)
        self._prev_cpu = np.zeros(0, np.float64)
        self._mn_series = self.registry.series(
            "mn.load", ("tick", "mid", "bytes", "verbs", "qdepth",
                        "cpu_ops", "util"))
        self.heat = self.registry.heat("cache.heat", heat_width)
        self._c_settled = self.registry.counter("op.settled")
        self._c_crashed = self.registry.counter("op.crashed")
        self._c_begun = self.registry.counter("op.begun")
        self._shard_cache: Dict[int, int] = {}
        self._hists: Dict[str, object] = {}
        # streaming hot-key/skew monitor (obs/hotspot.py): opt-in via
        # enable_hotspot() — the attached-hub overhead claim measures the
        # hub alone; the "profiled" bench mode measures hub + monitor
        self.hotspot = None
        self._hot_handles = None

    # ------------------------------------------------------- hot path ----
    def _intern(self, label: str) -> int:
        i = self._label_ids.get(label)
        if i is None:
            i = self._label_ids[label] = len(self._labels)
            self._labels.append(label)
        return i

    def op_begin(self, rec, tick: int):
        key = rec.key if type(rec.key) is int else -1
        if key >= 1 << 63:           # uint64 key -> int64 two's complement
            key -= 1 << 64
        self._pend.append((tick, EV_BEGIN, rec.cid, rec.op_id,
                           self._intern(rec.kind), key, -1, 0, 0, -1))
        if len(self._pend) >= self.flush_every:
            self.flush()

    def op_settled(self, rec, tick: int):
        key = rec.key if type(rec.key) is int else -1
        if key >= 1 << 63:           # uint64 key -> int64 two's complement
            key -= 1 << 64
        res = rec.result
        status = self._intern(res.status) if res is not None else -1
        self._pend.append((tick, EV_SETTLE, rec.cid, rec.op_id,
                           self._intern(rec.kind), key, -1,
                           tick - rec.inv_tick, rec.rtts, status))
        if len(self._pend) >= self.flush_every:
            self.flush()

    def fault(self, action: str, target: int, tick: int):
        self._pend.append((tick, EV_FAULT, -1, -1, self._intern(action),
                           -1, target, 0, 0, -1))
        if len(self._pend) >= self.flush_every:
            self.flush()

    def recovery(self, what: str, tick: int, *, cid: int = -1,
                 arg: int = -1, rtts: int = 0):
        self._pend.append((tick, EV_RECOVERY, cid, -1, self._intern(what),
                           -1, arg, int(rtts), 0, -1))
        if len(self._pend) >= self.flush_every:
            self.flush()

    def migration(self, phase: str, region: int, tick: int):
        self._pend.append((tick, EV_MIG, -1, -1, self._intern(phase),
                           -1, region, 0, 0, -1))
        if len(self._pend) >= self.flush_every:
            self.flush()

    def heat_keys(self, buckets: np.ndarray, keys32=None):
        """Vectorized heat update — ``buckets`` are RACE first-choice
        bucket hashes (shadow.hash32_np(keys32, 1)); one add.at per wave.
        ``keys32`` (the UNhashed fold32 keys the buckets were derived
        from) additionally feeds the hot-key monitor when one is
        enabled — same wave, one extra batched sketch update."""
        self.heat.update(buckets)
        if self.hotspot is not None and keys32 is not None:
            self.hotspot.observe_keys(keys32)

    def heat_touch(self, bucket: int):
        self.heat.touch(bucket)

    def heat_key64(self, key64: int):
        """Scalar cache-path heat touch (client.py): buffered as a fold32
        key and hashed into buckets vectorized at flush — one hash call
        per flush, not one per op."""
        self._heat_pend.append((key64 ^ (key64 >> 32)) & 0xFFFFFFFF)

    # ---------------------------------------------------- flush / hists --
    def _hist(self, name: str, unit: str):
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = self.registry.histogram(name, unit)
        return h

    def _shard_of(self, key: int) -> int:
        s = self._shard_cache.get(key)
        if s is None:
            s = self._shard_cache[key] = self.pool.shard_of(key)
        return s

    def flush(self):
        """Drain the tuple buffers: one scatter into the flight ring, one
        bulk histogram pass over the settles, one bucket-hash pass over
        the scalar heat touches."""
        hp = self._heat_pend
        if hp:
            self._heat_pend = []
            # local import: the obs package carries no module-level core
            # dependency; the bucket family must match the RACE index's
            from ..core.shadow import hash32_np
            hpa = np.asarray(hp, np.uint32)
            self.heat.update(hash32_np(hpa, 1))
            if self.hotspot is not None:
                self.hotspot.observe_keys(hpa)
        pend = self._pend
        if pend:
            self._pend = []
            rows = np.asarray(pend, np.int64)
            self.flight.push_rows(rows)
            et = rows[:, 1]
            self._c_begun.value += int((et == EV_BEGIN).sum())
            s = rows[et == EV_SETTLE]
            if len(s):
                self._observe_settles(s)
        if self.hotspot is not None and (hp or pend):
            self._hotspot_tick()

    def _observe_settles(self, s: np.ndarray):   # lint: allow-obs-loop (dim walk is bounded by live kinds/shards/MNs per flush, not ops)
        kinds, keys = s[:, 4], s[:, 5]
        lat, rtts = s[:, 7], s[:, 8]
        self._c_settled.value += len(s)
        crashed_id = self._label_ids.get("CRASHED")
        if crashed_id is not None:
            self._c_crashed.value += int((s[:, 9] == crashed_id).sum())
        pool = self.pool
        # shard / primary-MN attribution at flush time (placement changes
        # are protocol events, identically ordered in fused and oracle
        # runs, so attribution is deterministic per seed)
        if pool.num_shards == 1:
            shards = np.zeros(len(s), np.int64)
        else:
            # undo the int64 two's-complement reinterpretation of the key
            shards = np.fromiter(
                (self._shard_of(int(k) & 0xFFFFFFFFFFFFFFFF) for k in keys),
                np.int64, count=len(s))
        prim = np.asarray([pool.primary_mn(g) for g in pool.index_regions],
                          np.int64)
        mns = prim[shards]
        for dim, ids in (("kind", kinds), ("shard", shards), ("mn", mns)):
            for u in np.unique(ids):
                sel = ids == u
                name = self._labels[int(u)] if dim == "kind" else int(u)
                self._hist(f"op.lat_ticks.{dim}.{name}",
                           "ticks").observe_many(lat[sel])
                self._hist(f"op.lat_rtts.{dim}.{name}",
                           "rtts").observe_many(rtts[sel])
        if self.hotspot is not None:
            self.hotspot.observe_load(shards, mns)

    # ------------------------------------------------- per-MN sampling ---
    def on_fleet_tick(self, fleet, by_kind: Dict[str, list]):
        """Called once per fleet tick; samples the per-MN series every
        ``window`` ticks.  The by_kind walk (verb -> primary MN) runs only
        on sample ticks — amortized, not per-tick."""
        tick = self.sched.tick
        if tick - self._last_sample < self.window:
            return
        w = max(tick - self._last_sample, 1)
        self._last_sample = tick
        pool = self.pool
        n = len(pool.mns)
        table = pool.placement
        verbs = np.zeros(n, np.float64)
        for items in by_kind.values():
            for it in items:
                verb = it[-1]
                reps = table.get(getattr(verb, "region", -1))
                if reps is not None and verb.replica < len(reps):
                    verbs[reps[verb.replica]] += 1
        qd = np.zeros(n, np.float64)
        for pipe in self.sched.pipes.values():
            for mn, q in pipe.qp.items():
                if mn < n:
                    qd[mn] += len(q)
        byt = pool.mn_bytes.astype(np.float64)
        cpu = np.fromiter((mn.cpu_ops for mn in pool.mns), np.float64,
                          count=n)
        pb = np.zeros(n, np.float64)
        pb[:len(self._prev_bytes)] = self._prev_bytes[:n]
        pc = np.zeros(n, np.float64)
        pc[:len(self._prev_cpu)] = self._prev_cpu[:n]
        bytes_w = byt - pb
        util = bytes_w / (w * self.link_bytes_per_tick)
        self._prev_bytes, self._prev_cpu = byt, cpu
        rows = np.column_stack([
            np.full(n, float(tick)), np.arange(n, dtype=np.float64),
            bytes_w, verbs, qd, cpu - pc, util])
        self._mn_series.append_rows(rows)

    # ------------------------------------------------ hot-key monitor ----
    def enable_hotspot(self, **kw):
        """Attach the streaming hot-key/skew monitor (obs/hotspot.py).
        Idempotent; keyword args pass through to ``HotKeyMonitor``.
        Surfaces ``hot.*`` gauges in the registry (fixed-point milli ints
        — deterministic, same-seed snapshots stay byte-identical) and
        emits typed ``regime`` rows into the flight ring on threshold
        crossings."""
        if self.hotspot is not None:
            return self.hotspot
        from .hotspot import HotKeyMonitor   # local: opt-in estimator
        self.hotspot = HotKeyMonitor(**kw)
        reg = self.registry
        self._hot_handles = {
            "theta": reg.gauge("hot.theta_milli"),
            "imb": reg.gauge("hot.imbalance_milli"),
            "regime": reg.gauge("hot.regime"),
            "flips": reg.counter("hot.regime_flips"),
        }
        return self.hotspot

    def _hotspot_tick(self):
        """Refresh the monitor's derived gauges; record regime crossings
        as EV_REGIME flight rows (ring-direct — flush() already drained
        the tuple buffer when this runs)."""
        hs = self.hotspot
        ev = hs.evaluate()
        h = self._hot_handles
        h["theta"].set(int(round(hs.theta * 1000)))
        h["imb"].set(int(round(max(hs.shard_imbalance,
                                   hs.mn_imbalance) * 1000)))
        h["regime"].set(0 if hs.regime == "uniform" else 1)
        if ev is not None:
            h["flips"].value += 1
            self.flight.push_rows(np.asarray(
                [(self.sched.tick, EV_REGIME, -1, -1,
                  self._intern(ev["regime"]), -1, ev["theta_milli"],
                  ev["imbalance_milli"], 0, -1)], np.int64))

    # ----------------------------------------------------------- dumps ---
    def dump(self, reason: str, *, force: bool = False) -> Optional[str]:
        """Dump the flight ring once per ``reason`` class (armed only when
        ``dump_dir`` is set).  Returns the path, or None when disarmed or
        already dumped for this reason."""
        if self.dump_dir is None:
            return None
        if not force and reason in self.dumped:
            return None
        self.flush()
        os.makedirs(self.dump_dir, exist_ok=True)
        path = os.path.join(
            self.dump_dir, f"flight_{reason}_t{self.sched.tick}.npz")
        self.flight.save(path, self._labels)
        self.dumped[reason] = path
        return path

    def labels(self) -> List[str]:
        return list(self._labels)

    def flight_events(self) -> Dict[str, np.ndarray]:
        """The flight ring's retained events, **flushing first** — the
        safe accessor for profilers/exporters (reading ``.flight.events()``
        directly can miss the buffered tail between flush cadences)."""
        self.flush()
        return self.flight.events()

    def snapshot(self) -> Dict:
        self.flush()
        return self.registry.snapshot()
