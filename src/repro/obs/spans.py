"""Causal op span trees: the verb ring joined to the flight recorder.

``build_spans`` links the ``VerbTracer`` verb ring (analysis/trace.py; one
row per executed one-sided verb, each carrying the issuing op's
``(cid, op_id)``, its phase ordinal, the interned phase label, the typed
retry/stall **cause** and the background bit) to the flight recorder's op
begin/settle rows (obs/flight.py) and reconstructs, for every op, the
tree of protocol-phase **spans** it executed:

    op (flight begin..settle)
      +- 1:read_index            cause=""            1 RTT
      +- 2:cas_backups           cause=""            1 RTT
      +- 4:cas_primary           cause=""            1 RTT
      +- 1:read_index            cause="cas_lost"    1 RTT   <- retry round
      +- ...
      +- (untraced)              n RTTs                      <- see below

The reconstruction is **fully vectorized** — one lexsort over the ring and
``reduceat`` segment passes; no per-op Python loops — so profiling a
multi-million-verb ring costs a sort, not a Python traversal.

RTT accounting contract (the conservation guarantee, property-tested in
tests/test_profile.py):

* one phase = one doorbell-batched RTT (core/events.py), and the
  scheduler numbers phases with a per-op monotone ordinal
  (``rtts + bg_rtts`` at issue time) — so one ring segment keyed
  ``(cid, op_id, phase)`` is exactly one RTT of that op;
* some RTT beats leave **no ring rows**: empty wait phases
  (``Phase([], ...)``), alloc/free RPC phases (the tracer wraps only the
  eight array-verb entry points), and phases whose every verb was dropped
  pre-pool by the §5.2 stale-epoch guard.  These are materialized as one
  ``(untraced)`` filler entry per op carrying the residual RTT count, so

      observed foreground spans + untraced RTTs == flight-recorder rtts

  holds **exactly** for every settled op — and a negative residual (more
  observed spans than the op reports) is flagged as over-attribution
  instead of being silently clamped;
* background phases (``bg`` column, NOT label conventions) are kept as
  spans but excluded from the foreground conservation sum, mirroring the
  scheduler's ``rtts`` / ``bg_rtts`` split.

Partial trees are flagged, never guessed: an op whose rows may have
fallen off a wrapped verb ring gets ``FLAG_PARTIAL``; an op that never
settled (still in flight, e.g. its client crashed mid-op) gets
``FLAG_OPEN`` and is excluded from conservation; a settled-as-CRASHED op
gets ``FLAG_CRASHED`` (its spans are real — the §5.3 contract is that
partial effects are repaired, not that they didn't happen).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from .flight import EV_BEGIN, EV_SETTLE

__all__ = ["SpanSet", "build_spans", "spans_from_cluster",
           "spans_to_perfetto", "FLAG_PARTIAL", "FLAG_OVER", "FLAG_OPEN",
           "FLAG_CRASHED", "UNTRACED"]

FLAG_PARTIAL = 1       # verb ring wrapped under this op: spans may be missing
FLAG_OVER = 2          # more fg spans observed than the op's settled rtts
FLAG_OPEN = 4          # op began but never settled (in flight / crashed client)
FLAG_CRASHED = 8       # op settled with status CRASHED (mid-flight crash)

UNTRACED = "(untraced)"

_SPAN_COLS = ("cid", "op_id", "phase", "label", "cause", "bg",
              "t0", "t1", "verbs", "ok_verbs", "op_row")
_OP_COLS = ("cid", "op_id", "kind", "status", "begin_tick", "settle_tick",
            "lat", "rtts", "fg_spans", "bg_spans", "untraced", "flags")


@dataclass
class SpanSet:
    """Column-oriented span trees; see module docstring.

    ``spans`` — one row per executed phase (``op_row`` indexes ``ops``;
    -1 when the op has no flight settle).  ``ops`` — one row per
    flight-recorder op (settled AND still-open).  ``labels`` interns both
    phase labels and causes (the tracer's table); ``flight_labels``
    interns op kinds and statuses.
    """
    spans: Dict[str, np.ndarray]
    ops: Dict[str, np.ndarray]
    labels: List[str]
    flight_labels: List[str]
    trace_dropped: int = 0
    flight_dropped: int = 0

    def label(self, i: int) -> str:
        return self.labels[i] if 0 <= i < len(self.labels) else UNTRACED

    def cause(self, i: int) -> str:
        return self.labels[i] if 0 <= i < len(self.labels) else ""

    @property
    def n_spans(self) -> int:
        return len(self.spans["cid"])

    @property
    def n_ops(self) -> int:
        return len(self.ops["cid"])

    def op_tree(self, cid: int, op_id: int) -> Optional[Dict]:
        """One op's span tree as a plain dict (tests / debugging; the
        profiler folds the column arrays directly)."""
        o = self.ops
        sel = np.flatnonzero((o["cid"] == cid) & (o["op_id"] == op_id))
        if len(sel) == 0:
            return None
        r = int(sel[0])
        s = self.spans
        rows = np.flatnonzero((s["cid"] == cid) & (s["op_id"] == op_id))
        children = [dict(phase=int(s["phase"][i]),
                         label=self.label(int(s["label"][i])),
                         cause=self.cause(int(s["cause"][i])),
                         bg=bool(s["bg"][i]), t0=int(s["t0"][i]),
                         t1=int(s["t1"][i]), verbs=int(s["verbs"][i]),
                         ok_verbs=int(s["ok_verbs"][i]))
                    for i in rows]
        fl = self.flight_labels
        return dict(
            cid=cid, op_id=op_id,
            kind=fl[int(o["kind"][r])] if o["kind"][r] >= 0 else "?",
            status=fl[int(o["status"][r])] if o["status"][r] >= 0 else "",
            begin_tick=int(o["begin_tick"][r]),
            settle_tick=int(o["settle_tick"][r]), lat=int(o["lat"][r]),
            rtts=int(o["rtts"][r]), fg_spans=int(o["fg_spans"][r]),
            untraced=int(o["untraced"][r]), flags=int(o["flags"][r]),
            spans=children)


def _empty(cols) -> Dict[str, np.ndarray]:
    return {c: np.zeros(0, np.int64) for c in cols}


def _pack(cid: np.ndarray, op_id: np.ndarray, base: int) -> np.ndarray:
    """Collision-free composite (cid, op_id) key for searchsorted joins."""
    return cid.astype(np.int64) * base + op_id.astype(np.int64)


def build_spans(trace_ev: Dict[str, np.ndarray], trace_labels: List[str],
                flight_ev: Dict[str, np.ndarray],
                flight_labels: List[str], *,
                trace_dropped: int = 0,
                flight_dropped: int = 0) -> SpanSet:
    """Reconstruct span trees; one sort + segment passes, no per-op loops.

    ``trace_ev`` is ``VerbTracer.events()`` (or a loaded trace npz);
    ``flight_ev`` is ``FlightRecorder.events()`` (or a loaded dump).
    """
    cid_t = np.asarray(trace_ev["cid"], np.int64)
    opid_t = np.asarray(trace_ev["op_id"], np.int64)
    keep = (cid_t >= 0) & (opid_t >= 0)       # client-op-attributable rows

    f_et = np.asarray(flight_ev["etype"], np.int64)
    f_cid = np.asarray(flight_ev["cid"], np.int64)
    f_opid = np.asarray(flight_ev["op_id"], np.int64)
    base = int(max(opid_t.max(initial=0), f_opid.max(initial=0))) + 2

    # ---- span segmentation: one lexsort, one boundary pass --------------
    if keep.any():
        cid_k, opid_k = cid_t[keep], opid_t[keep]
        ph = np.asarray(trace_ev["phase"], np.int64)[keep]
        seq = np.asarray(trace_ev["seq"], np.int64)[keep]
        lab = np.asarray(trace_ev["label"], np.int64)[keep]
        cau = np.asarray(trace_ev["cause"], np.int64)[keep]
        bg = np.asarray(trace_ev["bg"], np.int64)[keep]
        tick = np.asarray(trace_ev["tick"], np.int64)[keep]
        ok = np.asarray(trace_ev["ok"], np.int64)[keep]

        order = np.lexsort((seq, ph, opid_k, cid_k))
        cid_k, opid_k, ph = cid_k[order], opid_k[order], ph[order]
        lab, cau, bg = lab[order], cau[order], bg[order]
        tick, ok = tick[order], ok[order]

        okey = _pack(cid_k, opid_k, base)
        skey = okey * (int(ph.max(initial=0)) + 2) + ph
        starts = np.flatnonzero(np.diff(skey, prepend=skey[0] - 1))
        spans = {
            "cid": cid_k[starts], "op_id": opid_k[starts],
            "phase": ph[starts], "label": lab[starts],
            # a migration window opening mid-phase stamps later verbs of
            # the phase mig_dual_write while earlier ones carry -1: the
            # span takes the max so the window is never lost
            "cause": np.maximum.reduceat(cau, starts),
            "bg": bg[starts],
            "t0": np.minimum.reduceat(tick, starts),
            "t1": np.maximum.reduceat(tick, starts),
            "verbs": np.diff(starts, append=len(skey)),
            "ok_verbs": np.add.reduceat(ok, starts),
        }
        span_okey = okey[starts]
        trace_t_oldest = int(np.asarray(trace_ev["tick"], np.int64).min()) \
            if trace_dropped > 0 else -1
    else:
        spans = _empty(_SPAN_COLS[:-1])
        span_okey = np.zeros(0, np.int64)
        trace_t_oldest = -1

    # ---- the op universe: every flight begin/settle row -----------------
    b_sel = f_et == EV_BEGIN
    s_sel = f_et == EV_SETTLE
    # settled ops (searchsorted join on the packed (cid, op_id) key)
    s_key = _pack(f_cid[s_sel], f_opid[s_sel], base)
    s_sort = np.argsort(s_key, kind="stable")
    s_key = s_key[s_sort]
    s_idx = np.flatnonzero(s_sel)[s_sort]
    # open ops = begins with no settle
    b_key = _pack(f_cid[b_sel], f_opid[b_sel], base)
    b_sort = np.argsort(b_key, kind="stable")
    b_key_s = b_key[b_sort]
    b_idx = np.flatnonzero(b_sel)[b_sort]
    pos = np.searchsorted(s_key, b_key_s)
    has_settle = (pos < len(s_key)) & (s_key[np.minimum(
        pos, max(len(s_key) - 1, 0))] == b_key_s) if len(s_key) else \
        np.zeros(len(b_key_s), bool)
    open_idx = b_idx[~has_settle]
    open_key = b_key_s[~has_settle]

    f_tick = np.asarray(flight_ev["tick"], np.int64)
    f_kind = np.asarray(flight_ev["kind"], np.int64)
    f_lat = np.asarray(flight_ev["lat"], np.int64)
    f_rtts = np.asarray(flight_ev["rtts"], np.int64)
    f_status = np.asarray(flight_ev["status"], np.int64)
    horizon = int(f_tick.max(initial=0))

    n_s, n_o = len(s_idx), len(open_idx)
    ops = {c: np.zeros(n_s + n_o, np.int64) for c in _OP_COLS}
    ops["cid"][:n_s] = f_cid[s_idx]
    ops["op_id"][:n_s] = f_opid[s_idx]
    ops["kind"][:n_s] = f_kind[s_idx]
    ops["status"][:n_s] = f_status[s_idx]
    ops["settle_tick"][:n_s] = f_tick[s_idx]
    ops["lat"][:n_s] = f_lat[s_idx]
    ops["rtts"][:n_s] = f_rtts[s_idx]
    ops["begin_tick"][:n_s] = f_tick[s_idx] - f_lat[s_idx]
    # exact begin ticks where the begin row survived the flight ring
    bpos = np.searchsorted(s_key, b_key_s[has_settle])
    np.put(ops["begin_tick"], bpos, f_tick[b_idx[has_settle]])
    ops["cid"][n_s:] = f_cid[open_idx]
    ops["op_id"][n_s:] = f_opid[open_idx]
    ops["kind"][n_s:] = f_kind[open_idx]
    ops["status"][n_s:] = -1
    ops["begin_tick"][n_s:] = f_tick[open_idx]
    ops["settle_tick"][n_s:] = horizon
    ops["lat"][n_s:] = horizon - f_tick[open_idx]
    ops["rtts"][n_s:] = -1                     # unknown until settle
    ops["flags"][n_s:] |= FLAG_OPEN

    op_key = np.concatenate([s_key, open_key])

    # ---- join spans -> ops, fold per-op observed counts -----------------
    if len(span_okey):
        o_sort = np.argsort(op_key, kind="stable")
        op_key_s = op_key[o_sort]
        pos = np.searchsorted(op_key_s, span_okey)
        posc = np.minimum(pos, max(len(op_key_s) - 1, 0))
        hit = (len(op_key_s) > 0) & (op_key_s[posc] == span_okey) \
            if len(op_key_s) else np.zeros(len(span_okey), bool)
        spans["op_row"] = np.where(hit, o_sort[posc], -1)
        fg = (spans["bg"] == 0).astype(np.int64)
        rows = spans["op_row"][hit]
        np.add.at(ops["fg_spans"], rows, fg[hit])
        np.add.at(ops["bg_spans"], rows, 1 - fg[hit])
    else:
        spans["op_row"] = np.zeros(0, np.int64)

    settled = ops["rtts"] >= 0
    ops["untraced"] = np.where(settled, ops["rtts"] - ops["fg_spans"], 0)
    ops["flags"] |= np.where(settled & (ops["untraced"] < 0), FLAG_OVER, 0)
    crashed_id = flight_labels.index("CRASHED") \
        if "CRASHED" in flight_labels else -2
    ops["flags"] |= np.where(ops["status"] == crashed_id, FLAG_CRASHED, 0)
    if trace_t_oldest >= 0:
        # ring wrapped: any op already in flight at the oldest retained
        # verb may have lost spans — partial, never silently mis-counted
        ops["flags"] |= np.where(ops["begin_tick"] <= trace_t_oldest,
                                 FLAG_PARTIAL, 0)

    return SpanSet(spans=spans, ops=ops, labels=list(trace_labels),
                   flight_labels=list(flight_labels),
                   trace_dropped=int(trace_dropped),
                   flight_dropped=int(flight_dropped))


def spans_from_cluster(cluster) -> SpanSet:
    """Build span trees from a live cluster: requires an attached verb
    tracer (``cluster.attach_tracer()``) and the default obs hub."""
    tr = cluster.pool._tracer
    if tr is None:
        raise ValueError("no tracer attached — call attach_tracer() before "
                         "profiling (the flight recorder alone has no "
                         "per-verb rows to fold)")
    obs = cluster.obs
    obs.flush()
    return build_spans(tr.events(), tr.labels, obs.flight.events(),
                       obs.labels(), trace_dropped=tr.dropped,
                       flight_dropped=obs.flight.dropped)


def spans_to_perfetto(ss: SpanSet, *, tick_us: float = 2.0) -> List[Dict]:
    """Chrome-trace events for the span layer: one nested ``X`` sub-span
    per executed phase under the op's lane (pid 1 / tid cid — Perfetto
    nests complete events by time containment), plus one instant per op
    carrying its untraced-RTT residual and flags.  Merge with
    ``export.flight_to_perfetto(..., spans=ss)``."""
    ev: List[Dict] = []
    s, o = ss.spans, ss.ops
    # The op's flight slice ends at its settle tick (dur == lat ticks),
    # but the final RTT's verbs execute *at* the settle tick — clamp span
    # extents into the parent slice so Perfetto's time-containment
    # nesting holds for the last phase too.
    orow = s["op_row"]
    joined = (orow >= 0) & (orow < ss.n_ops)
    cap = np.full(ss.n_spans, np.inf)
    cap[joined] = np.where(o["rtts"][orow[joined]] >= 0,
                           o["settle_tick"][orow[joined]].astype(float),
                           np.inf)
    for i in range(ss.n_spans):   # lint: allow-obs-loop (export path, not the fold; bounded by retained spans)
        cause = ss.cause(int(s["cause"][i]))
        name = ss.label(int(s["label"][i]))
        if cause:
            name = f"{name} [{cause}]"
        t0 = min(float(s["t0"][i]), cap[i] - 0.5)
        t1 = min(float(s["t1"][i]) + 0.5, cap[i])
        ev.append({
            "name": name, "cat": "phase", "ph": "X", "pid": 1,
            "tid": int(s["cid"][i]), "ts": t0 * tick_us,
            "dur": max(t1 - t0, 0.0) * tick_us,
            "args": {"op_id": int(s["op_id"][i]),
                     "phase": int(s["phase"][i]),
                     "cause": cause, "bg": bool(s["bg"][i]),
                     "verbs": int(s["verbs"][i]),
                     "ok_verbs": int(s["ok_verbs"][i])}})
    flagged = np.flatnonzero((o["untraced"] != 0) | (o["flags"] != 0))
    for r in flagged:   # lint: allow-obs-loop (export path; flagged ops only)
        r = int(r)
        ev.append({
            "name": UNTRACED, "cat": "phase", "ph": "i", "s": "t",
            "pid": 1, "tid": int(o["cid"][r]),
            "ts": int(o["settle_tick"][r]) * tick_us,
            "args": {"op_id": int(o["op_id"][r]),
                     "untraced_rtts": int(o["untraced"][r]),
                     "flags": int(o["flags"][r])}})
    return ev
