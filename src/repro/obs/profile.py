"""Critical-path RTT attribution over span trees (obs/spans.py).

``critical_path_report`` folds a ``SpanSet`` into the table the paper's
RTT arguments are made of: **where does each op kind spend its round
trips**, per protocol phase, per typed retry/stall cause — with a
conservation check that the attribution is exact, not approximate:

    for every settled op:
        foreground spans attributed + untraced residual == flight rtts

Violations (over-attribution — more spans than the op reports) are
counted and surfaced, never clamped; partial trees (wrapped verb ring)
are counted separately so a truncated profile is visibly truncated.

The fold is vectorized: groups are packed integer keys over
``(kind, phase-label, cause)``, per-group RTT counts come from
``np.unique``, and per-group p50/p99 of span *tick* durations come from
one lexsort + boundary gather.  The per-row assembly at the end walks
**groups** (taxonomy-bounded, dozens), not ops.

``tick_phase_report`` wraps ``FleetEngine.tick_phase_profile()`` — the
wall-clock coord-build / sweep / scatter / bookkeeping split of the fused
megakernel tick — so ``roofline.py``'s ms/tick numbers decompose into
the same report.  Wall-clock numbers never enter the metrics registry
(same-seed snapshots stay byte-identical); RTT attribution, by contrast,
is exact integer arithmetic and bit-identical across same-seed runs.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .spans import FLAG_OVER, FLAG_PARTIAL, UNTRACED, SpanSet

__all__ = ["critical_path_report", "format_report", "tick_phase_report"]


def _group_pct(dur: np.ndarray, inv: np.ndarray, n_groups: int, q: float
               ) -> np.ndarray:
    """Per-group q-quantile (nearest-rank) of ``dur`` — one lexsort."""
    order = np.lexsort((dur, inv))
    inv_s, dur_s = inv[order], dur[order]
    starts = np.searchsorted(inv_s, np.arange(n_groups))
    ends = np.searchsorted(inv_s, np.arange(n_groups), side="right")
    cnt = np.maximum(ends - starts, 1)
    at = starts + np.minimum((q * (cnt - 1)).astype(np.int64) + (
        ((q * (cnt - 1)) % 1) > 0).astype(np.int64), cnt - 1)
    return dur_s[np.minimum(at, len(dur_s) - 1)] if len(dur_s) else \
        np.zeros(n_groups, np.int64)


def critical_path_report(ss: SpanSet, *, include_bg: bool = False) -> Dict:
    """Fold span trees into the RTT-attribution report.

    Returns ``{"rows": [...], "conservation": {...}, "totals": {...}}``.
    Rows are ``(kind, phase, cause) -> rtts/share/dur_p50/dur_p99``,
    sorted by attributed RTTs descending; untraced residuals appear as
    ``(kind, "(untraced)", "")`` rows so every row set still sums to the
    ops' measured totals.  Only settled ops participate (open ops have no
    measured total to conserve against)."""
    s, o = ss.spans, ss.ops
    settled = o["rtts"] >= 0
    op_settled = np.zeros(ss.n_ops + 1, bool)
    op_settled[:-1] = settled

    sel = s["op_row"] >= 0
    sel &= op_settled[np.minimum(s["op_row"], ss.n_ops)]
    if not include_bg:
        sel &= s["bg"] == 0
    kind = o["kind"][s["op_row"][sel]]
    lab, cau = s["label"][sel], s["cause"][sel]
    dur = s["t1"][sel] - s["t0"][sel] + 1

    nl = len(ss.labels) + 1
    key = (kind * nl + lab) * (nl + 1) + (cau + 1)
    groups, inv, counts = np.unique(key, return_inverse=True,
                                    return_counts=True)
    p50 = _group_pct(dur, inv, len(groups), 0.50)
    p99 = _group_pct(dur, inv, len(groups), 0.99)

    g_cau = groups % (nl + 1) - 1
    g_lab = (groups // (nl + 1)) % nl
    g_kind = groups // (nl + 1) // nl

    fl = ss.flight_labels
    rows: List[Dict] = []
    for i in range(len(groups)):   # lint: allow-obs-loop (taxonomy-bounded group walk, not per-op)
        rows.append({
            "kind": fl[int(g_kind[i])] if 0 <= g_kind[i] < len(fl)
            else f"?{int(g_kind[i])}",
            "phase": ss.label(int(g_lab[i])),
            "cause": ss.cause(int(g_cau[i])) if g_cau[i] >= 0 else "",
            "rtts": int(counts[i]),
            "dur_p50": int(p50[i]), "dur_p99": int(p99[i]),
        })

    # untraced residuals, folded per kind (exact conservation filler)
    unt = np.where(settled, np.maximum(o["untraced"], 0), 0)
    uk = np.unique(o["kind"][unt > 0]) if ss.n_ops \
        else np.zeros(0, np.int64)
    for k in uk:   # lint: allow-obs-loop (one row per op kind, not per op)
        tot = int(unt[(o["kind"] == k) & settled].sum())
        rows.append({"kind": fl[int(k)] if 0 <= k < len(fl) else f"?{int(k)}",
                     "phase": UNTRACED, "cause": "", "rtts": tot,
                     "dur_p50": 0, "dur_p99": 0})

    attributed = int(counts.sum()) if len(counts) else 0
    untraced_total = int(unt.sum())
    total_rtts = int(o["rtts"][settled].sum())
    for r in rows:   # lint: allow-obs-loop (row list is taxonomy-bounded)
        r["share"] = r["rtts"] / total_rtts if total_rtts else 0.0
    rows.sort(key=lambda r: (-r["rtts"], r["kind"], r["phase"], r["cause"]))

    over = int((settled & (o["flags"] & FLAG_OVER > 0)).sum())
    partial = int((settled & (o["flags"] & FLAG_PARTIAL > 0)).sum())
    conservation = {
        "ops": int(settled.sum()),
        "total_rtts": total_rtts,
        "attributed_rtts": attributed,
        "untraced_rtts": untraced_total,
        "violations": over,
        "partial_ops": partial,
        # exact: every settled op's fg spans + untraced == its rtts, and
        # no op attributed more than it measured
        "ok": over == 0 and (not include_bg) and
        attributed + untraced_total == total_rtts,
    }
    if include_bg:
        # bg spans ride on top of the fg budget; the exact-sum identity
        # only holds for the foreground fold
        conservation["ok"] = over == 0
    return {"rows": rows, "conservation": conservation,
            "totals": {"spans": int(sel.sum()), "ops": ss.n_ops,
                       "open_ops": int((~settled).sum()),
                       "trace_dropped": ss.trace_dropped,
                       "flight_dropped": ss.flight_dropped}}


def format_report(report: Dict, *, top: Optional[int] = None) -> str:
    """Render the attribution rows as an aligned text table (drills/CLI)."""
    rows = report["rows"][:top] if top else report["rows"]
    head = f"{'kind':<14} {'phase':<22} {'cause':<14} " \
           f"{'rtts':>8} {'share':>7} {'p50':>5} {'p99':>5}"
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(f"{r['kind']:<14} {r['phase']:<22} {r['cause']:<14} "
                     f"{r['rtts']:>8} {r['share']:>6.1%} "
                     f"{r['dur_p50']:>5} {r['dur_p99']:>5}")
    c = report["conservation"]
    lines.append(f"conservation: {'OK' if c['ok'] else 'VIOLATED'} "
                 f"({c['attributed_rtts']} attributed + "
                 f"{c['untraced_rtts']} untraced = {c['total_rtts']} rtts "
                 f"over {c['ops']} ops; {c['violations']} violations, "
                 f"{c['partial_ops']} partial)")
    return "\n".join(lines)


def tick_phase_report(engine) -> Dict[str, float]:
    """The fused-megakernel tick decomposition (coord-build / sweep /
    scatter / bookkeeping) from a ``FleetEngine`` — see
    ``FleetEngine.tick_phase_profile``.  Re-exported here so profiling
    callers need only the obs package."""
    return engine.tick_phase_profile()
