"""Exporters/loaders for the observability layer.

* ``flight_to_perfetto`` — Chrome-trace/Perfetto JSON from a flight
  recorder dump: op timelines (one lane per client), migration windows
  (one lane per region) and Alg-3 / §5.3 recovery spans, fault instants.
  Load the result at ``ui.perfetto.dev`` (or chrome://tracing).
* ``load_perfetto`` / ``load_flight`` / ``load_metrics`` — the matching
  loaders; tests round-trip every export through them.
* ``metrics_to_json`` — a registry snapshot (``cluster.metrics()``) to a
  stable JSON file (sorted keys, so same-seed runs produce byte-identical
  files); wired into ``benchmarks/run.py --metrics-out``.

Ticks convert to microseconds with the paper's verb RTT (one fleet tick =
one RTT beat, §6.1: ~2 us) so trace timelines are comparable to the
paper's latency numbers.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from .flight import (EV_BEGIN, EV_FAULT, EV_MIG, EV_RECOVERY, EV_REGIME,
                     EV_SETTLE, FIELDS, FlightRecorder)

__all__ = ["flight_to_perfetto", "load_perfetto", "load_flight",
           "metrics_to_json", "load_metrics", "TICK_US"]

TICK_US = 2.0      # FuseePaperConfig.rtt_us: one tick ~= one verb RTT


def load_flight(path: str) -> Dict:
    """Load a flight-recorder ``.npz`` dump (columns + labels)."""
    return FlightRecorder.load(path)


def metrics_to_json(snapshot: Dict, path: str) -> str:
    with open(path, "w") as f:
        json.dump(snapshot, f, sort_keys=True, separators=(",", ":"))
    return path


def load_metrics(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def _label(labels: List[str], i: int) -> str:
    return labels[i] if 0 <= i < len(labels) else f"?{i}"


def flight_to_perfetto(dump: Dict, path: Optional[str] = None, *,
                       tick_us: float = TICK_US, spans=None) -> Dict:
    """Convert a flight dump (``load_flight`` dict, or a live
    ``FlightRecorder.events()`` dict plus ``labels``) into Chrome-trace
    JSON.  Writes to ``path`` when given; returns the trace dict.

    ``spans`` (a ``SpanSet`` from obs/spans.py) nests the causal
    phase-level sub-spans under the op lanes — same pid/tid, ``cat``
    "phase" — so Perfetto renders each op's protocol phases (and their
    retry causes) inside the op slice."""
    labels = dump.get("labels", [])
    cols = {f: np.asarray(dump[f], np.int64) for f in FIELDS}
    n = len(cols["tick"])
    ev: List[Dict] = []
    horizon = int(cols["tick"].max()) if n else 0

    et = cols["etype"]
    # --- op spans: begin matched to settle by (cid, op_id) -------------
    begins: Dict[tuple, int] = {}
    for i in np.nonzero(et == EV_BEGIN)[0]:
        begins[(int(cols["cid"][i]), int(cols["op_id"][i]))] = \
            int(cols["tick"][i])
    for i in np.nonzero(et == EV_SETTLE)[0]:
        cid, op_id = int(cols["cid"][i]), int(cols["op_id"][i])
        lat = int(cols["lat"][i])
        t0 = begins.pop((cid, op_id), int(cols["tick"][i]) - lat)
        ev.append({
            "name": _label(labels, int(cols["kind"][i])),
            "cat": "op", "ph": "X", "pid": 1, "tid": cid,
            "ts": t0 * tick_us, "dur": max(lat, 1) * tick_us,
            "args": {"op_id": op_id, "key": int(cols["key"][i]),
                     "rtts": int(cols["rtts"][i]),
                     "status": _label(labels, int(cols["status"][i]))
                     if cols["status"][i] >= 0 else ""}})
    for (cid, op_id), t0 in sorted(begins.items()):   # still in flight
        ev.append({"name": "in-flight", "cat": "op", "ph": "X",
                   "pid": 1, "tid": cid, "ts": t0 * tick_us,
                   "dur": max(horizon - t0, 1) * tick_us,
                   "args": {"op_id": op_id, "open": True}})

    # --- cluster events: faults, recovery spans, migration windows -----
    for i in np.nonzero(et == EV_FAULT)[0]:
        ev.append({"name": _label(labels, int(cols["kind"][i])),
                   "cat": "fault", "ph": "i", "s": "g",
                   "pid": 2, "tid": 0,
                   "ts": int(cols["tick"][i]) * tick_us,
                   "args": {"target": int(cols["arg"][i])}})
    for i in np.nonzero(et == EV_RECOVERY)[0]:
        rtts = int(cols["lat"][i])
        ev.append({"name": _label(labels, int(cols["kind"][i])),
                   "cat": "recovery", "ph": "X", "pid": 2, "tid": 1,
                   "ts": int(cols["tick"][i]) * tick_us,
                   "dur": max(rtts, 1) * tick_us,
                   "args": {"cid": int(cols["cid"][i]),
                            "arg": int(cols["arg"][i]), "rtts": rtts}})
    open_migs: Dict[int, int] = {}
    for i in np.nonzero(et == EV_MIG)[0]:
        region = int(cols["arg"][i])
        phase = _label(labels, int(cols["kind"][i]))
        tick = int(cols["tick"][i])
        if phase == "start":
            open_migs[region] = tick
        else:                        # cutover / abort closes the window
            t0 = open_migs.pop(region, tick)
            ev.append({"name": f"migrate r{region} ({phase})",
                       "cat": "migration", "ph": "X", "pid": 2,
                       "tid": 2 + region, "ts": t0 * tick_us,
                       "dur": max(tick - t0, 1) * tick_us,
                       "args": {"region": region, "phase": phase}})
    for region, t0 in sorted(open_migs.items()):
        ev.append({"name": f"migrate r{region} (open)",
                   "cat": "migration", "ph": "X", "pid": 2,
                   "tid": 2 + region, "ts": t0 * tick_us,
                   "dur": max(horizon - t0, 1) * tick_us,
                   "args": {"region": region, "phase": "open"}})

    # --- regime crossings from the hot-key monitor ---------------------
    for i in np.nonzero(et == EV_REGIME)[0]:
        ev.append({"name": f"regime: {_label(labels, int(cols['kind'][i]))}",
                   "cat": "regime", "ph": "i", "s": "g",
                   "pid": 2, "tid": 0,
                   "ts": int(cols["tick"][i]) * tick_us,
                   "args": {"theta_milli": int(cols["arg"][i]),
                            "imbalance_milli": int(cols["lat"][i])}})

    # --- causal phase sub-spans (opt-in: profiler attach) --------------
    if spans is not None:
        from .spans import spans_to_perfetto
        ev.extend(spans_to_perfetto(spans, tick_us=tick_us))

    # process naming metadata
    for pid, name in ((1, "clients"), (2, "cluster")):
        ev.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                   "args": {"name": name}})
    trace = {"traceEvents": sorted(
        ev, key=lambda e: (e.get("ts", 0), e.get("pid", 0),
                           e.get("tid", 0), e.get("name", ""))),
        "displayTimeUnit": "ms",
        "otherData": {"tick_us": tick_us, "events": n,
                      "dropped": int(dump.get("dropped", 0))}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f, separators=(",", ":"))
    return trace


def load_perfetto(path: str) -> Dict:
    """Load an exported Chrome-trace JSON back (round-trip check)."""
    with open(path) as f:
        trace = json.load(f)
    if "traceEvents" not in trace:
        raise ValueError(f"{path}: not a Chrome-trace JSON "
                         f"(missing traceEvents)")
    return trace
