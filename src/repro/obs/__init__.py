"""Cluster observability: typed metrics registry, op-level flight
recorder, per-MN load time-series, heat sketches, and trace export.

FUSEE's client-centric design leaves no metadata server where telemetry
naturally accumulates; this package is the deterministic, vectorized
substitute.  See README "Observability" for the metric naming contract,
histogram bucket scheme, and the Perfetto export walkthrough.
"""
from .registry import (Counter, Gauge, HeatSketch, Histogram,  # noqa: F401
                       LegacyCounters, PATH_DEPENDENT, Registry, Series,
                       deterministic_view, legacy_counters_view,
                       snapshot_diff, snapshot_merge)
from .flight import (ClusterObs, FlightRecorder,  # noqa: F401
                     EV_BEGIN, EV_FAULT, EV_MIG, EV_RECOVERY, EV_REGIME,
                     EV_SETTLE, EV_NAMES, FIELDS)
from .export import (flight_to_perfetto, load_flight,  # noqa: F401
                     load_metrics, load_perfetto, metrics_to_json)
from .spans import (SpanSet, build_spans,  # noqa: F401
                    spans_from_cluster, spans_to_perfetto,
                    FLAG_PARTIAL, FLAG_OVER, FLAG_OPEN, FLAG_CRASHED,
                    UNTRACED)
from .profile import (critical_path_report, format_report,  # noqa: F401
                      tick_phase_report)
from .hotspot import HotKeyMonitor, SpaceSaving, zipf_theta  # noqa: F401
