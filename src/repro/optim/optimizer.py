"""Optimizers from scratch: AdamW / Lion / SGD-momentum, with optional
int8-quantized moments (8-bit Adam) for the >=100B architectures.

ZeRO note: parameters in this framework are already FSDP-sharded over the
'data' mesh axis (models/sharding.py), and optimizer state mirrors parameter
sharding exactly — i.e. moments are partitioned over data x model, which is
the ZeRO-3 superset of ZeRO-1.  ``state_specs`` simply reuses param specs.

int8 moments use blockwise absmax quantization over the last axis (block =
whole row; dequant-update-requant per step with fp32 scales).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # 'adamw' | 'lion' | 'sgdm'
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments: str = "fp32"            # 'fp32' | 'int8'
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ------------------------------------------------------- int8 moment codec --
def _quant(x):
    """fp32 -> (int8, fp32 row scales)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


class Moment(NamedTuple):
    """A possibly-quantized moment tensor."""
    value: jax.Array                   # fp32 or int8
    scale: Optional[jax.Array]         # None for fp32


def _moment_init(p, quantized: bool) -> Moment:
    if quantized and p.ndim >= 1:
        z = jnp.zeros(p.shape, jnp.int8)
        s = jnp.zeros((*p.shape[:-1], 1), jnp.float32)
        return Moment(z, s)
    return Moment(jnp.zeros(p.shape, jnp.float32), None)


def _moment_get(m: Moment, sqrt_domain: bool = False):
    if m.scale is None:
        return m.value
    v = _dequant(m.value, m.scale)
    return jnp.square(v) if sqrt_domain else v


def _moment_set(m: Moment, x, sqrt_domain: bool = False) -> Moment:
    """``sqrt_domain``: store sqrt(x) (x >= 0).  Linear int8 cannot span the
    dynamic range of Adam's second moment (g^2): small-v rows quantize to 0
    and m/(sqrt(0)+eps) explodes.  sqrt halves the dynamic range (|g|), the
    standard fix for 8-bit second moments."""
    if m.scale is None:
        return Moment(x.astype(jnp.float32), None)
    q, s = _quant(jnp.sqrt(x) if sqrt_domain else x)
    return Moment(q, s)


# ---------------------------------------------------------------- updates --
def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor), grads), norm


class Optimizer:
    """Pure-functional optimizer: state is a pytree, update is jittable."""

    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params):
        q = self.cfg.moments == "int8"
        mk = lambda p: _moment_init(p, q)
        state: Dict[str, Any] = {"count": jnp.zeros((), jnp.int32)}
        if self.cfg.name in ("adamw",):
            state["m"] = jax.tree.map(mk, params)
            state["v"] = jax.tree.map(mk, params)
        elif self.cfg.name in ("lion", "sgdm"):
            state["m"] = jax.tree.map(mk, params)
        else:
            raise ValueError(self.cfg.name)
        return state

    def state_specs(self, param_specs):
        """PartitionSpecs for the state, mirroring param sharding."""
        from jax.sharding import PartitionSpec as P

        def expand(ps):
            # Moment(value sharded like the param; row scales shed the last
            # dim's sharding — their trailing axis has size 1)
            if self.cfg.moments == "int8":
                lst = list(ps)
                if lst:
                    lst[-1] = None
                return Moment(value=ps, scale=P(*lst))
            return Moment(value=ps, scale=None)

        out = {"count": P()}
        keys = ["m", "v"] if self.cfg.name == "adamw" else ["m"]
        for k in keys:
            out[k] = jax.tree.map(
                expand, param_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        return out

    def update(self, grads, state, params, extra_decay_mask=None):
        """Returns (new_params, new_state, metrics)."""
        cfg = self.cfg
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        count = state["count"] + 1
        lr = schedule(cfg, count)
        metrics = {"grad_norm": gnorm, "lr": lr}

        if cfg.name == "adamw":
            bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
            bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

            def upd(p, g, m, v):
                mf = cfg.b1 * _moment_get(m) + (1 - cfg.b1) * g
                vf = (cfg.b2 * _moment_get(v, sqrt_domain=True)
                      + (1 - cfg.b2) * jnp.square(g))
                step = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
                decay = cfg.weight_decay * p.astype(jnp.float32)
                new_p = p.astype(jnp.float32) - lr * (step + decay)
                return (new_p.astype(p.dtype), _moment_set(m, mf),
                        _moment_set(v, vf, sqrt_domain=True))

            out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                               is_leaf=lambda x: isinstance(x, Moment))
            leaves = lambda i: jax.tree.map(
                lambda t: t[i], out,
                is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3
                and isinstance(t[1], Moment))
            new_params, new_m, new_v = leaves(0), leaves(1), leaves(2)
            return new_params, {"count": count, "m": new_m, "v": new_v}, metrics

        if cfg.name == "lion":
            def upd(p, g, m):
                mf = _moment_get(m)
                step = jnp.sign(cfg.b1 * mf + (1 - cfg.b1) * g)
                new_m = cfg.b2 * mf + (1 - cfg.b2) * g
                decay = cfg.weight_decay * p.astype(jnp.float32)
                new_p = p.astype(jnp.float32) - lr * (step + decay)
                return new_p.astype(p.dtype), _moment_set(m, new_m)

            out = jax.tree.map(upd, params, grads, state["m"],
                               is_leaf=lambda x: isinstance(x, Moment))
            leaves = lambda i: jax.tree.map(
                lambda t: t[i], out,
                is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                and isinstance(t[1], Moment))
            return leaves(0), {"count": count, "m": leaves(1)}, metrics

        if cfg.name == "sgdm":
            def upd(p, g, m):
                new_m = cfg.b1 * _moment_get(m) + g
                new_p = p.astype(jnp.float32) - lr * new_m
                return new_p.astype(p.dtype), _moment_set(m, new_m)

            out = jax.tree.map(upd, params, grads, state["m"],
                               is_leaf=lambda x: isinstance(x, Moment))
            leaves = lambda i: jax.tree.map(
                lambda t: t[i], out,
                is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                and isinstance(t[1], Moment))
            return leaves(0), {"count": count, "m": leaves(1)}, metrics

        raise ValueError(cfg.name)
