"""Cross-pod gradient compression (int8 + error feedback).

At multi-pod scale the pod<->pod links (DCI) are the scarcest bandwidth; the
standard trick is to run the intra-pod reduction at full precision and the
cross-pod merge quantized.  In JAX SPMD the cross-pod all-reduce is implicit
in ``jax.grad`` (parameters are replicated over 'pod'), so to compress it we
run the *whole grad computation* under a ``shard_map`` that maps ONLY the
'pod' axis (every other mesh axis stays auto-sharded, ``auto=...``):

    per-pod grads  ->  (+ error feedback)  ->  int8 quantize
      ->  all_gather over 'pod' (int8 on the wire, 4x less DCI traffic)
      ->  local dequant + sum  ->  update

The residual ``g - dequant(q)`` is carried in the train state and re-added
next step (error feedback), which keeps the quantization bias from
accumulating.  ``compression error -> 0`` over steps is property-tested.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True) if x.ndim else jnp.abs(x)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_compressed_mean(grads, err, *, axis: str = "pod"):
    """Inside shard_map(mapped over 'pod'): per-pod grads -> global mean.

    grads: per-pod gradient pytree (fp32).  err: error-feedback pytree.
    Returns (merged grads, new err).
    """
    npod = jax.lax.axis_size(axis)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quant(g)
        # int8 all-gather: wire traffic is 1 byte/elem instead of >=4
        qg = jax.lax.all_gather(q, axis)          # (npod, ...)
        sg = jax.lax.all_gather(s, axis)
        merged = jnp.sum(qg.astype(jnp.float32) * sg, axis=0) / npod
        new_e = g - q.astype(jnp.float32) * s     # local residual
        return merged, new_e

    out = jax.tree.map(one, grads, err)
    merged = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                          and isinstance(t[0], jax.Array))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                           and isinstance(t[0], jax.Array))
    return merged, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
