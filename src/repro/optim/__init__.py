"""Optimizers, schedules, grad clipping, and gradient compression."""
from .compress import init_error_feedback, pod_compressed_mean  # noqa: F401
from .optimizer import (Moment, OptConfig, Optimizer, clip_by_global_norm,  # noqa
                        global_norm, schedule)
