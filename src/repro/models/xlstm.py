"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential).

mLSTM is structurally an attention-with-decay: C_t = f_t C_{t-1} + i_t v_t
k_t^T, y_t = C_t q_t / max(|n_t q_t|, 1).  We reuse the SSD chunking idea
(mamba.py): per-head scalar log-forget gates make the intra-chunk decay a
rank-1 (L x L) mask.  Exponential input gates are stabilized with the
running max trick of the paper (m_t), folded into the chunk-local softmax
-style normalization.

sLSTM keeps true sequential semantics (its recurrent weights break
parallelism by construction) — a ``lax.scan`` over time; the paper's
block-diagonal 4-head structure keeps the recurrent matmul small.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ParamBuilder, rms_norm


# ----------------------------------------------------------------- mLSTM ---
def make_mlstm_params(pb: ParamBuilder, d_model: int, n_heads: int,
                      proj_factor: float = 2.0):
    d_in = int(d_model * proj_factor)
    return {
        "up_proj": pb.param((d_model, 2 * d_in), ("fsdp", "mlp")),
        "wq": pb.param((d_in, d_in), ("mlp", None)),
        "wk": pb.param((d_in, d_in), ("mlp", None)),
        "wv": pb.param((d_in, d_in), ("mlp", None)),
        "w_if": pb.param((d_in, 2 * n_heads), (None, None), scale=0.5),
        "b_if": pb.param((2 * n_heads,), (None,), init="zeros"),
        "norm": pb.param((d_in,), ("mlp",), init="ones"),
        "down_proj": pb.param((d_in, d_model), ("mlp", "fsdp")),
    }


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, nh, P, P) matrix memory
    n: jax.Array   # (B, nh, P)    normalizer
    m: jax.Array   # (B, nh)       gate stabilizer (log domain)


def init_mlstm_state(batch: int, d_model: int, n_heads: int,
                     proj_factor: float = 2.0) -> MLSTMState:
    d_in = int(d_model * proj_factor)
    P = d_in // n_heads
    return MLSTMState(c=jnp.zeros((batch, n_heads, P, P), jnp.float32),
                      n=jnp.zeros((batch, n_heads, P), jnp.float32),
                      m=jnp.full((batch, n_heads), -1e30, jnp.float32))


def _mlstm_qkvif(p, x, nh: int):
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"].astype(dt))
    d_in = xz.shape[-1] // 2
    xi, z = xz[..., :d_in], xz[..., d_in:]
    B, S = xi.shape[:2]
    P = d_in // nh
    q = (xi @ p["wq"].astype(dt)).reshape(B, S, nh, P)
    k = (xi @ p["wk"].astype(dt)).reshape(B, S, nh, P) * (P ** -0.5)
    v = (xi @ p["wv"].astype(dt)).reshape(B, S, nh, P)
    gif = (xi @ p["w_if"].astype(dt)).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    ig, fg = gif[..., :nh], gif[..., nh:]      # (B, S, nh) log-domain gates
    logf = jax.nn.log_sigmoid(fg)
    return q, k, v, ig, logf, z, d_in, P


def mlstm_chunked(p, x, *, chunk: int, n_heads: int, state=None):
    """Full-sequence chunkwise mLSTM.  Returns (y, final_state)."""
    B, S, D = x.shape
    q, k, v, ig, logf, z, d_in, P = _mlstm_qkvif(p, x, n_heads)
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    st = state if state is not None else init_mlstm_state(B, D, n_heads)
    rs = lambda t: jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    def chunk_step(carry, inp):
        c, n, m = carry
        qc, kc, vc, igc, lfc = inp             # (B,L,nh,P) ... (B,L,nh)
        clf = jnp.cumsum(lfc, axis=1)          # (B, L, nh) cumulative log-f
        # stabilizer: m_t = max(m_prev + clf_t, max_{s<=t}(clf_t - clf_s + ig_s))
        a = igc - clf                          # (B, L, nh): ig_s - clf_s
        a_run = jax.lax.cummax(a, axis=1)
        m_t = clf + jnp.maximum(m[:, None], a_run)   # (B, L, nh)
        # intra-chunk attention weights: exp(clf_t - clf_s + ig_s - m_t),
        # built natively in (B, nh, Lt, Ls) — trailing (L, L) marks the VMEM
        # chunk panel for the kernelized roofline memory model.
        clf_h = clf.transpose(0, 2, 1)         # (B, nh, L)
        ig_h = igc.transpose(0, 2, 1)
        dmat = (clf_h[:, :, :, None] - clf_h[:, :, None, :]
                + ig_h[:, :, None, :])         # (B, nh, Lt, Ls)
        tri = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(tri[None, None], dmat, -jnp.inf)
        w = jnp.exp(dmat - m_t.transpose(0, 2, 1)[:, :, :, None])
        # (k is pre-scaled by P**-0.5; the state c/n store scaled-k sums so
        # every q-dot below needs no further scaling.)
        qk = jnp.einsum("bthp,bshp->bhts", qc, kc,
                        preferred_element_type=jnp.float32)
        aw = w * qk                            # (B, nh, Lt, Ls)
        y_in = jnp.einsum("bhts,bshp->bthp", aw.astype(vc.dtype), vc)
        qn_in = jnp.einsum("bhts->bth", aw)    # sum over s -> (B, L, nh)
        # inter-chunk contribution: decay exp(clf_t + m_prev - m_t)
        dec = jnp.exp(clf + m[:, None] - m_t)  # (B, L, nh)
        y_ext = jnp.einsum("bthp,bhrp,bth->bthr", qc.astype(jnp.float32),
                           c, dec).astype(vc.dtype)
        n_ext = jnp.einsum("bthp,bhp,bth->bth", qc.astype(jnp.float32),
                           n, dec)
        y = y_in.astype(jnp.float32) + y_ext.astype(jnp.float32)
        qn = jnp.abs(qn_in + n_ext)
        y = y / jnp.maximum(qn, jnp.exp(-m_t))[..., None]
        # carry update at chunk end
        m_end = m_t[:, -1]                     # (B, nh)
        dec_end = jnp.exp(clf[:, -1:, :] - clf + igc - m_end[:, None])
        kv = jnp.einsum("bshp,bshr,bsh->bhrp", kc.astype(jnp.float32),
                        vc.astype(jnp.float32), dec_end)
        c_new = jnp.exp(clf[:, -1] + m - m_end)[:, :, None, None] * c + kv
        n_new = jnp.exp(clf[:, -1] + m - m_end)[:, :, None] * n + \
            jnp.einsum("bshp,bsh->bhp", kc.astype(jnp.float32), dec_end)
        return (c_new, n_new, m_end), y.astype(x.dtype)

    (cT, nT, mT), ys = jax.lax.scan(
        chunk_step, (st.c, st.n, st.m),
        (rs(q), rs(k), rs(v), rs(ig), rs(logf)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down_proj"].astype(x.dtype))
    return out, MLSTMState(c=cT, n=nT, m=mT)


def mlstm_decode(p, x, state: MLSTMState, *, n_heads: int):
    B = x.shape[0]
    q, k, v, ig, logf, z, d_in, P = _mlstm_qkvif(p, x, n_heads)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]     # k pre-scaled by P**-0.5
    ig1, lf1 = ig[:, 0], logf[:, 0]            # (B, nh)
    m_new = jnp.maximum(lf1 + state.m, ig1)
    fdec = jnp.exp(lf1 + state.m - m_new)
    idec = jnp.exp(ig1 - m_new)
    c = fdec[:, :, None, None] * state.c + \
        idec[:, :, None, None] * jnp.einsum(
            "bhr,bhp->bhrp", v1.astype(jnp.float32), k1.astype(jnp.float32))
    n = fdec[:, :, None] * state.n + idec[:, :, None] * k1.astype(jnp.float32)
    num = jnp.einsum("bhrp,bhp->bhr", c, q1.astype(jnp.float32))
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", n, q1.astype(jnp.float32)))
    y = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None])
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down_proj"].astype(x.dtype))
    return out, MLSTMState(c=c, n=n, m=m_new)


# ----------------------------------------------------------------- sLSTM ---
def make_slstm_params(pb: ParamBuilder, d_model: int, n_heads: int,
                      ffn_factor: float = 4 / 3):
    dp = int(d_model * ffn_factor)
    return {
        "w_in": pb.param((d_model, 4 * d_model), ("fsdp", "mlp")),
        "w_rec": pb.param((d_model, 4 * d_model), (None, "mlp"), scale=0.5),
        "b": pb.param((4 * d_model,), (None,), init="zeros"),
        "norm": pb.param((d_model,), (None,), init="ones"),
        "up": pb.param((d_model, dp), ("fsdp", "mlp")),
        "down": pb.param((dp, d_model), ("mlp", "fsdp")),
    }


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, D)
    c: jax.Array   # (B, D)
    n: jax.Array   # (B, D)
    m: jax.Array   # (B, D)


def init_slstm_state(batch: int, d_model: int) -> SLSTMState:
    z = lambda: jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMState(h=z(), c=z(), n=z(), m=jnp.full((batch, d_model), -1e30,
                                                      jnp.float32))


def _slstm_gates(g, st: SLSTMState) -> SLSTMState:
    """Cell update from the full gate pre-activation g (B, 4D), fp32."""
    i, f, zg, o = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + st.m, i)
    idec = jnp.exp(i - m_new)
    fdec = jnp.exp(logf + st.m - m_new)
    c = fdec * st.c + idec * jnp.tanh(zg)
    n = fdec * st.n + idec
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return SLSTMState(h=h, c=c, n=n, m=m_new)


def _slstm_cell(p, xt, st: SLSTMState):
    """One sLSTM step.  xt: (B, 4D) pre-projected input contribution."""
    g = (xt.astype(jnp.float32)
         + (st.h.astype(p["w_rec"].dtype) @ p["w_rec"]).astype(jnp.float32)
         + p["b"].astype(jnp.float32))
    return _slstm_gates(g, st)


@jax.custom_vjp
def _slstm_scan(w_rec, b, xin, st0):
    """Sequential sLSTM over time.  Returns (h_seq (S, B, D), stT).

    Custom VJP rationale (§Perf, the collective hillclimb): under plain
    autodiff the gradient of the (replicated or sharded) recurrent matrix
    accumulates in the backward *while loop*, and with a batch-sharded
    ``h`` SPMD must psum the (D, 4D) outer product EVERY timestep —
    measured 8.3e11 collective bytes/device on xlstm train_4k.  This VJP
    carries only the per-step gate cotangents ``dg`` out of the loop and
    forms  dW = h_prev_seqᵀ @ dg_seq  as ONE matmul (one reduction) after
    the scan — the standard deferred-reduction RNN training trick.
    """
    h_seq, stT, _ = _slstm_fwd_scan(w_rec, b, xin, st0)
    return h_seq, stT


def _slstm_fwd_scan(w_rec, b, xin, st0):
    def step(st, xt):
        g = (xt.astype(jnp.float32)
             + (st.h.astype(w_rec.dtype) @ w_rec).astype(jnp.float32)
             + b.astype(jnp.float32))
        st2 = _slstm_gates(g, st)
        return st2, (st2.h, st)

    stT, (h_seq, st_seq) = jax.lax.scan(step, st0, xin)
    return h_seq, stT, st_seq


def _slstm_scan_fwd(w_rec, b, xin, st0):
    h_seq, stT, st_seq = _slstm_fwd_scan(w_rec, b, xin, st0)
    return (h_seq, stT), (w_rec, b, xin, st_seq)


def _slstm_scan_bwd(res, cts):
    w_rec, b, xin, st_seq = res
    dh_seq, dstT = cts

    def back_step(dst_next, inp):
        st_prev, xt, dh_t = inp
        g = (xt.astype(jnp.float32)
             + (st_prev.h.astype(w_rec.dtype) @ w_rec).astype(jnp.float32)
             + b.astype(jnp.float32))
        _, cell_vjp = jax.vjp(_slstm_gates, g, st_prev)
        dst_in = dst_next._replace(h=dst_next.h + dh_t)
        dg, dst_prev = cell_vjp(dst_in)
        dst_prev = dst_prev._replace(
            h=dst_prev.h + (dg.astype(w_rec.dtype) @ w_rec.T
                            ).astype(jnp.float32))
        return dst_prev, dg

    dst0, dg_seq = jax.lax.scan(back_step, dstT, (st_seq, xin, dh_seq),
                                reverse=True)
    # deferred reductions: ONE matmul / ONE sum instead of per-step psums
    h_prev_seq = st_seq.h                              # (S, B, D)
    dW = jnp.einsum("sbd,sbe->de", h_prev_seq.astype(jnp.float32),
                    dg_seq).astype(w_rec.dtype)
    db = jnp.sum(dg_seq, axis=(0, 1)).astype(b.dtype)
    dxin = dg_seq.astype(xin.dtype)
    return dW, db, dxin, dst0


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_seq(p, x, state=None):
    """x: (B, S, D) -> (B, S, D), sequential scan over time."""
    B, S, D = x.shape
    st = state if state is not None else init_slstm_state(B, D)
    xin = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    hs, stT = _slstm_scan(p["w_rec"], p["b"], jnp.moveaxis(xin, 1, 0), st)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = rms_norm(y, p["norm"])
    y = jax.nn.gelu(jnp.einsum("bsd,dp->bsp", y, p["up"].astype(x.dtype)))
    out = jnp.einsum("bsp,pd->bsd", y, p["down"].astype(x.dtype))
    return out, stT


def slstm_decode(p, x, state: SLSTMState):
    xin = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    st2 = _slstm_cell(p, xin[:, 0], state)
    y = rms_norm(st2.h.astype(x.dtype)[:, None], p["norm"])
    y = jax.nn.gelu(jnp.einsum("bsd,dp->bsp", y, p["up"].astype(x.dtype)))
    out = jnp.einsum("bsp,pd->bsd", y, p["down"].astype(x.dtype))
    return out, st2
