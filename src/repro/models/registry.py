"""Model registry: build any assigned architecture against a mesh, produce
step functions and abstract input specs for the dry-run.

``input_specs(model, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step the shape exercises (train_step for ``train_*``, prefill
for ``prefill_*``, serve_step for ``decode_*``/``long_*``) — weak-type
correct, shardable, zero allocation.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as C
from .model import Model
from .sharding import (BASELINE_RULES, DECODE_RULES, LONG_DECODE_RULES,
                       MeshRules)


def build(arch: "str | C.ArchConfig", mesh, rules: Optional[MeshRules] = None,
          use_kernels: bool = False) -> Model:
    cfg = C.get(arch) if isinstance(arch, str) else arch
    return Model(cfg, mesh, rules or BASELINE_RULES, use_kernels=use_kernels)


def pick_rules(cfg: C.ArchConfig, shape: C.ShapeSpec,
               mesh=None) -> MeshRules:
    """Default rule preset per shape kind (the §Perf baseline)."""
    if shape.kind == "train":
        return BASELINE_RULES
    rules = DECODE_RULES if shape.seq_len < 100_000 else LONG_DECODE_RULES
    # models whose DENSE weights are too large for TP-only keep FSDP at
    # serve time (weights all-gathered per layer inside the scan; latency
    # traded for fit).  Expert weights are excluded: at decode they are
    # 'split'-sharded over experts x d_ff (DECODE_RULES) and never gathered.
    big = _dense_param_bytes(cfg) / 16 > 12e9
    if big:
        rules = rules.replace(fsdp="data")
    return rules


def _rough_param_bytes(cfg: C.ArchConfig) -> float:
    return cfg.n_params() * 2.0  # bf16


def _dense_param_bytes(cfg: C.ArchConfig) -> float:
    n = cfg.n_params()
    if cfg.moe is not None:
        m = cfg.moe
        me = m.moe_every or 1
        n_moe_layers = cfg.n_layers // me
        n -= n_moe_layers * m.n_experts * 3 * cfg.d_model * m.d_ff_expert
    return n * 2.0  # bf16


def input_specs(model: Model, shape: C.ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the step function this shape lowers."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        out = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.enc_dec:
            out["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                                 model.dtype)
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok(B, S)}
        if cfg.enc_dec:
            out["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                                 model.dtype)
        return out
    # decode: one new token against a cache of seq_len
    cache = model.init_cache(B, S, abstract=True)
    return {"token": tok(B, 1), "cache": cache}


def batch_specs(model: Model, shape: C.ShapeSpec):
    """PartitionSpecs matching input_specs."""
    r = model.resolver
    if shape.kind in ("train", "prefill"):
        out = {"tokens": r.spec(("batch", None), (shape.global_batch,
                                                  shape.seq_len))}
        if shape.kind == "train":
            out["labels"] = out["tokens"]
        if model.cfg.enc_dec:
            out["frames"] = r.spec(("batch", None, None),
                                   (shape.global_batch, model.cfg.enc_seq,
                                    model.cfg.d_model))
        return out
    cache = model.init_cache(shape.global_batch, shape.seq_len, abstract=True)
    return {"token": r.spec(("batch", None), (shape.global_batch, 1)),
            "cache": model.cache_specs(cache)}


# ------------------------------------------------------------ param count --
def param_stats(model: Model) -> Dict[str, float]:
    """Exact parameter counts from the abstract tree (N for 6*N*D)."""
    params = model.abstract_params()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = active = embed = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        total += n
        if "embed" in keys or "lm_head" in keys or "pos_embed" in keys:
            embed += n
            active += n
            continue
        if "experts" in keys:
            m = model.cfg.moe
            active += n * m.top_k / m.n_experts
        else:
            active += n
    return {"total": total, "active": active, "embed": embed,
            "non_embed": total - embed,
            "active_non_embed": active - embed}
