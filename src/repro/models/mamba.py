"""Mamba (SSD / Mamba-2 style) selective state-space mixer.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel does not port;
instead we use the chunkwise-parallel SSD formulation — within a chunk of
``L`` tokens the recurrence is an attention-like (L x L) masked matmul (MXU
friendly); across chunks a sequential ``lax.scan`` carries the (heads, P, N)
state.  Per-head *scalar* decay (Mamba-2) keeps the decay matrix rank-1 so
the intra-chunk mask is (B, nh, L, L) — bounded VMEM, hardware-aligned dims.

Decode is the plain recurrence on the carried state: O(1) per token, which
is why ssm/hybrid archs run the ``long_500k`` cell.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ParamBuilder, rms_norm


def make_mamba_params(pb: ParamBuilder, d_model: int, d_state: int,
                      d_conv: int, expand: int, head_p: int = 128):
    d_in = expand * d_model
    nh = max(1, d_in // head_p)
    return {
        "in_proj": pb.param((d_model, 2 * d_in), ("fsdp", "mlp")),
        "conv_w": pb.param((d_conv, d_in), (None, "mlp"), scale=0.5),
        "dt_proj": pb.param((d_model, nh), (None, None), scale=0.5),
        "dt_bias": pb.param((nh,), (None,), init="zeros"),
        "bc_proj": pb.param((d_model, 2 * d_state), (None, None)),
        "a_log": pb.param((nh,), (None,), init="zeros"),
        "d_skip": pb.param((nh,), (None,), init="ones"),
        "norm": pb.param((d_in,), ("mlp",), init="ones"),
        "out_proj": pb.param((d_in, d_model), ("mlp", "fsdp")),
    }


class MambaState(NamedTuple):
    h: jax.Array          # (B, nh, P, N) inter-chunk SSM state
    conv: jax.Array       # (B, d_conv-1, d_in) conv tail


def _segsum_mask(adt):
    """adt: (B, L, nh) per-step log-decays -> (B, nh, L, L) decay matrix
    M[t, s] = exp(sum_{r=s+1..t} adt_r) for s <= t, else 0.

    Built directly in (B, nh, L, L) layout: the trailing (L, L) dims mark it
    as a VMEM-resident chunk panel for the roofline's kernelized memory
    model (launch/hlo_analysis.py panel_dims)."""
    B, L, nh = adt.shape
    ca = jnp.cumsum(adt, axis=1).transpose(0, 2, 1)    # (B, nh, L)
    diff = ca[:, :, :, None] - ca[:, :, None, :]       # (B, nh, Lt, Ls)
    tri = jnp.tril(jnp.ones((L, L), bool))
    diff = jnp.where(tri[None, None], diff, -jnp.inf)
    return jnp.exp(diff)                               # (B, nh, L, L)


def _proj_inputs(p, x):
    """x: (B, S, D) -> gated inputs for the SSM."""
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))
    d_in = xz.shape[-1] // 2
    xi, z = xz[..., :d_in], xz[..., d_in:]
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))            # (B, S, nh)
    bc = jnp.einsum("bsd,dn->bsn", x, p["bc_proj"].astype(dt))
    n = bc.shape[-1] // 2
    bmat, cmat = bc[..., :n], bc[..., n:]              # (B, S, N)
    return xi, z, dtv, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def _causal_conv(p, xi, tail=None):
    """Depthwise causal conv over seq.  tail: (B, d_conv-1, d_in) context."""
    w = p["conv_w"].astype(xi.dtype)                   # (d_conv, d_in)
    dc = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xi.shape[0], dc - 1, xi.shape[2]), xi.dtype)
    xp = jnp.concatenate([tail, xi], axis=1)
    out = sum(xp[:, i:i + xi.shape[1]] * w[i] for i in range(dc))
    new_tail = xp[:, -(dc - 1):] if dc > 1 else tail
    return jax.nn.silu(out), new_tail


def mamba_chunked(p, x, *, chunk: int, state: MambaState = None):
    """Full-sequence (train/prefill) chunkwise SSD. Returns (y, final_state)."""
    B, S, D = x.shape
    xi, z, dtv, bmat, cmat = _proj_inputs(p, x)
    conv_tail = state.conv if state is not None else None
    xi, conv_tail = _causal_conv(p, xi, conv_tail)
    d_in = xi.shape[-1]
    nh = p["a_log"].shape[0]
    P = d_in // nh
    N = bmat.shape[-1]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (nh,) negative decay

    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    xh = xi.reshape(B, nc, L, nh, P)
    dtc = dtv.reshape(B, nc, L, nh)
    bm = bmat.reshape(B, nc, L, N)
    cm = cmat.reshape(B, nc, L, N)
    h0 = (state.h if state is not None
          else jnp.zeros((B, nh, P, N), jnp.float32))

    def chunk_step(h, inp):
        xc, dc_, bc_, cc_ = inp                        # (B,L,nh,P) (B,L,nh) ..
        adt = dc_ * a[None, None, :]                   # (B, L, nh) log decays
        mask = _segsum_mask(adt)                       # (B, nh, L, L)
        ca = jnp.cumsum(adt, axis=1)                   # (B, L, nh)
        # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) M[t,s] dt_s x_s
        cb = jnp.einsum("btn,bsn->bts", cc_, bc_)      # (B, L, L)
        w = cb[:, None] * mask                         # (B, nh, L, L)
        xdt = xc * dc_[..., None].astype(xc.dtype)     # (B, L, nh, P)
        y_in = jnp.einsum("bhts,bshp->bthp", w.astype(xc.dtype), xdt)
        # inter-chunk: y_ext[t] = C_t . (exp(ca_t) h_in)
        dec_t = jnp.exp(ca)                            # (B, L, nh)
        y_ext = jnp.einsum("btn,bhpn,bth->bthp",
                           cc_.astype(jnp.float32), h,
                           dec_t).astype(xc.dtype)
        # state update: h' = exp(ca_L) h + sum_s exp(ca_L - ca_s) dt_s x_s B_s^T
        dec_end = jnp.exp(ca[:, -1:, :] - ca)          # (B, L, nh)
        hb = jnp.einsum("bshp,bsn,bsh->bhpn",
                        xdt.astype(jnp.float32), bc_, dec_end)
        h_new = jnp.exp(ca[:, -1])[:, :, None, None] * h + hb
        return h_new, (y_in + y_ext)

    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(bm, 1, 0), jnp.moveaxis(cm, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, P)
    y = y + xh.reshape(B, S, nh, P) * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, MambaState(h=hT, conv=conv_tail)


def mamba_decode(p, x, state: MambaState):
    """One-token recurrence.  x: (B, 1, D) -> (B, 1, D), new state."""
    B = x.shape[0]
    xi, z, dtv, bmat, cmat = _proj_inputs(p, x)
    xi, conv_tail = _causal_conv(p, xi, state.conv)
    d_in = xi.shape[-1]
    nh = p["a_log"].shape[0]
    P = d_in // nh
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    adt = dtv[:, 0] * a[None, :]                       # (B, nh)
    xh = xi[:, 0].reshape(B, nh, P)
    xdt = (xh * dtv[:, 0, :, None].astype(xh.dtype)).astype(jnp.float32)
    hb = jnp.einsum("bhp,bn->bhpn", xdt, bmat[:, 0])
    h = jnp.exp(adt)[:, :, None, None] * state.h + hb
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], h).astype(x.dtype)
    y = y + xh * p["d_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, MambaState(h=h, conv=conv_tail)


def init_mamba_state(batch: int, d_model: int, d_state: int, d_conv: int,
                     expand: int, head_p: int = 128,
                     dtype=jnp.bfloat16) -> MambaState:
    d_in = expand * d_model
    nh = max(1, d_in // head_p)
    P = d_in // nh
    return MambaState(
        h=jnp.zeros((batch, nh, P, d_state), jnp.float32),
        conv=jnp.zeros((batch, d_conv - 1, d_in), dtype))
