"""Feed-forward layers: dense SwiGLU and expert-parallel MoE.

MoE design (TPU-native adaptation, see DESIGN.md §2): routing is computed
redundantly on every model shard (router weights are tiny and replicated),
experts are sharded over the ``expert`` rule axis ('model'), and tokens stay
resident on their data shard.  Each (data, model) device scatters its local
tokens into the capacity buffers of *its own* experts, runs the expert FFNs,
scatters results back, and a single ``psum`` over the model axis merges the
per-expert partial outputs — the same collective cost as a Megatron TP FFN
(one all-reduce of (tokens, d_model)), with **zero all-to-alls**.  This is
the DeepSeek-EP-style redundant-routing layout; it sidesteps GShard's
(tokens, experts, capacity) dispatch einsum, which cannot be materialized at
384 experts x 1M tokens.

Expert weights are additionally FSDP-sharded over 'data' and all-gathered
just-in-time inside the shard_map (manual ZeRO-3; the transpose rule makes
the backward a reduce-scatter of the weight grads).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder


def _shard_map(fn, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across jax versions: top-level alias from 0.6.x
    (``check_vma``), the experimental module before that (``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)


def make_dense_ffn_params(pb: ParamBuilder, d_model: int, d_ff: int):
    return {
        "w_gate": pb.param((d_model, d_ff), ("fsdp", "mlp")),
        "w_up": pb.param((d_model, d_ff), ("fsdp", "mlp")),
        "w_down": pb.param((d_ff, d_model), ("mlp", "fsdp")),
    }


def dense_ffn(p, x):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["w_down"].astype(dt))


def make_moe_params(pb: ParamBuilder, d_model: int, n_experts: int,
                    d_ff_expert: int):
    return {
        "router": pb.param((d_model, n_experts), (None, None), scale=1.0),
        "experts": {
            "w_gate": pb.param((n_experts, d_model, d_ff_expert),
                               ("expert", "expert_din", "expert_dff")),
            "w_up": pb.param((n_experts, d_model, d_ff_expert),
                             ("expert", "expert_din", "expert_dff")),
            "w_down": pb.param((n_experts, d_ff_expert, d_model),
                               ("expert", "expert_dff", "expert_din")),
        },
    }


def _axes_tuple(ax):
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(a for a in ax)


class MoEContext:
    """Mesh-resolved shard_map specs for the MoE layer (built once per model)."""

    def __init__(self, mesh, rules, n_experts: int, top_k: int,
                 capacity_factor: float):
        self.mesh = mesh
        self.n_experts = n_experts
        self.top_k = top_k
        self.capacity_factor = capacity_factor
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        exp_axes = tuple(a for a in _axes_tuple(rules.expert) if a in sizes)
        din_axes = tuple(a for a in _axes_tuple(rules.expert_din)
                         if a in sizes)
        dff_axes = tuple(a for a in _axes_tuple(rules.expert_dff)
                         if a in sizes)
        batch_axes = tuple(a for a in _axes_tuple(rules.batch) if a in sizes)
        ep = math.prod(sizes[a] for a in exp_axes) if exp_axes else 1
        if n_experts % max(ep, 1):
            exp_axes, ep = (), 1  # fallback: replicate experts
        self.exp_axes, self.batch_axes = exp_axes, batch_axes
        self.fsdp_axes = din_axes        # 'gather' layout: D sharded (FSDP)
        self.dff_axes = dff_axes         # 'split' layout: F sharded
        self.split_layout = bool(dff_axes)
        self.ep = ep
        e_ax = exp_axes if exp_axes else None
        self.x_spec = P(batch_axes if batch_axes else None, None, None)
        self.w_spec = P(e_ax, din_axes if din_axes else None,
                        dff_axes if dff_axes else None)
        self.wd_spec = P(e_ax, dff_axes if dff_axes else None,
                         din_axes if din_axes else None)
        self.r_spec = P(None, None)
        # expert shards each contribute partial sums for their experts only;
        # the psum over the expert axes merges them.  Axes that are neither
        # batch nor expert see fully replicated compute (no psum, or the
        # output would be multiplied by the axis size).
        self.reduce_axes = exp_axes


def moe_ffn(ctx: MoEContext, p, x):
    """x: (B, S, D) sharded per ctx.x_spec -> (B, S, D)."""

    def local(router, wg, wu, wd, xl):
        Bl, Sl, D = xl.shape
        T = Bl * Sl
        E, k = ctx.n_experts, ctx.top_k
        e_loc = E // ctx.ep
        cap = max(1, int(math.ceil(T * k / E * ctx.capacity_factor)))
        xt = xl.reshape(T, D)
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
        gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # position of each (token, slot) within its expert's capacity buffer
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # (T, k, E)
        flat = onehot.reshape(T * k, E)
        pos = jnp.cumsum(flat, axis=0) - 1                       # (T*k, E)
        pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)         # (T, k)
        keep = pos < cap

        # this shard owns experts [lo, lo+e_loc)
        if ctx.exp_axes:
            ep_idx = jax.lax.axis_index(ctx.exp_axes[0])
            for a in ctx.exp_axes[1:]:
                ep_idx = ep_idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        else:
            ep_idx = 0
        lo = ep_idx * e_loc
        mine = (idx >= lo) & (idx < lo + e_loc) & keep
        le = jnp.where(mine, idx - lo, e_loc)                    # e_loc = drop row
        lp = jnp.where(mine, pos, cap)

        # scatter tokens into (e_loc, cap, D) capacity buffers (+1 drop row)
        buf = jnp.zeros((e_loc + 1, cap + 1, D), xt.dtype)
        buf = buf.at[le.reshape(-1), lp.reshape(-1)].add(
            jnp.repeat(xt, k, axis=0))
        buf = buf[:e_loc, :cap]

        if ctx.split_layout:
            # 'split' layout (decode): weights stay put (F sharded over the
            # dff axes, which coincide with the batch/data axes at decode);
            # the *tokens* travel instead: gather every data shard's tiny
            # capacity buffers, compute the F-shard partial for all of them,
            # psum the down-proj partials, and keep the local slice.  Wire
            # bytes are O(experts x cap x D) activations — MBs — instead of
            # the gather layout's per-step expert-weight all-gathers (GBs).
            my = 0
            for a in ctx.dff_axes:
                my = my * jax.lax.axis_size(a) + jax.lax.axis_index(a)
                buf = jax.lax.all_gather(buf, a, axis=1, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xt.dtype))
            u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xt.dtype))
            y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                           wd.astype(xt.dtype))
            for a in ctx.dff_axes:
                y = jax.lax.psum(y, a)
            y = jax.lax.dynamic_slice_in_dim(y, my * cap, cap, axis=1)
        else:
            # 'gather' layout (train): JIT all-gather of FSDP-sharded expert
            # weights (manual ZeRO-3) — right when tokens >> weights.
            for a in ctx.fsdp_axes:
                wg = jax.lax.all_gather(wg, a, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, a, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, a, axis=2, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xt.dtype))
            u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xt.dtype))
            y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                           wd.astype(xt.dtype))                  # (e_loc,cap,D)

        # gather back with gate weights; drop-row trick keeps shapes static
        y = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        picked = y[le, lp]                                       # (T, k, D)
        out = jnp.einsum("tkd,tk->td", picked,
                         gate.astype(picked.dtype) * mine.astype(picked.dtype))
        for a in ctx.reduce_axes:
            out = jax.lax.psum(out, a)
        return out.reshape(Bl, Sl, D)

    fn = _shard_map(
        local, mesh=ctx.mesh,
        in_specs=(ctx.r_spec, ctx.w_spec, ctx.w_spec, ctx.wd_spec, ctx.x_spec),
        out_specs=ctx.x_spec,
        check_vma=False,
    )
    e = p["experts"]
    return fn(p["router"], e["w_gate"], e["w_up"], e["w_down"], x)
