"""GQA attention: chunked (flash-style) causal attention for train/prefill,
block-partial (flash-decode) attention for decode with an optionally
sequence-sharded KV cache.

Memory discipline
-----------------
* train/prefill never materializes the (S x S) score matrix: an outer
  ``lax.scan`` over query chunks (``attn_chunk_q``) holds one
  (B, KV, G, qc, S) panel at a time; this is the pure-jnp twin of the Pallas
  ``flash_attention`` kernel (kernels/flash_attention.py) and is what the
  dry-run lowers (clean HLO for the roofline; identical math).
* decode uses a KV cache laid out as ``(S_blocks, T_blk, B, KV, hd)``.  The
  leading block axis is the FUSEE "memory pool" axis: sharding it over mesh
  axes = pages spread over memory nodes.  Attention computes per-block
  partial (max, denom, weighted-sum) and combines across blocks — under SPMD
  the combine is the only cross-shard traffic (B*H*hd-sized), the
  flash-decode trick that makes 500k-token caches shardable.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamBuilder, apply_rope, rms_norm, rope_angles

NEG_INF = -1e30


def make_attn_params(pb: ParamBuilder, d_model: int, n_heads: int,
                     n_kv: int, head_dim: int, qk_norm: bool):
    p = {
        "wq": pb.param((d_model, n_heads, head_dim), ("fsdp", "heads", "head_dim"),
                       fan_in=d_model),
        "wk": pb.param((d_model, n_kv, head_dim), ("fsdp", "kv_heads", "head_dim"),
                       fan_in=d_model),
        "wv": pb.param((d_model, n_kv, head_dim), ("fsdp", "kv_heads", "head_dim"),
                       fan_in=d_model),
        "wo": pb.param((n_heads, head_dim, d_model), ("heads", "head_dim", "fsdp"),
                       fan_in=n_heads * head_dim),
    }
    if qk_norm:
        p["q_norm"] = pb.param((head_dim,), (None,), init="ones")
        p["k_norm"] = pb.param((head_dim,), (None,), init="ones")
    return p


def _project_qkv(p, x, positions, theta: float, qk_norm: bool):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    hd = q.shape[-1]
    cos, sin = rope_angles(positions, hd, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def flash_attention_jnp(q, k, v, *, causal: bool, q_chunk: int,
                        q_offset=0, kv_valid: Optional[jax.Array] = None):
    """Chunked online-softmax attention (GQA via repeat-kv).

    q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd).
    ``q_offset``: absolute position of q[0] (prefill continuation).
    ``kv_valid``: number of valid kv positions (mask tail), scalar.

    Sharding note: kv heads are repeated up to H *before* the score einsum
    so every tensor keeps the head axis = H, which shards over 'model'
    without resharding (KV=8 never divides tp=16 in the assigned pool; a
    (KV, G) grouped layout would force a per-layer all-to-all of q).

    Memory note: the per-chunk score panel is the only O(Sq*Skv) tensor and
    the q-step body is ``jax.checkpoint``ed, so the backward *recomputes*
    scores per chunk instead of saving all panels — the same
    recompute-in-backward the Pallas flash kernel does in VMEM.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk:
        q_chunk //= 2
    nq = Sq // q_chunk
    qr = q.reshape(B, nq, q_chunk, H, hd)
    kv_pos = jnp.arange(Skv)

    def q_step(_, qi):
        qc, qidx = qi                      # (B, qc, H, hd), scalar chunk idx
        q_pos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)
        s = jnp.einsum("bqhd,bshd->bhqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((q_chunk, Skv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_valid is not None:
            mask &= (kv_pos < kv_valid)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(m))
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1, 3)
        return None, o.astype(q.dtype)

    q_step = jax.checkpoint(
        q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(q_step, None,
                          (jnp.moveaxis(qr, 1, 0), jnp.arange(nq)))
    # out: (nq, B, qc, H, hd) -> (B, Sq, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)


class KVCache(NamedTuple):
    """Block-paged KV cache: (S_blocks, T_blk, B, KV, hd) per layer stack.

    ``S_blocks`` is the FUSEE pool axis (shardable over mesh axes); a
    (block, slot) pair is a page address exactly like a FUSEE pointer.
    """
    k: jax.Array
    v: jax.Array
    length: jax.Array  # scalar int32: tokens currently stored


def init_cache(n_super: int, per_super: int, batch: int, max_len: int,
               n_kv: int, hd: int, n_blocks: int, dtype) -> KVCache:
    t_blk = max_len // n_blocks
    shape = (n_super, per_super, n_blocks, t_blk, batch, n_kv, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def cache_from_prefill(k, v, n_blocks: int, max_len: int):
    """(B, S, KV, hd) -> block layout (n_blocks, T_blk, B, KV, hd), padded."""
    B, S, KV, hd = k.shape
    t_blk = max_len // n_blocks
    pad = n_blocks * t_blk - S
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    f = lambda x: x.reshape(B, n_blocks, t_blk, KV, hd).transpose(1, 2, 0, 3, 4)
    return f(k), f(v)


def cache_append(kc, vc, k_new, v_new, length):
    """Write one token's k/v (B, 1, KV, hd) at position ``length``."""
    t_blk = kc.shape[1]
    blk = length // t_blk
    off = length % t_blk
    k1 = k_new[:, 0][None, None]  # (1, 1, B, KV, hd)
    v1 = v_new[:, 0][None, None]
    kc = jax.lax.dynamic_update_slice(kc, k1.astype(kc.dtype),
                                      (blk, off, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v1.astype(vc.dtype),
                                      (blk, off, 0, 0, 0))
    return kc, vc


def flash_decode_jnp(q, kc, vc, valid_len, k_new=None, v_new=None):
    """Block-partial decode attention.

    q: (B, 1, H, hd); kc/vc: (n_blocks, T_blk, B, KV, hd); valid_len: scalar
    — the number of valid tokens ALREADY IN the cache.  If ``k_new/v_new``
    (B, 1, KV, hd) are given, the current token participates via an extra
    softmax partial (so the cache itself is read-only this step; the
    engine/pool commits the token once, outside the layer scan).
    Per-block partial softmax stats combine across the block axis — the
    only cross-shard reduction when blocks are sharded over the mesh.
    """
    nb, tb, B, KV, hd = kc.shape
    H = q.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q[:, 0].reshape(B, KV, G, hd)

    pos = (jnp.arange(nb)[:, None] * tb + jnp.arange(tb)[None, :])
    mask = pos < valid_len                                  # (nb, tb)
    s = jnp.einsum("bkgh,ntbkh->nbkgt", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                 # (nb,B,KV,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                 # (nb,B,KV,G)
    o = jnp.einsum("nbkgt,ntbkh->nbkgh", p.astype(kc.dtype), vc,
                   preferred_element_type=jnp.float32)      # (nb,B,KV,G,hd)
    # combine partials across blocks (the flash-decode reduction)
    m_glob = jnp.max(m, axis=0)                             # (B,KV,G)
    if k_new is not None:
        s_new = jnp.einsum("bkgh,bkh->bkg", qg.astype(jnp.float32),
                           k_new[:, 0].astype(jnp.float32)) * scale
        m_glob = jnp.maximum(m_glob, s_new)
    w = jnp.exp(m - m_glob[None])                           # (nb,B,KV,G)
    denom = jnp.sum(l * w, axis=0)                          # (B,KV,G)
    num = jnp.sum(o * w[..., None], axis=0)                 # (B,KV,G,hd)
    if k_new is not None:
        w_new = jnp.exp(s_new - m_glob)                     # (B,KV,G)
        denom = denom + w_new
        num = num + w_new[..., None] * v_new[:, 0].astype(
            jnp.float32)[:, :, None, :]
    out = num / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attn_decode_readonly(p, x, pos, kc, vc, *, theta, qk_norm):
    """Decode WITHOUT touching the cache: the current token's K/V is folded
    into the softmax combine and returned for a single post-scan commit.
    x: (B, 1, D); kc/vc: this layer's (nb, tb, B, KV, hd) read-only pages."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, x, positions.reshape(1), theta, qk_norm)
    o = flash_decode_jnp(q, kc, vc, pos, k_new=k, v_new=v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (k[:, 0], v[:, 0])     # (B, KV, hd) new-token page entries


def attn_train(p, x, positions, *, theta, qk_norm, q_chunk):
    q, k, v = _project_qkv(p, x, positions, theta, qk_norm)
    o = flash_attention_jnp(q, k, v, causal=True, q_chunk=q_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def attn_prefill(p, x, positions, *, theta, qk_norm, q_chunk,
                 n_blocks, max_len, use_kernel: bool = False):
    q, k, v = _project_qkv(p, x, positions, theta, qk_norm)
    if use_kernel:
        from repro.kernels import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            block_q=min(256, q.shape[1]),
                            block_kv=min(512, k.shape[1])
                            ).transpose(0, 2, 1, 3)
    else:
        o = flash_attention_jnp(q, k, v, causal=True, q_chunk=q_chunk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    kc, vc = cache_from_prefill(k, v, n_blocks, max_len)
    return out, (kc, vc)


def attn_decode_carry(p, x, pos, kc_stack, vc_stack, li, *, theta, qk_norm,
                      use_kernel: bool = False):
    """Decode against the FULL stacked cache (n_super, nb, tb, B, KV, hd),
    carried through the layer scan.  Only the new token's K/V is written
    (dynamic_update_slice at (layer, block, offset)) so the while-loop
    carry aliases in place — no per-step full-cache copy (the copy was the
    dominant memory term of the baseline decode cells; see §Perf)."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, x, positions.reshape(1), theta, qk_norm)
    t_blk = kc_stack.shape[2]
    blk = pos // t_blk
    off = pos % t_blk
    k1 = k[:, 0][None, None, None].astype(kc_stack.dtype)  # (1,1,1,B,KV,hd)
    v1 = v[:, 0][None, None, None].astype(vc_stack.dtype)
    kc_stack = jax.lax.dynamic_update_slice(kc_stack, k1, (li, blk, off, 0, 0, 0))
    vc_stack = jax.lax.dynamic_update_slice(vc_stack, v1, (li, blk, off, 0, 0, 0))
    kc = jax.lax.dynamic_index_in_dim(kc_stack, li, 0, keepdims=False)
    vc = jax.lax.dynamic_index_in_dim(vc_stack, li, 0, keepdims=False)
    if use_kernel:
        from repro.kernels import paged_attention
        o = paged_attention(q[:, 0], kc, vc, pos + 1)[:, None]
    else:
        o = flash_decode_jnp(q, kc, vc, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (kc_stack, vc_stack)


def attn_decode(p, x, pos, kc, vc, *, theta, qk_norm,
                use_kernel: bool = False):
    """x: (B, 1, D); pos: scalar current position; returns out + new cache."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, x, positions.reshape(1), theta, qk_norm)
    kc, vc = cache_append(kc, vc, k, v, pos)
    if use_kernel:
        from repro.kernels import paged_attention
        o = paged_attention(q[:, 0], kc, vc, pos + 1)[:, None]
    else:
        o = flash_decode_jnp(q, kc, vc, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (kc, vc)


# ------------------------------------------------------ whisper cross-attn --
def make_cross_attn_params(pb: ParamBuilder, d_model: int, n_heads: int,
                           n_kv: int, head_dim: int):
    return {
        "wq": pb.param((d_model, n_heads, head_dim), ("fsdp", "heads", "head_dim"),
                       fan_in=d_model),
        "wk": pb.param((d_model, n_kv, head_dim), ("fsdp", "kv_heads", "head_dim"),
                       fan_in=d_model),
        "wv": pb.param((d_model, n_kv, head_dim), ("fsdp", "kv_heads", "head_dim"),
                       fan_in=d_model),
        "wo": pb.param((n_heads, head_dim, d_model), ("heads", "head_dim", "fsdp"),
                       fan_in=n_heads * head_dim),
    }


def cross_attn_kv(p, enc_out):
    """Precompute cross-attention K/V from encoder output (per request)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def cross_attn(p, x, k, v, *, q_chunk):
    """Non-causal attention of decoder states over encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    o = flash_attention_jnp(q, k, v, causal=False, q_chunk=q_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
