"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from .model import Model, LayerSpec, superblock  # noqa: F401
from .registry import (batch_specs, build, input_specs, param_stats,  # noqa
                       pick_rules)
from .sharding import (BASELINE_RULES, DECODE_RULES, LONG_DECODE_RULES,  # noqa
                       MeshRules, ShardingResolver)
