"""Shared model components: parameter builder (values + logical axes),
RMSNorm, RoPE, embeddings, losses, dtype policy.

Parameters are plain nested dicts of arrays.  Every leaf has a parallel
*logical axes* tuple (see sharding.py) collected by ``ParamBuilder`` at
definition time, so a model is fully described by ``(params, axes)`` and any
mesh/rules pair can shard it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def dtype_of(name: str):
    return DTYPES[name]


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


class ParamBuilder:
    """Collects (shape, dtype, init, logical_axes) leaves; materializes either
    real initialized arrays or abstract ShapeDtypeStructs (dry-run)."""

    def __init__(self, key: Optional[jax.Array], abstract: bool,
                 param_dtype):
        self.key = key
        self.abstract = abstract
        self.param_dtype = param_dtype
        self.axes: Dict[str, Any] = {}

    def _split(self):
        if self.abstract:
            return None
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, shape: Sequence[int], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: float = 1.0, dtype=None,
              fan_in: Optional[int] = None):
        assert len(shape) == len(axes), (shape, axes)
        dtype = dtype or self.param_dtype
        if self.abstract:
            val = jax.ShapeDtypeStruct(tuple(shape), dtype)
        else:
            k = self._split()
            if init == "normal":
                if fan_in is None:
                    fan_in = shape[-2] if len(shape) > 1 else max(shape[0], 1)
                std = scale / math.sqrt(fan_in)
                val = (jax.random.normal(k, tuple(shape), jnp.float32) * std
                       ).astype(dtype)
            elif init == "zeros":
                val = jnp.zeros(tuple(shape), dtype)
            elif init == "ones":
                val = jnp.ones(tuple(shape), dtype)
            elif init == "embed":
                val = (jax.random.normal(k, tuple(shape), jnp.float32) * scale
                       ).astype(dtype)
            else:
                raise ValueError(init)
        return val, tuple(axes)


def split_tree(tree):
    """(value, axes) leaf tuples -> (values_tree, axes_tree)."""
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[1], tuple))
    vals = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return vals, axes


# ---------------------------------------------------------------- numerics --
def rms_norm(x, gain, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * gain.astype(jnp.float32)).astype(dt)


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, hd//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def softmax_cross_entropy(logits, labels, vocab: int):
    """logits: (B, S, Vp) fp32-reduced; labels (B, S) with -1 = masked.

    ``vocab`` is the true vocabulary size; padded logit columns are masked.
    """
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp != vocab:
        pad_mask = jnp.arange(vp) >= vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    valid = labels >= 0
    labels_c = jnp.clip(labels, 0, vocab - 1)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


# ------------------------------------------------------------- embeddings --
def make_embedding(pb: ParamBuilder, vocab_padded: int, d_model: int):
    return pb.param((vocab_padded, d_model), ("vocab", None), init="embed",
                    scale=0.02)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def lm_head(x, table, transpose: bool):
    """x: (B,S,D) -> logits (B,S,Vp); fp32 accumulation."""
    w = table.astype(jnp.bfloat16) if x.dtype == jnp.bfloat16 else table
    if transpose:
        return jnp.einsum("bsd,vd->bsv", x, w,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x, w,
                      preferred_element_type=jnp.float32)
