"""Logical-axis sharding: every parameter/activation carries *logical* axis
names; a ``MeshRules`` table maps logical axes to physical mesh axes.

This is the single knob the §Perf hillclimb turns: changing a rule (e.g.
``mlp: 'model' -> ('data','model')``) re-shards the whole model without
touching model code.  Rules resolve to ``PartitionSpec``s against whatever
mesh is active (single-pod ``(data, model)`` or multi-pod
``(pod, data, model)``); axes absent from the mesh are dropped, and logical
dims whose size does not divide the mapped mesh-axis product fall back to
replication (recorded, so the dry-run can report every fallback).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class MeshRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""
    batch: Axis = ("pod", "data")     # data parallel over pod x data
    fsdp: Axis = "data"               # weight-shard axis (ZeRO-3 style)
    tp: Axis = "model"                # tensor-parallel axis
    mlp: Axis = "model"               # FFN hidden dim (Megatron split)
    seq: Axis = None                  # sequence parallelism (long-context)
    expert: Axis = "model"            # expert parallelism
    # expert weight layout: 'gather' mode shards D over expert_din (FSDP,
    # weights all-gathered just-in-time — right for training where tokens
    # >> weights); 'split' mode shards F over expert_dff (weights stay put,
    # the down-proj partial sums psum — right for decode where tokens per
    # expert are tiny and weight gathers dominate; §Perf cell 4).
    expert_din: Axis = "data"
    expert_dff: Axis = None
    vocab: Axis = "model"
    heads: Axis = "model"
    kv_heads: Axis = "model"
    head_dim: Axis = None
    kv_seq: Axis = None               # decode KV-cache sequence sharding
    pages: Axis = "model"             # FUSEE KV-pool page axis ("memory nodes")
    replica: Axis = None

    def get(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        return getattr(self, name)

    def replace(self, **kw) -> "MeshRules":
        return dataclasses.replace(self, **kw)


# Rule presets ----------------------------------------------------------------
# paper-faithful baseline: TP over 'model', DP over 'pod','data', FSDP for
# weights over 'data' (needed to fit >=100B params), no sequence parallelism.
BASELINE_RULES = MeshRules()

# decode rules: batch over 'data'; KV-cache pages over 'model' (the FUSEE
# pool axis — pages live on "memory nodes"); weights TP-only by default
# (pick_rules adds fsdp='data' for models too big for TP-only); expert
# weights in 'split' layout (see above — ship activations, not weights).
DECODE_RULES = MeshRules(batch="data", fsdp=None, kv_seq="model",
                         expert_din=None, expert_dff="data")

# long-context decode (batch=1): pages spread over the whole mesh.
LONG_DECODE_RULES = MeshRules(batch=None, fsdp=None,
                              kv_seq=("pod", "data", "model"))

# pure data parallelism: every device holds the full model, batch shards
# over the whole mesh.  For sub-~1B models TP over 16 ways wastes more in
# collectives + indivisible-head replication than it saves (§Perf: smollm
# useful_ratio 0.038 under TP vs ~0.5 under DP); params/grads/moments fit
# per-device, and the only collective left is the gradient all-reduce.
DP_ONLY_RULES = MeshRules(batch=("pod", "data", "model"), fsdp=None,
                          tp=None, mlp=None, expert=None, vocab=None,
                          heads=None, kv_heads=None, head_dim=None,
                          kv_seq=None, pages=None)


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_product(axis: Axis, sizes: Dict[str, int]) -> Tuple[Tuple[str, ...], int]:
    if axis is None:
        return (), 1
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    present = tuple(a for a in names if a in sizes)
    prod = 1
    for a in present:
        prod *= sizes[a]
    return present, prod


class ShardingResolver:
    """Resolves (logical_axes, shape) -> PartitionSpec for a given mesh."""

    def __init__(self, mesh: Mesh, rules: MeshRules):
        self.mesh = mesh
        self.rules = rules
        self.sizes = _mesh_axis_sizes(mesh)
        self.fallbacks: list = []  # (logical_axis, dim_size, mesh_axes) records

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        parts = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            axis = self.rules.get(name)
            names, prod = _axis_product(axis, self.sizes)
            names = tuple(n for n in names if n not in used)
            prod = 1
            for n in names:
                prod *= self.sizes[n]
            if not names or prod == 1:
                parts.append(None)
                continue
            if shape is not None and shape[i] % prod != 0:
                # try prefixes of the axis tuple before giving up
                ok = None
                for j in range(len(names) - 1, 0, -1):
                    sub = names[:j]
                    p = 1
                    for n in sub:
                        p *= self.sizes[n]
                    if shape[i] % p == 0:
                        ok = sub
                        break
                if ok is None:
                    self.fallbacks.append((name, None if shape is None else shape[i], names))
                    parts.append(None)
                    continue
                names = ok
            parts.append(names if len(names) > 1 else names[0])
            used.update(names)
        return P(*parts)

    def named(self, logical_axes: Sequence[Optional[str]],
              shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def tree_specs(resolver: ShardingResolver, axes_tree, shape_tree):
    """Map a pytree of logical-axes tuples + matching ShapeDtypeStructs to
    PartitionSpecs."""
    return jax.tree.map(
        lambda ax, sh: resolver.spec(ax, sh.shape),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
