"""Unified LM assembly for all assigned architectures.

Every architecture is a *superblock* — a short, repeating pattern of layers
(e.g. jamba: 7 mamba + 1 attention, MoE on odd positions) — scanned
``n_super`` times with per-position stacked parameters.  This keeps the HLO
one-superblock-sized regardless of depth (88-layer mistral compiles as fast
as 2-layer smollm) and makes remat policy uniform.

Modes:
  train    — full causal sequence, logits for every position.
  prefill  — full sequence + returns the block-paged KV/state cache.
  decode   — one token against the cache (``serve_step``).

The decode KV cache uses the FUSEE block-pool layout (attention.py): its
leading block axis shards over the mesh like pages over memory nodes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from . import attention as A
from . import ffn as F
from . import mamba as M
from . import xlstm as X
from .common import (ParamBuilder, dtype_of, embed_lookup, lm_head,
                     pad_to_multiple, rms_norm, softmax_cross_entropy,
                     split_tree)
from .sharding import MeshRules, ShardingResolver


@dataclass(frozen=True)
class LayerSpec:
    mixer: str            # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    ffn: str              # 'dense' | 'moe' | 'moe+dense' | 'none'
    cross: bool = False   # whisper decoder cross-attention


def superblock(cfg: ArchConfig) -> Tuple[List[LayerSpec], int]:
    if cfg.family == "ssm":  # xlstm
        period = cfg.ssm.slstm_every or 1
        specs = [LayerSpec("mlstm", "none") for _ in range(period - 1)]
        specs += [LayerSpec("slstm", "none")]
        return specs, cfg.n_layers // period
    if cfg.family == "hybrid":  # jamba
        period = cfg.attn_every
        me = cfg.moe.moe_every if cfg.moe else 1
        specs = []
        for i in range(period):
            mixer = "attn" if i % cfg.attn_every == cfg.attn_phase else "mamba"
            ffn = "moe" if (cfg.moe and i % me == me - 1) else "dense"
            specs.append(LayerSpec(mixer, ffn))
        return specs, cfg.n_layers // period
    ffn = "dense"
    if cfg.moe is not None:
        ffn = "moe+dense" if cfg.moe.dense_residual_d_ff else "moe"
    cross = cfg.enc_dec
    return [LayerSpec("attn", ffn, cross=cross)], cfg.n_layers


def _make_layer_params(pb: ParamBuilder, cfg: ArchConfig, spec: LayerSpec,
                       n_super: int):
    """One superblock position; all leaves get a leading (n_super,) dim."""
    stack = _Stacker(pb, n_super)
    p: Dict[str, Any] = {"ln1": stack.param((cfg.d_model,), (None,),
                                            init="ones")}
    if spec.mixer == "attn":
        p["attn"] = _stack_tree(
            stack, lambda b: A.make_attn_params(
                b, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                cfg.qk_norm))
    elif spec.mixer == "mamba":
        s = cfg.ssm
        p["mamba"] = _stack_tree(
            stack, lambda b: M.make_mamba_params(
                b, cfg.d_model, s.d_state, s.d_conv, s.expand))
    elif spec.mixer == "mlstm":
        p["mlstm"] = _stack_tree(
            stack, lambda b: X.make_mlstm_params(b, cfg.d_model, cfg.n_heads))
    elif spec.mixer == "slstm":
        p["slstm"] = _stack_tree(
            stack, lambda b: X.make_slstm_params(b, cfg.d_model, cfg.n_heads))
    if spec.cross:
        p["ln_x"] = stack.param((cfg.d_model,), (None,), init="ones")
        p["cross"] = _stack_tree(
            stack, lambda b: A.make_cross_attn_params(
                b, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd))
    if spec.ffn != "none":
        p["ln2"] = stack.param((cfg.d_model,), (None,), init="ones")
    if spec.ffn in ("dense",):
        p["ffn"] = _stack_tree(
            stack, lambda b: F.make_dense_ffn_params(b, cfg.d_model, cfg.d_ff))
    elif spec.ffn in ("moe", "moe+dense"):
        m = cfg.moe
        p["moe"] = _stack_tree(
            stack, lambda b: F.make_moe_params(b, cfg.d_model, m.n_experts,
                                               m.d_ff_expert))
        if spec.ffn == "moe+dense":
            p["ffn"] = _stack_tree(
                stack, lambda b: F.make_dense_ffn_params(
                    b, cfg.d_model, m.dense_residual_d_ff))
    return p


class _Stacker:
    """ParamBuilder proxy that prepends a stacked (n_super,) leading dim."""

    def __init__(self, pb: ParamBuilder, n: int):
        self.pb = pb
        self.n = n

    def param(self, shape, axes, **kw):
        return self.pb.param((self.n, *shape), (None, *axes), **kw)


def _stack_tree(stack: _Stacker, fn):
    return fn(stack)


# ============================================================== the model ===
class Model:
    """A built (arch x mesh x rules) model: pure-function API over params."""

    def __init__(self, cfg: ArchConfig, mesh, rules: MeshRules,
                 use_kernels: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        # serving path only: Pallas flash/paged attention (interpret on CPU)
        self.use_kernels = use_kernels
        self.resolver = ShardingResolver(mesh, rules)
        self.specs, self.n_super = superblock(cfg)
        self.vocab_p = pad_to_multiple(cfg.vocab, 256)
        self.dtype = dtype_of(cfg.dtype)
        if cfg.moe is not None:
            self.moe_ctx = F.MoEContext(mesh, rules, cfg.moe.n_experts,
                                        cfg.moe.top_k, cfg.moe.capacity_factor)
        else:
            self.moe_ctx = None
        # filled by init(); axes of every param leaf
        self.axes: Any = None

    # ----------------------------------------------------------- building --
    def init(self, key: Optional[jax.Array] = None, abstract: bool = False):
        cfg = self.cfg
        pb = ParamBuilder(key, abstract, self.dtype)
        tree: Dict[str, Any] = {}
        # embed is sharded on vocab only: an fsdp-sharded gather dimension
        # triggers XLA's "involuntary full rematerialization" (the lookup
        # gather cannot be partitioned on the feature dim) — vocab sharding
        # alone keeps the lookup a masked-local-gather + psum.
        tree["embed"] = pb.param((self.vocab_p, cfg.d_model),
                                 ("vocab", None), init="embed", scale=0.02)
        tree["final_norm"] = pb.param((cfg.d_model,), (None,), init="ones")
        if not cfg.tie_embeddings:
            tree["lm_head"] = pb.param((cfg.d_model, self.vocab_p),
                                       ("fsdp", "vocab"), init="normal")
        tree["layers"] = [
            _make_layer_params(pb, cfg, s, self.n_super) for s in self.specs]
        if cfg.enc_dec:
            enc_spec = LayerSpec("attn", "dense")
            tree["enc"] = {
                "layers": [_make_layer_params(pb, cfg, enc_spec,
                                              cfg.n_enc_layers)],
                "final_norm": pb.param((cfg.d_model,), (None,), init="ones"),
                "pos_embed": pb.param((cfg.enc_seq, cfg.d_model),
                                      (None, None), init="embed", scale=0.02),
            }
        params, axes = split_tree(tree)
        self.axes = axes
        return params

    def param_specs(self, params_shape=None):
        """PartitionSpecs for every leaf, resolved against mesh+rules."""
        if self.axes is None:
            self.init(abstract=True)
        if params_shape is None:
            params_shape = self.abstract_params()
        return jax.tree.map(
            lambda ax, sh: self.resolver.spec(ax, sh.shape),
            self.axes, jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_shape),
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    def abstract_params(self):
        return self.init(abstract=True)

    def _c(self, x, axes):
        """Activation sharding constraint by logical axes."""
        spec = self.resolver.spec(axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------ forward --
    def _block(self, spec: LayerSpec, p, x, positions, mode,
               cache, enc_kv=None, cache_geom=None):
        """One layer.  cache: per-mixer state or (kc, vc) or None.
        cache_geom: static (n_blocks, max_len) for prefill cache layout."""
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        new_cache = cache
        if spec.mixer == "attn":
            if mode == "train":
                mix = A.attn_train(p["attn"], h, positions, theta=cfg.rope_theta,
                                   qk_norm=cfg.qk_norm, q_chunk=cfg.attn_chunk_q)
            elif mode == "prefill":
                mix, new_cache = A.attn_prefill(
                    p["attn"], h, positions, theta=cfg.rope_theta,
                    qk_norm=cfg.qk_norm, q_chunk=cfg.attn_chunk_q,
                    n_blocks=cache_geom[0], max_len=cache_geom[1],
                    use_kernel=self.use_kernels)
            elif mode == "encode":
                q, k, v = A._project_qkv(p["attn"], h, positions,
                                         cfg.rope_theta, cfg.qk_norm)
                o = A.flash_attention_jnp(q, k, v, causal=False,
                                          q_chunk=cfg.attn_chunk_q)
                mix = jnp.einsum("bshk,hkd->bsd", o,
                                 p["attn"]["wo"].astype(h.dtype))
            else:  # decode
                kc, vc = cache
                mix, new_cache = A.attn_decode(
                    p["attn"], h, positions, kc, vc, theta=cfg.rope_theta,
                    qk_norm=cfg.qk_norm, use_kernel=self.use_kernels)
        elif spec.mixer == "mamba":
            if mode in ("train", "prefill", "encode"):
                mix, st = M.mamba_chunked(p["mamba"], h, chunk=cfg.ssm.chunk,
                                          state=cache if mode == "prefill"
                                          else None)
                new_cache = st if mode == "prefill" else cache
            else:
                mix, new_cache = M.mamba_decode(p["mamba"], h, cache)
        elif spec.mixer == "mlstm":
            if mode in ("train", "prefill", "encode"):
                mix, st = X.mlstm_chunked(p["mlstm"], h, chunk=cfg.ssm.chunk,
                                          n_heads=cfg.n_heads,
                                          state=cache if mode == "prefill"
                                          else None)
                new_cache = st if mode == "prefill" else cache
            else:
                mix, new_cache = X.mlstm_decode(p["mlstm"], h,
                                                cache, n_heads=cfg.n_heads)
        elif spec.mixer == "slstm":
            if mode in ("train", "prefill", "encode"):
                mix, st = X.slstm_seq(p["slstm"], h,
                                      state=cache if mode == "prefill"
                                      else None)
                new_cache = st if mode == "prefill" else cache
            else:
                mix, new_cache = X.slstm_decode(p["slstm"], h, cache)
        else:
            raise ValueError(spec.mixer)
        x = x + mix
        if spec.cross and enc_kv is not None:
            hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
            k, v = enc_kv
            x = x + A.cross_attn(p["cross"], hx, k, v,
                                 q_chunk=cfg.attn_chunk_q)
        if spec.ffn != "none":
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            out = 0.0
            if "moe" in spec.ffn:
                out = F.moe_ffn(self.moe_ctx, p["moe"], h2)
            if "dense" in spec.ffn:
                out = out + F.dense_ffn(p["ffn"], h2)
            x = x + out
        return x, new_cache

    def _stack(self, layers_p, x, positions, mode, caches, enc_kv=None,
               cross_cache=None, specs=None, n_super=None,
               want_cache: bool = False, cache_geom=None):
        """Scan the superblock stack.  caches: list (per position) of stacked
        states (leading n_super dim) or None.  want_cache: emit (prefill) or
        thread (decode) per-layer caches through the scan."""
        specs = specs or self.specs
        n_super = n_super or self.n_super
        remat = self.cfg.remat != "none" and mode == "train"

        def body(x, xs):
            p_sl, cache_sl, xkv_sl = xs
            new_caches = []
            for i, spec in enumerate(specs):
                ekv = xkv_sl[i] if xkv_sl is not None else None
                x, nc = self._block(spec, p_sl[i], x, positions, mode,
                                    cache_sl[i] if cache_sl is not None
                                    else None,
                                    enc_kv=ekv, cache_geom=cache_geom)
                new_caches.append(nc)
            x = self._c(x, ("batch", None, None))
            return x, (new_caches if want_cache else 0)

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (layers_p, caches, cross_cache)
        x, new_caches = jax.lax.scan(body, x, xs, length=n_super)
        return x, (new_caches if want_cache else None)

    def _stack_decode(self, layers_p, x, pos, caches, cross_cache=None):
        """Decode scan with READ-ONLY caches as scan xs: attention folds the
        current token's K/V into its softmax combine, the scan emits only
        the tiny new-token page entries (ys), and the pool is committed ONCE
        post-scan with a single batched dynamic_update_slice — the
        baseline's per-step full-cache copy (scan ys threading) disappears
        and the step's cache traffic drops to one read + one token write
        (§Perf).  Recurrent states (small) stay as xs -> ys."""
        specs = self.specs

        def body(x, xs):
            p_sl, cache_sl, xkv_sl = xs
            new_entries = []
            for i, spec in enumerate(specs):
                p = p_sl[i]
                h = rms_norm(x, p["ln1"], self.cfg.norm_eps)
                if spec.mixer == "attn":
                    kc, vc = cache_sl[i]
                    mix, entry = A.attn_decode_readonly(
                        p["attn"], h, pos, kc, vc,
                        theta=self.cfg.rope_theta, qk_norm=self.cfg.qk_norm)
                else:
                    st = cache_sl[i]
                    if spec.mixer == "mamba":
                        mix, entry = M.mamba_decode(p["mamba"], h, st)
                    elif spec.mixer == "mlstm":
                        mix, entry = X.mlstm_decode(p["mlstm"], h, st,
                                                    n_heads=self.cfg.n_heads)
                    else:
                        mix, entry = X.slstm_decode(p["slstm"], h, st)
                    entry = jax.tree.map(
                        lambda s, old: s.astype(old.dtype), entry, st)
                new_entries.append(entry)
                x = x + mix
                if spec.cross and xkv_sl is not None and xkv_sl[i] is not None:
                    hx = rms_norm(x, p["ln_x"], self.cfg.norm_eps)
                    k, v = xkv_sl[i]
                    x = x + A.cross_attn(p["cross"], hx, k, v,
                                         q_chunk=self.cfg.attn_chunk_q)
                if spec.ffn != "none":
                    h2 = rms_norm(x, p["ln2"], self.cfg.norm_eps)
                    out = 0.0
                    if "moe" in spec.ffn:
                        out = F.moe_ffn(self.moe_ctx, p["moe"], h2)
                    if "dense" in spec.ffn:
                        out = out + F.dense_ffn(p["ffn"], h2)
                    x = x + out
            x = self._c(x, ("batch", None, None))
            return x, new_entries

        x, entries = jax.lax.scan(body, x, (layers_p, caches, cross_cache),
                                  length=self.n_super)
        # single post-scan commit of all layers' new-token pages
        new_caches = []
        for i, spec in enumerate(specs):
            if spec.mixer == "attn":
                kc, vc = caches[i]
                kn, vn = entries[i]            # (n_super, B, KV, hd)
                t_blk = kc.shape[2]
                blk, off = pos // t_blk, pos % t_blk
                upd = lambda c, t: jax.lax.dynamic_update_slice(
                    c, t[:, None, None].astype(c.dtype),
                    (0, blk, off, 0, 0, 0))
                new_caches.append((upd(kc, kn), upd(vc, vn)))
            else:
                new_caches.append(entries[i])  # full new state stacks
        return x, new_caches

    # --------------------------------------------------------- public API --
    def forward(self, params, tokens, frames=None):
        """tokens (B, S) -> logits (B, S, vocab_p).  Train-mode path.
        ``frames``: encoder inputs for enc-dec archs (whisper stub)."""
        return self._forward_mode(params, tokens, mode="train", frames=frames)

    def _embed(self, params, tokens):
        x = embed_lookup(params["embed"], tokens).astype(self.dtype)
        return self._c(x, ("batch", None, None))

    def _head(self, params, x):
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = lm_head(x, params["embed"], transpose=True)
        else:
            logits = lm_head(x, params["lm_head"], transpose=False)
        return self._c(logits, ("batch", None, "vocab"))

    def _forward_mode(self, params, tokens, mode, frames=None):
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = self._embed(params, tokens)
        enc_kv = None
        cross_cache = None
        if self.cfg.enc_dec:
            enc_out = self.encode(params, frames)
            # per decoder superblock position, precompute cross K/V stacks
            cross_cache = self._cross_kv(params, enc_out)
        x, _ = self._stack(params["layers"], x, positions, mode, None,
                           cross_cache=cross_cache)
        return self._head(params, x)

    def encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        enc = params["enc"]
        x = (frames + enc["pos_embed"][None, :frames.shape[1]]).astype(self.dtype)
        pos = jnp.arange(x.shape[1])
        x, _ = self._stack(enc["layers"], x, pos, "encode", None,
                           specs=[LayerSpec("attn", "dense")],
                           n_super=self.cfg.n_enc_layers)
        return rms_norm(x, enc["final_norm"], self.cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Stacked (n_super, ...) cross K/V for each decoder position."""
        out = []
        for i, spec in enumerate(self.specs):
            if not spec.cross:
                out.append(None)
                continue
            cp = params["layers"][i]["cross"]
            k = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                           cp["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                           cp["wv"].astype(enc_out.dtype))
            out.append((k, v))
        return out

    def loss(self, params, batch):
        logits = self._forward_mode(params, batch["tokens"], "train",
                                    frames=batch.get("frames"))
        return softmax_cross_entropy(logits, batch["labels"], self.cfg.vocab)

    # ----------------------------------------------------------- serving --
    def cache_blocks(self, max_len: int) -> int:
        nb = max(1, max_len // 1024)
        return nb

    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   prompt_len: Optional[int] = None):
        """Full decode-cache dict with per-position stacked layer caches
        (leading n_super), as produced by ``prefill``."""
        cfg = self.cfg
        nb = self.cache_blocks(max_len)
        t_blk = max_len // nb
        caches = []
        mk = (jax.ShapeDtypeStruct if abstract
              else lambda s, d: jnp.zeros(s, d))
        for spec in self.specs:
            if spec.mixer == "attn":
                shp = (self.n_super, nb, t_blk, batch, cfg.n_kv_heads, cfg.hd)
                caches.append((mk(shp, self.dtype), mk(shp, self.dtype)))
            elif spec.mixer == "mamba":
                d_in = cfg.ssm.expand * cfg.d_model
                nh = max(1, d_in // 128)
                Pd = d_in // nh
                caches.append(M.MambaState(
                    h=mk((self.n_super, batch, nh, Pd, cfg.ssm.d_state),
                         jnp.float32),
                    conv=mk((self.n_super, batch, cfg.ssm.d_conv - 1, d_in),
                            self.dtype)))
            elif spec.mixer == "mlstm":
                d_in = int(cfg.d_model * 2.0)
                Pd = d_in // cfg.n_heads
                caches.append(X.MLSTMState(
                    c=mk((self.n_super, batch, cfg.n_heads, Pd, Pd), jnp.float32),
                    n=mk((self.n_super, batch, cfg.n_heads, Pd), jnp.float32),
                    m=mk((self.n_super, batch, cfg.n_heads), jnp.float32)))
            elif spec.mixer == "slstm":
                z = lambda: mk((self.n_super, batch, cfg.d_model), jnp.float32)
                caches.append(X.SLSTMState(h=z(), c=z(), n=z(), m=z()))
        cross = None
        if cfg.enc_dec:
            cross = [(mk((self.n_super, batch, cfg.enc_seq, cfg.n_kv_heads,
                          cfg.hd), self.dtype),
                      mk((self.n_super, batch, cfg.enc_seq, cfg.n_kv_heads,
                          cfg.hd), self.dtype))
                     for s in self.specs]
        length = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                  else jnp.array(prompt_len or 0, jnp.int32))
        return {"layers": caches, "length": length, "cross": cross}

    def cache_specs(self, cache):
        """PartitionSpecs for the cache pytree (pages over the pool axes)."""
        def spec_of(leaf):
            if leaf.ndim == 6:   # attn kv: (L, nb, tb, B, KV, hd)
                return self.resolver.spec(
                    (None, "kv_seq", None, "batch", "kv_heads", "head_dim"),
                    leaf.shape)
            if leaf.ndim == 5 and self.cfg.enc_dec:  # cross kv (L,B,S,KV,hd)
                return self.resolver.spec(
                    (None, "batch", None, "kv_heads", "head_dim"), leaf.shape)
            if leaf.ndim == 0:
                return P()
            # recurrent states: (L, B, ...)
            ax = [None, "batch"] + [None] * (leaf.ndim - 2)
            return self.resolver.spec(tuple(ax), leaf.shape)
        return jax.tree.map(spec_of, cache)

    def prefill(self, params, tokens, frames=None, max_len: int = 0):
        """Returns (last-token logits, cache) for a prompt batch.

        ``max_len`` (>= prompt length) sizes the block-paged cache; defaults
        to the prompt length padded to the 1024-token page size.
        """
        B, S = tokens.shape
        max_len = max(max_len, pad_to_multiple(S, 1024))
        nb = self.cache_blocks(max_len)
        positions = jnp.arange(S)
        x = self._embed(params, tokens)
        cross_cache = None
        if self.cfg.enc_dec:
            cross_cache = self._cross_kv(params, self.encode(params, frames))
        # prefill caches are *produced* as scan ys (no inputs needed)
        x, new_caches = self._stack(params["layers"], x, positions, "prefill",
                                    None, cross_cache=cross_cache,
                                    want_cache=True, cache_geom=(nb, max_len))
        logits = self._head(params, x[:, -1:])
        return logits, {"layers": new_caches,
                        "length": jnp.array(S, jnp.int32),
                        "cross": cross_cache}

    def decode_step(self, params, cache, token):
        """token (B, 1) int32; cache from prefill.  One serve step."""
        x = self._embed(params, token)
        x, new_caches = self._stack_decode(params["layers"], x,
                                           cache["length"], cache["layers"],
                                           cross_cache=cache.get("cross"))
        logits = self._head(params, x)
        return logits, {"layers": new_caches, "length": cache["length"] + 1,
                        "cross": cache.get("cross")}
