"""Event-level concurrency scheduler for the FUSEE protocol simulation.

Clients are generators yielding ``Phase``s (doorbell-batched verb groups) and
``MasterCall``s.  The scheduler executes *one verb per tick*, chosen by a
schedule (hypothesis-controlled in tests, RNG-driven in benchmarks), while
preserving per-(client, MN) FIFO ordering — the RDMA QP ordering guarantee
the paper's embedded-log used-bit argument depends on (§4.5).

A client may have **many ops in flight** (the pipelined batch API of
core/api.py): each op is keyed by ``(cid, op_id)`` and owns its own
generator, but all of a client's outstanding verbs share one FIFO queue per
target MN — the queue-pair model.  A verb enters its QP queue when the
owning op's phase is issued, so verbs of different ops interleave across
MNs but never reorder on one (client, MN) pair.

Crash injection: ``crash_client`` freezes a client at an arbitrary verb
boundary (partially executed phase = partially written doorbell batch,
for *every* op in its pipeline); its in-flight ops resolve to the typed
retriable ``CRASHED`` outcome (their ``on_done`` hooks fire, so API-level
futures never leak), and further submits raise ``faults.ClientCrashed``.
``crash_mn`` makes every verb touching that MN return FAIL (crash-stop
§5.1); the scheduler detects the dead MN itself ``mn_detect_delay`` ticks
later and runs the master's Alg-3 recovery — no manual
``master.maybe_recover_mns()`` calls.  Tick hooks (``add_tick_hook``)
let a ``faults.FaultInjector`` drive declarative fault schedules.

The scheduler also keeps the raw *history* (invocation/response ticks per op)
consumed by the linearizability checker in tests, and the RTT / byte traffic
tallies consumed by the network performance model (netmodel.py).
"""
from __future__ import annotations

import hashlib
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from .client import FuseeClient
from .events import CRASHED, MasterCall, OpResult, Phase, Verb
from .faults import ClientCrashed, ProtocolViolation, SchedulerStalled
from .heap import DMPool
from .master import Master
from .rng import SimRng, as_simrng
from ..obs.registry import Registry

# TEST-ONLY: when True, the §5.2 stale-lease-epoch guard is bypassed — a
# verb posted under an expired epoch executes against the *new* placement
# instead of bouncing (the historical PR-3 stale-epoch redirection bug).
# Exists solely so regression tests can re-introduce the bug and assert
# the race detector (repro.analysis.races) flags it.  Never enable
# outside tests; fleet.py honors the same flag.
UNSAFE_EXEC_STALE_EPOCH = False


def _canon_bytes(v, out: list):
    """Flatten a delivered value (phase results / master answers) into a
    canonical byte stream: type-tagged so e.g. 0 and [0] never collide."""
    if v is None:
        out.append(b"N")
    elif isinstance(v, bool):
        out.append(b"B1" if v else b"B0")
    elif isinstance(v, (int, np.integer)):
        out.append(b"I" + int(v).to_bytes(17, "little", signed=True))
    elif isinstance(v, np.ndarray):
        out.append(b"A" + np.ascontiguousarray(v).tobytes())
    elif isinstance(v, (list, tuple)):
        out.append(b"L%d(" % len(v))
        for x in v:
            _canon_bytes(x, out)
        out.append(b")")
    elif isinstance(v, dict):
        out.append(b"D%d(" % len(v))
        for k in sorted(v, key=repr):
            out.append(repr(k).encode())
            _canon_bytes(v[k], out)
        out.append(b")")
    elif isinstance(v, str):
        out.append(b"S" + v.encode())
    else:  # rare: dataclass answers etc. — repr is deterministic here
        out.append(b"R" + repr(v).encode())


def _digest_mix(h: int, op_id: int, send_value) -> int:
    parts = [h.to_bytes(16, "little"), op_id.to_bytes(8, "little")]
    _canon_bytes(send_value, parts)
    return int.from_bytes(
        hashlib.blake2b(b"".join(parts), digest_size=16).digest(), "little")


@dataclass(frozen=True, order=True)
class Choice:
    """One enabled scheduler transition — the enumerable choice-point unit
    the model checker (repro.analysis.explore) explores.

    kind 'lane'    fire the head verb of client ``cid``'s QP lane to ``mn``
    kind 'master'  dispatch client ``cid``'s pending master call
    kind 'event'   fire the armed boundary event ``name`` (crash point,
                   MN-failure detection, migration chunk/cutover commit, ...)

    Every nondeterministic decision of a step-mode run flows through this
    type: ``Scheduler.choices()`` enumerates the enabled set in a
    deterministic order and ``Scheduler.fire()`` executes exactly one.
    ``step(cid, pick)`` remains the schedule-replay surface; it and
    ``fire`` share the same underlying transition helpers, so a run driven
    by either is bit-identical given the same transition sequence."""
    kind: str
    cid: int = -1
    mn: int = -1
    name: str = ""

    def __str__(self) -> str:
        if self.kind == "lane":
            return f"lane(cid={self.cid}, mn={self.mn})"
        if self.kind == "master":
            return f"master(cid={self.cid})"
        return f"event({self.name})"


@dataclass
class _ArmedEvent:
    """An armed boundary event: enumerable as a ``Choice`` while enabled."""
    fire: Callable[["Scheduler"], Any]
    enabled: Optional[Callable[["Scheduler"], bool]] = None
    once: bool = True


@dataclass(frozen=True)
class SimTrace:
    """A replayable schedule: the exact ``(cid, pick)`` sequence a run fed
    through ``Scheduler.step``.  Together with ``(seed, config)`` and the
    same submission sequence, ``Scheduler.run_trace`` reproduces the run
    bit-identically (fleet-mode ticks are schedule-free — deterministic
    from the seed alone — so they contribute no decisions)."""
    seed: int
    decisions: Tuple[Tuple[int, int], ...]
    ticks: int

    def __len__(self) -> int:
        return len(self.decisions)


@dataclass
class OpRecord:
    cid: int
    op_id: int
    kind: str                  # 'search' | 'insert' | 'update' | 'delete' | ...
    key: Any
    value: Optional[list]
    inv_tick: int
    resp_tick: int = -1
    result: Optional[OpResult] = None
    rtts: int = 0
    bg_rtts: int = 0
    # invoked at completion (same tick as resp_tick); used by the batch API
    # to expand multi-key ops into per-key history records and to resubmit
    # fallback ops at the exact response boundary.
    on_done: Optional[Callable[["OpRecord"], None]] = field(
        default=None, repr=False, compare=False)


@dataclass
class _Running:
    gen: Any
    record: OpRecord
    results: List[Any] = field(default_factory=list)
    pending: int = 0                       # unexecuted verbs of current phase
    master_call: Optional[MasterCall] = None
    done: bool = False
    # issue-time context of the current phase, consumed by the verb tracer
    # (repro.analysis.trace) when one is attached to the pool
    phase_no: int = 0
    phase_label: str = ""
    phase_cause: str = ""                  # typed retry/stall cause (CAUSES)
    phase_bg: bool = False


class _ClientPipe:
    """Per-client pipeline state: in-flight ops + per-MN QP FIFO queues."""

    __slots__ = ("runs", "qp", "master_q")

    def __init__(self):
        self.runs: Dict[int, _Running] = {}          # op_id -> run
        self.qp: Dict[int, Deque[Tuple[_Running, int, Verb]]] = {}
        self.master_q: Deque[_Running] = deque()

    def has_work(self) -> bool:
        return bool(self.master_q) or any(self.qp.values())


class Scheduler:
    def __init__(self, pool: DMPool, master: Master, *, seed: int = 0,
                 rng: Optional[SimRng] = None,
                 mn_detect_delay: int = 0, auto_mn_recovery: bool = True):
        self.pool = pool
        self.master = master
        # every random choice derives from one SimRng root (named
        # substreams), so a run is bit-identically replayable from
        # (seed, config); see core/rng.py
        self.simrng = as_simrng(rng, default_seed=seed)
        self.rng = self.simrng.stream("scheduler")
        self.decisions: List[Tuple[int, int]] = []   # every step(cid, pick)
        self.tick = 0
        self.pipes: Dict[int, _ClientPipe] = {}      # cid -> pipeline
        self.history: List[OpRecord] = []
        self._op_counter = itertools.count()
        self.clients: Dict[int, FuseeClient] = {}
        self.removed: set = set()                    # cids removed gracefully
        self.completed_ops = 0                       # ops that responded OK-ish
        self.crashed_ops = 0                         # ops resolved CRASHED
        self.mn_recoveries = 0
        # the cluster metrics registry (repro.obs): protocol components
        # (fleet, migrate, obs hub) register their counters here under
        # stable dotted names; always present, a Counter bump is the only
        # per-event cost.  ``obs`` is the ClusterObs hub (op latency
        # histograms, flight recorder, per-MN series) — None unless a
        # FuseeCluster attached one; every hook site is a single
        # ``is None`` test, so a detached scheduler pays nothing.
        self.metrics = Registry()
        self.obs = None
        # automatic MN failure detection: crash_mn() arms a deadline; the
        # master's Alg-3 recovery runs inside step() once it passes.
        self.auto_mn_recovery = auto_mn_recovery
        self.mn_detect_delay = mn_detect_delay
        self._mn_detect_at: Optional[int] = None
        self._tick_hooks: List[Callable[["Scheduler"], None]] = []
        # choice-point API state (model-checker mode): armed boundary
        # events, the fired-choice log, and manual_boundaries — when True
        # the armed MN-failure detection does NOT auto-fire in begin_tick
        # but surfaces as an enumerable 'mn_detect' event choice instead.
        self._events: Dict[str, _ArmedEvent] = {}
        self.choice_log: List[Choice] = []
        self.manual_boundaries = False
        # model-checker support: when True, every value delivered into an op
        # generator is folded into a per-client rolling digest.  Client-side
        # state (allocator cursors, caches, generator frames) is a pure
        # function of its delivery history, so equal digests + equal pool
        # bytes + equal queue contents imply equal continuations.
        self.track_digests = False
        self.client_digest: Dict[int, int] = {}

    # ------------------------------------------------------------- spawning
    def add_client(self, client: FuseeClient):
        self.clients[client.cid] = client
        self.removed.discard(client.cid)
        self.pipes.setdefault(client.cid, _ClientPipe())
        self.master.register(client)

    def remove_client(self, cid: int):
        """Deregister a drained client.  The cluster surface drains first;
        at this level a non-empty pipeline is a caller bug."""
        if cid not in self.clients:
            raise ClientCrashed(cid, "removed" if cid in self.removed
                                else "unknown")
        pipe = self.pipes.get(cid)
        if pipe is not None and pipe.runs:
            raise ClientCrashed(cid, f"busy ({len(pipe.runs)} ops in flight; "
                                     "drain before remove)")
        self.clients.pop(cid)
        self.pipes.pop(cid, None)
        self.removed.add(cid)
        self.master.deregister(cid)

    def add_tick_hook(self, hook: Callable[["Scheduler"], None]):
        """Invoke ``hook(self)`` at every tick (FaultInjector.poll etc.)."""
        self._tick_hooks.append(hook)

    def remove_tick_hook(self, hook: Callable[["Scheduler"], None]):
        try:
            self._tick_hooks.remove(hook)
        except ValueError:
            pass

    def next_op_id(self) -> int:
        return next(self._op_counter)

    def submit(self, cid: int, kind: str, key, value=None, *,
               gen=None) -> OpRecord:
        """Enqueue one op on client ``cid``'s pipeline.  Any number of ops
        may be in flight per client; per-(client, MN) verb order is FIFO
        across all of them.  ``gen`` overrides the client op generator
        (used by the batch API for multi-key fused ops).

        Raises the typed ``ClientCrashed`` on a crashed, removed, or
        unknown ``cid`` — the op never enters the pipeline."""
        client = self.clients.get(cid)
        if client is None:
            raise ClientCrashed(cid, "removed" if cid in self.removed
                                else "unknown")
        if client.crashed:
            raise ClientCrashed(cid)
        if gen is None:
            gen = {
                "search": lambda: client.op_search(key),
                "insert": lambda: client.op_insert(key, value),
                "update": lambda: client.op_update(key, value),
                "delete": lambda: client.op_delete(key),
                "reclaim": lambda: client.op_reclaim(),
                # ordered keydir (core/ordered.py): value = count / end key
                "scan": lambda: client.op_scan(key, value),
                "range": lambda: client.op_range(key, value),
            }[kind]()
        rec = OpRecord(cid=cid, op_id=self.next_op_id(), kind=kind,
                       key=key, value=value, inv_tick=self.tick)
        self.history.append(rec)
        run = _Running(gen=gen, record=rec)
        self.pipes.setdefault(cid, _ClientPipe()).runs[rec.op_id] = run
        obs = self.obs
        if obs is not None:
            obs.op_begin(rec, self.tick)
        self._advance(cid, run, None)  # prime to the first phase
        return rec

    # ------------------------------------------------------------ execution
    def _advance(self, cid: int, run: _Running, send_value):
        """Resume the generator until it yields the next phase or finishes."""
        pipe = self.pipes[cid]
        if self.track_digests:
            self.client_digest[cid] = _digest_mix(
                self.client_digest.get(cid, 0), run.record.op_id, send_value)
        while True:
            try:
                item = run.gen.send(send_value)
            except StopIteration as stop:
                res: OpResult = stop.value
                run.record.result = res
                run.record.resp_tick = self.tick
                run.done = True
                self.completed_ops += 1
                pipe.runs.pop(run.record.op_id, None)
                obs = self.obs
                if obs is not None:   # buffered; bulk-flushed (obs/flight)
                    obs.op_settled(run.record, self.tick)
                if run.record.on_done is not None:
                    cb, run.record.on_done = run.record.on_done, None
                    cb(run.record)   # cleared first: history retains the
                    return           # record forever, the closure must not
                return               # pin futures/backends with it
            if isinstance(item, MasterCall):
                run.master_call = item
                pipe.master_q.append(run)
                return
            if not isinstance(item, Phase):
                raise ProtocolViolation(
                    f"client {cid} op {run.record.op_id} "
                    f"({run.record.kind}) yielded {type(item).__name__!r}; "
                    "ops must yield Phase or MasterCall")
            run.results = [None] * len(item.verbs)
            run.pending = len(item.verbs)
            if item.background:
                run.record.bg_rtts += 1
            else:
                run.record.rtts += 1
            run.phase_no = run.record.rtts + run.record.bg_rtts
            run.phase_label = item.label
            run.phase_cause = item.cause
            run.phase_bg = item.background
            if not item.verbs:   # empty phase = pure wait (1 RTT beat)
                send_value = []
                continue
            for idx, verb in enumerate(item.verbs):
                verb.epoch = self.pool.epoch   # stale-epoch verbs FAIL (§5.2)
                mn = verb.target_mn(self.pool)
                pipe.qp.setdefault(mn, deque()).append((run, idx, verb))
            return

    def inflight(self, cid: int) -> int:
        pipe = self.pipes.get(cid)
        return len(pipe.runs) if pipe is not None else 0

    def eligible(self, cid: int) -> bool:
        pipe = self.pipes.get(cid)
        return pipe is not None and pipe.has_work()

    def has_work(self) -> bool:
        return any(p.has_work() for p in self.pipes.values())

    def eligible_cids(self) -> List[int]:
        return sorted(c for c, p in self.pipes.items() if p.has_work())

    def begin_tick(self):
        """Advance the clock one tick: run tick hooks (fault injection) and
        the automatic MN-failure detection.  Shared by the per-verb ``step``
        path and the fleet engine's batched tick (core/fleet.py)."""
        self.tick += 1
        tr = self.pool._tracer
        if tr is not None:
            # all pool traffic in a tick is master/recovery context unless a
            # client verb claims it below (step) or in the fleet batch path
            tr.set_master_ctx(self.tick)
        if self._tick_hooks:
            for hook in tuple(self._tick_hooks):  # hooks may self-remove
                hook(self)
        if self._mn_detect_at is not None and self.tick >= self._mn_detect_at \
                and not self.manual_boundaries:
            self._mn_detect_at = None
            if self.master.maybe_recover_mns():
                self.mn_recoveries += 1
                obs = self.obs
                if obs is not None:
                    obs.recovery("mn_recovery", self.tick)

    def step(self, cid: int, pick: int = 0) -> bool:
        """Execute one verb (or master call) of client ``cid``.

        ``pick`` chooses among the client's per-MN FIFO queues, enabling the
        schedule to explore cross-MN orderings within and across the
        doorbell batches of the client's in-flight ops.
        Returns False if the client has nothing to do.
        """
        self.decisions.append((cid, pick))
        self.begin_tick()
        pipe = self.pipes.get(cid)
        if pipe is None:
            return False
        if pipe.master_q:
            return self._fire_master(pipe, cid)
        keys = sorted(mn for mn, q in pipe.qp.items() if q)
        if not keys:
            return False
        return self._fire_lane(pipe, cid, keys[pick % len(keys)])

    # ----------------------------------------------- shared transition core
    def _fire_master(self, pipe: "_ClientPipe", cid: int) -> bool:
        run = pipe.master_q.popleft()
        call, run.master_call = run.master_call, None
        ans = self._master_dispatch(call)
        self._advance(cid, run, ans)
        return True

    def _fire_lane(self, pipe: "_ClientPipe", cid: int, mn: int) -> bool:
        run, idx, verb = pipe.qp[mn].popleft()
        if not pipe.qp[mn]:
            del pipe.qp[mn]
        tr = self.pool._tracer
        if tr is not None:
            tr.set_ctx(self.tick, cid, run.record.op_id, run.phase_no,
                       tr.intern(run.phase_label), verb.epoch,
                       tr.intern(run.phase_cause) if run.phase_cause else -1,
                       1 if run.phase_bg else 0)
        run.results[idx] = self._exec_verb(verb, cid)
        run.pending -= 1
        if run.pending == 0:
            self._advance(cid, run, run.results)
        return True

    # -------------------------------------------------- choice-point API
    def arm_event(self, name: str, fire: Callable[["Scheduler"], Any], *,
                  enabled: Optional[Callable[["Scheduler"], bool]] = None,
                  once: bool = True):
        """Arm a named boundary event (crash point, migration tick,
        recovery trigger, ...).  While armed and enabled it enumerates as
        ``Choice('event', name=...)``; firing runs ``fire(self)`` and —
        with ``once=True`` — disarms it."""
        self._events[name] = _ArmedEvent(fire=fire, enabled=enabled,
                                         once=once)

    def disarm_event(self, name: str):
        self._events.pop(name, None)

    def choices(self) -> List[Choice]:
        """The enabled transition set at the current state, deterministic
        order: per client (sorted cid) either its pending master call or
        one choice per non-empty QP lane (sorted mn); then armed events
        (sorted by name); then — under ``manual_boundaries`` — the armed
        MN-failure detection.  A client whose master call is pending
        exposes only that choice (``step`` gives master calls priority, so
        lane firings under a pending call are unreachable by schedules)."""
        out: List[Choice] = []
        for cid in sorted(self.pipes):
            pipe = self.pipes[cid]
            if pipe.master_q:
                out.append(Choice("master", cid=cid))
            else:
                out += [Choice("lane", cid=cid, mn=mn)
                        for mn in sorted(m for m, q in pipe.qp.items() if q)]
        for name in sorted(self._events):
            ev = self._events[name]
            if ev.enabled is None or ev.enabled(self):
                out.append(Choice("event", name=name))
        if self.manual_boundaries and self._mn_detect_at is not None:
            out.append(Choice("event", name="mn_detect"))
        return out

    def fire(self, ch: Choice) -> bool:
        """Execute one enabled transition (see ``choices``).  Lane and
        master firings also append a ``(cid, pick)`` decision, so a run
        that fired no events replays through ``run_trace`` unchanged.
        Returns False when the choice is not currently enabled."""
        if ch.kind == "event":
            if ch.name == "mn_detect":
                if not (self.manual_boundaries
                        and self._mn_detect_at is not None):
                    return False
                self.choice_log.append(ch)
                self.begin_tick()
                self._mn_detect_at = None
                if self.master.maybe_recover_mns():
                    self.mn_recoveries += 1
                    obs = self.obs
                    if obs is not None:
                        obs.recovery("mn_recovery", self.tick)
                return True
            ev = self._events.get(ch.name)
            if ev is None or (ev.enabled is not None
                              and not ev.enabled(self)):
                return False
            self.choice_log.append(ch)
            self.begin_tick()
            if ev.once:
                self._events.pop(ch.name, None)
            ev.fire(self)
            return True
        pipe = self.pipes.get(ch.cid)
        if pipe is None:
            return False
        if ch.kind == "master":
            if not pipe.master_q:
                return False
            self.choice_log.append(ch)
            self.decisions.append((ch.cid, 0))
            self.begin_tick()
            return self._fire_master(pipe, ch.cid)
        if ch.kind == "lane":
            if pipe.master_q:
                return False       # master call has priority (see choices)
            keys = sorted(mn for mn, q in pipe.qp.items() if q)
            if ch.mn not in keys:
                return False
            self.choice_log.append(ch)
            self.decisions.append((ch.cid, keys.index(ch.mn)))
            self.begin_tick()
            return self._fire_lane(pipe, ch.cid, ch.mn)
        raise ValueError(ch.kind)

    def _exec_verb(self, v: Verb, cid: int):
        p = self.pool
        if 0 <= v.epoch != p.epoch and not UNSAFE_EXEC_STALE_EPOCH:
            return None   # posted under an expired lease epoch: MR invalid
        if v.kind == "read":
            return p.read(v.region, v.replica, v.off, v.n)
        if v.kind == "write":
            ok = p.write(v.region, v.replica, v.off, v.words)
            return True if ok else None
        if v.kind == "cas":
            return p.cas(v.region, v.replica, v.off, v.exp, v.new)
        if v.kind == "faa":
            return p.faa(v.region, v.replica, v.off, v.delta)
        if v.kind == "alloc":
            return p.alloc_block(v.mn, cid)
        if v.kind == "free":
            return p.free_block(v.mn, v.region, v.off)
        raise ValueError(v.kind)

    def _master_dispatch(self, call: MasterCall):
        if call.kind == "fail_query":
            return self.master.fail_query(**{k: v for k, v in call.payload.items()
                                             if k in ("slot_off", "region")})
        if call.kind == "bucket_query":
            return self.master.bucket_query(
                call.payload["off"],
                region=call.payload.get("region", 0))
        if call.kind == "fail_report":
            self.master.maybe_recover_mns()
            return None
        raise ValueError(call.kind)

    # ------------------------------------------------------------- failure
    def crash_client(self, cid: int):
        """Crash-stop at the current verb boundary: every in-flight doorbell
        batch of the client's pipeline stays partially executed (exactly the
        paper's failure model).  Each in-flight op resolves to the typed
        retriable ``CRASHED`` outcome — its ``on_done`` hook fires so the
        API layer can settle futures (including fused-batch expansion)
        instead of leaking them."""
        client = self.clients.get(cid)
        if client is None:
            raise ClientCrashed(cid, "removed" if cid in self.removed
                                else "unknown")
        pipe = self.pipes.get(cid)
        client.crashed = True
        if pipe is None:
            return
        runs = list(pipe.runs.values())
        self.pipes[cid] = _ClientPipe()
        obs = self.obs
        for run in runs:
            rec = run.record
            rec.result = OpResult(CRASHED, rtts=rec.rtts,
                                  bg_rtts=rec.bg_rtts)
            rec.resp_tick = self.tick
            run.done = True
            self.crashed_ops += 1
            if obs is not None:
                obs.op_settled(rec, self.tick)
            if rec.on_done is not None:
                cb, rec.on_done = rec.on_done, None
                cb(rec)

    def crash_mn(self, mid: int):
        """Crash-stop an MN.  Detection + Alg-3 recovery run automatically
        inside the scheduler loop ``mn_detect_delay`` ticks later (the
        lease window); clients that touch the dead MN before then see FAIL
        verbs and take the Alg-4 degraded path."""
        self.pool.crash_mn(mid)
        if self.auto_mn_recovery:
            deadline = self.tick + self.mn_detect_delay
            if self._mn_detect_at is None:
                self._mn_detect_at = deadline
            else:
                self._mn_detect_at = min(self._mn_detect_at, deadline)

    # ------------------------------------------------------------- driving
    def run_round_robin(self, max_ticks: int = 1_000_000):
        """Drive all in-flight ops to completion, round-robin.

        ``pick`` rotates deterministically so every (client, MN) QP lane
        makes progress: a fixed pick=0 would starve higher lanes whenever
        some op keeps refilling a lower one (e.g. the ordered keydir's
        bounded retry loops waiting on a racing splitter's clears)."""
        ticks = 0
        while ticks < max_ticks:
            progressed = False
            for cid in self.eligible_cids():
                if self.step(cid, pick=ticks):
                    ticks += 1
                    progressed = True
            if not progressed:
                break
        if self.has_work():
            raise SchedulerStalled(
                f"ops did not converge after {ticks} round-robin ticks "
                f"(tick {self.tick}, eligible cids "
                f"{self.eligible_cids()}): possible livelock")

    def run_random(self, rng=None, max_ticks: int = 2_000_000):
        rng = rng or self.rng
        ticks = 0
        while ticks < max_ticks:
            cids = self.eligible_cids()
            if not cids:
                break
            cid = cids[int(rng.integers(len(cids)))]
            self.step(cid, pick=int(rng.integers(4)))
            ticks += 1
        if self.has_work():
            raise SchedulerStalled(
                f"ops did not converge after {ticks} random ticks "
                f"(tick {self.tick}, eligible cids "
                f"{self.eligible_cids()}): possible livelock")

    def run_schedule(self, schedule, max_extra: int = 500_000):
        """Drive with an explicit (cid, pick) schedule; fall back to
        round-robin once the schedule is exhausted (ensures completion)."""
        for (cid, pick) in schedule:
            cids = self.eligible_cids()
            if not cids:
                return
            self.step(cids[cid % len(cids)], pick=pick)
        self.run_round_robin(max_ticks=max_extra)

    # ------------------------------------------------------------- replay
    def trace(self) -> SimTrace:
        """Snapshot of every scheduling decision taken so far (the
        schedule-replay hook of the deterministic-simulation contract)."""
        return SimTrace(seed=self.simrng.seed,
                        decisions=tuple(self.decisions), ticks=self.tick)

    def run_trace(self, trace: SimTrace, *, start: int = 0):
        """Re-execute a recorded schedule verbatim: ``step(cid, pick)`` for
        every recorded decision from index ``start`` on.  Replaying against
        the same ``(seed, config)`` and submission sequence reproduces the
        original run bit-identically."""
        for (cid, pick) in trace.decisions[start:]:
            self.step(cid, pick=pick)


def run_ops_concurrently(pool: DMPool, master: Master, ops, *, seed=0,
                         schedule=None) -> List[OpRecord]:
    """Convenience: submit ``ops`` = [(client, kind, key, value)], run all."""
    sched = Scheduler(pool, master, seed=seed)
    for c in {c for (c, *_ ) in ops}:
        sched.add_client(c)
    recs = []
    for (client, kind, key, value) in ops:
        recs.append(sched.submit(client.cid, kind, key, value))
    if schedule is not None:
        sched.run_schedule(schedule)
    else:
        sched.run_random()
    return recs
