"""Event-level concurrency scheduler for the FUSEE protocol simulation.

Clients are generators yielding ``Phase``s (doorbell-batched verb groups) and
``MasterCall``s.  The scheduler executes *one verb per tick*, chosen by a
schedule (hypothesis-controlled in tests, RNG-driven in benchmarks), while
preserving per-(client, MN) FIFO ordering — the RDMA QP ordering guarantee
the paper's embedded-log used-bit argument depends on (§4.5).

Crash injection: ``crash_client`` freezes a client at an arbitrary verb
boundary (partially executed phase = partially written doorbell batch);
``crash_mn`` makes every verb touching that MN return FAIL (crash-stop §5.1).

The scheduler also keeps the raw *history* (invocation/response ticks per op)
consumed by the linearizability checker in tests, and the RTT / byte traffic
tallies consumed by the network performance model (netmodel.py).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .client import FuseeClient
from .events import MasterCall, OpResult, Phase, Verb
from .heap import DMPool
from .master import Master


@dataclass
class OpRecord:
    cid: int
    op_id: int
    kind: str                  # 'search' | 'insert' | 'update' | 'delete'
    key: int
    value: Optional[list]
    inv_tick: int
    resp_tick: int = -1
    result: Optional[OpResult] = None
    rtts: int = 0
    bg_rtts: int = 0


@dataclass
class _Running:
    gen: Any
    record: OpRecord
    # outstanding verbs of the current phase, grouped per target MN (FIFO)
    queues: Dict[int, List[Tuple[int, Verb]]] = field(default_factory=dict)
    results: List[Any] = field(default_factory=list)
    n_verbs: int = 0
    phase: Optional[Phase] = None
    master_call: Optional[MasterCall] = None
    done: bool = False


class Scheduler:
    def __init__(self, pool: DMPool, master: Master, *, seed: int = 0):
        self.pool = pool
        self.master = master
        self.rng = np.random.default_rng(seed)
        self.tick = 0
        self.running: Dict[int, _Running] = {}   # cid -> in-flight op
        self.history: List[OpRecord] = []
        self._op_counter = itertools.count()
        self.clients: Dict[int, FuseeClient] = {}

    # ------------------------------------------------------------- spawning
    def add_client(self, client: FuseeClient):
        self.clients[client.cid] = client
        self.master.register(client)

    def submit(self, cid: int, kind: str, key: int, value=None) -> OpRecord:
        assert cid not in self.running, f"client {cid} already has an op in flight"
        client = self.clients[cid]
        assert not client.crashed
        gen = {
            "search": lambda: client.op_search(key),
            "insert": lambda: client.op_insert(key, value),
            "update": lambda: client.op_update(key, value),
            "delete": lambda: client.op_delete(key),
            "reclaim": lambda: client.op_reclaim(),
        }[kind]()
        rec = OpRecord(cid=cid, op_id=next(self._op_counter), kind=kind,
                       key=key, value=value, inv_tick=self.tick)
        self.history.append(rec)
        run = _Running(gen=gen, record=rec)
        self.running[cid] = run
        self._advance(run, None)  # prime to the first phase
        return rec

    # ------------------------------------------------------------ execution
    def _advance(self, run: _Running, send_value):
        """Resume the generator until it yields the next phase or finishes."""
        try:
            item = run.gen.send(send_value)
        except StopIteration as stop:
            res: OpResult = stop.value
            run.record.result = res
            run.record.resp_tick = self.tick
            run.done = True
            self.running.pop(run.record.cid, None)
            return
        if isinstance(item, MasterCall):
            run.master_call = item
            run.phase = None
            return
        assert isinstance(item, Phase)
        run.phase = item
        run.queues = {}
        run.results = [None] * len(item.verbs)
        run.n_verbs = len(item.verbs)
        if item.background:
            run.record.bg_rtts += 1
        else:
            run.record.rtts += 1
        if not item.verbs:   # empty phase = pure wait (1 RTT beat)
            self._advance(run, [])
            return
        for idx, verb in enumerate(item.verbs):
            mn = verb.target_mn(self.pool)
            run.queues.setdefault(mn, []).append((idx, verb))

    def eligible(self, cid: int) -> bool:
        run = self.running.get(cid)
        return run is not None and not run.done

    def step(self, cid: int, pick: int = 0) -> bool:
        """Execute one verb (or master call) of client ``cid``.

        ``pick`` chooses among the client's per-MN FIFO queues, enabling the
        schedule to explore cross-MN orderings within a doorbell batch.
        Returns False if the client has nothing to do.
        """
        self.tick += 1
        run = self.running.get(cid)
        if run is None:
            return False
        if run.master_call is not None:
            call = run.master_call
            run.master_call = None
            ans = self._master_dispatch(call)
            self._advance(run, ans)
            return True
        if run.phase is None:
            return False
        keys = sorted(run.queues.keys())
        if not keys:
            return False
        mn = keys[pick % len(keys)]
        idx, verb = run.queues[mn].pop(0)
        if not run.queues[mn]:
            del run.queues[mn]
        run.results[idx] = self._exec_verb(verb, cid)
        run.n_verbs -= 1
        if run.n_verbs == 0:
            self._advance(run, run.results)
        return True

    def _exec_verb(self, v: Verb, cid: int):
        p = self.pool
        if v.kind == "read":
            return p.read(v.region, v.replica, v.off, v.n)
        if v.kind == "write":
            ok = p.write(v.region, v.replica, v.off, v.words)
            return True if ok else None
        if v.kind == "cas":
            return p.cas(v.region, v.replica, v.off, v.exp, v.new)
        if v.kind == "faa":
            return p.faa(v.region, v.replica, v.off, v.delta)
        if v.kind == "alloc":
            return p.alloc_block(v.mn, cid)
        if v.kind == "free":
            return p.free_block(v.mn, v.region, v.off)
        raise ValueError(v.kind)

    def _master_dispatch(self, call: MasterCall):
        if call.kind == "fail_query":
            return self.master.fail_query(**{k: v for k, v in call.payload.items()
                                             if k == "slot_off"})
        if call.kind == "bucket_query":
            return self.master.bucket_query(call.payload["off"])
        if call.kind == "fail_report":
            self.master.maybe_recover_mns()
            return None
        raise ValueError(call.kind)

    # ------------------------------------------------------------- failure
    def crash_client(self, cid: int):
        """Crash-stop at the current verb boundary: in-flight doorbell batch
        stays partially executed (exactly the paper's failure model)."""
        self.running.pop(cid, None)
        self.clients[cid].crashed = True

    def crash_mn(self, mid: int):
        self.pool.crash_mn(mid)

    # ------------------------------------------------------------- driving
    def run_round_robin(self, max_ticks: int = 1_000_000):
        """Drive all in-flight ops to completion, round-robin."""
        ticks = 0
        while self.running and ticks < max_ticks:
            for cid in list(self.running.keys()):
                if self.step(cid):
                    ticks += 1
        assert not self.running, "ops did not converge (possible livelock)"

    def run_random(self, rng=None, max_ticks: int = 2_000_000):
        rng = rng or self.rng
        ticks = 0
        while self.running and ticks < max_ticks:
            cids = list(self.running.keys())
            cid = cids[int(rng.integers(len(cids)))]
            self.step(cid, pick=int(rng.integers(4)))
            ticks += 1
        assert not self.running, "ops did not converge (possible livelock)"

    def run_schedule(self, schedule, max_extra: int = 500_000):
        """Drive with an explicit (cid, pick) schedule; fall back to
        round-robin once the schedule is exhausted (ensures completion)."""
        for (cid, pick) in schedule:
            if not self.running:
                return
            cids = sorted(self.running.keys())
            self.step(cids[cid % len(cids)], pick=pick)
        self.run_round_robin(max_ticks=max_extra)


def run_ops_concurrently(pool: DMPool, master: Master, ops, *, seed=0,
                         schedule=None) -> List[OpRecord]:
    """Convenience: submit ``ops`` = [(client, kind, key, value)], run all."""
    sched = Scheduler(pool, master, seed=seed)
    for c in {c for (c, *_ ) in ops}:
        sched.add_client(c)
    recs = []
    for (client, kind, key, value) in ops:
        recs.append(sched.submit(client.cid, kind, key, value))
    if schedule is not None:
        sched.run_schedule(schedule)
    else:
        sched.run_random()
    return recs
