"""Fleet mode: vectorized thousand-client ticks for the FUSEE simulator.

The step scheduler (sim.py) executes **one verb per tick** — perfect for
schedule-exploring correctness tests, hopeless for the paper's headline
claim that client-centric metadata management *scales with the number of
clients* (Fig. 13 tops out at 4.5x over Clover at 128 clients, and the
ROADMAP north star wants orders of magnitude more).  ``FleetEngine``
reworks the hot path: one tick advances **every** client's in-flight
op-phases at once,

* popping the head verb of every ``(client, MN)`` QP lane (the RDMA
  queue-pair FIFO — verbs of one lane never reorder, verbs of different
  lanes are concurrent, exactly the §4.5 used-bit ordering argument);
* executing the tick's verbs as *batched array operations* grouped by
  verb kind — one gather/scatter/CAS sweep per (region, replica[, len])
  group on the pool (heap.DMPool.read_batch & co.) instead of one Python
  pool call per verb;
* serving **every client's cache-resident GET probe with one batched
  ``race_lookup`` invocation** (``probe_wave``): all clients' keys are
  salted per-cid, folded into one shared shadow index, and probed in a
  single kernel call (Pallas on TPU, its bit-exact numpy mirror
  elsewhere) — one invocation per tick, not one per client.

Determinism: a fleet tick makes no random choices — gathering walks
clients and lanes in sorted order, batched verbs serialize same-word
conflicts in that same order — so a fleet run is bit-identically
replayable from ``(seed, config)`` alone (the seed feeds workload
generation and fault plans through core/rng.SimRng; the engine itself is
schedule-free).  ``sim.Scheduler.trace()`` therefore records nothing for
fleet ticks; it captures only step-mode decisions.
"""
from __future__ import annotations

import time
from itertools import chain
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import codec
from . import ordered
from .api import KVFuture, Op, SimBackend, _fold32
from .faults import SchedulerStalled
from .shadow import build_shadow, hash32_np, race_lookup_np
from . import sim as sim_module
from .sim import Scheduler
from ..obs.registry import LegacyCounters, legacy_counters_view

__all__ = ["FleetEngine"]

_VERB_ORDER = ("read", "write", "cas", "faa", "alloc", "free")


def _cid_salt(cid: int) -> int:
    """Per-client 32-bit salt so one shared shadow index can hold every
    client's (private) cache entries without cross-client key collisions
    becoming hits: probe keys are ``fold32(key) ^ salt(cid)``; a residual
    fp/fold collision is rejected by the exact (cid, key) guard."""
    return int(hash32_np(np.array([cid], np.uint32), 5)[0])


class FleetEngine:
    """Batched tick driver over a ``sim.Scheduler``.  See module docstring.

    One engine per scheduler; mixing ``tick()`` with per-verb ``step()``
    driving is legal (both are valid schedules of the same machine) —
    benchmarks use pure fleet ticks, correctness tests mix freely.
    """

    def __init__(self, scheduler: Scheduler, *, use_kernel: bool = True,
                 fused: bool = True):
        self.sched = scheduler
        self.use_kernel = use_kernel
        # fused=True executes each tick's four array-verb sweeps as ONE
        # pool dispatch over the flat region slab
        # (heap.DMPool.exec_fused_tick); per tick it falls back to the
        # per-kind *_batch oracle whenever semantics demand it (live
        # migration dual-writes, an attached + recording tracer).  Both
        # paths are bit-identical — tests/test_fleet_fused.py is the
        # differential replay oracle.
        self.fused = fused
        # fleet counters live in the scheduler's metrics registry under
        # "fleet.<name>" dotted names; the old ``counters`` dict survives
        # one release as a read-only deprecation alias (see obs/registry).
        reg = scheduler.metrics
        names = ("ticks", "verbs", "array_calls", "master_calls",
                 "index_probe_verbs", "probe_invocations", "probe_keys",
                 "probe_hits", "shadow_rebuilds", "ord_leaf_verbs",
                 "scan_locate_invocations", "scan_locate_keys",
                 "fused_ticks", "fallback_ticks")
        self._handles: Dict[str, Any] = {
            k: reg.counter("fleet." + k) for k in names}
        self._handles["max_lanes"] = reg.gauge("fleet.max_lanes")
        for _k in _VERB_ORDER:
            self._handles["verbs_" + _k] = reg.counter("fleet.verbs_" + _k)
        # hot-loop handle caches: bump .value directly, no dict lookups
        self._c_ticks = self._handles["ticks"]
        self._c_verbs = self._handles["verbs"]
        self._c_master = self._handles["master_calls"]
        self._g_max_lanes = self._handles["max_lanes"]
        self._c_array = self._handles["array_calls"]
        self._c_idx_probe = self._handles["index_probe_verbs"]
        self._c_ord_leaf = self._handles["ord_leaf_verbs"]
        self._c_fused = self._handles["fused_ticks"]
        self._c_fallback = self._handles["fallback_ticks"]
        self._c_verbs_kind = {k: self._handles["verbs_" + k]
                              for k in _VERB_ORDER}
        # memoized combined shadow: (per-backend fingerprints, entries, table)
        self._probe_memo = (None, None, None)
        # wall-clock per-tick phase accumulators (seconds): coord-build /
        # sweep / scatter / bookkeeping.  Host- and path-dependent by
        # nature, so they live on the engine, NOT in the metrics registry —
        # same-seed registry snapshots stay byte-identical.  Folded into
        # the fused-tick phase breakdown by obs/profile.py.
        self._tp = [0.0, 0.0, 0.0, 0.0]
        self._tp_ticks = 0
        self._fused_tp = (0.0, 0.0)

    @property
    def counters(self) -> LegacyCounters:
        """Deprecated read-only view of the fleet metrics under their
        historical key names; read ``stats()`` or the registry instead."""
        return legacy_counters_view("FleetEngine", self._handles)

    # ------------------------------------------------------------- ticking
    def tick(self) -> int:
        """One fleet tick: scheduler tick preamble (fault hooks, MN-failure
        detection), then the head verb of EVERY (client, MN) lane plus one
        queued master call per client, executed as batched array ops.
        Returns the number of verbs + master calls executed."""
        sched = self.sched
        sched.begin_tick()
        _pc = time.perf_counter
        t_coord0 = _pc()
        by_kind: Dict[str, List[Tuple[int, Any, int, Any]]] = {}
        master_runs: List[Tuple[int, Any]] = []
        lanes = 0
        for cid in sorted(sched.pipes):
            pipe = sched.pipes[cid]
            if pipe.master_q:
                master_runs.append((cid, pipe.master_q.popleft()))
            for mn in sorted(pipe.qp):
                q = pipe.qp[mn]
                run, idx, verb = q.popleft()
                if not q:
                    del pipe.qp[mn]
                by_kind.setdefault(verb.kind, []).append((cid, run, idx, verb))
                lanes += 1
        executed = lanes + len(master_runs)
        self._c_ticks.value += 1
        self._c_verbs.value += lanes
        self._c_master.value += len(master_runs)
        self._g_max_lanes.set_max(lanes)

        finished: List[Tuple[int, Any]] = []
        epoch = sched.pool.epoch
        pool = sched.pool
        tr = pool._tracer
        # the fused sweep bypasses the *_batch entry points (which a
        # recording tracer instruments via instance-attribute wrappers)
        # and cannot mirror migration dual-writes — those ticks fall back
        # to the per-kind oracle path rather than silently dropping verbs
        use_fused = (self.fused and not pool.migrations
                     and (tr is None or tr.paused))
        live_by_kind: Dict[str, list] = {}
        for kind, items in by_kind.items():
            self._c_verbs_kind[kind].value += len(items)
            # stale-epoch verbs FAIL without touching the pool (§5.2 —
            # mirrors sim._exec_verb's guard; same test-only bypass flag)
            if sim_module.UNSAFE_EXEC_STALE_EPOCH:
                live_by_kind[kind] = items
            else:
                live_by_kind[kind] = [it for it in items
                                      if not (0 <= it[3].epoch != epoch)]
        coord = _pc() - t_coord0
        sweep = scatter = 0.0
        fused_res: Dict[str, list] = {}
        if use_fused and any(live_by_kind.get(k)
                             for k in ("read", "write", "cas", "faa")):
            fused_res = self._exec_fused(live_by_kind)
            d_coord, d_sweep = self._fused_tp
            coord += d_coord
            sweep += d_sweep
            self._c_fused.value += 1
        elif lanes and self.fused:
            self._c_fallback.value += 1
        for kind in _VERB_ORDER:
            items = by_kind.get(kind)
            if not items:
                continue
            live = live_by_kind[kind]
            if kind in fused_res:
                results = fused_res[kind]
            else:
                t0 = _pc()
                results = self._exec_kind(kind, live) if live else []
                sweep += _pc() - t0
            t0 = _pc()
            res_by_id = {id(it): r for it, r in zip(live, results)}
            for it in items:
                cid, run, idx, _verb = it
                run.results[idx] = res_by_id.get(id(it))
                run.pending -= 1
                if run.pending == 0:
                    finished.append((cid, run))
            scatter += _pc() - t0
        # resume generators only after every verb of the tick executed, in
        # deterministic (gather) order: master answers first (step() gives
        # master_q priority), then completed phases
        t0 = _pc()
        for cid, run in master_runs:
            call, run.master_call = run.master_call, None
            sched._advance(cid, run, sched._master_dispatch(call))
        for cid, run in finished:
            sched._advance(cid, run, run.results)
        obs = sched.obs
        if obs is not None:
            obs.on_fleet_tick(self, by_kind)
        tp = self._tp
        tp[0] += coord
        tp[1] += sweep
        tp[2] += scatter
        tp[3] += _pc() - t0
        self._tp_ticks += 1
        return executed

    def tick_phase_profile(self) -> Dict[str, float]:
        """Cumulative wall-clock breakdown of ``tick()``: coord-build
        (lane gather + stale-epoch filter + fused coordinate arrays),
        sweep (the pool array dispatch — ``exec_fused_tick`` or the
        per-kind ``*_batch`` oracle), scatter (result distribution back
        onto the runs), bookkeeping (generator resumes + obs sampling).
        Wall-clock and host-dependent — reported here, never through the
        metrics registry (same-seed snapshots stay byte-identical).  This
        is what makes ``roofline.py``'s ms/tick numbers explainable."""
        names = ("coord_build", "sweep", "scatter", "bookkeeping")
        total = sum(self._tp)
        out: Dict[str, float] = {n: self._tp[i]
                                 for i, n in enumerate(names)}
        for i, n in enumerate(names):
            out[n + "_frac"] = self._tp[i] / total if total > 0 else 0.0
        out["total_s"] = total
        out["ticks"] = float(self._tp_ticks)
        out["us_per_tick"] = (1e6 * total / self._tp_ticks
                              if self._tp_ticks else 0.0)
        return out

    def _exec_kind(self, kind: str, items) -> list:  # lint: allow-epoch (tick() drops stale-epoch verbs before dispatch)
        pool = self.sched.pool
        verbs = [v for (_c, _r, _i, v) in items]
        tr = pool._tracer
        if tr is not None and not tr.paused \
                and kind in ("read", "write", "cas", "faa"):
            # per-verb issue context for the tracer: one batch, one call
            tr.set_batch_ctx(
                self.sched.tick,
                [c for (c, _r, _i, _v) in items],
                [r.record.op_id for (_c, r, _i, _v) in items],
                [r.phase_no for (_c, r, _i, _v) in items],
                [tr.intern(r.phase_label) for (_c, r, _i, _v) in items],
                [v.epoch for v in verbs],
                [tr.intern(r.phase_cause) if r.phase_cause else -1
                 for (_c, r, _i, _v) in items],
                [1 if r.phase_bg else 0 for (_c, r, _i, _v) in items])
        if kind == "read":
            self._c_array.value += 1
            shard_set = pool.index_region_set
            self._c_idx_probe.value += sum(
                v.region in shard_set for v in verbs)
            # ordered-keydir leaf sweeps of EVERY in-flight scan coalesce
            # into this same one-gather-per-tick read sweep
            self._c_ord_leaf.value += sum(
                v.region in pool.ordered_region_set for v in verbs)
            return pool.read_batch([v.region for v in verbs],
                                   [v.replica for v in verbs],
                                   [v.off for v in verbs],
                                   [v.n for v in verbs])
        if kind == "write":
            self._c_array.value += 1
            oks = pool.write_batch([v.region for v in verbs],
                                   [v.replica for v in verbs],
                                   [v.off for v in verbs],
                                   [v.words for v in verbs])
            return [True if ok else None for ok in oks]
        if kind == "cas":
            self._c_array.value += 1
            return pool.cas_batch([v.region for v in verbs],
                                  [v.replica for v in verbs],
                                  [v.off for v in verbs],
                                  [v.exp for v in verbs],
                                  [v.new for v in verbs])
        if kind == "faa":
            self._c_array.value += 1
            return pool.faa_batch([v.region for v in verbs],
                                  [v.replica for v in verbs],
                                  [v.off for v in verbs],
                                  [v.delta for v in verbs])
        if kind == "alloc":
            return [pool.alloc_block(v.mn, cid)
                    for (cid, _r, _i, v) in items]
        if kind == "free":
            return [pool.free_block(v.mn, v.region, v.off) for v in verbs]
        raise ValueError(kind)

    def _exec_fused(self, live_by_kind) -> Dict[str, list]:
        """ONE pool dispatch for the tick's four array-verb sweeps
        (``heap.DMPool.exec_fused_tick`` over the flat region slab).
        Returns ``{kind: results}`` aligned with ``live_by_kind[kind]`` —
        element-wise identical to four ``_exec_kind`` calls.  ALLOC/FREE
        are MN-CPU RPCs, not array verbs; they stay on the per-item path.
        """
        pool = self.sched.pool
        t_build0 = time.perf_counter()

        def _i64(vals, k):
            # verb coords go straight to int64 arrays (asarray in the pool
            # sweeps is then a no-op) — the per-kind oracle builds lists
            return np.fromiter(vals, np.int64, count=k)

        def _u64(verbs_, attr, k):
            # word values as uint64 arrays; out-of-range values fall back
            # to the plain list (the pool sweeps mask them per element)
            try:
                return np.fromiter((getattr(v, attr) for v in verbs_),
                                   np.uint64, count=k)
            except (OverflowError, TypeError, ValueError):
                return [getattr(v, attr) for v in verbs_]

        reads = writes = cass = faas = None
        r_items = live_by_kind.get("read")
        if r_items:
            verbs = [v for (_c, _r, _i, v) in r_items]
            shard_set = pool.index_region_set
            self._c_idx_probe.value += sum(
                v.region in shard_set for v in verbs)
            self._c_ord_leaf.value += sum(
                v.region in pool.ordered_region_set for v in verbs)
            k = len(verbs)
            reads = (_i64((v.region for v in verbs), k),
                     _i64((v.replica for v in verbs), k),
                     _i64((v.off for v in verbs), k),
                     _i64((v.n for v in verbs), k))
        w_items = live_by_kind.get("write")
        if w_items:
            verbs = [v for (_c, _r, _i, v) in w_items]
            k = len(verbs)
            words = [v.words for v in verbs]
            ns = _i64(map(len, words), k)
            try:
                # flatten all word values in one C pass while the verb
                # list is hot; the sweep scatters this directly and only
                # falls back to per-list flattening when absent
                vals = np.fromiter(chain.from_iterable(words), np.uint64,
                                   count=int(ns.sum()))
            except (OverflowError, TypeError, ValueError):
                vals = None        # out-of-range word: sweep masks per list
            writes = (_i64((v.region for v in verbs), k),
                      _i64((v.replica for v in verbs), k),
                      _i64((v.off for v in verbs), k),
                      words, ns, vals)
        c_items = live_by_kind.get("cas")
        if c_items:
            verbs = [v for (_c, _r, _i, v) in c_items]
            k = len(verbs)
            cass = (_i64((v.region for v in verbs), k),
                    _i64((v.replica for v in verbs), k),
                    _i64((v.off for v in verbs), k),
                    _u64(verbs, "exp", k), _u64(verbs, "new", k))
        f_items = live_by_kind.get("faa")
        if f_items:
            verbs = [v for (_c, _r, _i, v) in f_items]
            k = len(verbs)
            faas = (_i64((v.region for v in verbs), k),
                    _i64((v.replica for v in verbs), k),
                    _i64((v.off for v in verbs), k),
                    _u64(verbs, "delta", k))
        self._c_array.value += 1
        t_exec0 = time.perf_counter()
        r, w, c, f = pool.exec_fused_tick(reads, writes, cass, faas)
        out = {"read": r, "write": [True if ok else None for ok in w],
               "cas": c, "faa": f}
        t_end = time.perf_counter()
        self._fused_tp = (t_exec0 - t_build0, t_end - t_exec0)
        return out

    # ------------------------------------------------------------- driving
    def run(self, max_ticks: int = 1_000_000) -> int:
        """Drive every in-flight op of every client to completion with
        batched ticks; returns ticks spent."""
        sched = self.sched
        ticks = 0
        while sched.has_work():
            if ticks >= max_ticks or self.tick() == 0:
                raise SchedulerStalled(
                    f"fleet run did not converge after {ticks} ticks "
                    f"(possible livelock)")
            ticks += 1
        return ticks

    # ------------------------------------- cluster-wide batched GET probe
    def probe_wave(self, wants: Sequence[Tuple[SimBackend, Sequence[int]]]
                   ) -> List[list]:
        """ONE batched ``race_lookup`` invocation across every client
        probing the index this tick.

        ``wants`` is ``[(backend, [key64, ...]), ...]``.  Every backend's
        eligible cache entries are folded (salted per cid) into one shared
        shadow index; all keys are probed in a single kernel call.
        Returns, per backend, a CacheEntry-or-None list aligned with its
        keys — exactly what ``SimBackend.submit_many(probed=...)`` takes.
        """
        # (re)build the combined shadow only when some probing client's
        # cache moved since the last wave (same dirty signal as the
        # per-backend memo in SimBackend._kernel_probe)
        fprint = tuple(sorted((be.cid, be._cache_fingerprint())
                              for be, _k in wants))
        if self._probe_memo[0] == fprint:
            _, entries_all, shadow = self._probe_memo
        else:
            entries_all = []                   # (cid, key64, entry)
            keys32: List[int] = []
            cap = (1 << 24) - 2                # shadow ptr field is 24 bits
            for be, _keys in wants:
                salt = _cid_salt(be.cid)
                for k, ce in be._cache_entries():
                    if len(entries_all) >= cap:
                        break
                    entries_all.append((be.cid, k, ce))
                    keys32.append(_fold32(k) ^ salt)
            shadow = build_shadow(np.array(keys32, np.uint32))
            self._probe_memo = (fprint, entries_all, shadow)
            self._handles["shadow_rebuilds"].value += 1
        q: List[int] = []
        spans: List[Tuple[int, int]] = []
        for be, keys64 in wants:
            salt = _cid_salt(be.cid)
            spans.append((len(q), len(keys64)))
            q.extend(_fold32(k) ^ salt for k in keys64)
        self._handles["probe_invocations"].value += 1
        self._handles["probe_keys"].value += len(q)
        obs = self.sched.obs
        if obs is not None and q:
            # heat sketch: UNsalted fold32 keys hashed into the RACE
            # first-choice bucket family — one vectorized update per wave
            qa = np.asarray(q, np.uint32)
            salts = np.empty(len(q), np.uint32)
            for (be, _k), (s, m) in zip(wants, spans):
                salts[s:s + m] = np.uint32(_cid_salt(be.cid))
            unsalted = qa ^ salts
            obs.heat_keys(hash32_np(unsalted, 1), keys32=unsalted)
        if not entries_all or not q:
            return [[None] * n for (_s, n) in spans]
        ptr, found = self._race_lookup(np.array(q, np.uint32), shadow)
        c_hits = self._handles["probe_hits"]
        out: List[list] = []
        for (be, keys64), (start, n) in zip(wants, spans):
            hits = []
            for j, key64 in enumerate(keys64):
                ce = None
                p = int(ptr[start + j])
                if found[start + j] and p > 0:
                    ecid, ekey, entry = entries_all[p - 1]
                    # exact guard: the shadow hit must be THIS client's key
                    if ecid == be.cid and ekey == key64:
                        ce = entry
                hits.append(ce)
                if ce is not None:
                    c_hits.value += 1
            out.append(hits)
        return out

    def _race_lookup(self, q: np.ndarray, shadow: np.ndarray):
        if self.use_kernel:
            try:
                from repro.kernels import race_lookup_batch
                return race_lookup_batch(q, shadow)
            except Exception:       # pragma: no cover - jax-less fallback
                pass
        return race_lookup_np(q, shadow)

    def locate_wave(self, wave: Sequence[Tuple[SimBackend, Sequence[Op]]]
                    ) -> Dict[int, List[int]]:
        """ONE vectorized ``leaf_probe`` invocation locating the covering
        leaf of every SCAN/RANGE start key across every client in the
        wave (the scan twin of ``probe_wave``).  Clients' fence caches
        are unioned — leaf ids are global facts, and a stale hint is
        merely re-validated by the scan's own leaf read.  Returns
        ``{wave_row: [leaf_id hints aligned with the row's scans]}``."""
        fences: Dict[int, int] = {}
        spans: List[Tuple[int, int, int]] = []   # (row, start_pos, n)
        starts: List[int] = []
        for row, (be, ops) in enumerate(wave):
            row_starts = [codec.encode_key(op.key) for op in ops
                          if op.kind in ("scan", "range")]
            if not row_starts:
                continue
            fences.update(be.client.ord_fences)
            spans.append((row, len(starts), len(row_starts)))
            starts.extend(row_starts)
        if not starts or not fences:
            return {row: [-1] * n for (row, _s, n) in spans}
        by_low = sorted((low, lid) for lid, low in fences.items())
        lows = np.array([low for (low, _lid) in by_low], np.uint64)
        idx = ordered._leaf_probe(np.array(starts, np.uint64), lows)
        self._handles["scan_locate_invocations"].value += 1
        self._handles["scan_locate_keys"].value += len(starts)
        hints = [by_low[int(i)][1] if i >= 0 else by_low[0][1]
                 for i in idx]
        return {row: hints[s:s + n] for (row, s, n) in spans}

    def submit_wave(self, wave: Sequence[Tuple[SimBackend, Sequence[Op]]]
                    ) -> List[List[KVFuture]]:
        """Submit one op batch per backend with all cache-resident GET
        probes served by a single cluster-wide kernel invocation (instead
        of one probe per client, which is what per-backend
        ``submit_batch`` would do), and all SCAN/RANGE start keys located
        by a single ``leaf_probe`` invocation (``locate_wave``).
        Backends should be constructed with ``max_inflight=0``
        (unlimited) — fleet mode paces admission by waves, not by
        per-client backpressure pumps."""
        wants = []
        rows = []                      # per wave row: index into wants or -1
        for be, ops in wave:
            keys64 = [codec.encode_key(op.key) for op in ops
                      if op.kind == "search"]
            if (len(keys64) >= be.batch_search_min and be.client.enable_cache
                    and not be.client.crashed):
                rows.append(len(wants))
                wants.append((be, keys64))
            else:
                rows.append(-1)
        probes = self.probe_wave(wants) if wants else []
        located = self.locate_wave(wave) \
            if any(op.kind in ("scan", "range")
                   for _be, ops in wave for op in ops) else {}
        return [be.submit_many(list(ops),
                               probed=probes[row] if row >= 0 else None,
                               located=located.get(r))
                for r, ((be, ops), row) in enumerate(zip(wave, rows))]

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        c = {k: h.value for k, h in self._handles.items()}
        c["verbs_per_tick"] = c["verbs"] / max(c["ticks"], 1)
        c["array_calls_per_tick"] = c["array_calls"] / max(c["ticks"], 1)
        return c
