"""Key/value codec for the public store API.

The FUSEE protocol machinery (client.py / sim.py) works on 64-bit integer
keys and word-list values — the granularity at which RDMA verbs, RACE
fingerprints, and the embedded log operate.  This module is the boundary
between user-facing **bytes/str keys and variable-length byte values** and
that word-level substrate:

* keys: arbitrary ``bytes``/``str`` are hashed to a 64-bit key with a
  SplitMix64-based byte hash (the same avalanche core as
  ``layout.hash64``, which then derives RACE bucket pair + fingerprint).
  Integer keys pass through unchanged so protocol-level tests and
  benchmarks can still address slots deterministically.
* values: ``bytes``/``str`` are packed into 8-byte little-endian words
  behind a tagged header word carrying the byte length, so decode can
  recover the exact byte string (including lengths not divisible by 8).
  Plain word lists (``list[int]``) pass through untagged — the legacy
  representation used by the protocol benchmarks.

The header tag occupies the top 16 bits of word 0; a value that round-trips
through ``encode_value`` always starts with it, and ``decode_value`` falls
back to returning the raw word list when the tag is absent.
"""
from __future__ import annotations

from typing import List, Optional, Union

from . import layout as L

Key = Union[bytes, str, int]
Value = Union[bytes, str, List[int]]

_MASK64 = (1 << 64) - 1
VALUE_TAG = 0xB5EE            # 16-bit magic in the header word's top bits
_TAG_SHIFT = 48
_LEN_MASK = (1 << 40) - 1     # byte length field (plenty for slab objects)


class CodecError(TypeError, ValueError):
    """Typed error for anything the codec boundary rejects: non-key types,
    ambiguous raw word lists that masquerade as tagged byte payloads, and
    (in strict decode) malformed tags.  Subclasses both TypeError and
    ValueError so legacy ``except`` clauses keep working."""


def encode_key(key: Key) -> int:
    """Map a user key to the 64-bit protocol key space."""
    if isinstance(key, int):
        return key & _MASK64
    if isinstance(key, str):
        key = key.encode("utf-8")
    if not isinstance(key, (bytes, bytearray)):
        raise CodecError(f"key must be bytes/str/int, got {type(key)!r}")
    # SplitMix64 absorption over 8-byte chunks; avalanche via layout.hash64.
    h = 0x9E3779B97F4A7C15 ^ (len(key) << 1)
    for i in range(0, len(key), 8):
        chunk = int.from_bytes(bytes(key[i:i + 8]), "little")
        h = L.hash64((h ^ chunk) & _MASK64, seed=11)
    return h & _MASK64


def encode_value(value: Optional[Value]) -> List[int]:
    """Pack a user value into protocol words (tagged for byte payloads)."""
    if value is None:
        return []
    if isinstance(value, str):
        value = value.encode("utf-8")
    if isinstance(value, (bytes, bytearray)):
        b = bytes(value)
        header = (VALUE_TAG << _TAG_SHIFT) | (len(b) & _LEN_MASK)
        words = [header]
        for i in range(0, len(b), 8):
            words.append(int.from_bytes(b[i:i + 8], "little"))
        return words
    # raw word list (legacy / protocol-level callers)
    words = [int(v) & _MASK64 for v in value]
    if _looks_tagged(words):
        raise CodecError(
            "raw word list is ambiguous: word 0 carries the byte-payload "
            "tag and a consistent length; pass the payload as bytes instead")
    return words


def _looks_tagged(words: List[int]) -> bool:
    """True iff ``words`` is exactly what ``encode_value(bytes)`` emits:
    tag in the header, a length field matching the word count, and zeroed
    padding in the final word.  Anything else is a raw word list."""
    if not words or (words[0] >> _TAG_SHIFT) & 0xFFFF != VALUE_TAG:
        return False
    nbytes = words[0] & _LEN_MASK
    if len(words) - 1 != (nbytes + 7) // 8:
        return False
    pad = len(words[1:]) * 8 - nbytes
    if pad and words[-1] >> (64 - pad * 8):
        return False              # nonzero bytes beyond the stated length
    return True


def decode_value(words, *, strict: bool = False) -> Optional[Value]:
    """Inverse of ``encode_value``; untagged word lists return unchanged.

    ``strict=True`` turns a *malformed* tag — the header word carries the
    byte-payload magic but the length field disagrees with the word count,
    or padding bytes beyond the stated length are nonzero — into a typed
    ``CodecError`` instead of the lenient raw-word-list fallback.  Use it
    wherever the words are known to come from ``encode_value`` (store
    round trips), keep the default for legacy protocol-word callers."""
    if words is None:
        return None
    words = [int(w) for w in words]
    if not _looks_tagged(words):
        if (strict and words
                and (words[0] >> _TAG_SHIFT) & 0xFFFF == VALUE_TAG):
            raise CodecError(
                f"malformed value tag: header declares a "
                f"{words[0] & _LEN_MASK}-byte payload but "
                f"{len(words) - 1} data word(s) follow (or padding beyond "
                f"the stated length is nonzero)")
        return words
    nbytes = words[0] & _LEN_MASK
    raw = b"".join(int(w).to_bytes(8, "little") for w in words[1:])
    return raw[:nbytes]
