"""FUSEE core: the paper's contribution (SNAPSHOT replication, two-level
memory management, embedded operation logs, failure recovery) plus the
event-level disaggregated-memory simulation substrate."""
from .events import CRASHED, EXISTS, FULL, NOT_FOUND, OK, OpResult  # noqa: F401
from .heap import DMConfig, DMPool, INDEX_REGION, META_REGION  # noqa: F401
from .client import FuseeClient  # noqa: F401
from .master import Master, RecoveryStats  # noqa: F401
from .faults import (ClientCrashed, ClientHealth, ClusterError,  # noqa: F401
                     ClusterHealth, FaultEvent, FaultInjector, FaultPlan,
                     InsufficientReplicas, MNHealth, OrderedIndexDisabled,
                     ProtocolViolation, RegionLost, SchedulerStalled)
from . import ordered  # noqa: F401
from .ring import PlacementDirectory  # noqa: F401
from .rng import SimRng  # noqa: F401
from .migrate import MigrationEngine  # noqa: F401
from .sim import Scheduler, SimTrace, run_ops_concurrently  # noqa: F401
from .api import KVFuture, KVStore, Op, SimBackend  # noqa: F401
from .fleet import FleetEngine  # noqa: F401
from .store import FuseeCluster  # noqa: F401
from . import codec  # noqa: F401
from .codec import CodecError  # noqa: F401
