"""Epoch-versioned placement directory: the pinned region -> MN map.

FUSEE consistent-hashes regions onto MNs (FaRM-style, §4.4), but *where a
region lives* must never be an implicit function of the current alive
list: recomputing the ring on every call silently re-homes every region
the instant an MN dies — before Alg-3 recovery has copied a single byte —
so reads chase replicas that do not exist and acknowledged writes become
unreachable.  ``PlacementDirectory`` pins placement explicitly:

* ``place()`` computes a region's replica set from the *membership ring*
  (the committed member list, not the alive list) exactly once and pins
  it in the table;
* the ONLY mutation paths are ``rehome()`` (Alg-3 MN recovery and the
  migration engine's cutover, core/migrate.py) and membership changes
  (``add_member`` / ``remove_member``);
* every rehome bumps the region's **version** and the directory
  generation.  Clients key their per-shard index caches by these
  versions, and the pool's lease ``epoch`` (bumped by the master at each
  membership/cutover commit, §5.2) invalidates in-flight verbs — the
  same stale-epoch FAIL-and-retry guard as MN recovery.

Index shards are placed with an explicit per-shard stride on the ring so
``S`` shards spread across ``min(S, N)`` MNs even when hashes collide —
the whole point of sharding the RACE table is that its CAS hot words and
probe traffic no longer all land on the same r MNs.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from . import layout as L

__all__ = ["PlacementDirectory", "ring_replicas"]


def ring_replicas(region_id: int, members: List[int], r: int,
                  *, start: Optional[int] = None) -> List[int]:
    """Consistent hashing: region -> r successive members on the ring.

    Pure function of ``(region_id, members, r)`` — callers pin the result
    in a ``PlacementDirectory``; nothing recomputes it against an alive
    list.  ``start`` overrides the hash start (index-shard striding)."""
    if start is None:
        start = L.hash64(region_id, seed=3) % len(members)
    r = min(r, len(members))
    return [members[(start + i) % len(members)] for i in range(r)]


class PlacementDirectory:
    """Pinned, version-tracked region placement (see module docstring)."""

    def __init__(self, replication: int, members: List[int]):
        self.replication = replication
        self.members: List[int] = list(members)       # committed membership
        self.table: Dict[int, List[int]] = {}         # region -> [mid, ...]
        self.versions: Dict[int, int] = {}            # region -> rehome count
        self.gen = 0                                  # total mutations

    # ------------------------------------------------------------ placement
    def place(self, region: int, *, start: Optional[int] = None) -> List[int]:
        """Pin a fresh region's replica set (ring hash over *members*)."""
        reps = ring_replicas(region, self.members, self.replication,
                             start=start)
        self.table[region] = reps
        self.versions[region] = 0
        return reps

    def pin(self, region: int, reps: List[int]) -> List[int]:
        """Pin an explicit replica set for a fresh region (e.g. data
        regions primaried on a just-added MN)."""
        self.table[region] = list(reps)
        self.versions[region] = 0
        return self.table[region]

    def replicas(self, region: int) -> List[int]:
        return self.table[region]

    def primary(self, region: int) -> int:
        return self.table[region][0]

    def version(self, region: int) -> int:
        """Rehome count of ``region`` — the per-shard epoch clients key
        their index-cache entries by."""
        return self.versions.get(region, 0)

    # ------------------------------------------------------------ mutation
    def rehome(self, region: int, new_reps: List[int]):
        """Move a region to a new replica set.  The ONLY placement
        mutation path besides membership bookkeeping — called by Alg-3 MN
        recovery and by the migration engine's cutover, never by the data
        path."""
        self.table[region] = list(new_reps)
        self.versions[region] = self.versions.get(region, 0) + 1
        self.gen += 1

    def add_member(self, mid: int):
        if mid not in self.members:
            self.members.append(mid)
            self.gen += 1

    def remove_member(self, mid: int):
        if mid in self.members:
            self.members.remove(mid)
            self.gen += 1
