"""RACE-hashing index math (Zuo et al., ATC'21), as used by FUSEE §4.2.

The index is an array of combined buckets, each holding ``slots_per_bucket``
8-byte slots.  A key hashes to two candidate buckets (h1, h2); slots hold
``fp | size_class | pointer`` (layout.py).  The index lives in a dedicated
replicated region (heap.INDEX_REGION); a slot's address is its word offset,
identical in every replica — which is what lets SNAPSHOT CAS "the same slot"
on r MNs.

Deterministic slot choice: INSERT always targets the first empty slot of h1,
then h2 ("earliest candidate first").  Concurrent same-key inserts therefore
usually race on the *same* slot and are resolved by SNAPSHOT; the residual
cross-bucket duplicate case is handled by the post-insert re-read + canonical
dedup (smallest slot offset survives), mirroring RACE's insert check.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from . import layout as L


def bucket_pair(key: int, n_buckets: int) -> Tuple[int, int]:
    b1 = L.hash64(key, seed=1) % n_buckets
    b2 = L.hash64(key, seed=2) % n_buckets
    if b2 == b1:
        b2 = (b1 + 1) % n_buckets
    return b1, b2


def bucket_off(bucket: int, slots_per_bucket: int) -> int:
    return bucket * slots_per_bucket


def slot_offsets(key: int, n_buckets: int, slots_per_bucket: int) -> List[int]:
    """All candidate slot word-offsets for a key (both buckets, in order)."""
    b1, b2 = bucket_pair(key, n_buckets)
    offs = [bucket_off(b1, slots_per_bucket) + i for i in range(slots_per_bucket)]
    offs += [bucket_off(b2, slots_per_bucket) + i for i in range(slots_per_bucket)]
    return offs


def find_matches(bucket_words, base_off: int, fp: int) -> List[Tuple[int, int]]:
    """(slot_off, slot_value) for every non-empty slot with matching fp."""
    out = []
    for i, w in enumerate(bucket_words):
        if not L.is_empty(w) and L.slot_fp(w) == fp:
            out.append((base_off + i, int(w)))
    return out


def find_empty(bucket_words, base_off: int) -> Optional[int]:
    for i, w in enumerate(bucket_words):
        if L.is_empty(w):
            return base_off + i
    return None
