"""Ordered secondary index on DM: a replicated, client-managed keydir.

FUSEE's RACE hash index cannot answer range queries, which closes the
whole YCSB-E / prefix-listing workload class.  This module adds a second,
*ordered* index beside the RACE shards: a B+-tree-style keydir of fat,
cache-line-multiple leaves living in its own epoch-versioned region
(``DMConfig.ordered_index=True``; heap.py hosts + places it on the ring
like any other region), mutated with the same client-centric one-sided
verbs and repaired by the master with the same Alg-3 adopt-backup rule.

Layout (word-addressed, ``LEAF_WORDS`` = 16 words = 128 B = two cache
lines)::

    word 0                    leaf-alloc cursor (next free leaf id; FAA)
    word LEAF_BASE + i*16     leaf i:
        w0   low fence key (raw 64-bit; immutable for the leaf's lifetime)
        w1   | magic:8 | ver:16 | next_leaf:20 | reserved:12 | crc:8 |
        w2   prev leaf id   (the embedded *split record*: which leaf
             spawned this one; crc in w1 covers (low, prev) and acts as
             the record's commit mark)
        w3.. LEAF_ENTRIES entry words, each ``key+1`` (0 = empty slot)

Protocol (all client-side, generator-yielded phases, exactly the
one-sided discipline of client.py):

* **locate** — clients cache ``(low, leaf_id)`` fences (append-only
  facts: a leaf's low never changes and leaves are never merged), pick
  the rightmost fence <= key via the vectorized ``leaf_probe`` entry
  point (Pallas on TPU, the bit-exact numpy mirror below elsewhere), and
  B-link *move right* along next pointers for leaves split since.
* **ensure** (ordered half of INSERT, after the RACE commit) — claim an
  empty entry word with CAS on the primary (unique winner), broadcast the
  word to backups, then re-read the leaf version in the same QP (FIFO
  after the claim) — a version bumped by a concurrent split means the
  claim may straddle the split's fence, so it is undone and retried.
* **split** — FAA the cursor to allocate a leaf id, write the new leaf
  (movers = keys >= median, low = median, embedded prev record) to all
  replicas while it is still unreachable, link it with a CAS on the old
  leaf's meta word (primary winner election, version bump), then re-read
  the old leaf and move any straggler claims that raced the first pass
  before clearing movers (backups first, primary last — the "backups are
  never older than the primary" invariant Alg-3 repair relies on).
* **clear** (ordered half of DELETE, after the RACE commit) — CAS the
  entry to 0 (backups first), then re-check the key against the RACE
  index: if a concurrent re-insert committed, the entry is re-ensured.
  Erring toward a *present* entry is always safe — scans validate every
  candidate against the RACE index, so a spurious entry is filtered, but
  a missing entry would hide a committed key.
* **scan / range** — sweep the leaf chain in batched multi-leaf reads
  (``ORD_SWEEP`` leaves per doorbell batch = 1 RTT), select in-range
  entries, then fetch + validate the values through the RACE index in two
  batched phases (bucket reads, object reads) for the whole candidate
  set.  The naive baseline (``batched=False``) reads one leaf per RTT and
  verifies one key per 2 RTTs — the scan benchmark's >=4x ops/RTT claim.

Failure contract: by the time an op acks, every replica holds its ordered
mutation, so the master's word-wise adopt-backup repair can never revert
an acknowledged entry.  ``repair_ordered`` (run by Alg-3 MN recovery, the
migration cutover, and §5.3 client recovery) additionally (1) discards
written-but-never-linked leaves via their embedded split records (the
half-split case), and (2) re-homes entries stranded outside their leaf's
fence range by a crashed splitter.  Scans after recovery + quiescence
return exactly the committed keys (tests/test_ordered.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from . import layout as L
from . import race
from .events import FULL, NOT_FOUND, OK, MasterCall, OpResult, Phase, Verb

__all__ = ["LEAF_WORDS", "LEAF_ENTRIES", "leaf_probe_np", "init_region",
           "op_scan", "op_range", "ord_ensure", "ord_clear",
           "repair_ordered", "ensure_entry_direct", "ordered_keys_direct"]

MASK64 = (1 << 64) - 1

# ---------------------------------------------------------------- geometry
LEAF_WORDS = 16          # 128 B: fat, cache-line-multiple leaves
LEAF_HDR = 3
LEAF_ENTRIES = LEAF_WORDS - LEAF_HDR
LEAF_BASE = 8            # words 0..7: cursor + reserved header
CURSOR_OFF = 0
ORD_MAGIC = 0xB7

ORD_SWEEP = 32           # leaves per batched chain-sweep phase (1 RTT)
ORD_VBATCH = 64          # scan candidates validated per phase pair
MAX_ORD_RETRIES = 64     # bounded retry, mirrors client.MAX_OP_RETRIES


def leaf_off(leaf_id: int) -> int:
    return LEAF_BASE + leaf_id * LEAF_WORDS


def entry_off(leaf_id: int, j: int) -> int:
    return leaf_off(leaf_id) + LEAF_HDR + j


def max_leaves(region_words: int) -> int:
    return (region_words - LEAF_BASE) // LEAF_WORDS


def stored(key: int) -> int:
    """Entry encoding: key+1, so 0 unambiguously means "empty".  The one
    unrepresentable key (2^64-1) is reserved while the ordered index is
    enabled (hashed byte keys land there with probability 2^-64)."""
    return (int(key) + 1) & MASK64


def unstored(word: int) -> int:
    return (int(word) - 1) & MASK64


def pack_meta(ver: int, next_id: int, crc: int) -> int:
    return ((ORD_MAGIC << 56) | ((ver & 0xFFFF) << 40)
            | ((next_id & 0xFFFFF) << 20) | (crc & 0xFF))


def meta_magic(w) -> int:
    return (int(w) >> 56) & 0xFF


def meta_ver(w) -> int:
    return (int(w) >> 40) & 0xFFFF


def meta_next(w) -> int:
    return (int(w) >> 20) & 0xFFFFF


def meta_crc(w) -> int:
    return int(w) & 0xFF


def leaf_crc(low: int, prev: int) -> int:
    return L.crc8([int(low), int(prev)])


def build_leaf(low: int, ver: int, next_id: int, prev: int,
               entries: List[int]) -> List[int]:
    """Full word list of a leaf (entries already in stored encoding)."""
    assert len(entries) <= LEAF_ENTRIES  # lint: allow-assert (internal geometry; callers split first)
    words = [int(low), pack_meta(ver, next_id, leaf_crc(low, prev)),
             int(prev)] + [int(e) for e in entries]
    words += [0] * (LEAF_WORDS - len(words))
    return words


def parse_leaf(words) -> Dict:
    """Header + entries of one leaf's word list; ``valid`` = the embedded
    (low, prev, crc) record committed, i.e. the leaf was fully written."""
    words = [int(w) for w in words]
    low, meta, prev = words[0], words[1], words[2]
    return dict(
        low=low, ver=meta_ver(meta), next=meta_next(meta), prev=prev,
        meta=meta, entries=words[LEAF_HDR:],
        valid=(meta_magic(meta) == ORD_MAGIC
               and meta_crc(meta) == leaf_crc(low, prev)),
    )


# ------------------------------------------------- vectorized leaf probe --
def leaf_probe_np(starts: np.ndarray, lows: np.ndarray):
    """NumPy mirror of the kernels/leaf_probe entry point: for each start
    key, the index of the rightmost fence low <= start (``-1`` when every
    low exceeds the start — impossible against a chain rooted at low 0).

    ``lows`` must be sorted ascending.  Bit-exact with the Pallas kernel's
    hi/lo-pair uint64 comparison (tests/test_kernels.py pins this)."""
    starts = np.asarray(starts, np.uint64)
    lows = np.asarray(lows, np.uint64)
    return np.searchsorted(lows, starts, side="right").astype(np.int32) - 1


def locate_leaves(client, starts: List[int]) -> List[int]:
    """Map start keys to covering-leaf-id hints from the client's fence
    cache, via the vectorized probe (kernel on TPU, numpy elsewhere).
    Returns -1 hints when the cache is cold — the scan bootstraps."""
    fences = client.ord_fences
    if not fences:
        return [-1] * len(starts)
    lows = np.array(sorted(fences.values()), np.uint64)
    ids_by_low = sorted((low, lid) for lid, low in fences.items())
    idx = _leaf_probe(np.array(starts, np.uint64), lows)
    return [ids_by_low[int(i)][1] if i >= 0 else ids_by_low[0][1]
            for i in idx]


def _leaf_probe(starts: np.ndarray, lows: np.ndarray):
    try:                                   # Pallas on TPU, numpy elsewhere
        from repro.kernels import leaf_probe_batch
        return leaf_probe_batch(starts, lows)
    except Exception:                      # pragma: no cover - jax-less env
        return leaf_probe_np(starts, lows)


# ------------------------------------------------------ region bootstrap --
def init_region(pool, region: int):  # lint: allow-pool-mutation (bootstrap: pool not live yet, no verb layer to go through)
    """Write the cursor + head leaf into every replica of a fresh ordered
    region (pool construction time; no verbs, the pool is not live yet)."""
    head = build_leaf(low=0, ver=0, next_id=0, prev=0, entries=[])
    for mid in pool.placement[region]:
        mem = pool.mns[mid].regions[region]
        mem[CURSOR_OFF] = np.uint64(1)
        mem[leaf_off(0):leaf_off(0) + LEAF_WORDS] = np.array(
            [w & MASK64 for w in head], np.uint64)


# ================================================== client-side protocol ==
def _region_of(client) -> Optional[int]:
    regs = getattr(client.pool, "ordered_regions", None)
    return regs[0] if regs else None


def _read_leaf_verb(region: int, leaf_id: int, replica: int = 0) -> Verb:
    return Verb("read", region=region, replica=replica,
                off=leaf_off(leaf_id), n=LEAF_WORDS)


def _r(client, region: int) -> int:
    return len(client.pool.placement[region])


def _fail_wait(client):
    """FAIL verb seen (dead MN / stale epoch): report + wait a beat."""
    yield MasterCall("fail_report", payload=dict(cid=client.cid))
    yield Phase([], label="ord:wait_membership")


def _read_leaf(client, region: int, leaf_id: int):
    """Read one leaf (primary), retrying across FAIL/epoch bounces."""
    for _ in range(MAX_ORD_RETRIES):
        res = yield Phase([_read_leaf_verb(region, leaf_id)],
                          label="ord:read_leaf")
        if res[0] is not None:
            return parse_leaf(res[0])
        yield from _fail_wait(client)
    return None


def _bootstrap_fences(client, region: int):
    """Cold start: read the cursor, sweep every allocated leaf in batched
    multi-leaf reads, and walk the chain from leaf 0 to learn the fence
    table.  Only *reachable* leaves enter the cache — a written-but-
    unlinked leaf (a split that never linked) must never attract claims."""
    for _ in range(MAX_ORD_RETRIES):
        res = yield Phase([Verb("read", region=region, replica=0,
                                off=CURSOR_OFF, n=1)], label="ord:cursor")
        if res[0] is not None:
            n_leaves = int(res[0][0])
            break
        yield from _fail_wait(client)
    else:
        return
    leaves: Dict[int, Dict] = {}
    ids = list(range(min(n_leaves, max_leaves(client.cfg.region_words))))
    for s in range(0, len(ids), ORD_SWEEP):
        chunk = ids[s:s + ORD_SWEEP]
        for _ in range(MAX_ORD_RETRIES):
            res = yield Phase([_read_leaf_verb(region, i) for i in chunk],
                              label="ord:sweep")
            if all(r is not None for r in res):
                break
            yield from _fail_wait(client)
        for i, raw in zip(chunk, res):
            if raw is not None:
                leaves[i] = parse_leaf(raw)
    # chain walk from the head: reachable leaves only
    client.ord_fences = {}
    cur, hops = 0, 0
    while cur in leaves and hops <= len(leaves):
        lf = leaves[cur]
        if not lf["valid"]:
            break
        client.ord_fences[cur] = lf["low"]
        cur, hops = lf["next"], hops + 1
        if cur == 0:
            break


def _locate(client, key: int, *, hint: int = -1):
    """Find the covering leaf of ``key``: fence-cache probe (or ``hint``
    from a fleet-wide batched probe), then B-link move-right.  Returns
    ``(leaf_id, parsed_leaf)`` — the leaf's low is <= key and its
    successor's low (if any) is > key at read time."""
    region = _region_of(client)
    if not client.ord_fences and hint < 0:
        yield from _bootstrap_fences(client, region)
    if hint >= 0:
        cand = hint       # fleet-wide probe hint; validated by the read
    elif client.ord_fences:
        cand = locate_leaves(client, [key])[0]
    else:
        cand = 0
    for _ in range(MAX_ORD_RETRIES):
        lf = yield from _read_leaf(client, region, cand)
        if lf is None or not lf["valid"] or lf["low"] > key:
            # stale/invalid hint (repair discarded a leaf, or a cold
            # cache miss): restart from the chain head
            yield from _bootstrap_fences(client, region)
            cand = (locate_leaves(client, [key])[0]
                    if client.ord_fences else 0)
            lf = yield from _read_leaf(client, region, cand)
            if lf is None:
                return None, None
        client.ord_fences[cand] = lf["low"]
        if lf["next"] == 0:
            return cand, lf
        nxt = yield from _read_leaf(client, region, lf["next"])
        if nxt is None or not nxt["valid"]:
            return cand, lf           # half-linked successor: ours covers
        client.ord_fences[lf["next"]] = nxt["low"]
        if nxt["low"] > key:
            return cand, lf
        cand = lf["next"]             # move right
    return None, None


# --------------------------------------------------------------- ensure --
def ord_ensure(client, key: int):
    """Ordered half of INSERT (runs after the RACE commit, before the op
    acks): make ``key``'s entry present on every replica of its covering
    leaf.  See the module docstring for the claim/guard protocol."""
    region = _region_of(client)
    if region is None or int(key) == MASK64:
        return OK
    sv = stored(key)
    for _ in range(MAX_ORD_RETRIES):
        leaf_id, lf = yield from _locate(client, key)
        if leaf_id is None:
            return FULL
        r = _r(client, region)
        present = [j for j, e in enumerate(lf["entries"]) if e == sv]
        if present:
            # complete replication (a racing claimer may have crashed
            # between its primary CAS and its backup broadcast)
            if r > 1:
                res = yield Phase(
                    [Verb("write", region=region, replica=i,
                          off=entry_off(leaf_id, present[0]), words=[sv])
                     for i in range(1, r)], label="ord:ensure_backups")
                if any(x is None for x in res):
                    yield from _fail_wait(client)
                    continue
            return OK
        empty = [j for j, e in enumerate(lf["entries"]) if e == 0]
        if not empty:
            st = yield from _split(client, region, leaf_id, lf)
            if st == FULL:
                return FULL
            continue
        j = empty[0]
        # claim (primary CAS) + version guard read in ONE phase: both
        # verbs target the primary MN, so QP FIFO executes the guard
        # strictly after the claim — a version unchanged at guard time
        # means any later splitter's post-link re-read will see our entry
        res = yield Phase(
            [Verb("cas", region=region, replica=0,
                  off=entry_off(leaf_id, j), exp=0, new=sv),
             Verb("read", region=region, replica=0,
                  off=leaf_off(leaf_id) + 1, n=1)],
            label="ord:claim")
        if res[0] is None or res[1] is None:
            yield from _fail_wait(client)
            continue
        old = int(res[0])
        if old not in (0, sv):
            continue                  # slot raced away: re-read the leaf
        if r > 1:
            bres = yield Phase(
                [Verb("write", region=region, replica=i,
                      off=entry_off(leaf_id, j), words=[sv])
                 for i in range(1, r)], label="ord:claim_backups")
            if any(x is None for x in bres):
                yield from _fail_wait(client)
                continue
        if meta_ver(int(res[1][0])) != lf["ver"]:
            # a split linked concurrently: our claim may sit outside the
            # new fence — undo (backups first) and retry against the
            # post-split chain
            yield from _clear_entry(client, region, leaf_id, j, sv)
            continue
        return OK
    return FULL


def _clear_entry(client, region: int, leaf_id: int, j: int, sv: int):
    """CAS one entry word back to 0, backups first, primary last."""
    r = _r(client, region)
    off = entry_off(leaf_id, j)
    if r > 1:
        yield Phase([Verb("cas", region=region, replica=i, off=off,
                          exp=sv, new=0) for i in range(1, r)],
                    label="ord:clear_backups")
    yield Phase([Verb("cas", region=region, replica=0, off=off,
                      exp=sv, new=0)], label="ord:clear_primary")


# ---------------------------------------------------------------- clear --
def ord_clear(client, key: int):
    """Ordered half of DELETE (after the RACE commit): clear the key's
    entry, then re-check the RACE index — a concurrent re-insert that
    committed gets its entry re-ensured (spurious entries are safe,
    missing entries are not)."""
    region = _region_of(client)
    if region is None or int(key) == MASK64:
        return OK
    sv = stored(key)
    leaf_id, lf = yield from _locate(client, key)
    if leaf_id is not None:
        for j, e in enumerate(lf["entries"]):
            if e == sv:
                yield from _clear_entry(client, region, leaf_id, j, sv)
    # RACE re-check: is the key live again (racing re-insert committed)?
    out = yield from client._read_index_for(key, [])
    buckets, base_offs, _ = out
    if buckets is None:
        return OK                     # degraded: repair converges later
    cands = client._locate(key, buckets, base_offs)
    _off, _sv, obj, _stale = yield from client._verify_candidates(key, cands)
    if obj is not None:
        yield from ord_ensure(client, key)
    return OK


# ---------------------------------------------------------------- split --
def _split(client, region: int, leaf_id: int, lf: Dict):
    """Split a full leaf (see module docstring).  Returns OK (split done
    or lost to a racer — either way the caller re-locates) or FULL."""
    # fullness is often transient under pile-ups (a racing winner's
    # clears in flight): re-read before allocating anything, so losers
    # back off instead of minting a leaf id they will leak on the link CAS
    lf2 = yield from _read_leaf(client, region, leaf_id)
    if lf2 is None or not lf2["valid"]:
        return OK
    if lf2["meta"] != lf["meta"] or any(e == 0 for e in lf2["entries"]):
        yield Phase([], label="ord:split_backoff")
        return OK
    lf = lf2
    ent = [e for e in lf["entries"] if e != 0]
    raws = sorted(unstored(e) for e in ent)
    # median must exceed low so the old leaf keeps at least its fence key
    med_cands = [k for k in raws[len(raws) // 2:] if k > lf["low"]]
    if not med_cands:
        return FULL                   # all entries at the fence: can't split
    median = med_cands[0]
    r = _r(client, region)
    if lf["next"] != 0:
        # a racing split at this median may already be linked (its clears
        # of the old leaf still in flight make the leaf look full): if the
        # successor already covers the median, don't split again — retry
        # and let the racer's clears land.  Without this guard, concurrent
        # splitters mint duplicate-range leaves for every pile-up.
        nxt = yield from _read_leaf(client, region, lf["next"])
        if nxt is not None and nxt["valid"] and nxt["low"] <= median:
            yield Phase([], label="ord:split_backoff")
            return OK
    movers = [e for e in ent if unstored(e) >= median]
    # allocate a leaf id: FAA the cursor on every replica (FAA commutes,
    # so replicas converge regardless of interleaving); primary's old
    # value is the claimed id
    res = yield Phase([Verb("faa", region=region, replica=i,
                            off=CURSOR_OFF, delta=1) for i in range(r)],
                      label="ord:alloc_leaf")
    if res[0] is None:
        yield from _fail_wait(client)
        return OK
    new_id = int(res[0])
    if new_id >= max_leaves(client.cfg.region_words):
        return FULL
    # write the (unreachable) new leaf everywhere; its (low, prev, crc)
    # header is the split's embedded log record
    words = build_leaf(low=median, ver=0, next_id=lf["next"], prev=leaf_id,
                       entries=movers)
    wres = yield Phase([Verb("write", region=region, replica=i,
                             off=leaf_off(new_id), words=words)
                        for i in range(r)], label="ord:write_leaf")
    if any(x is None for x in wres):
        yield from _fail_wait(client)
        return OK                     # unlinked leaf leaks; repair reaps it
    # link: CAS the old leaf's meta word on the primary (unique winner,
    # version bump), then broadcast to backups
    new_meta = pack_meta(lf["ver"] + 1, new_id,
                         leaf_crc(lf["low"], lf["prev"]))
    cres = yield Phase([Verb("cas", region=region, replica=0,
                             off=leaf_off(leaf_id) + 1,
                             exp=lf["meta"], new=new_meta)],
                       label="ord:link")
    if cres[0] is None:
        yield from _fail_wait(client)
        return OK
    if int(cres[0]) != lf["meta"]:
        return OK                     # lost the split race; leaf leaks
    if r > 1:
        yield Phase([Verb("write", region=region, replica=i,
                          off=leaf_off(leaf_id) + 1, words=[new_meta])
                     for i in range(1, r)], label="ord:link_backups")
    # post-link second pass: claims that raced the first read are now
    # stragglers (their guard read saw the old version only if our
    # re-read here sees their entry — see ord_ensure)
    res = yield Phase([_read_leaf_verb(region, leaf_id)],
                      label="ord:post_link_read")
    mover_set = set(movers)
    stragglers = []
    if res[0] is not None:
        lf2 = parse_leaf(res[0])
        stragglers = [e for e in lf2["entries"]
                      if e != 0 and unstored(e) >= median
                      and e not in mover_set]
    # a straggler may only be cleared from the old leaf once it is
    # CONFIRMED fully replicated in the new leaf — its owner acked
    # relying on this move, so a failed move (bounced read, full new
    # leaf, lost slot CAS, incomplete backups) must leave the entry where
    # it is (repair re-homes it later); clearing anyway would make a
    # committed key scan-invisible with no fault in the system
    moved: set = set()
    if stragglers:
        nres = yield Phase([_read_leaf_verb(region, new_id)],
                           label="ord:read_new")
        if nres[0] is not None:
            nlf = parse_leaf(nres[0])
            free = [j for j, e in enumerate(nlf["entries"]) if e == 0]
            have = set(nlf["entries"])
            for sv in stragglers:
                if sv in have:
                    moved.add(sv)
                    continue
                if not free:
                    continue          # full new leaf: repair re-homes later
                j = free.pop(0)
                cres2 = yield Phase(
                    [Verb("cas", region=region, replica=0,
                          off=entry_off(new_id, j), exp=0, new=sv)],
                    label="ord:move_claim")
                if cres2[0] is None or int(cres2[0]) not in (0, sv):
                    continue          # bounced / lost the slot: not moved
                if r > 1:
                    bres2 = yield Phase(
                        [Verb("write", region=region, replica=i,
                              off=entry_off(new_id, j), words=[sv])
                         for i in range(1, r)], label="ord:move_backups")
                    if any(x is None for x in bres2):
                        continue      # backups incomplete: not moved
                moved.add(sv)
    # clear movers (written to the new leaf pre-link) + confirmed-moved
    # stragglers from the old leaf (backups first)
    clear_set = mover_set | moved
    old_now = (parse_leaf(res[0])["entries"] if res[0] is not None
               else lf["entries"])
    to_clear = [(j, e) for j, e in enumerate(old_now) if e in clear_set]
    if to_clear:
        if r > 1:
            yield Phase([Verb("cas", region=region, replica=i,
                              off=entry_off(leaf_id, j), exp=e, new=0)
                         for (j, e) in to_clear for i in range(1, r)],
                        label="ord:split_clear_backups")
        yield Phase([Verb("cas", region=region, replica=0,
                          off=entry_off(leaf_id, j), exp=e, new=0)
                     for (j, e) in to_clear], label="ord:split_clear")
    client.ord_fences[new_id] = median
    return OK


# ----------------------------------------------------------------- scan --
def op_scan(client, start: int, count: int, *, hint: int = -1,
            batched: bool = True):
    """SCAN(start_key, count): the next ``count`` live keys >= start, in
    key order, with their values.  Returns ``OpResult(OK, value=[(key,
    value_words), ...])``."""
    return (yield from _scan(client, start, count=count, end=None,
                             hint=hint, batched=batched))


def op_range(client, start: int, end: int, *, hint: int = -1,
             batched: bool = True):
    """RANGE(start, end): every live key in ``[start, end)`` with its
    value, in key order."""
    return (yield from _scan(client, start, count=None, end=end,
                             hint=hint, batched=batched))


def _scan(client, start: int, *, count: Optional[int], end: Optional[int],
          hint: int = -1, batched: bool = True):
    region = _region_of(client)
    if region is None:
        return OpResult(NOT_FOUND)
    if end is not None and end <= start:
        return OpResult(OK, value=[])
    hi = MASK64 if end is None else int(end) - 1
    results: List[Tuple[int, list]] = []
    seen: set = set()
    leaf_id, lf = yield from _locate(client, int(start), hint=hint)
    if leaf_id is None:
        return OpResult(NOT_FOUND)
    exhausted = False
    for _round in range(MAX_ORD_RETRIES):
        # ---- traverse: collect candidate keys from the leaf chain ------
        want = (ORD_VBATCH if count is None
                else max(count - len(results), 1) + 8)
        cands: List[int] = []
        while lf is not None and len(cands) < want:
            for e in lf["entries"]:
                if e == 0:
                    continue
                k = unstored(e)
                if k >= start and k <= hi and k not in seen:
                    cands.append(k)
            if lf["low"] > hi:
                exhausted = True
                break
            nxt_id = lf["next"]
            if nxt_id == 0:
                exhausted = True
                break
            if batched:
                # speculative multi-leaf sweep: the next chain segment
                # predicted from the fence cache, one doorbell batch
                ids = _predict_chain(client, nxt_id, ORD_SWEEP)
                res = yield Phase([_read_leaf_verb(region, i) for i in ids],
                                  label="ord:scan_sweep")
                chain: Dict[int, Dict] = {}
                for i, raw in zip(ids, res):
                    if raw is not None:
                        p = parse_leaf(raw)
                        if p["valid"]:
                            chain[i] = p
                            client.ord_fences[i] = p["low"]
                if nxt_id not in chain:
                    lf = yield from _read_leaf(client, region, nxt_id)
                    if lf is not None and lf["valid"]:
                        client.ord_fences[nxt_id] = lf["low"]
                    leaf_id = nxt_id
                    continue
                # walk the fetched segment in chain order
                cur = nxt_id
                while cur in chain and len(cands) < want:
                    lf = chain[cur]
                    leaf_id = cur
                    for e in lf["entries"]:
                        if e == 0:
                            continue
                        k = unstored(e)
                        if k >= start and k <= hi and k not in seen:
                            cands.append(k)
                    if lf["low"] > hi or lf["next"] == 0:
                        exhausted = lf["low"] > hi or lf["next"] == 0
                        lf = None
                        break
                    cur = lf["next"]
                else:
                    if cur not in chain and lf is not None:
                        lf = yield from _read_leaf(client, region, cur)
                        leaf_id = cur
            else:
                # naive per-slot traversal: one leaf per RTT
                lf = yield from _read_leaf(client, region, nxt_id)
                leaf_id = nxt_id
        # ---- validate + fetch values through the RACE index ------------
        cands = sorted(set(cands))
        if batched:
            fetched = yield from _fetch_values(client, cands)
        else:
            fetched = []
            for k in cands:
                r1 = yield from client._read_index_for(k, [])
                buckets, base_offs, _ = r1
                if buckets is None:
                    continue
                cs = client._locate(k, buckets, base_offs)
                _o, _s, obj, _st = yield from client._verify_candidates(k, cs)
                if obj is not None:
                    fetched.append((k, obj["value"]))
        for k, v in fetched:
            if k not in seen:
                seen.add(k)
                results.append((k, v))
        if count is not None and len(results) >= count:
            results = sorted(results)[:count]
            break
        if exhausted or lf is None:
            break     # end of chain, or a mid-chain read failed terminally
    return OpResult(OK, value=sorted(results))


def _predict_chain(client, head: int, n: int) -> List[int]:
    """Next ``n`` leaf ids after (and including) ``head`` in fence order —
    the speculative sweep set.  Mispredictions (fresh splits) are healed
    by the per-leaf chain walk that follows the read."""
    fences = client.ord_fences
    if head not in fences:
        return [head]
    by_low = sorted((low, lid) for lid, low in fences.items())
    pos = by_low.index((fences[head], head))
    return [lid for (_low, lid) in by_low[pos:pos + n]]


def _fetch_values(client, keys: List[int]):
    """Batched value fetch + liveness validation for scan candidates: one
    phase reads both RACE buckets of every key (one doorbell batch), one
    phase reads every fp-matching object; keys whose object fails the
    (key, used, !invalid, crc) check are dropped (stale ordered entries —
    deleted or never-committed keys)."""
    out: List[Tuple[int, list]] = []
    for s in range(0, len(keys), ORD_VBATCH):
        chunk = keys[s:s + ORD_VBATCH]
        retry = chunk
        for _attempt in range(4):
            if not retry:
                break
            verbs, spans = [], []
            for k in retry:
                region = client._index_region(k)
                b1, b2 = race.bucket_pair(k, client.cfg.index_buckets)
                spans.append((k, region, len(verbs)))
                verbs.append(Verb("read", region=region, replica=0,
                                  off=race.bucket_off(
                                      b1, client.cfg.slots_per_bucket),
                                  n=client.cfg.slots_per_bucket))
                verbs.append(Verb("read", region=region, replica=0,
                                  off=race.bucket_off(
                                      b2, client.cfg.slots_per_bucket),
                                  n=client.cfg.slots_per_bucket))
            bres = yield Phase(verbs, label="ord:val_buckets")
            obj_verbs, obj_map = [], []
            bounced = []
            for (k, region, vi) in spans:
                if bres[vi] is None or bres[vi + 1] is None:
                    bounced.append(k)
                    continue
                fp = L.fingerprint(k)
                cands = race.find_matches(list(bres[vi]), 0, fp) \
                    + race.find_matches(list(bres[vi + 1]), 0, fp)
                for (_off, sv) in cands:
                    obj_map.append(k)
                    obj_verbs.append(Verb(
                        "read", region=L.ptr_region(L.slot_ptr(sv)),
                        replica=0, off=L.ptr_offset(L.slot_ptr(sv)),
                        n=L.size_class_words(L.slot_size_class(sv))))
            if obj_verbs:
                ores = yield Phase(obj_verbs, label="ord:val_objects")
                got = set()
                for k, raw in zip(obj_map, ores):
                    if raw is None or k in got:
                        continue
                    obj = L.parse_object(list(raw))
                    if (obj["key"] == k and obj["used"]
                            and not obj["invalid"] and obj["crc_ok"]):
                        got.add(k)
                        out.append((k, obj["value"]))
            if bounced:
                yield from _fail_wait(client)
            retry = bounced
    return out


# ====================================================== master-side repair
def _alive_arrays(pool, region: int):
    reps = pool.placement.get(region, [])
    return [(i, pool.mns[r].regions[region])
            for i, r in enumerate(reps)
            if pool.mns[r].alive and region in pool.mns[r].regions]


def _reachable(leaves: Dict[int, Dict]) -> List[int]:
    """Leaf ids reachable from the chain head via valid next pointers —
    the only leaves scans can see (written-but-unlinked half-splits and
    reaped leaves are excluded even when their stale parse looks valid)."""
    reach, cur, seen = [], 0, set()
    while cur in leaves and leaves[cur]["valid"] and cur not in seen:
        seen.add(cur)
        reach.append(cur)
        cur = leaves[cur]["next"]
        if cur == 0:
            break
    return reach


def _chain_windows(leaves: Dict[int, Dict], reach) -> Tuple[List[int], Dict]:
    """Low-sorted reachable leaves and each leaf's fence window high: the
    next *strictly greater* low in the chain.  Racing splits can mint
    duplicate-low leaves (legal: scans sweep both and dedupe), so
    same-low leaves share one window — a zero-width window would strand
    their entries."""
    order = sorted(reach, key=lambda i: (leaves[i]["low"], i))
    lows = [leaves[i]["low"] for i in order]
    highs: Dict[int, int] = {}
    nxt = MASK64 + 1
    for pos in range(len(order) - 1, -1, -1):
        highs[order[pos]] = nxt
        if pos and lows[pos] > lows[pos - 1]:
            nxt = lows[pos]
    return order, highs


def repair_ordered(pool):
    """Alg-3 for the ordered keydir, run by MN recovery, the migration
    cutover, and §5.3 client recovery (all execute atomically at a tick):

    1. word-wise adopt-backup: where alive replicas disagree, adopt an
       alive *backup* value (entry claims broadcast to backups before the
       op acks, and clears hit backups first, so backups are never older
       than the primary for acknowledged mutations);
    2. reap half-splits: a valid-header leaf unreachable from the chain
       was written but never linked (its embedded (low, prev, crc) split
       record committed, the link CAS did not) — discard it; its movers
       still live in the source leaf, which only clears them post-link;
    3. re-home stragglers: entries stranded outside their leaf's fence
       window (a splitter crashed mid-move) are moved to their covering
       leaf so scans — which sweep only fence-relevant leaves — see them.
    """
    for region in getattr(pool, "ordered_regions", []):
        arrays = _alive_arrays(pool, region)
        if not arrays:
            continue
        # ---- 1. adopt-backup convergence (vectorized) -------------------
        if len(arrays) > 1:
            stack = np.stack([a for (_i, a) in arrays])
            diff = np.nonzero((stack != stack[0]).any(axis=0))[0]
            backups = [a for (i, a) in arrays if i > 0]
            chosen_src = backups[0] if backups else arrays[0][1]
            for off in diff:
                v = chosen_src[off]
                for (_i, a) in arrays:
                    a[off] = v
        mem = arrays[0][1]
        n_leaves = min(int(mem[CURSOR_OFF]),
                       max_leaves(pool.cfg.region_words))
        leaves = {i: parse_leaf(
            mem[leaf_off(i):leaf_off(i) + LEAF_WORDS])
            for i in range(n_leaves)}
        # ---- 2. reap unreachable (half-split) leaves, SALVAGING their
        # entries: an unreachable leaf is usually a never-linked loser
        # (entries are mover copies still in the source leaf — the
        # present-check dedups them), but it can also hold independent
        # claims acked through a primary-only link that adopt-backup just
        # reverted, or a promoted-backup's view after a primary crash —
        # those acked keys must be re-homed, never dropped
        reach = set(_reachable(leaves))
        moves: List[int] = []
        for i, lf in leaves.items():
            if i not in reach and lf["valid"]:
                moves.extend(unstored(e) for e in lf["entries"] if e != 0)
                for (_r, a) in arrays:
                    a[leaf_off(i) + 1] = np.uint64(0)   # void the header
        # ---- 3. re-home stranded entries --------------------------------
        order, highs = _chain_windows(leaves, reach)
        for i in order:
            lf = leaves[i]
            for j, e in enumerate(lf["entries"]):
                if e == 0:
                    continue
                k = unstored(e)
                if lf["low"] <= k < highs[i]:
                    continue
                for (_r, a) in arrays:
                    a[entry_off(i, j)] = np.uint64(0)
                moves.append(k)
        for k in moves:
            # windows recomputed per placement: a _place_direct may have
            # split a full covering leaf, shifting every later fence
            order, highs = _chain_windows(leaves, _reachable(leaves))
            _place_direct(pool, region, arrays, leaves, order, highs, k)


def _place_direct(pool, region, arrays, leaves, order, highs, key: int):
    """Master-side direct placement of one key into its covering reachable
    leaf (atomic-at-a-tick recovery write, all alive replicas).  When
    every covering leaf is full, the master splits one directly — a
    recovered key must never stay scan-invisible."""
    sv = stored(key)
    covering = [i for i in order
                if leaves[i]["low"] <= key < highs[i]]
    for i in covering:
        ent = leaves[i]["entries"]
        if sv in ent:
            return True
    for i in covering:
        ent = leaves[i]["entries"]
        for j, e in enumerate(ent):
            if e == 0:
                for (_r, a) in arrays:
                    a[entry_off(i, j)] = np.uint64(sv)
                ent[j] = sv
                return True
    if covering and _split_direct(pool, region, arrays, leaves, covering[-1]):
        # retry against the re-parsed post-split chain — REACHABLE leaves
        # only (the stale dict still carries reaped half-splits whose
        # fence windows would otherwise swallow the key invisibly)
        order2, highs2 = _chain_windows(leaves, _reachable(leaves))
        return _place_direct(pool, region, arrays, leaves, order2, highs2,
                             key)
    return False


def _split_direct(pool, region, arrays, leaves, leaf_id: int) -> bool:
    """Master-side leaf split (atomic at a tick): allocate a fresh leaf,
    move the upper half, link.  Updates ``leaves`` in place."""
    lf = leaves[leaf_id]
    raws = sorted(unstored(e) for e in lf["entries"] if e != 0)
    cands = [k for k in raws[len(raws) // 2:] if k > lf["low"]]
    if not cands:
        return False
    median = cands[0]
    mem = arrays[0][1]
    new_id = int(mem[CURSOR_OFF])
    if new_id >= max_leaves(pool.cfg.region_words):
        return False
    movers = [e for e in lf["entries"] if e != 0 and unstored(e) >= median]
    new_words = build_leaf(low=median, ver=0, next_id=lf["next"],
                           prev=leaf_id, entries=movers)
    new_meta = pack_meta(lf["ver"] + 1, new_id,
                         leaf_crc(lf["low"], lf["prev"]))
    for (_r, a) in arrays:
        a[CURSOR_OFF] = np.uint64(new_id + 1)
        a[leaf_off(new_id):leaf_off(new_id) + LEAF_WORDS] = np.array(
            [w & MASK64 for w in new_words], np.uint64)
        a[leaf_off(leaf_id) + 1] = np.uint64(new_meta)
        for j, e in enumerate(lf["entries"]):
            if e in movers:
                a[entry_off(leaf_id, j)] = np.uint64(0)
    leaves[leaf_id] = parse_leaf(mem[leaf_off(leaf_id):
                                     leaf_off(leaf_id) + LEAF_WORDS])
    leaves[new_id] = parse_leaf(mem[leaf_off(new_id):
                                    leaf_off(new_id) + LEAF_WORDS])
    return True


def ensure_entry_direct(pool, key: int):
    """Master-side: make ``key``'s ordered entry present (recovery of a
    crashed client whose RACE write was redone/completed — §5.3 must
    restore scan visibility of the recovered key)."""
    regs = getattr(pool, "ordered_regions", [])
    if not regs or int(key) == MASK64:
        return
    region = regs[0]
    arrays = _alive_arrays(pool, region)
    if not arrays:
        return
    mem = arrays[0][1]
    n_leaves = min(int(mem[CURSOR_OFF]), max_leaves(pool.cfg.region_words))
    leaves = {i: parse_leaf(mem[leaf_off(i):leaf_off(i) + LEAF_WORDS])
              for i in range(n_leaves)}
    order, highs = _chain_windows(leaves, _reachable(leaves))
    _place_direct(pool, region, arrays, leaves, order, highs, int(key))


def ordered_keys_direct(pool) -> List[int]:
    """Whitebox view (tests): every key currently in the ordered keydir,
    sorted, read straight from the primary arrays."""
    regs = getattr(pool, "ordered_regions", [])
    if not regs:
        return []
    region = regs[0]
    arrays = _alive_arrays(pool, region)
    if not arrays:
        return []
    mem = arrays[0][1]
    n_leaves = min(int(mem[CURSOR_OFF]), max_leaves(pool.cfg.region_words))
    out = set()
    cur, hops = 0, 0
    while cur < n_leaves and hops <= n_leaves:
        lf = parse_leaf(mem[leaf_off(cur):leaf_off(cur) + LEAF_WORDS])
        if not lf["valid"]:
            break
        for e in lf["entries"]:
            if e != 0:
                out.add(unstored(e))
        cur, hops = lf["next"], hops + 1
        if cur == 0:
            break
    return sorted(out)
