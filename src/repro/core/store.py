"""Cluster surface for the FUSEE store: membership, faults, health.

``FuseeCluster`` wires up the pool + master + scheduler and owns the
cluster lifecycle as a first-class API (the failure counterpart of the
PR-1 ``KVStore`` data-path redesign):

* ``cluster.store(cid)`` — the public pipelined ``KVStore`` (core/api.py)
  bound to one client;
* **dynamic membership** — ``add_client()`` / ``remove_client()`` at
  runtime, with lease-epoch propagation (the membership commit of §5.2)
  so every live client observes the new epoch; removed cids surrender
  their meta words and blocks to the master and are reused by later joins;
* **declarative faults** — ``inject(FaultPlan)`` installs a
  ``FaultInjector`` on the scheduler: crash_client / crash_mn /
  recover_client fire at tick- or completed-op boundaries while the
  workload runs.  In-flight futures of a crashed client resolve to the
  typed retriable ``CRASHED`` outcome; MN crashes are detected and
  repaired (Alg. 3) inside the scheduler loop;
* **observability** — ``health()`` returns a ``ClusterHealth`` snapshot:
  per-MN liveness, lease epoch, per-client pipeline depth and cache
  state, and cumulative ``RecoveryStats`` across every recovery the
  cluster performed.

Concurrency/crash tests that need verb-level schedules still drive
``sim.Scheduler`` directly.
"""
from __future__ import annotations

from typing import Dict, Optional

from .api import KINDS, KVStore, SimBackend
from .client import FuseeClient
from .events import CRASHED
from .faults import (ClientCrashed, ClientHealth, ClusterHealth, FaultInjector,
                     FaultPlan, MNHealth, RecoveryStats, SchedulerStalled,
                     accumulate_recovery)
from .heap import META_WORDS_PER_CLIENT, DMConfig, DMPool
from .master import Master
from .migrate import MigrationEngine
from .rng import SimRng
from .sim import Choice, Scheduler, SimTrace
from ..configs.fusee_paper import FuseePaperConfig
from ..obs.flight import ClusterObs


class FuseeCluster:
    def __init__(self, cfg: Optional[DMConfig] = None, *, num_clients: int = 4,
                 seed: int = 0, enable_cache: bool = True,
                 cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot",
                 mn_detect_delay: int = 0,
                 obs_dump_dir: Optional[str] = None):
        self.cfg = cfg or DMConfig()
        self.seed = seed
        # single randomness root: every random decision of the run
        # (scheduler, fault storms, workload generation) derives from
        # named substreams of this SimRng, making the run bit-identically
        # replayable from (seed, config) — see core/rng.py
        self.rng = SimRng(seed)
        self._client_kw = dict(enable_cache=enable_cache,
                               cache_threshold=cache_threshold,
                               replication_mode=replication_mode)
        self.pool = DMPool(self.cfg, num_clients=num_clients, seed=seed)
        self.master = Master(self.pool)
        self.scheduler = Scheduler(self.pool, self.master, seed=seed,
                                   rng=self.rng,
                                   mn_detect_delay=mn_detect_delay)
        # elastic shard subsystem: the migration engine drives MN
        # scale-out/in; the master arbitrates its cutovers (core/migrate.py)
        self.migrator = MigrationEngine(self.pool, self.master,
                                        self.scheduler)
        self.master.migrator = self.migrator
        # observability hub (repro.obs): op-level flight recorder, latency
        # histograms, per-MN load series, heat sketch — attached to the
        # hot-path hook points by default; detach_obs() restores the
        # structurally-zero-cost path (one is-None test per hook site).
        # The cap-model link rate comes from the paper config: one tick is
        # one verb RTT, so link_gbps/8 * rtt_us of bytes move per tick.
        pc = FuseePaperConfig()
        self.obs = ClusterObs(
            self.scheduler, self.pool, kinds=KINDS + ("search_batch",),
            link_bytes_per_tick=pc.link_gbps * 1e9 / 8 * pc.rtt_us * 1e-6,
            dump_dir=obs_dump_dir)
        self.attach_obs()
        self._fleet = None
        self.clients: Dict[int, FuseeClient] = {}
        self._next_cid = 0
        self._free_cids: list = []          # cids of removed clients, reusable
        self.recovery_totals = RecoveryStats()
        self.client_recoveries = 0
        for _ in range(num_clients):
            self._spawn_client()

    # --------------------------------------------------------------- stores
    def store(self, cid: int = 0, *, max_inflight: int = 16) -> KVStore:
        """The unified pipelined store API over client ``cid``."""
        client = self.clients.get(cid)
        if client is None:
            raise ClientCrashed(cid, "removed" if cid in self.scheduler.removed
                                else "unknown")
        return KVStore(SimBackend(self.scheduler, client,
                                  max_inflight=max_inflight))

    # ----------------------------------------------------------- membership
    def _spawn_client(self, **overrides) -> int:
        # reuse cids surrendered by remove_client (their meta words were
        # scrubbed and their blocks disowned), so add/remove churn never
        # exhausts the meta region
        if self._free_cids:
            cid = self._free_cids.pop(0)
        else:
            cid = self._next_cid
            self._next_cid += 1
        if (cid + 1) * META_WORDS_PER_CLIENT > self.cfg.region_words:
            raise ValueError(
                f"meta region full: cid {cid} needs "
                f"{(cid + 1) * META_WORDS_PER_CLIENT} words, region has "
                f"{self.cfg.region_words} (raise DMConfig.region_words)")
        c = FuseeClient(cid, self.pool, seed=self.seed,
                        **{**self._client_kw, **overrides})
        self.clients[cid] = c
        self.pool.num_clients = max(self.pool.num_clients, cid + 1)
        self.scheduler.add_client(c)
        return cid

    def add_client(self, **overrides) -> int:
        """Join a fresh client at runtime (elasticity, Fig. 21).  Bumps the
        lease epoch and propagates it to every live client; the new cid is
        returned — bind a store with ``cluster.store(cid)``.  Per-client
        keyword overrides (``enable_cache`` etc.) default to the cluster's
        construction settings."""
        cid = self._spawn_client(**overrides)
        self._bump_epoch()
        return cid

    def remove_client(self, cid: int, *, drain: bool = True):
        """Leave gracefully: drain the client's in-flight pipeline, then
        deregister it and bump the lease epoch.  Subsequent submits (or
        ``store(cid)`` bindings) raise the typed ``ClientCrashed`` with
        reason ``'removed'``."""
        client = self.clients.get(cid)
        if client is None:
            raise ClientCrashed(cid, "removed" if cid in self.scheduler.removed
                                else "unknown")
        if drain and not client.crashed:
            # round-robin the WHOLE cluster: an in-flight op of this client
            # may legally wait on another client's progress (e.g. a SNAPSHOT
            # loser polling for the winner's commit)
            guard = 0
            while self.scheduler.inflight(cid):
                progressed = False
                for ecid in self.scheduler.eligible_cids():
                    # rotate the lane pick: no QP starves behind a retry
                    # loop flooding another lane (see run_round_robin)
                    progressed |= self.scheduler.step(ecid, pick=guard)
                if not progressed or (guard := guard + 1) > 10**6:
                    raise SchedulerStalled(
                        f"client {cid}: could not drain before removal")
        self.scheduler.remove_client(cid)
        self.master.release_client(cid)
        self.clients.pop(cid)
        self._free_cids.append(cid)
        self._bump_epoch()

    def _bump_epoch(self):
        """Commit a lease-epoch bump to every live client — the same
        membership commit the master performs after MN recovery (§5.2)."""
        self.pool.epoch += 1
        for c in self.clients.values():
            if not c.crashed:
                c.epoch = self.pool.epoch

    def _master_trace_ctx(self):
        """Attribute the upcoming pool traffic to the master in the verb
        trace: direct API calls (recover_client, add/remove_mn, rebalance)
        run outside a scheduler tick, so the tracer context may still hold
        the last-stepped client's identity."""
        tr = self.pool._tracer
        if tr is not None:
            tr.set_master_ctx(self.scheduler.tick)

    # ------------------------------------------------------- MN elasticity
    def add_mn(self, *, wait: bool = True) -> int:
        """Join a fresh memory node at runtime (online scale-out): the
        node commits to the membership ring, receives fresh data regions,
        and index shards are re-homed onto the grown ring by live
        migration — bulk copy + dual-write window + epoch-bump cutover
        (core/migrate.py).  With ``wait=True`` (and no concurrent
        workload) the call drives the migrations to completion; with
        ``wait=False`` they ride the workload's own scheduler/fleet ticks
        — the store stays fully available throughout.  Returns the new
        MN id."""
        self._master_trace_ctx()
        mid = self.migrator.add_mn()
        if wait:
            self.migrator.drive()
        return mid

    def remove_mn(self, mid: int, *, wait: bool = True):
        """Gracefully drain + retire a memory node (online scale-in).
        Every region it hosts — index shards, data regions, metadata — is
        migrated to the shrunk ring first; no acknowledged write is lost.
        Raises the typed ``InsufficientReplicas`` if removal would leave
        fewer members than the replication factor."""
        self._master_trace_ctx()
        self.migrator.remove_mn(mid)
        if wait:
            self.migrator.drive()

    def rebalance(self, *, wait: bool = True) -> int:
        """Re-place index shards on the current membership ring (e.g.
        after config changes); returns the number of shard migrations
        started."""
        self._master_trace_ctx()
        n = self.migrator.rebalance()
        if wait:
            self.migrator.drive()
        return n

    # --------------------------------------------------------------- faults
    def crash_mn(self, mid: int):
        """Crash-stop an MN; the scheduler auto-detects and the master
        re-homes its regions (Alg. 3) ``mn_detect_delay`` ticks later."""
        obs = self.scheduler.obs
        if obs is not None:
            obs.fault("crash_mn", mid, self.scheduler.tick)
        self.scheduler.crash_mn(mid)

    def crash_client(self, cid: int):
        """Crash-stop a client; its in-flight futures resolve ``CRASHED``
        (retriable) and later submits raise ``ClientCrashed``."""
        obs = self.scheduler.obs
        if obs is not None:
            obs.fault("crash_client", cid, self.scheduler.tick)
        self.scheduler.crash_client(cid)

    def recover_client(self, cid: int, reassign_to_cid: Optional[int] = None
                       ) -> RecoveryStats:
        """§5.3 recovery of a crashed client from its embedded operation
        logs; stats also accumulate into ``health().recovery``."""
        self._master_trace_ctx()
        target = (self.clients[reassign_to_cid]
                  if reassign_to_cid is not None else None)
        st = self.master.recover_client(cid, reassign_to=target)
        accumulate_recovery(self.recovery_totals, st)
        self.client_recoveries += 1
        obs = self.scheduler.obs
        if obs is not None:
            obs.recovery("client_recovery", self.scheduler.tick, cid=cid,
                         arg=st.redone_ops,
                         rtts=(st.get_metadata_rtts + st.traverse_log_rtts
                               + st.recover_requests_rtts
                               + st.construct_free_list_rtts))
        return st

    def inject(self, plan: FaultPlan) -> FaultInjector:
        """Install a declarative fault schedule on the scheduler loop."""
        injector = FaultInjector(self, plan)
        self.scheduler.add_tick_hook(injector.poll)
        return injector

    # -------------------------------------------------------------- driving
    def drain(self):
        """Drive every in-flight op of every live client to completion."""
        self.scheduler.run_round_robin()

    def fleet(self, *, use_kernel: bool = True, fused: bool = True):
        """The (memoized) fleet engine over this cluster's scheduler: one
        tick advances every client's in-flight op-phases as batched array
        operations — the ≥1024-concurrent-client driving mode.  See
        core/fleet.py.

        ``fused=True`` (the default) executes each tick's array-verb
        sweeps as a single fused dispatch over the flat region slab;
        ``fused=False`` keeps the per-kind batch path — the differential
        oracle both must match bit-for-bit."""
        from .fleet import FleetEngine            # local: avoid import cycle
        if self._fleet is None:
            self._fleet = FleetEngine(self.scheduler, use_kernel=use_kernel,
                                      fused=fused)
        else:
            self._fleet.use_kernel = use_kernel   # honor the latest settings
            self._fleet.fused = fused
        return self._fleet

    # ------------------------------------------------------- choice points
    def choices(self):
        """The enabled scheduler transitions at the current state — the
        model checker's enumeration surface (see sim.Scheduler.choices)."""
        return self.scheduler.choices()

    def fire(self, ch: Choice) -> bool:
        """Execute one enabled transition (see sim.Scheduler.fire)."""
        return self.scheduler.fire(ch)

    def arm_migration_event(self, name: str = "migrate"):
        """Expose live-migration progress as an enumerable choice point:
        while any migration is active, ``Choice('event', name=...)`` is
        enabled and each firing advances the migration engine one boundary
        (one bulk-copy chunk, or the master-arbitrated cutover commit).
        With this armed, a checker controls exactly when the cutover's
        epoch bump lands relative to every client verb."""
        # detach the auto tick hook: begin_tick runs inside every fired
        # choice, so leaving it hooked would advance the migration (and
        # land the cutover) implicitly, outside the enumerated schedule
        self.migrator.manual = True
        self.scheduler.remove_tick_hook(self.migrator._tick_hook)
        self.migrator._hooked = False
        self.scheduler.arm_event(
            name, lambda s: self.migrator.tick(),
            enabled=lambda s: bool(self.migrator.active), once=False)

    # --------------------------------------------------------------- replay
    def trace(self) -> SimTrace:
        """Schedule-replay hook: the (cid, pick) decisions taken so far by
        step-mode driving.  Feed to ``replay`` on a fresh same-(seed,
        config) cluster given the same submission sequence to reproduce
        the run bit-identically.  Fleet-mode ticks are schedule-free
        (deterministic from the seed alone) and contribute no decisions."""
        return self.scheduler.trace()

    def replay(self, trace: SimTrace, *, start: int = 0):
        """Re-execute a recorded schedule verbatim (see ``trace``)."""
        self.scheduler.run_trace(trace, start=start)

    # ------------------------------------------------------------ sanitizers
    def attach_tracer(self, capacity: int = 1 << 16):
        """Attach a verb tracer (``repro.analysis``) to this cluster's pool
        and return it.  While attached, every one-sided verb is appended to
        a fixed-capacity ring; ``detach()`` restores the unwrapped verbs
        (zero residual cost).  Idempotent: returns the existing tracer if
        one is already attached."""
        from ..analysis.trace import VerbTracer  # local: analysis is opt-in
        if self.pool._tracer is not None:
            return self.pool._tracer
        return VerbTracer(capacity=capacity).attach(self.pool)

    def race_findings(self, rules=None, on_truncated: str = "warn"):
        """Happens-before race pass over the attached tracer's events (see
        ``repro.analysis.races``).  Requires ``attach_tracer`` first.
        ``on_truncated`` governs saturated-ring behavior: "warn" (default)
        emits ``TruncatedTraceWarning``, "fail" raises, "ignore" is
        silent — a wrapped ring can hide both races and their guards."""
        from ..analysis import races             # local: analysis is opt-in
        if self.pool._tracer is None:
            raise ValueError(
                "no tracer attached — call attach_tracer() before running "
                "the race detector")
        findings = races.detect(self.pool._tracer, scheduler=self.scheduler,
                                rules=rules, on_truncated=on_truncated)
        obs = self.scheduler.obs
        if obs is not None and findings:
            obs.dump("race_finding")
        return findings

    def heap_audit(self):
        """Post-drain DM heap/epoch sanitizer (``repro.analysis.heapcheck``):
        index→object reachability, leak/double-free/use-after-free checks,
        placement-ring epoch consistency.  Call after ``drain()``."""
        from ..analysis import heapcheck         # local: analysis is opt-in
        report = heapcheck.audit(self)
        obs = self.scheduler.obs
        if obs is not None and not report.ok:
            obs.dump("heap_audit")
        return report

    # --------------------------------------------------------- observability
    def attach_obs(self) -> ClusterObs:
        """(Re)attach the observability hub to the hot-path hook points
        (scheduler op begin/settle, fleet per-tick sampling, heap heat)."""
        self.scheduler.obs = self.obs
        self.pool._obs = self.obs
        return self.obs

    def detach_obs(self) -> ClusterObs:
        """Detach the hub: every hook site degrades to one attribute load
        + ``is None`` test (claims-checked by ``benchmarks/run.py --only
        obs_overhead``).  The metrics registry itself stays live — fleet /
        migration counters are plain handle bumps, not hub hooks."""
        self.obs.flush()
        self.scheduler.obs = None
        self.pool._obs = None
        return self.obs

    def metrics(self) -> Dict:
        """Registry snapshot plus a latency summary: for every op-latency
        histogram, conservative p50/p99/p999 (bucket upper edges) and the
        sample count.  Deterministic — ``json.dumps`` of this snapshot is
        byte-identical across same-(seed, config, schedule) runs.  When
        the hot-key monitor is enabled (``enable_hotspot``), a
        ``"hotspot"`` block (top-k keys, zipf-θ, imbalance, regime) rides
        along — int-valued, so the determinism contract is unchanged."""
        snap = self.obs.snapshot()
        reg = self.scheduler.metrics
        pct: Dict[str, Dict] = {}
        for name in snap["histograms"]:
            h = reg.get(name)
            pct[name] = {"count": h.total, "p50": h.percentile(0.50),
                         "p99": h.percentile(0.99),
                         "p999": h.percentile(0.999)}
        snap["percentiles"] = pct
        if self.obs.hotspot is not None:
            snap["hotspot"] = self.obs.hotspot.snapshot()
        return snap

    def enable_hotspot(self, **kw):
        """Turn on the streaming hot-key/skew monitor (obs/hotspot.py):
        space-saving top-k over the heat-touch key stream, online zipf-θ,
        EWMA shard/MN imbalance, and typed ``regime`` flight events on
        threshold crossings.  Opt-in: the default hub carries no monitor,
        so baseline snapshots and the attached-overhead claim are
        unaffected.  Returns the ``HotKeyMonitor``."""
        return self.obs.enable_hotspot(**kw)

    def profile(self, *, include_bg: bool = False) -> Dict:
        """One-call causal profile of everything recorded so far: span
        trees (obs/spans.py) folded into the critical-path RTT-attribution
        report (obs/profile.py).  Requires ``attach_tracer()`` — the
        flight recorder alone has no per-verb rows.  When this cluster
        drives a ``FleetEngine`` the wall-clock tick-phase split rides
        along under ``"tick_phases"``."""
        from ..obs.profile import critical_path_report
        from ..obs.spans import spans_from_cluster
        ss = spans_from_cluster(self)
        report = critical_path_report(ss, include_bg=include_bg)
        report["spans"] = ss
        fleet = getattr(self, "_fleet", None)
        if fleet is not None:
            report["tick_phases"] = fleet.tick_phase_profile()
        return report

    # ---------------------------------------------------------------- health
    def health(self) -> ClusterHealth:
        """Cluster observability snapshot: MN liveness, lease epoch,
        per-client pipeline depth / cache stats, cumulative recovery."""
        sched = self.scheduler
        done_by_cid: Dict[int, int] = {}
        crashed_by_cid: Dict[int, int] = {}
        for r in sched.history:
            if r.result is None:
                continue
            if r.result.status == CRASHED:
                crashed_by_cid[r.cid] = crashed_by_cid.get(r.cid, 0) + 1
            else:
                done_by_cid[r.cid] = done_by_cid.get(r.cid, 0) + 1
        clients = [
            ClientHealth(cid=cid, status="crashed" if c.crashed else "live",
                         epoch=c.epoch, inflight=sched.inflight(cid),
                         cache_entries=len(c.cache),
                         completed_ops=done_by_cid.get(cid, 0),
                         crashed_ops=crashed_by_cid.get(cid, 0))
            for cid, c in sorted(self.clients.items())
        ] + [
            ClientHealth(cid=cid, status="removed", epoch=-1, inflight=0,
                         cache_entries=0,
                         completed_ops=done_by_cid.get(cid, 0),
                         crashed_ops=crashed_by_cid.get(cid, 0))
            for cid in sorted(sched.removed)
        ]
        mns = [MNHealth(mid=m.mid, alive=m.alive,
                        primary_regions=sum(
                            reps[0] == m.mid
                            for reps in self.pool.placement.values()),
                        hosted_regions=len(m.regions),
                        bytes_served=int(self.pool.mn_bytes[m.mid]),
                        retired=m.retired)
               for m in self.pool.mns]
        return ClusterHealth(epoch=self.pool.epoch, tick=sched.tick,
                             mns=mns, clients=clients,
                             recovery=self.recovery_totals,
                             client_recoveries=self.client_recoveries,
                             mn_recoveries=sched.mn_recoveries,
                             crashed_ops=sched.crashed_ops,
                             migrating_regions=len(self.migrator.active),
                             migrations=self.migrator.status())
