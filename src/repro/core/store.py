"""High-level FUSEE store API.

``FuseeCluster`` bootstraps the pool + master + N clients.  ``KVStore`` wraps
one client with a synchronous interface (each op runs to completion on a
private scheduler) — the ergonomic entry point for examples and non-
concurrency tests.  Concurrency/crash tests drive ``sim.Scheduler`` directly.
"""
from __future__ import annotations

from typing import List, Optional

from .client import FuseeClient
from .events import OK, OpResult
from .heap import DMConfig, DMPool
from .master import Master
from .sim import Scheduler


class FuseeCluster:
    def __init__(self, cfg: Optional[DMConfig] = None, *, num_clients: int = 4,
                 seed: int = 0, enable_cache: bool = True,
                 cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot"):
        self.cfg = cfg or DMConfig()
        self.pool = DMPool(self.cfg, num_clients=num_clients, seed=seed)
        self.master = Master(self.pool)
        self.clients: List[FuseeClient] = [
            FuseeClient(cid, self.pool, enable_cache=enable_cache,
                        cache_threshold=cache_threshold,
                        replication_mode=replication_mode, seed=seed)
            for cid in range(num_clients)
        ]
        self.scheduler = Scheduler(self.pool, self.master, seed=seed)
        for c in self.clients:
            self.scheduler.add_client(c)

    def store(self, cid: int = 0) -> "KVStore":
        return KVStore(self, cid)

    def crash_mn(self, mid: int):
        self.scheduler.crash_mn(mid)

    def crash_client(self, cid: int):
        self.scheduler.crash_client(cid)

    def recover_client(self, cid: int, reassign_to_cid: Optional[int] = None):
        target = self.clients[reassign_to_cid] if reassign_to_cid is not None else None
        return self.master.recover_client(cid, reassign_to=target)


class KVStore:
    """Synchronous single-client view over the cluster."""

    def __init__(self, cluster: FuseeCluster, cid: int = 0):
        self.cluster = cluster
        self.cid = cid

    def _run(self, kind: str, key: int, value=None) -> OpResult:
        sched = self.cluster.scheduler
        rec = sched.submit(self.cid, kind, key, value)
        while sched.eligible(self.cid):
            sched.step(self.cid)
        assert rec.result is not None
        rec.result.rtts = rec.rtts
        rec.result.bg_rtts = rec.bg_rtts
        return rec.result

    def insert(self, key: int, value) -> OpResult:
        return self._run("insert", key, list(value))

    def update(self, key: int, value) -> OpResult:
        return self._run("update", key, list(value))

    def delete(self, key: int) -> OpResult:
        return self._run("delete", key)

    def search(self, key: int) -> OpResult:
        return self._run("search", key)

    def reclaim(self) -> OpResult:
        return self._run("reclaim", 0)

    def get(self, key: int):
        r = self.search(key)
        return r.value if r.status == OK else None
