"""Cluster bootstrap for the FUSEE store.

``FuseeCluster`` wires up the pool + master + N clients.  ``cluster.store(cid)``
returns the public pipelined ``KVStore`` (core/api.py) bound to one client —
the ergonomic entry point for examples, benchmarks, and non-concurrency
tests.  Concurrency/crash tests drive ``sim.Scheduler`` directly.
"""
from __future__ import annotations

from typing import List, Optional

from .api import KVStore, SimBackend
from .client import FuseeClient
from .heap import DMConfig, DMPool
from .master import Master
from .sim import Scheduler


class FuseeCluster:
    def __init__(self, cfg: Optional[DMConfig] = None, *, num_clients: int = 4,
                 seed: int = 0, enable_cache: bool = True,
                 cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot"):
        self.cfg = cfg or DMConfig()
        self.pool = DMPool(self.cfg, num_clients=num_clients, seed=seed)
        self.master = Master(self.pool)
        self.clients: List[FuseeClient] = [
            FuseeClient(cid, self.pool, enable_cache=enable_cache,
                        cache_threshold=cache_threshold,
                        replication_mode=replication_mode, seed=seed)
            for cid in range(num_clients)
        ]
        self.scheduler = Scheduler(self.pool, self.master, seed=seed)
        for c in self.clients:
            self.scheduler.add_client(c)

    def store(self, cid: int = 0, *, max_inflight: int = 16) -> KVStore:
        """The unified pipelined store API over client ``cid``."""
        return KVStore(SimBackend(self.scheduler, self.clients[cid],
                                  max_inflight=max_inflight))

    def crash_mn(self, mid: int):
        self.scheduler.crash_mn(mid)

    def crash_client(self, cid: int):
        self.scheduler.crash_client(cid)

    def recover_client(self, cid: int, reassign_to_cid: Optional[int] = None):
        target = self.clients[reassign_to_cid] if reassign_to_cid is not None else None
        return self.master.recover_client(cid, reassign_to=target)
