"""Single seeded randomness root for a simulation run.

FUSEE's fleet-scale simulation promises **bit-identical replay from
``(seed, config)``** — every random decision a run makes (scheduler
interleavings, workload generation, fault storms, per-client protocol
jitter) must derive from one root seed through *named substreams* so that
adding a new consumer of randomness never perturbs the draws of an
existing one.

``SimRng`` wraps numpy's ``SeedSequence`` machinery: ``stream(name)``
returns a ``numpy.random.Generator`` keyed by ``(seed, crc32(name))``.
Streams are independent of both creation order and of each other, so

    SimRng(7).stream("workload")

draws the same sequence whether or not ``stream("faults")`` was ever
touched.  The conventional stream names used across the repo:

    scheduler   sim.Scheduler's schedule choices (run_random picks)
    faults      randomized FaultPlan generation (faults.FaultPlan.storm)
    workload    benchmark/test op-mix + key generation
    client.<i>  per-client protocol jitter (FuseeClient)
"""
from __future__ import annotations

import zlib
from typing import Dict, Union

import numpy as np

__all__ = ["SimRng"]


class SimRng:
    """Deterministic named-substream RNG root.  See module docstring."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._streams: Dict[str, np.random.Generator] = {}

    def _origin(self, name: str) -> np.random.SeedSequence:
        # 64-bit mask (not 32): seeds must not alias below the word size a
        # reproducing seed is reported at, or "different seeds differ"
        # silently breaks for seeds above 2**32
        return np.random.SeedSequence(
            [self.seed & 0xFFFF_FFFF_FFFF_FFFF,
             zlib.crc32(name.encode("utf-8"))])

    def stream(self, name: str) -> np.random.Generator:
        """The (memoized) generator for substream ``name``.  Repeated calls
        return the *same* generator object — draws advance it."""
        gen = self._streams.get(name)
        if gen is None:
            gen = self._streams[name] = np.random.default_rng(
                self._origin(name))
        return gen

    def fresh(self, name: str) -> np.random.Generator:
        """A *new* generator for ``name``, rewound to the stream's origin
        (unlike ``stream``, draws on the returned object do not advance the
        memoized one).  Used by replay harnesses."""
        return np.random.default_rng(self._origin(name))

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"SimRng(seed={self.seed})"


def as_simrng(rng: Union["SimRng", int, None], *, default_seed: int = 0) -> "SimRng":
    """Coerce an int seed / None / SimRng into a SimRng (API convenience)."""
    if isinstance(rng, SimRng):
        return rng
    return SimRng(default_seed if rng is None else int(rng))
