"""The public FUSEE store API: pipelined batch ops over futures.

FUSEE's whole point is that *clients* drive metadata concurrently — each
client keeps many doorbell-batched ops in flight against the replicated
RACE index (§4.3, Fig. 9).  This module is the client-facing surface over
that machinery:

* ``Op`` — an immutable request (get/insert/update/delete/reclaim) over
  **bytes/str keys and variable-length byte values** (core/codec.py maps
  them onto the 64-bit-key, word-value protocol substrate);
* ``KVFuture`` — a handle to an in-flight op; ``result()`` drives the
  event scheduler until the op responds;
* ``KVStore`` — ``submit`` / ``submit_batch`` plus blocking
  ``get``/``put``/``delete``/``scan``/``range``/``stats`` conveniences,
  over a pluggable backend:

  - ``SimBackend``: the paper-faithful event-level simulation
    (core/client.py + core/sim.py), with any number of ops in flight per
    client ((cid, op_id) pipelines, per-(client, MN) FIFO preserved);
  - ``DeviceBackend`` (serving/backend.py): the jitted device-resident
    pool used by the serving engine.  One surface, two substrates.

Batched SEARCH fast path: when a ``submit_batch`` carries several GETs
whose keys are resident in the client's adaptive index cache (§4.6), the
API matches the batch against a shadow copy of the cache through the
``race_lookup`` Pallas kernel and fuses all hits into **one** doorbell
batch (client.op_search_batch) — the whole batch costs 1 RTT instead of
1-2 RTTs per key.  Keys that miss (or fail validation) fall back to
individual SEARCH ops, resubmitted at the batch's response tick.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from . import codec
from . import ordered
from .events import CRASHED, NOT_FOUND, OK, OpResult
from .faults import ClientCrashed, OrderedIndexDisabled, SchedulerStalled
from ..obs.registry import LegacyCounters, Registry, legacy_counters_view

__all__ = ["Op", "KVFuture", "KVStore", "SimBackend"]


# ----------------------------------------------------------------- requests
KINDS = ("search", "insert", "update", "delete", "reclaim", "scan", "range")


@dataclass(frozen=True)
class Op:
    """One store request.  Keys are bytes/str/int; values bytes/str or a
    raw word list (legacy protocol callers).

    Ordering: ops submitted together (or while others are still in
    flight) are **concurrent** — like verbs in one RDMA doorbell batch,
    they may take effect in any linearizable order.  For read-your-write
    ordering, ``result()`` the earlier future before submitting the next
    op."""
    kind: str                      # one of KINDS
    key: Any = None
    value: Any = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    @staticmethod
    def get(key) -> "Op":
        return Op("search", key)

    @staticmethod
    def put(key, value) -> "Op":
        """Upsert (the paper's INSERT upserts on duplicate keys)."""
        return Op("insert", key, value)

    @staticmethod
    def insert(key, value) -> "Op":
        return Op("insert", key, value)

    @staticmethod
    def update(key, value) -> "Op":
        return Op("update", key, value)

    @staticmethod
    def delete(key) -> "Op":
        return Op("delete", key)

    @staticmethod
    def reclaim() -> "Op":
        return Op("reclaim")

    @staticmethod
    def scan(start_key, count: int) -> "Op":
        """SCAN: the next ``count`` live keys >= start_key in key order,
        with their values (ordered keydir; needs ordered_index=True).
        Byte/str start keys address the hashed 64-bit key space — integer
        keys scan in true numeric order."""
        return Op("scan", start_key, int(count))

    @staticmethod
    def range(start_key, end_key) -> "Op":
        """RANGE: every live key in ``[start_key, end_key)`` with its
        value, in key order (ordered keydir; needs ordered_index=True)."""
        return Op("range", start_key, end_key)


# ------------------------------------------------------------------ futures
class KVFuture:
    """Handle to an in-flight op.  ``result()`` drives the backend until
    the op responds, then returns the decoded ``OpResult``."""

    __slots__ = ("_backend", "record", "_resolved")

    def __init__(self, backend, record=None):
        self._backend = backend
        self.record = record        # sim OpRecord (rebindable on fallback)
        self._resolved: Optional[OpResult] = None

    def _resolve(self, result: OpResult, record=None):
        self._resolved = result
        if record is not None:
            self.record = record

    def done(self) -> bool:
        if self._resolved is not None:
            return True
        return self.record is not None and self.record.result is not None

    def result(self) -> OpResult:
        if not self.done():
            self._backend.drive(self)
        if self._resolved is not None:
            res = self._resolved
        else:
            rec = self.record
            res = dataclasses.replace(rec.result, rtts=rec.rtts,
                                      bg_rtts=rec.bg_rtts)
        kind = self.record.kind if self.record is not None else None
        v = res.value
        if isinstance(v, list) and (kind in ("scan", "range")
                                    or (v and isinstance(v[0], tuple))):
            # scan results are [(key, value_words), ...]: decode each
            # (device futures carry no record, so pair lists self-identify)
            return dataclasses.replace(res, value=[
                (k, codec.decode_value(w)) for (k, w) in v])
        return dataclasses.replace(res, value=codec.decode_value(v))


# -------------------------------------------------------------- sim backend
# one shared hash/probe implementation with the kernel stack (core/shadow.py;
# bit-exactness pinned by tests/test_api.py::test_shadow_hash_matches_kernel_ref)
from .shadow import build_shadow, race_lookup_np  # noqa: E402
from .shadow import hash32_np as _hash32_np  # noqa: E402


def _fold32(key64: int) -> int:
    return (key64 ^ (key64 >> 32)) & 0xFFFFFFFF


class SimBackend:
    """Pipelined backend over the event-level protocol simulation.

    Binds one ``FuseeClient`` + the cluster ``Scheduler``; ops are
    submitted as (cid, op_id) pipeline entries, so a client has up to
    ``max_inflight`` concurrent doorbell-batched ops — the scheduler
    preserves per-(client, MN) FIFO verb order across all of them.
    """

    SHADOW_SPB = 8          # slots per bucket of the shadow cache index

    def __init__(self, scheduler, client, *, max_inflight: int = 16,
                 batch_search_min: int = 2, use_kernel: bool = True):
        self.sched = scheduler
        self.client = client
        self.cid = client.cid
        self.max_inflight = max_inflight
        self.batch_search_min = batch_search_min
        self.use_kernel = use_kernel
        # per-backend metrics registry ("api.*" names): backends are
        # transient (one per ``cluster.store()`` call), so each carries
        # its own small registry rather than sharing the scheduler's —
        # aggregate across backends with obs.registry.snapshot_merge.
        # The old ``counters`` dict survives one release as a read-only
        # deprecation alias (see obs/registry.py).
        self.metrics = Registry()
        self._handles = {
            k: self.metrics.counter("api." + k)
            for k in ("ops", "batch_lookups", "batch_fast_hits",
                      "batch_fallbacks", "shadow_rebuilds", "scans",
                      "scan_locate_batches")}
        # memoized shadow index: (cache fingerprint, entries, shadow table)
        self._shadow = (None, None, None)
        self._pump_rr = 0     # rotating QP-lane pick (starvation freedom)

    @property
    def counters(self) -> LegacyCounters:
        """Deprecated read-only view of the backend metrics under their
        historical key names; read ``stats()`` or ``self.metrics``."""
        return legacy_counters_view("SimBackend", self._handles)

    # ------------------------------------------------------------- submit
    def submit_many(self, ops: Sequence[Op], *,
                    probed: Optional[list] = None,
                    located: Optional[list] = None) -> List[KVFuture]:
        """Submit a batch.  ``probed`` optionally carries precomputed cache
        probe results for the batch's GET keys (CacheEntry-or-None aligned
        with the GETs, in op order) — the fleet engine passes these so ONE
        cluster-wide ``race_lookup`` invocation serves every client's batch
        in a tick instead of one probe per client.  ``located`` is the
        scan twin: covering-leaf-id hints for the batch's SCAN/RANGE start
        keys (aligned with them in op order, -1 = no hint), from the fleet
        engine's single ``leaf_probe`` invocation per tick."""
        if self.client.crashed:
            raise ClientCrashed(self.cid)
        if self.sched.clients.get(self.cid) is not self.client:
            # stale handle: the client left (or its cid was reused by a
            # later add_client) — reject rather than run on the wrong client
            raise ClientCrashed(self.cid,
                                "removed" if self.cid in self.sched.removed
                                else "replaced")
        futs = [KVFuture(self) for _ in ops]
        self._handles["ops"].value += len(ops)
        scans = [i for i, op in enumerate(ops)
                 if op.kind in ("scan", "range")]
        if scans and not self.client.pool.ordered_regions:
            # reject BEFORE submitting anything: raising mid-batch would
            # strand the already-accepted ops' futures
            raise OrderedIndexDisabled()
        hints: Dict[int, int] = {}
        if scans:
            if located is not None:
                hints = dict(zip(scans, located))
            elif self.client.ord_fences and len(scans) >= 2:
                # one vectorized leaf_probe call locates every scan of
                # the batch (the scan twin of the fused GET fast path)
                starts = [codec.encode_key(ops[i].key) for i in scans]
                hints = dict(zip(scans,
                                 ordered.locate_leaves(self.client, starts)))
                self._handles["scan_locate_batches"].value += 1
        batched: Dict[int, Any] = {}
        gets = [i for i, op in enumerate(ops) if op.kind == "search"]
        if (len(gets) >= self.batch_search_min and self.client.enable_cache
                and not self.client.crashed):
            batched = self._try_batch_search(ops, gets, futs, probed=probed)
        for i, op in enumerate(ops):
            if i in batched:
                continue
            try:
                self._submit_one(op, futs[i], hint=hints.get(i, -1))
            except ClientCrashed:
                if not (i or batched):
                    raise      # nothing accepted yet: reject the whole batch
                # the client died mid-batch (fault injection during the
                # backpressure pump): the batch was accepted, so its
                # remaining ops settle CRASHED like any in-flight work.
                for fut in futs[i:]:
                    if not fut.done():
                        fut._resolve(OpResult(CRASHED))
                break
        return futs

    def _submit_one(self, op: Op, fut: KVFuture, *, hint: int = -1):
        while self.max_inflight and self.sched.inflight(self.cid) >= self.max_inflight:
            self._pump()
        key = codec.encode_key(op.key) if op.key is not None else 0
        if op.kind in ("scan", "range"):
            if not self.client.pool.ordered_regions:
                raise OrderedIndexDisabled()
            self._handles["scans"].value += 1
            if op.kind == "scan":
                value = int(op.value)
                gen = self.client.op_scan(key, value, hint=hint)
            else:
                value = codec.encode_key(op.value)
                gen = self.client.op_range(key, value, hint=hint)
            fut.record = self.sched.submit(self.cid, op.kind, key, value,
                                           gen=gen)
            return
        value = codec.encode_value(op.value) if op.kind in ("insert", "update") \
            else None
        fut.record = self.sched.submit(self.cid, op.kind, key, value)

    # --------------------------------------------- batched SEARCH fast path
    def _try_batch_search(self, ops, gets, futs, *,
                          probed: Optional[list] = None) -> Dict[int, Any]:
        """Probe the batch's GET keys against a shadow of the client's index
        cache via the race_lookup kernel; fuse all confirmed-resident keys
        into one 1-RTT multi-key SEARCH.  Returns {op_index: key64} for the
        ops consumed by the fused path."""
        keys64 = [codec.encode_key(ops[i].key) for i in gets]
        hit_entries = probed if probed is not None \
            else self._kernel_probe(keys64)
        batch = [(i, k, ce) for i, k, ce in
                 zip(gets, keys64, hit_entries) if ce is not None]
        if len(batch) < self.batch_search_min:
            return {}
        self._handles["batch_lookups"].value += 1
        items = [(k, ce.slot_off, ce.slot_val) for (_, k, ce) in batch]
        rec = self.sched.submit(
            self.cid, "search_batch", None, None,
            gen=self.client.op_search_batch(items))

        def finish(record, batch=batch, futs=futs):
            if record.result.status != OK:
                # client crashed mid-flight: the fused op resolves CRASHED,
                # and so does every per-key future riding on it — no
                # resubmits (the client is dead), no leaked futures.
                res = OpResult(record.result.status)
                for (i, _key64, _ce) in batch:
                    futs[i]._resolve(res, record=record)
                return
            per_key = record.result.value
            for (i, key64, _ce), (stat, val) in zip(batch, per_key):
                if stat == OK:
                    res = OpResult(OK, value=val, rtts=1)
                    # per-key history record for the linearizability checker;
                    # rtts=0 — the single network RTT is tallied on the
                    # parent search_batch record, not once per key
                    sub = type(record)(
                        cid=record.cid, op_id=self.sched.next_op_id(),
                        kind="search", key=key64, value=None,
                        inv_tick=record.inv_tick, resp_tick=record.resp_tick,
                        result=res, rtts=0)
                    self.sched.history.append(sub)
                    futs[i]._resolve(res, record=sub)
                    self._handles["batch_fast_hits"].value += 1
                else:
                    # cache entry went stale mid-flight: full SEARCH,
                    # invoked at the batch's response tick
                    futs[i].record = self.sched.submit(self.cid, "search",
                                                       key64)
                    self._handles["batch_fallbacks"].value += 1

        rec.on_done = finish
        return {i: k for (i, k, _ce) in batch}

    def _cache_entries(self):
        """Cache entries eligible for the fused 1-RTT fast path: healthy
        invalid-ratio AND a current shard version — entries whose index
        shard migrated since fill are left to the full SEARCH path for
        revalidation (the keyed-by-shard-epoch cache contract)."""
        thr = self.client.cache_threshold
        directory = self.client.pool.directory
        return [(k, ce) for k, ce in self.client.cache.items()
                if ce.invalid_ratio <= thr
                and ce.shard_ver == directory.version(ce.region)
                ][:(1 << 24) - 2]

    def _cache_fingerprint(self):
        """Cheap dirty signal for the shadow memo: every cache mutation in
        client.py either changes the entry count or bumps an access /
        invalid counter, and every placement change (migration cutover,
        Alg-3 re-homing) bumps the directory generation.  A (rare) stale
        hit is safe — op_search_batch re-validates every entry against
        the heap and falls back."""
        cache = self.client.cache
        acc = inv = 0
        for ce in cache.values():
            acc += ce.access
            inv += ce.invalid
        return (len(cache), acc, inv, self.client.pool.directory.gen)

    def _shadow_index(self, entries):
        """Build the 32-bit shadow RACE index over the cache (vectorized;
        core/shadow.py).  Overflow entries are unreachable via the fast
        path — a miss, never a wrong hit."""
        keys32 = np.array([_fold32(k) for k, _ in entries], np.uint32)
        return build_shadow(keys32, spb=self.SHADOW_SPB)

    def _kernel_probe(self, keys64):
        """Match ``keys64`` against the client's index cache with one
        batched RACE probe (the race_lookup Pallas kernel on a memoized
        32-bit shadow index).  Returns a per-key list of
        CacheEntry-or-None."""
        fpr = self._cache_fingerprint()
        if self._shadow[0] == fpr:
            _, entries, shadow = self._shadow
        else:
            entries = self._cache_entries()
            shadow = self._shadow_index(entries)
            self._shadow = (fpr, entries, shadow)
            self._handles["shadow_rebuilds"].value += 1
        q = np.array([_fold32(k) for k in keys64], np.uint32)
        obs = self.sched.obs
        if obs is not None and len(q):
            # heat sketch over the RACE first-choice bucket family (the
            # fleet probe_wave records its own wave; the two paths are
            # mutually exclusive per batch, so no double-count)
            obs.heat_keys(_hash32_np(q, 1))
        if not entries:
            return [None] * len(keys64)
        ptr, found = self._race_lookup(q, shadow)
        out = []
        for j, k in enumerate(keys64):
            if found[j] and ptr[j] > 0:
                ekey, ce = entries[int(ptr[j]) - 1]
                # guard fp/fold collisions: the table entry must be OUR key
                if ekey == k:
                    out.append(ce)
                    continue
            out.append(None)
        return out

    def _race_lookup(self, q: np.ndarray, shadow: np.ndarray):
        if self.use_kernel:
            try:
                # batched kernel entry point: Pallas on TPU, the bit-exact
                # numpy mirror elsewhere (kernels/race_lookup/ops.py)
                from repro.kernels import race_lookup_batch
                return race_lookup_batch(q, shadow)
            except Exception:       # pragma: no cover - jax-less fallback
                pass
        return race_lookup_np(q, shadow)

    # -------------------------------------------------------------- driving
    def _pump(self):
        """One round-robin pass over every client with pending work.  The
        lane pick rotates so no (client, MN) QP queue starves behind a
        retry loop flooding another lane (see run_round_robin)."""
        cids = self.sched.eligible_cids()
        if not cids:
            raise SchedulerStalled(
                f"client {self.cid}: scheduler has no runnable work but "
                f"{self.sched.inflight(self.cid)} op(s) are unresolved — "
                "a future detached from its record (wiring bug)")
        for c in cids:
            self._pump_rr += 1
            self.sched.step(c, pick=self._pump_rr)

    def drive(self, fut: KVFuture):
        while not fut.done():
            self._pump()

    def drain(self):
        while self.sched.inflight(self.cid) > 0:
            self._pump()

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        recs = [r for r in self.sched.history
                if r.cid == self.cid and r.result is not None]
        rtts: Dict[str, list] = {}
        for r in recs:
            rtts.setdefault(r.kind, []).append(r.rtts)
        return {
            "backend": "sim",
            "cid": self.cid,
            "crashed": self.client.crashed,
            "epoch": self.client.epoch,
            "mns_alive": sum(m.alive for m in self.sched.pool.mns),
            "inflight": self.sched.inflight(self.cid),
            "completed_ops": len(recs),
            "crashed_ops": sum(r.result.status == CRASHED for r in recs),
            "avg_rtts_by_kind": {k: float(np.mean(v)) for k, v in rtts.items()},
            "cache_entries": len(self.client.cache),
            # inserts whose ordered-keydir entry hit FULL (scan-invisible
            # until the region is resized; size it for the keyspace —
            # benchmarks.common.fleet_dmconfig(ordered=True) does)
            "ord_full_drops": self.client.ord_full_drops,
            **{k: h.value for k, h in self._handles.items()},
            **self._hot_stats(),
        }

    def _hot_stats(self) -> Dict[str, Any]:
        """Hot-key monitor summary (cluster-wide, not per-cid) when the
        obs hub carries one — empty otherwise so baseline stats dicts are
        unchanged."""
        obs = self.sched.obs
        if obs is None or obs.hotspot is None:
            return {}
        hs = obs.hotspot
        return {
            "hot_keys": [k for k, _c, _e in hs.sketch.top(8)],
            "hot_theta_milli": int(round(hs.theta * 1000)),
            "hot_regime": hs.regime,
        }


# -------------------------------------------------------------------- store
class KVStore:
    """The unified client-facing store: pipelined batch ops over futures.

    One surface for both substrates — construct over ``SimBackend`` (the
    event-level protocol simulation; ``FuseeCluster.store()`` does this)
    or ``serving.DeviceBackend`` (the jitted device-resident pool).
    """

    def __init__(self, backend):
        self.backend = backend

    # ------------------------------------------------------------ pipelined
    def submit(self, op: Op) -> KVFuture:
        return self.backend.submit_many([op])[0]

    def submit_batch(self, ops: Sequence[Op]) -> List[KVFuture]:
        return self.backend.submit_many(list(ops))

    def drain(self):
        """Block until every op this store submitted has responded."""
        self.backend.drain()

    # ------------------------------------------------------------- blocking
    def get(self, key):
        """Value of ``key`` (decoded bytes / word list) or None."""
        r = self.submit(Op.get(key)).result()
        return r.value if r.status == OK else None

    def put(self, key, value) -> OpResult:
        return self.submit(Op.put(key, value)).result()

    def insert(self, key, value) -> OpResult:
        return self.submit(Op.insert(key, value)).result()

    def update(self, key, value) -> OpResult:
        return self.submit(Op.update(key, value)).result()

    def delete(self, key) -> OpResult:
        return self.submit(Op.delete(key)).result()

    def reclaim(self) -> OpResult:
        return self.submit(Op.reclaim()).result()

    def scan(self, start_key, count: int) -> List[tuple]:
        """The next ``count`` live keys >= start_key in key order, as
        ``[(key64, value), ...]`` (needs ``DMConfig.ordered_index=True``;
        integer keys scan in numeric order, byte/str keys in hashed-key
        order)."""
        r = self.submit(Op.scan(start_key, count)).result()
        return r.value if r.status == OK else []

    def range(self, start_key, end_key) -> List[tuple]:
        """Every live key in ``[start_key, end_key)`` with its value, in
        key order (needs ``DMConfig.ordered_index=True``)."""
        r = self.submit(Op.range(start_key, end_key)).result()
        return r.value if r.status == OK else []

    def stats(self) -> Dict[str, Any]:
        """Backend counters: RTT tallies, cache and pipeline state."""
        return self.backend.stats()

    def scan_stats(self) -> Dict[str, Any]:
        """Deprecated alias of :meth:`stats` (renamed so the name no
        longer collides with the SCAN verb)."""
        import warnings
        warnings.warn("KVStore.scan_stats() is deprecated; use "
                      "KVStore.stats()", DeprecationWarning, stacklevel=2)
        return self.stats()
