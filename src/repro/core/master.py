"""The FUSEE master (§5): a fault-tolerant cluster-management process.

The master is off every critical path; it only (1) initializes clients/MNs,
(2) recovers from MN crashes (Alg. 3 — representative-last-writer slot
repair + region re-homing), and (3) recovers crashed clients from their
embedded operation logs (§5.3: memory re-management + index repair).

Simplification vs. the paper (documented in DESIGN.md): the master itself is
assumed replicated/fault-tolerant (as in the paper) and its recovery
procedures execute atomically at one scheduler tick; client<->master RPCs are
charged `rpc_rtts` round trips by the network model.  The *client-side*
protocol under failures (Alg. 4) is fully interleaved and schedule-driven.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import layout as L
from . import ordered
from . import race
from .client import MASTER_COMMIT_MARK, FuseeClient
from .events import OK, OpResult
from .heap import (BAT_ORPHAN, INDEX_REGION, META_REGION,
                   META_WORDS_PER_CLIENT, DMPool)

# TEST-ONLY protocol hole: skip the §5.3 replica convergence of a crashed
# client's log-entry object before its redo re-installs the index slot.
# A client that dies mid-write-phase can leave the KV object on a subset
# of its replicas (the crash drops the remaining QP lanes); without the
# convergence the redo publishes a slot whose object exists only on the
# replica the log was read from, and a later MN recovery that loses that
# replica adopts an all-zero copy — the storm seeds-8/15 heap-audit
# failure.  The `loser_reset` model-checker scope
# (repro.analysis.explore) and regression tests re-enable the hole to
# prove the minimized schedule still reproduces it.
UNSAFE_REDO_NO_CONVERGE = False


@dataclass
class RecoveryStats:
    reconnect_ms: float = 0.0
    get_metadata_rtts: int = 0
    traverse_log_rtts: int = 0
    recover_requests_rtts: int = 0
    construct_free_list_rtts: int = 0
    redone_ops: int = 0
    fixed_primaries: int = 0
    reclaimed_objects: int = 0
    used_objects: int = 0


class Master:
    def __init__(self, pool: DMPool, *, reconnect_ms: float = 163.1):
        self.pool = pool
        self.reconnect_ms = reconnect_ms
        self.handled_mn_crashes: set = set()
        self.clients: Dict[int, FuseeClient] = {}
        # migration engine (core/migrate.py), wired by the cluster surface;
        # the master arbitrates its cutovers and aborts it around Alg-3
        self.migrator = None

    def register(self, client: FuseeClient):
        self.clients[client.cid] = client

    def deregister(self, cid: int):
        """Drop a removed client from membership (lease surrendered); it no
        longer receives prepare/commit notifications on recovery epochs."""
        self.clients.pop(cid, None)

    def release_client(self, cid: int):
        """Graceful leave (§5.2 membership change): scrub the client's meta
        words and re-tag its BAT entries as master-managed orphans, so a
        later holder of a reused cid inherits neither stale size-class list
        heads nor the leaver's blocks (whose live objects remain reachable
        through the index)."""
        pool = self.pool
        base = cid * META_WORDS_PER_CLIENT
        for i in range(len(pool.placement[META_REGION])):
            pool.write(META_REGION, i, base, [0] * META_WORDS_PER_CLIENT)
        for g in pool.data_regions:
            for rep_mid in pool.placement[g]:
                mn = pool.mns[rep_mid]
                if not mn.alive or g not in mn.regions:
                    continue
                bat = mn.regions[g]
                for b in range(pool.cfg.blocks_per_region):
                    if int(bat[b]) == cid + 1:
                        bat[b] = np.uint64(BAT_ORPHAN)
        self._resync_migrations()

    # ------------------------------------------------------------------ MN
    def detect_dead_mns(self) -> List[int]:
        return [m.mid for m in self.pool.mns
                if not m.alive and not m.retired
                and m.mid not in self.handled_mn_crashes]

    def commit_membership(self):
        """Commit a membership change (§5.2): bump the lease epoch and
        propagate it to every live client.  In-flight verbs stamped with
        the old epoch FAIL at execution and their ops retry — the same
        guard MN recovery uses.  Called for MN joins/retires and by every
        migration cutover."""
        self.pool.epoch += 1
        for c in self.clients.values():
            if not c.crashed:
                c.epoch = self.pool.epoch
                c.notified_prepare = False

    def commit_cutover(self, mig):
        """Atomically commit a completed region migration (the epoch-bump
        CAS cutover, arbitrated here so it serializes with Alg-3).

        For index shards the cutover first runs the Alg-3 slot repair
        across the *current alive* replicas: a SNAPSHOT round that
        straddles the cutover has its backup-CAS evidence only in the old
        backup arrays, and that evidence must be converged into every
        replica (committing the round's log) before roles change — the
        exact invariant MN recovery relies on ("backups are never older
        than the primary"); discarding it would let a later repair revert
        an acknowledged primary CAS.  After the repair all alive replicas
        agree, so the staged targets (bulk copy + dual-write mirror of
        the primary, resynced with the repaired slots here) equal the
        retained replicas, which keep their arrays.

        Then: install targets, re-home the region in the pinned directory
        (per-shard version bump), drop the copies of MNs leaving the
        replica set, close the dual-write window, and commit the
        membership epoch — in-flight verbs stamped with the old epoch
        FAIL and their ops retry."""
        pool = self.pool
        if mig.region in pool.index_region_set:
            self._repair_index_region(mig.region)
            prim = pool.mns[pool.placement[mig.region][0]]
            if prim.alive and mig.region in prim.regions:
                n = pool.cfg.index_words
                src = prim.regions[mig.region][:n]
                for arr in mig.targets.values():
                    arr[:n] = src
        elif mig.region in pool.ordered_region_set:
            # the ordered keydir migrates like an index shard: converge
            # straddling claim/clear rounds (adopt-backup + structural
            # repair) before roles change, then resync the staged targets
            ordered.repair_ordered(pool)
            prim = pool.mns[pool.placement[mig.region][0]]
            if prim.alive and mig.region in prim.regions:
                src = prim.regions[mig.region]
                for arr in mig.targets.values():
                    arr[:] = src
        old_reps = list(pool.placement[mig.region])
        for mid, arr in mig.targets.items():
            # install by copy into a slab-backed cell (heap.RegionSlab):
            # the staged target is a detached staging buffer, but every
            # *hosted* copy must live in the pool's flat slab so the fused
            # tick can address it
            mn = pool.mns[mid]
            if mig.region not in mn.regions:
                mn.host_region(mig.region)
            mn.regions[mig.region][:] = arr
        pool.directory.rehome(mig.region, mig.new_reps)
        for mid in old_reps:
            if mid not in mig.new_reps:
                pool.mns[mid].drop_region(mig.region)
        pool.migrations.pop(mig.region, None)
        self.commit_membership()
        # the repair's log commits may have poked objects in other
        # regions that are still mid-migration
        self._resync_migrations()

    def maybe_recover_mns(self) -> bool:
        dead = self.detect_dead_mns()
        if not dead:
            return False
        # in-flight migrations touching a dead MN are abandoned before
        # recovery re-homes anything (crash-during-migration arbitration:
        # nothing was installed, so aborting is always safe)
        if self.migrator is not None:
            self.migrator.abort_for_dead(dead)
        # disconnection phase: notify clients (lease expiry)
        for c in self.clients.values():
            if not c.crashed:
                c.notified_prepare = True
        for mid in dead:
            self.pool.directory.remove_member(mid)   # crash-stop: leaves ring
            self._recover_mn(mid)
            self.handled_mn_crashes.add(mid)
        # commit membership change
        self.commit_membership()
        self._resync_migrations()
        # re-plan aborted shard moves / pending drains on the new ring
        if self.migrator is not None:
            self.migrator.on_membership_change()
        return True

    def _slot_value_live(self, slot_val: int) -> bool:
        """May ``slot_val`` be adopted during repair?  A nonzero slot value
        whose object's used bit is already 0 is the *residue of a concluded
        round*: its writer lost, reset its embedded log (Alg 1 loser path)
        and may since have reclaimed and reused the object.  Adopting such
        a value resurrects a dead round — the index slot ends up
        referencing a reset object (heapcheck: "slot survived a loser
        reset", the storm-seeds-8/15 corruption).  Empty (0) values adopt
        freely (an in-flight DELETE broadcast)."""
        if slot_val == 0:
            return True
        ptr = L.slot_ptr(slot_val)
        region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
        n = L.size_class_words(L.slot_size_class(slot_val))
        for rep_mid in self.pool.placement.get(region, []):
            mn = self.pool.mns[rep_mid]
            if mn.alive and region in mn.regions:
                return bool(L.log_tail_used(
                    int(mn.regions[region][off + n - 1])))
        return False        # object unreadable: never adopt blind

    def _repair_index_region(self, g: int):
        """Alg 3, modification phase, for one index shard: for every slot
        where alive replicas disagree, adopt an alive *backup* value
        (backups are never older than the primary under SNAPSHOT) and
        commit that round's embedded log.  Shared by MN recovery and the
        migration cutover (which must converge straddling rounds before
        replica roles change).

        Adoption skips backup values whose round already concluded LOSE
        (``_slot_value_live``): only a value with a live embedded log may
        be installed, otherwise the first alive replica's value stands."""
        pool = self.pool
        reps = pool.placement[g]
        alive = [(i, r) for i, r in enumerate(reps) if pool.mns[r].alive]
        if not alive:
            return
        arrays = [pool.mns[r].regions[g] for _, r in alive]
        n = pool.cfg.index_words
        for off in range(n):
            vals = [int(a[off]) for a in arrays]
            if all(v == vals[0] for v in vals):
                continue
            backup_vals = [int(a[off]) for (i, _), a in zip(alive, arrays) if i > 0]
            chosen = next((v for v in backup_vals
                           if self._slot_value_live(v)), vals[0])
            for a in arrays:
                a[off] = np.uint64(chosen)
            self._commit_log_of(chosen)

    def _recover_mn(self, mid: int):
        pool = self.pool
        # 1. slot repair on the index (Alg 3, modification phase) — only
        #    the shards with a replica on the dead MN can have diverged
        #    from THIS crash
        for g in pool.index_regions:
            if mid in pool.placement[g]:
                self._repair_index_region(g)
        #    ... and the ordered keydir's adopt-backup + structural repair
        if any(mid in pool.placement[g] for g in pool.ordered_regions):
            ordered.repair_ordered(pool)
        # 2. region re-homing: every region with a replica on the dead MN gets
        #    a fresh replica on the next alive ring successor; the first alive
        #    replica becomes primary.
        alive_mids = [m.mid for m in pool.mns if m.alive]
        for g, reps in list(pool.placement.items()):
            if mid not in reps:
                continue
            survivors = [r for r in reps if pool.mns[r].alive]
            if not survivors:
                from .faults import RegionLost  # local: faults imports RecoveryStats
                raise RegionLost(g, f"placement {reps}, alive MNs "
                                    f"{alive_mids} (Alg-3 cannot re-home)")
            candidates = [m for m in alive_mids if m not in survivors]
            new_reps = survivors + candidates[:len(reps) - len(survivors)]
            pool.recover_mn_placement(g, new_reps)

    def _resync_migrations(self):
        """Master recovery procedures poke replica arrays directly (they
        run atomically at one tick), bypassing the pool's dual-write
        mirror.  Re-sync the already-copied prefix of every open migration
        window from its primary so staged targets never miss a repair."""
        pool = self.pool
        for g, mig in pool.migrations.items():
            prim = pool.placement[g][0]
            mn = pool.mns[prim]
            if mn.alive and g in mn.regions and mig.copied:
                src = mn.regions[g][:mig.copied]
                for arr in mig.targets.values():
                    arr[:mig.copied] = src

    def _commit_log_of(self, slot_val: int):
        """Write MASTER_COMMIT_MARK into the old_value field of the object the
        chosen slot value points to, so client recovery never redoes it."""
        if slot_val == 0:
            return
        ptr = L.slot_ptr(slot_val)
        sc = L.slot_size_class(slot_val)
        region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
        n = L.size_class_words(sc)
        crc = L.crc8([MASTER_COMMIT_MARK])
        for rep_mid in self.pool.placement.get(region, []):
            mn = self.pool.mns[rep_mid]
            if mn.alive and region in mn.regions:
                mem = mn.regions[region]
                mem[off + n - 3] = np.uint64(MASTER_COMMIT_MARK)
                mid_w = int(mem[off + n - 2])
                mem[off + n - 2] = np.uint64(int(L.pack_log_mid(
                    L.log_mid_next(mid_w), L.log_mid_opcode(mid_w), crc)))

    # ------------------------------------------------------------- queries
    def fail_query(self, slot_off: int, region: int = INDEX_REGION,
                   **_) -> Optional[int]:
        """Alg 4 line 35 + §A.4.3: decide (and complete) a contested slot
        of one index shard.

        If the backups agree on a value the primary does not hold, an
        in-flight SNAPSHOT round stalled — its winner crashed between the
        backup broadcast and the primary CAS, so pollers would wait
        forever.  The master arbitrates: it installs the backup-majority
        value on every replica and commits that round's embedded log (so
        §5.3 recovery never redoes it), then returns the decided value.
        Otherwise the primary value stands."""
        self.maybe_recover_mns()
        pool = self.pool
        reps = pool.placement[region]
        vals = []
        for i in range(len(reps)):
            v = pool.read(region, i, slot_off, 1)
            vals.append(None if v is None else int(v[0]))
        primary = vals[0]
        if primary is None:
            from .faults import RegionLost  # local: faults imports RecoveryStats
            raise RegionLost(region,
                             f"primary replica unreadable in fail_query "
                             f"(slot_off={slot_off}, placement={reps}) even "
                             "after maybe_recover_mns")
        backups = [v for v in vals[1:] if v is not None]
        # only values whose round is still live may be installed — the
        # residue of a concluded (reset) loser must never win arbitration
        # (same guard as _repair_index_region; storm seeds 8/15)
        live = [v for v in backups if self._slot_value_live(v)]
        if live:
            counts: Dict[int, int] = {}
            for v in live:
                counts[v] = counts.get(v, 0) + 1
            v_maj = max(counts, key=lambda k: (counts[k], -k))
            if (2 * counts[v_maj] >= len(backups)
                    and v_maj not in (primary, 0)):
                for i, v in enumerate(vals):
                    if v is not None:
                        pool.write(region, i, slot_off, [v_maj])
                self._commit_log_of(v_maj)
                self._resync_migrations()
                return v_maj
        return primary

    def bucket_query(self, off: int, region: int = INDEX_REGION):
        self.maybe_recover_mns()
        v = self.pool.read(region, 0, off, self.pool.cfg.slots_per_bucket)
        return list(v)

    # ------------------------------------------------------------- clients
    def recover_client(self, cid: int, *, reassign_to: Optional[FuseeClient] = None
                       ) -> RecoveryStats:
        """§5.3: memory re-management + index repair from the embedded log.

        Returns stats mirroring Table 1.  If ``reassign_to`` is given, the
        crashed client's blocks/free-lists are handed to that client
        (elastic replacement); otherwise they stay master-managed.
        """
        pool = self.pool
        st = RecoveryStats(reconnect_ms=self.reconnect_ms)
        self.maybe_recover_mns()
        # the crashed client may have died mid-leaf-split or mid-claim in
        # the ordered keydir: converge replicas, reap half-split leaves,
        # re-home stranded entries BEFORE replaying its embedded log (the
        # log replay below re-ensures entries for recovered keys)
        ordered.repair_ordered(pool)

        # -- step 1: find all blocks owned by cid via the BATs (MN-side scan)
        owned: List[Tuple[int, int]] = []  # (region, block_idx)
        for g in pool.data_regions:
            prim = pool.primary_mn(g)
            mem = pool.mns[prim].regions.get(g)
            if mem is None:
                continue
            for b in range(pool.cfg.blocks_per_region):
                if int(mem[b]) == cid + 1:
                    owned.append((g, b))
        st.construct_free_list_rtts += max(1, len(owned) // 16)

        # -- step 2: read per-size-class list heads (meta region)
        base = cid * META_WORDS_PER_CLIENT
        heads_raw = pool.read(META_REGION, 0, base, pool.cfg.size_classes)
        heads = [int(h) for h in (heads_raw if heads_raw is not None else [])]
        st.get_metadata_rtts += 1

        # -- step 3: traverse per-size-class linked lists; gather log entries
        tail_entries = []  # (ptr, sc, obj)
        for sc, head in enumerate(heads):
            if head == 0:
                continue
            ptr, hops, seen = head, 0, set()
            last_used = None
            while ptr != 0 and ptr not in seen and hops < 1 << 16:
                seen.add(ptr)
                hops += 1
                region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
                raw = pool.read(region, 0, off, L.size_class_words(sc))
                if raw is None:
                    break
                obj = L.parse_object(list(raw))
                st.traverse_log_rtts += 1
                if obj["used"]:
                    last_used = (ptr, sc, obj)
                    st.used_objects += 1
                ptr = obj["next_ptr"]
            if last_used is not None:
                tail_entries.append(last_used)

        # -- step 4: index repair (the at-most-one in-flight request per list)
        for (ptr, sc, obj) in tail_entries:
            st.recover_requests_rtts += 2
            self._repair_entry(cid, ptr, sc, obj, st)

        # -- step 5: memory re-management: scan blocks, rebuild free lists
        free_lists: Dict[int, List[int]] = {}
        for (g, b) in owned:
            mem = pool.mns[pool.primary_mn(g)].regions[g]
            bm_base = pool.bitmap_base(b)
            blk_base = pool.block_base(b)
            # size class of the block = inferred from first used object, else
            # reclaim whole block at min granularity
            sc = self._infer_block_sc(mem, blk_base)
            scw = L.size_class_words(sc)
            n_objs = pool.cfg.block_payload_words // scw
            for i in range(n_objs):
                off = blk_base + i * scw
                bit_idx = (off - blk_base) // L.MIN_OBJ_WORDS
                freed = bool(int(mem[bm_base + bit_idx // 64]) >> (bit_idx % 64) & 1)
                tail = int(mem[off + scw - 1])
                used = L.log_tail_used(tail)
                if used and not freed:
                    continue  # still-live object
                free_lists.setdefault(sc, []).append(L.pack_ptr(g, off))
                st.reclaimed_objects += 1
            st.construct_free_list_rtts += 1
            if reassign_to is not None:
                # re-own the block: rewrite BAT entries to the new client
                for rep_mid in pool.placement[g]:
                    mn = pool.mns[rep_mid]
                    if mn.alive and g in mn.regions:
                        mn.regions[g][b] = np.uint64(reassign_to.cid + 1)

        if reassign_to is not None:
            for sc, ptrs in free_lists.items():
                s = reassign_to._sc_state(sc)
                for p in ptrs:
                    s.free.append(p)
                for (g, b) in owned:
                    if (g, b) not in s.blocks:
                        s.blocks.append((g, b))
        self._resync_migrations()
        return st

    def _infer_block_sc(self, mem, blk_base: int) -> int:
        for sc in range(self.pool.cfg.size_classes):
            scw = L.size_class_words(sc)
            tail = int(mem[blk_base + scw - 1])
            if L.log_tail_used(tail):
                return sc
        return 0

    def _repair_entry(self, cid: int, ptr: int, sc: int, obj, st: RecoveryStats):
        """§5.3 index repair decision tree for one in-flight log entry."""
        pool = self.pool
        old_v = int(obj["old_value"])
        crc_ok = obj["old_crc"] == L.crc8([old_v]) and old_v != 0
        key = obj["key"]
        region = pool.index_region_of(key)     # shard routing (as clients do)
        v_new = int(L.pack_slot(L.fingerprint(key), sc, ptr))
        if not obj["crc_ok"]:
            # c0: crashed while writing the KV pair itself -> reclaim silently
            self._reclaim_obj(ptr, sc)
            return
        # the client may have crashed mid-write-phase with the KV object
        # landed on a subset of its replicas only (the crash drops the
        # remaining QP lanes).  Every branch below keeps the object
        # reachable, so converge the replicas from the copy the log was
        # validated against first — otherwise a later MN recovery can adopt
        # a torn (all-zero) copy and the index ends up referencing garbage
        # (storm seeds 8/15).
        if not UNSAFE_REDO_NO_CONVERGE:
            self._converge_obj_replicas(ptr, sc)
        if not crc_ok:
            # c1 (or a non-returned loser): old value incomplete -> REDO the
            # request on the client's behalf, via the normal SNAPSHOT path.
            st.redone_ops += 1
            self._redo(cid, key, obj, v_new, sc, ptr)
            return
        if old_v == MASTER_COMMIT_MARK:
            return  # already committed by the master during MN recovery
        # complete old value: the entry belongs to a round winner (c2/c3)
        slot_off = self._find_slot_of(key, old_v, v_new)
        if slot_off is None:
            return
        cur = pool.read(region, 0, slot_off, 1)
        if cur is not None and int(cur[0]) == old_v:
            # c2: winner crashed after commit, before the primary CAS
            for i in range(len(pool.placement[region])):
                pool.cas(region, i, slot_off, old_v, v_new)
            st.fixed_primaries += 1
        # else c3: finished; nothing to do
        if obj["opcode"] != L.OPCODE_DELETE:
            # the client may have crashed between its RACE commit and its
            # ordered-keydir ensure: restore scan visibility (§5.3)
            ordered.ensure_entry_direct(pool, key)

    def _find_slot_of(self, key: int, *vals) -> Optional[int]:
        cfg = self.pool.cfg
        region = self.pool.index_region_of(key)
        for off in race.slot_offsets(key, cfg.index_buckets, cfg.slots_per_bucket):
            cur = self.pool.read(region, 0, off, 1)
            if cur is not None and int(cur[0]) in [int(v) for v in vals]:
                return off
        return None

    def _redo(self, cid: int, key: int, obj, v_new: int, sc: int, ptr: int):
        """Re-execute the crashed request.  The KV object already exists, so
        the redo is the index write only, run through the SNAPSHOT protocol
        (the master acts as an ordinary writer, §5.4)."""
        opcode = obj["opcode"]
        target_v_new = 0 if opcode == L.OPCODE_DELETE else v_new
        cfg = self.pool.cfg
        region = self.pool.index_region_of(key)
        # locate the slot: existing entry for key, else an empty slot
        slot_off, v_old = None, 0
        offs = race.slot_offsets(key, cfg.index_buckets, cfg.slots_per_bucket)
        for off in offs:
            cur = self.pool.read(region, 0, off, 1)
            if cur is None:
                continue
            w = int(cur[0])
            if w != 0 and L.slot_fp(w) == L.fingerprint(key) and w != v_new:
                raw = self.pool.read(L.ptr_region(L.slot_ptr(w)), 0,
                                     L.ptr_offset(L.slot_ptr(w)),
                                     L.size_class_words(L.slot_size_class(w)))
                if raw is not None and L.parse_object(list(raw))["key"] == key:
                    slot_off, v_old = off, w
                    break
            if w == v_new:
                slot_off, v_old = off, w  # already applied
                break
        if slot_off is None:
            if opcode == L.OPCODE_DELETE:
                self._reclaim_obj(ptr, sc)
                return
            for off in offs:
                cur = self.pool.read(region, 0, off, 1)
                if cur is not None and int(cur[0]) == 0:
                    slot_off, v_old = off, 0
                    break
        if slot_off is None:
            return
        if v_old != int(target_v_new):
            # atomic redo: CAS backups then primary (master is the only
            # recovery writer for this client; concurrent client writers are
            # handled by CAS atomicity exactly as in SNAPSHOT)
            r = len(self.pool.placement[region])
            okb = all(int(self.pool.cas(region, i, slot_off, v_old,
                                        target_v_new)) == v_old
                      for i in range(1, r)) if r > 1 else True
            if okb:
                self.pool.cas(region, 0, slot_off, v_old, target_v_new)
        # commit the log so the op is never redone twice
        self._commit_log_of(v_new)
        if opcode == L.OPCODE_DELETE:
            self._reclaim_obj(ptr, sc)
        else:
            ordered.ensure_entry_direct(self.pool, key)

    def _converge_obj_replicas(self, ptr: int, sc: int) -> None:
        """§5.3: re-replicate a recovered log-entry object to all replicas.

        The embedded log is traversed on the primary replica, so the copy
        the repair decision was made from is authoritative; backup replicas
        that missed the crashed client's write phase are brought up to date
        before the entry is (re-)installed in the index.
        """
        pool = self.pool
        region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
        n = L.size_class_words(sc)
        src = pool.read(region, 0, off, n)
        if src is None:
            return
        words = [int(w) for w in src]
        for i in range(1, len(pool.placement.get(region, []))):
            cur = pool.read(region, i, off, n)
            if cur is not None and [int(w) for w in cur] != words:
                pool.write(region, i, off, words)

    def _reclaim_obj(self, ptr: int, sc: int):
        region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
        scw = L.size_class_words(sc)
        tail = int(L.pack_log_tail(0, used=False))
        for rep_mid in self.pool.placement.get(region, []):
            mn = self.pool.mns[rep_mid]
            if mn.alive and region in mn.regions:
                mn.regions[region][off + scw - 1] = np.uint64(tail)
