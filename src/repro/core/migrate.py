"""Live migration engine: online MN scale-out/in with shard re-homing.

``FuseeCluster.add_mn`` / ``remove_mn`` / ``rebalance`` land here.  The
engine re-homes regions (index shards, the meta region, and data regions)
shard-at-a-time through a three-stage state machine, DINOMO-style online
reconfiguration grafted onto FUSEE's lease-epoch membership model (§5.2):

1. **window open** — the region enters ``pool.migrations``: a fresh target
   array per destination MN, and from this instant every mutation applied
   to the *primary* replica is mirrored into the targets (the dual-write
   window; heap._mirror).  Placement, routing, and the data path are
   untouched — clients keep operating on the pinned old replica set.
2. **bulk copy** — each scheduler tick copies one chunk of the region from
   the primary into the targets via the pool's batched sweeps (a single
   ``read_batch`` serves every in-flight migration per tick), so a
   thousand-client fleet tick and a migration tick cost the same O(1)
   array calls.  Writes racing the copy are never lost: a chunk already
   copied receives them through the mirror, a chunk not yet copied picks
   them up from the (authoritative) primary when its turn comes.
3. **cutover** — when the copy completes, the *master* commits the move
   atomically at a tick boundary: target arrays are installed, the
   directory re-homes the region (version bump), MNs leaving the replica
   set drop their copy, and the lease epoch is CAS-bumped cluster-wide.
   In-flight verbs stamped with the old epoch FAIL and their ops retry —
   exactly the PR-3 stale-epoch guard that MN recovery already uses.

Fresh destinations cut over with a staged copy of the primary; replicas
retained across the cutover keep their own arrays.  For index shards the
master runs the Alg-3 slot repair immediately before installing — a
SNAPSHOT round that straddles the cutover has its backup-CAS evidence
only in the old backup arrays, and converging that evidence into every
replica (committing the round's log) before roles change preserves the
"backups are never older than the primary" invariant that both repair
and ``fail_query`` arbitration rely on.  Discarding it instead would let
a *later* repair revert an acknowledged primary CAS.

Crash-during-migration: if any participant (source primary, a target, a
retained survivor) dies before cutover, the migration **aborts** — the
window closes, targets are dropped, nothing was ever installed — and
Alg-3 recovery re-homes the region as usual; the engine re-plans from
the post-recovery ring (``on_membership_change``).  The state machine
therefore never has a half-cut-over region: a region is either entirely
on its old replica set or entirely on its new one.

Determinism: the engine makes no random choices — regions are planned
and copied in sorted order with a fixed chunk size — so migration runs
are bit-identically replayable from ``(seed, config)`` plus the same
membership-call sequence (FaultPlan add_mn/remove_mn events included).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Set

import numpy as np

from . import layout as L
from .faults import InsufficientReplicas, SchedulerStalled
from .heap import DMPool
from .ring import ring_replicas
from ..obs.registry import LegacyCounters, legacy_counters_view

__all__ = ["MigrationEngine", "RegionMigration"]

# words bulk-copied per migrating region per scheduler tick; small enough
# that a migration spans many ticks (a real dual-write window under load),
# large enough that a 2^15-word region moves in a handful of sweeps
CHUNK_WORDS = 4096


@dataclass
class RegionMigration:
    """One region mid-flight: state 'copy' until ``copied`` reaches the
    region size, then the master cuts it over."""
    region: int
    source: int                      # primary MN the copy reads from
    new_reps: List[int]
    targets: Dict[int, np.ndarray]   # destination mid -> staged array
    dir_version: int                 # directory version at window open
    copied: int = 0

    @property
    def state(self) -> str:
        return "copy"


class MigrationEngine:
    """Plans and drives region migrations over a cluster's scheduler.
    One engine per cluster; installed as a scheduler tick hook while any
    migration or pending MN removal is in flight."""

    def __init__(self, pool: DMPool, master, scheduler, *,
                 chunk_words: int = CHUNK_WORDS):
        self.pool = pool
        self.master = master
        self.sched = scheduler
        self.chunk_words = chunk_words
        self.active: Dict[int, RegionMigration] = {}
        self.removing: Set[int] = set()        # mids draining toward retire
        self._hooked = False
        # manual mode (model checking): migration advances ONLY through an
        # armed scheduler event (store.arm_migration_event), never through
        # the auto tick hook — begin_tick runs inside every fired choice,
        # so the hook would move the cutover boundary outside the
        # checker's enumerated schedule.
        self.manual = False
        # migration counters live in the scheduler's metrics registry
        # under "migrate.<name>"; the old ``counters`` dict survives one
        # release as a read-only deprecation alias (see obs/registry.py).
        self._handles = {
            k: scheduler.metrics.counter("migrate." + k)
            for k in ("migrations", "cutovers", "aborts", "copied_words",
                      "adds", "removes", "retires")}

    @property
    def counters(self) -> LegacyCounters:
        """Deprecated read-only view of the migration metrics under their
        historical key names; read the registry instead."""
        return legacy_counters_view("MigrationEngine", self._handles)

    # ----------------------------------------------------------- public API
    def add_mn(self) -> int:
        """Join a fresh MN: commit it to the membership ring, grant it
        fresh (empty) data regions, and start re-homing index shards onto
        the grown ring.  Returns the new mid immediately — the shard
        migrations ride subsequent scheduler ticks."""
        pool = self.pool
        mid = pool.add_node()
        pool.add_data_regions(mid)
        self._handles["adds"].value += 1
        obs = self.sched.obs
        if obs is not None:
            obs.fault("add_mn", mid, self.sched.tick)
        # membership commit: new MR visible, stale verbs FAIL and retry
        self.master.commit_membership()
        self._plan_index_rebalance()
        self._ensure_hook()
        return mid

    def remove_mn(self, mid: int):
        """Gracefully drain an MN: every region it hosts is migrated to
        the shrunk ring; once the last one cuts over the node retires.
        Raises the typed ``InsufficientReplicas`` if removal would leave
        fewer members than the replication factor."""
        pool = self.pool
        if mid >= len(pool.mns) or pool.mns[mid].retired \
                or mid not in pool.directory.members:
            raise ValueError(f"MN {mid} is not a removable member")
        if not pool.mns[mid].alive:
            raise ValueError(f"MN {mid} is crashed; Alg-3 recovery (not "
                             "remove_mn) re-homes its regions")
        members_after = [m for m in pool.directory.members if m != mid]
        if len(members_after) < pool.cfg.replication:
            raise InsufficientReplicas(
                f"removing MN {mid} leaves {len(members_after)} members < "
                f"replication factor {pool.cfg.replication}")
        pool.directory.remove_member(mid)
        self.removing.add(mid)
        self._handles["removes"].value += 1
        obs = self.sched.obs
        if obs is not None:
            obs.fault("remove_mn", mid, self.sched.tick)
        # in-flight migrations may still be HEADED for the draining MN
        # (e.g. shard moves planned by a recent add_mn): abort them before
        # re-planning, or their cutovers would install regions onto the
        # node we are emptying and nothing would ever move them off again
        for g in sorted(self.active):
            if mid in self.active[g].new_reps:
                self._abort(g)
        self._plan_index_rebalance()
        self._plan_drain(mid)
        self._ensure_hook()

    def rebalance(self) -> int:
        """Re-place index shards on the current membership ring; returns
        the number of shard migrations started."""
        n = self._plan_index_rebalance()
        self._ensure_hook()
        return n

    def drive(self, max_ticks: int = 1_000_000) -> int:
        """Tick the scheduler until every migration completed and every
        draining MN retired (for callers with no concurrent workload —
        under live traffic the migrations ride the workload's own ticks).
        Returns ticks spent."""
        t = 0
        while self.active or self.removing:
            if t >= max_ticks:
                raise SchedulerStalled(
                    f"migration did not converge after {t} ticks: "
                    f"{sorted(self.active)} active, "
                    f"{sorted(self.removing)} draining")
            self.sched.begin_tick()
            t += 1
        return t

    @property
    def busy(self) -> bool:
        return bool(self.active or self.removing)

    def status(self) -> List[Dict]:
        """Per-migration progress snapshot (health/observability)."""
        total = self.pool.cfg.region_words
        return [{"region": g, "state": m.state, "source": m.source,
                 "new_reps": list(m.new_reps),
                 "copied": m.copied, "total": total}
                for g, m in sorted(self.active.items())]

    # ------------------------------------------------------------- planning
    def _plan_index_rebalance(self) -> int:
        desired = self.pool.desired_index_placement()
        return sum(self._start(g, desired[g])
                   for g in sorted(desired))

    def _plan_drain(self, mid: int):
        """Plan migrations for every non-index region still replicated on
        ``mid`` (data + meta; index shards and the ordered keydir region
        go through the rebalance)."""
        pool = self.pool
        members = pool.directory.members
        for g in sorted(pool.placement):
            reps = pool.placement[g]
            if mid not in reps or g in pool.index_region_set \
                    or g in pool.ordered_region_set:
                continue
            survivors = [m for m in reps if m != mid]
            # full ring order from the region's hash start (one source of
            # truth for the ring math: ring.ring_replicas)
            ring_order = ring_replicas(g, members, len(members))
            fill = [m for m in ring_order if m not in survivors]
            want = min(len(reps), len(members))
            new_reps = (survivors + fill)[:want]
            self._start(g, new_reps)

    def _start(self, region: int, new_reps: List[int]) -> bool:
        pool = self.pool
        cur = pool.placement[region]
        if list(cur) == list(new_reps) or region in self.active:
            return False
        source = cur[0]
        # only destinations not already hosting the region get a staged
        # copy; retained replicas keep their arrays — their backup-CAS
        # evidence for rounds straddling the cutover is converged by the
        # master's pre-cutover Alg-3 slot repair (master.commit_cutover)
        targets = {m: np.zeros(pool.cfg.region_words, np.uint64)
                   for m in new_reps
                   if region not in pool.mns[m].regions}
        mig = RegionMigration(region=region, source=source,
                              new_reps=list(new_reps), targets=targets,
                              dir_version=pool.directory.version(region))
        pool.migrations[region] = mig
        self.active[region] = mig
        self._handles["migrations"].value += 1
        obs = self.sched.obs
        if obs is not None:
            obs.migration("start", region, self.sched.tick)
        return True

    # ------------------------------------------------------------- ticking
    def _ensure_hook(self):
        if not self._hooked and not self.manual:
            self.sched.add_tick_hook(self._tick_hook)
            self._hooked = True

    def _tick_hook(self, sched):
        self.tick()
        if not self.active and not self.removing:
            sched.remove_tick_hook(self._tick_hook)
            self._hooked = False

    def tick(self):
        """One migration tick: a chunk of every in-flight region copied
        with a single batched sweep, cutovers committed for completed
        copies, retires finalized for drained MNs."""
        pool = self.pool
        pending = []
        for g in sorted(self.active):
            mig = self.active[g]
            if pool.placement[g][0] != mig.source \
                    or pool.directory.version(g) != mig.dir_version:
                # the region was re-homed under us (Alg-3 recovery): our
                # copied prefix came from a replaced primary — abort and
                # let on_membership_change re-plan from the new ring
                self._abort(g)
                continue
            if any(not pool.mns[m].alive for m in mig.new_reps) \
                    or not pool.mns[mig.source].alive:
                self._abort(g)
                continue
            if mig.copied < pool.cfg.region_words:
                pending.append(mig)
        if pending:
            n = self.chunk_words
            rows = pool.read_batch([m.region for m in pending],
                                   [0] * len(pending),
                                   [m.copied for m in pending],
                                   [min(n, pool.cfg.region_words - m.copied)
                                    for m in pending])
            for mig, words in zip(pending, rows):
                if words is None:      # source died between checks
                    self._abort(mig.region)
                    continue
                for mid, arr in mig.targets.items():
                    arr[mig.copied:mig.copied + len(words)] = words
                    pool.mn_bytes[mid] += len(words) * L.WORD
                mig.copied += len(words)
                self._handles["copied_words"].value += len(words)
        for g in sorted(self.active):
            mig = self.active[g]
            if mig.copied >= pool.cfg.region_words:
                self.active.pop(g)
                self.master.commit_cutover(mig)
                self._handles["cutovers"].value += 1
                obs = self.sched.obs
                if obs is not None:
                    obs.migration("cutover", g, self.sched.tick)
        self._finalize_retires()

    def _finalize_retires(self):
        pool = self.pool
        for mid in sorted(self.removing):
            if not pool.mns[mid].alive:     # crashed while draining: the
                self.removing.discard(mid)  # drain became an Alg-3 recovery
                continue
            if pool.mns[mid].regions:
                continue
            pool.retire_node(mid)
            self.removing.discard(mid)
            self._handles["retires"].value += 1
            self.master.commit_membership()

    def _abort(self, region: int):
        self.pool.migrations.pop(region, None)
        self.active.pop(region, None)
        self._handles["aborts"].value += 1
        obs = self.sched.obs
        if obs is not None:
            obs.migration("abort", region, self.sched.tick)

    # ------------------------------------------------------------ recovery
    def abort_for_dead(self, dead: List[int]):
        """Called by the master *before* Alg-3 recovery: any migration
        whose source, targets, or retained survivors include a dead MN is
        abandoned (the window closes; nothing was installed)."""
        dead_set = set(dead)
        for g in sorted(self.active):
            mig = self.active[g]
            involved = {mig.source, *mig.targets, *mig.new_reps,
                        *self.pool.placement[g]}
            if involved & dead_set:
                self._abort(g)

    def on_membership_change(self):
        """Called by the master *after* Alg-3 recovery committed: re-plan
        aborted shard moves and still-draining removals against the
        post-recovery ring."""
        self._plan_index_rebalance()
        for mid in sorted(self.removing):
            if self.pool.mns[mid].alive:
                self._plan_drain(mid)
        if self.active or self.removing:
            self._ensure_hook()
