"""Jax-free NumPy mirror of the RACE probe (kernels/race_lookup/ref.py),
bit-exact, plus the vectorized shadow-index builder.

Lives in core (not kernels) so the event-level simulator (core/api.py,
core/fleet.py) shares one hash/probe implementation with the kernel stack
without importing jax — the simulator must stay runnable in jax-less
environments, and a thousand-client fleet tick must not pay
interpret-mode Pallas on CPU.  kernels/race_lookup/ops.py imports these
as the host-side fallback of its batched entry point; the bit-exactness
of ``hash32_np`` against the in-kernel hash is pinned by
tests/test_api.py::test_shadow_hash_matches_kernel_ref.
"""
from __future__ import annotations

import numpy as np

MASK24 = (1 << 24) - 1


def hash32_np(x: np.ndarray, seed: int) -> np.ndarray:
    """NumPy mirror of ref.py::hash32 (uint32 lanes)."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint32) + np.uint32((0x9E3779B9 * (seed + 1))
                                            & 0xFFFFFFFF)
        x = (x ^ (x >> np.uint32(16))) * np.uint32(0x85EBCA6B)
        x = (x ^ (x >> np.uint32(13))) * np.uint32(0xC2B2AE35)
        return (x ^ (x >> np.uint32(16))).astype(np.uint32)


def race_lookup_np(q: np.ndarray, table: np.ndarray):
    """NumPy mirror of race_lookup_ref: one vectorized gather + match.

    q: (N,) uint32 keys; table: (nb, spb) uint32 slots (fp:8 | ptr:24).
    Returns (ptr (N,) uint32 — 0 on miss, found (N,) bool)."""
    q = np.asarray(q, np.uint32)
    fpq = (hash32_np(q, 7) >> np.uint32(24)).astype(np.uint32)
    fpq = np.where(fpq == 0, np.uint32(1), fpq)
    nb = table.shape[0]
    b1 = hash32_np(q, 1) % nb
    b2 = hash32_np(q, 2) % nb
    b2 = np.where(b2 == b1, (b1 + 1) % nb, b2)
    rows = np.concatenate([table[b1], table[b2]], axis=1)
    match = (rows >> np.uint32(24)) == fpq[:, None]
    any_m = match.any(axis=1)
    first = match.argmax(axis=1)
    picked = np.take_along_axis(rows, first[:, None], axis=1)[:, 0]
    return np.where(any_m, picked & np.uint32(MASK24), np.uint32(0)), any_m


def build_shadow(keys32: np.ndarray, *, spb: int = 8,
                 min_buckets: int = 16) -> np.ndarray:
    """Vectorized construction of a 32-bit shadow RACE index over ``keys32``
    (entry i is stored as ``fp<<24 | i+1``).  Cuckoo-lite placement, fully
    array-level (no per-entry Python loop — this runs on every fleet tick
    whose caches moved): pass 1 ranks entries within their first-choice
    bucket; overflow retries in the second-choice bucket on top of pass-1
    occupancy; residual overflow is simply unreachable via the fast path
    (callers fall back to a full SEARCH), never wrong."""
    keys32 = np.asarray(keys32, np.uint32)
    n = len(keys32)
    nb = min_buckets
    while nb * spb < 4 * n:
        nb *= 2
    shadow = np.zeros((nb, spb), np.uint32)
    if n == 0:
        return shadow
    fp = (hash32_np(keys32, 7) >> np.uint32(24)).astype(np.uint32)
    fp = np.where(fp == 0, np.uint32(1), fp)
    b1 = (hash32_np(keys32, 1) % nb).astype(np.int64)
    b2 = (hash32_np(keys32, 2) % nb).astype(np.int64)
    b2 = np.where(b2 == b1, (b1 + 1) % nb, b2)
    slot = (fp << np.uint32(24)) | (np.arange(1, n + 1, dtype=np.uint32)
                                    & np.uint32(MASK24))

    def _rank_within(sorted_groups: np.ndarray) -> np.ndarray:
        first = np.searchsorted(sorted_groups, sorted_groups, side="left")
        return np.arange(len(sorted_groups)) - first

    order1 = np.argsort(b1, kind="stable")
    rank1 = _rank_within(b1[order1])
    fit1 = rank1 < spb
    placed1 = order1[fit1]
    shadow[b1[placed1], rank1[fit1]] = slot[placed1]

    spill = order1[~fit1]
    if len(spill):
        base = np.minimum(np.bincount(b1, minlength=nb), spb)  # pass-1 fill
        order2 = spill[np.argsort(b2[spill], kind="stable")]
        col = _rank_within(b2[order2]) + base[b2[order2]]
        fit2 = col < spb
        placed2 = order2[fit2]
        shadow[b2[placed2], col[fit2]] = slot[placed2]
    return shadow
