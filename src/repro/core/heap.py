"""The disaggregated-memory pool: passive memory nodes + one-sided verbs.

This is the event-level (NumPy) substrate used by the protocol simulator,
tests, and the paper benchmarks.  It models exactly what the paper's MNs
provide (§2.1): READ / WRITE / CAS / FAA at 8-byte-word atomicity, plus the
compute-light ALLOC/FREE RPC handled by the MN's 1-2 weak cores.

Faithfulness notes
------------------
* A verb addressed to a crashed MN returns ``FAIL`` (layout.FAIL) — the
  crash-stop model of §5.1.
* Verbs are atomic at word granularity; multi-word READ/WRITE are *not*
  atomic as a group unless executed within one scheduler tick.  The scheduler
  (sim.py) interleaves verbs from different clients arbitrarily while
  preserving per-(client, MN) FIFO order, which is the RDMA QP ordering the
  paper's used-bit argument relies on.
* Memory is organized as 2GB-analogue *regions*, consistent-hashed onto r MNs
  (FaRM-style, §4.4).  A 48-bit pointer names (region, offset) so one pointer
  resolves to all r physical replicas.
* The hash index is split into ``index_shards`` shard regions (S=1 is the
  degenerate classic layout).  A key's shard is a pure hash of the key;
  each shard is a full RACE table placed independently on the ring
  (core/ring.py) so index traffic and CAS hot words spread across
  min(S, num_mns) MNs instead of all landing on the same r nodes.
* Placement is **pinned** in an epoch-versioned ``PlacementDirectory`` and
  changes only through Alg-3 recovery or the migration engine's cutover
  (core/migrate.py) — never by recomputing a ring over the alive list.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import chain
from typing import Dict, List, Optional

import numpy as np

from . import layout as L
from .ring import PlacementDirectory, ring_replicas


@dataclass
class DMConfig:
    num_mns: int = 4
    replication: int = 2            # r: data + index replication factor
    region_words: int = 1 << 14     # scaled-down 2 GB region
    block_words: int = 1 << 9       # scaled-down 16 MB block
    regions_per_mn: int = 8         # primary regions initially owned per MN
    index_buckets: int = 256        # RACE: combined-bucket count (power of 2)
    slots_per_bucket: int = 7
    size_classes: int = 6
    index_shards: int = 1           # S: independent RACE shard regions
    # Ordered secondary index (core/ordered.py): a replicated keydir of
    # fat leaves in its own region, enabling SCAN/RANGE.  Off by default —
    # the classic layout and per-op RTT counts are bit-identical.
    ordered_index: bool = False
    # network model constants live in netmodel.py; kept out of the pool.

    @property
    def blocks_per_region(self) -> int:
        # one BAT word per block, bitmap ahead of each block's payload
        return self.region_words // (self.block_words + 1)

    @property
    def bat_words(self) -> int:
        return self.blocks_per_region

    @property
    def bitmap_words(self) -> int:
        max_objs = self.block_words // L.MIN_OBJ_WORDS
        return (max_objs + 63) // 64

    @property
    def block_payload_words(self) -> int:
        return self.block_words - self.bitmap_words

    @property
    def index_words(self) -> int:
        return self.index_buckets * self.slots_per_bucket


INDEX_REGION = 0   # replicated hash-index region (shard 0; extra shards get
                   # their own region ids after the initial data regions)
META_REGION = 1    # per-client metadata (per-size-class list heads)
FIRST_DATA_REGION = 2
SHARD_HASH_SEED = 11   # key -> index shard (pure hash, never placement)

META_WORDS_PER_CLIENT = 64  # sc list heads + scratch

# BAT owner tag for blocks surrendered by a gracefully-removed client:
# nonzero (never re-allocated by the MN) and above any cid+1, so a later
# holder of a reused cid never inherits them; their live objects stay
# readable through the index.
BAT_ORPHAN = 1 << 32


class RegionSlab:
    """Flat backing store for every hosted region copy.

    One contiguous uint64 buffer carved into region-sized *cells*; each
    ``MemoryNode.regions[g]`` entry is a zero-copy view of one cell, so
    all existing per-region code is unchanged while the fused tick
    (``DMPool.exec_fused_tick``) can gather/scatter/CAS an entire tick's
    verbs against the single flat buffer with **global word addresses**
    (``cell * region_words + offset``) — no per-(region, replica) group
    dispatch.

    Growth doubles the buffer and re-binds every registered node's views;
    nothing outside ``MemoryNode.regions`` may hold a cell view across a
    carve (callers that copy regions snapshot with ``.copy()`` first).
    """

    def __init__(self, region_words: int, capacity: int = 8):
        self.region_words = region_words
        self.capacity = max(1, capacity)
        self.buf = np.zeros(self.capacity * region_words, np.uint64)
        # free cells, descending, so pop() hands out the lowest cell first
        self._free = list(range(self.capacity - 1, -1, -1))
        self.cells: Dict[tuple, int] = {}      # (mid, region) -> cell
        self._nodes: List["MemoryNode"] = []   # rebind targets on growth
        self.gen = 0        # bumped on carve/release: cell-map version

    def register(self, mn: "MemoryNode"):
        self._nodes.append(mn)

    def view(self, cell: int) -> np.ndarray:
        rw = self.region_words
        return self.buf[cell * rw:(cell + 1) * rw]

    def carve(self, mid: int, region: int) -> np.ndarray:
        """Allocate (and zero) a cell for one region copy."""
        if not self._free:
            self._grow()
        cell = self._free.pop()
        self.cells[(mid, region)] = cell
        self.gen += 1
        v = self.view(cell)
        v[:] = 0
        return v

    def release(self, mid: int, region: int):
        cell = self.cells.pop((mid, region), None)
        if cell is not None:
            self._free.append(cell)
            self.gen += 1

    def _grow(self):
        old_cap = self.capacity
        self.capacity = old_cap * 2
        buf = np.zeros(self.capacity * self.region_words, np.uint64)
        buf[:self.buf.size] = self.buf
        self.buf = buf
        self._free.extend(range(self.capacity - 1, old_cap - 1, -1))
        for mn in self._nodes:
            for (mid, region), cell in self.cells.items():
                if mid == mn.mid and region in mn.regions:
                    mn.regions[region] = self.view(cell)


class MemoryNode:
    """A passive memory node.  Owns replica copies of regions."""

    def __init__(self, mid: int, cfg: DMConfig,
                 slab: Optional[RegionSlab] = None):
        self.mid = mid
        self.cfg = cfg
        self.alive = True
        self.retired = False            # gracefully removed (not crashed)
        self.regions: Dict[int, np.ndarray] = {}
        self._slab = slab               # pool-shared flat backing store
        # MN-side coarse allocation cursor per primary region (compute-light)
        self.alloc_cursor: Dict[int, int] = {}
        self.cpu_ops = 0  # number of MN-CPU operations served (for netmodel)
        if slab is not None:
            slab.register(self)

    def host_region(self, region_id: int):
        if self._slab is not None:
            self.regions[region_id] = self._slab.carve(self.mid, region_id)
        else:
            self.regions[region_id] = np.zeros(self.cfg.region_words,
                                               dtype=np.uint64)

    def drop_region(self, region_id: int):
        if self.regions.pop(region_id, None) is not None \
                and self._slab is not None:
            self._slab.release(self.mid, region_id)


class DMPool:
    """The full memory pool + placement. Verbs are synchronous and atomic."""

    def __init__(self, cfg: DMConfig, num_clients: int = 64, seed: int = 0):
        self.cfg = cfg
        self.num_clients = num_clients
        # flat backing store for every hosted region copy (fused tick
        # substrate); sized for the initial placement, grows by doubling
        r_eff = min(cfg.replication, cfg.num_mns)
        init_cells = (cfg.num_mns * cfg.regions_per_mn + 1
                      + cfg.index_shards + int(cfg.ordered_index)) * r_eff
        self.slab = RegionSlab(cfg.region_words, capacity=init_cells + 2)
        self.mns = [MemoryNode(i, cfg, self.slab) for i in range(cfg.num_mns)]
        self.epoch = 0
        # pinned, epoch-versioned region -> ordered MN list (replica 0 =
        # primary); mutated ONLY by recovery/migration (ring.py)
        self.directory = PlacementDirectory(cfg.replication,
                                            list(range(cfg.num_mns)))
        # regions undergoing live migration: region -> migrate.RegionMigration
        # (writes to the primary replica are mirrored into the targets —
        # the dual-write window of the shard migration state machine)
        self.migrations: Dict[int, object] = {}
        self._place_initial(seed)
        # traffic accounting (bytes in+out per MN) for the network model
        self.mn_bytes = np.zeros(cfg.num_mns, dtype=np.int64)
        # verb tracer (repro.analysis.trace) — None unless attached; the
        # tracer installs instance-attribute wrappers over the verb
        # methods, so the un-attached pool pays zero per-verb cost
        self._tracer = None
        # observability hub (repro.obs.ClusterObs) — None unless attached
        # by the cluster surface; client.py's scalar cache path feeds the
        # heat sketch through it (one is-None test when detached)
        self._obs = None
        # fused-tick (region, replica) -> (cell, mid) lookup table, cached
        # until the topology token changes (see _fused_cells)
        self._fused_lut = None
        self._alive_gen = 0     # bumped whenever an MN leaves the pool

    # ---------------- placement -------------------------------------------
    @property
    def placement(self) -> Dict[int, List[int]]:
        """The pinned placement table (read-only view; mutate through
        ``directory.rehome`` / ``recover_mn_placement`` only)."""
        return self.directory.table

    def _place_initial(self, seed: int):
        cfg = self.cfg
        data_count = cfg.num_mns * cfg.regions_per_mn
        self.data_regions: List[int] = list(
            range(FIRST_DATA_REGION, FIRST_DATA_REGION + data_count))
        # extra index shards live after the initial data regions so the
        # S=1 layout is bit-identical to the classic single-table one
        self.index_regions: List[int] = [INDEX_REGION] + [
            FIRST_DATA_REGION + data_count + i
            for i in range(cfg.index_shards - 1)]
        self.index_region_set = frozenset(self.index_regions)
        self.num_regions = FIRST_DATA_REGION + data_count \
            + (cfg.index_shards - 1)
        # the ordered keydir region (core/ordered.py) lives after the
        # index shards; strided on the ring like them, first-class for
        # migration/recovery.  Absent entirely when ordered_index=False.
        self.ordered_regions: List[int] = []
        if cfg.ordered_index:
            self.ordered_regions = [self.num_regions]
            self.num_regions += 1
        self.ordered_region_set = frozenset(self.ordered_regions)
        shard_placement = self.desired_index_placement()
        for g in range(FIRST_DATA_REGION, FIRST_DATA_REGION + data_count):
            self._host_all(g, self.directory.place(g))
        self._host_all(META_REGION, self.directory.place(META_REGION))
        for g in self.index_regions + self.ordered_regions:
            self._host_all(g, self.directory.pin(g, shard_placement[g]))
        if self.ordered_regions:
            from . import ordered                 # local: layering, no cycle
            ordered.init_region(self, self.ordered_regions[0])

    def _host_all(self, region: int, reps: List[int]):
        for mid in reps:
            if region not in self.mns[mid].regions:
                self.mns[mid].host_region(region)

    def desired_index_placement(self) -> Dict[int, List[int]]:
        """Where the index shards — and the ordered keydir region —
        *should* live on the current membership ring: shard 0 at the
        classic hash start (S=1 layout unchanged), shard s offset by s so
        S shards spread over min(S, N) MNs; the ordered region continues
        the stride after the shards.  The migration engine diffs this
        against the pinned table to plan shard-at-a-time re-homing after
        add_mn/remove_mn."""
        members = self.directory.members
        n = len(members)
        start0 = L.hash64(INDEX_REGION, seed=3) % n
        return {g: ring_replicas(g, members, self.cfg.replication,
                                 start=(start0 + s) % n)
                for s, g in enumerate(self.index_regions
                                      + self.ordered_regions)}

    # ---------------- key -> shard routing ---------------------------------
    @property
    def num_shards(self) -> int:
        return len(self.index_regions)

    def shard_of(self, key: int) -> int:
        """Index shard of a key: a pure key hash, independent of placement
        (re-homing a shard never re-shards keys)."""
        if len(self.index_regions) == 1:
            return 0
        return L.hash64(key, seed=SHARD_HASH_SEED) % len(self.index_regions)

    def index_region_of(self, key: int) -> int:
        return self.index_regions[self.shard_of(key)]

    def replicas(self, region_id: int) -> List[int]:
        return self.directory.table[region_id]

    def primary_mn(self, region_id: int) -> int:
        return self.directory.table[region_id][0]

    def data_regions_of_mn(self, mid: int) -> List[int]:
        return [g for g in self.data_regions
                if self.directory.table[g][0] == mid]

    # ---------------- elastic membership (migration engine hooks) ----------
    def add_node(self) -> int:
        """Register a fresh (empty) MN and commit it to the membership
        ring.  Region placement does NOT change here — the migration
        engine re-homes shards and grants the node fresh data regions."""
        mid = len(self.mns)
        self.mns.append(MemoryNode(mid, self.cfg, self.slab))
        self.mn_bytes = np.concatenate(
            [self.mn_bytes, np.zeros(1, np.int64)])
        self.directory.add_member(mid)
        return mid

    def add_data_regions(self, mid: int, count: Optional[int] = None
                         ) -> List[int]:
        """Grant ``count`` fresh data regions primaried on ``mid`` (ring
        successors as backups).  Fresh regions are empty, so no copy or
        dual-write window is needed — they are pinned and hosted at once."""
        cfg = self.cfg
        count = cfg.regions_per_mn if count is None else count
        members = self.directory.members
        pos = members.index(mid)
        r = min(cfg.replication, len(members))
        new: List[int] = []
        for _ in range(count):
            g = self.num_regions
            self.num_regions += 1
            reps = [members[(pos + i) % len(members)] for i in range(r)]
            self.directory.pin(g, reps)
            for m in reps:
                self.mns[m].host_region(g)
            self.data_regions.append(g)
            new.append(g)
        return new

    def retire_node(self, mid: int):
        """Finalize a graceful remove_mn: the node hosts no regions (the
        migration engine has re-homed them all) and leaves membership.
        Retired is distinct from crashed — Alg-3 must not run."""
        mn = self.mns[mid]
        if mn.regions:
            from .faults import ProtocolViolation  # local: faults->master->client->heap cycle
            raise ProtocolViolation(
                f"retire_node({mid}) while it still hosts regions "
                f"{sorted(mn.regions)}: drain (migrate) them first")
        mn.retired = True
        mn.alive = False
        self._alive_gen += 1
        self.directory.remove_member(mid)

    # ---------------- dual-write mirroring (live migration) ----------------
    def _mirror(self, region: int, replica: int, off: int, n: int,
                mem: np.ndarray):
        """Dual-write window: mutations applied to the *primary* replica of
        a migrating region are mirrored into every migration target copy,
        so a write racing the bulk copy is never lost — chunks not yet
        copied pick it up from the (authoritative) primary later, chunks
        already copied receive it here."""
        if replica != 0:
            return
        mig = self.migrations.get(region)
        if mig is None:
            return
        src = mem[off:off + n]
        for mid, arr in mig.targets.items():
            arr[off:off + n] = src
            self.mn_bytes[mid] += n * L.WORD

    def _mirror_idx(self, region: int, replica: int, idx: np.ndarray,
                    mem: np.ndarray):
        """Batched-verb twin of ``_mirror``: mirror an index array of
        just-mutated words into the migration targets."""
        if replica != 0:
            return
        mig = self.migrations.get(region)
        if mig is None:
            return
        src = mem[idx]
        for mid, arr in mig.targets.items():
            arr[idx] = src
            self.mn_bytes[mid] += idx.size * L.WORD

    # ---------------- verbs -------------------------------------------------
    def _mem(self, region: int, replica: int) -> Optional[np.ndarray]:
        reps = self.placement.get(region)
        if reps is None or replica >= len(reps):
            return None
        mn = self.mns[reps[replica]]
        if not mn.alive:
            return None
        return mn.regions.get(region)

    def read(self, region: int, replica: int, off: int, n: int):
        mem = self._mem(region, replica)
        if mem is None:
            return None  # FAIL
        self.mn_bytes[self.placement[region][replica]] += n * L.WORD
        return mem[off:off + n].copy()

    def write(self, region: int, replica: int, off: int, words) -> bool:
        mem = self._mem(region, replica)
        if mem is None:
            return False
        w = np.asarray([int(x) & 0xFFFF_FFFF_FFFF_FFFF for x in words], dtype=np.uint64)
        mem[off:off + len(w)] = w
        self.mn_bytes[self.placement[region][replica]] += len(w) * L.WORD
        self._mirror(region, replica, off, len(w), mem)
        return True

    def cas(self, region: int, replica: int, off: int, exp: int, new: int):
        """Atomic compare-and-swap; returns the *old* value (RDMA semantics)."""
        mem = self._mem(region, replica)
        if mem is None:
            return None
        old = np.uint64(mem[off])
        if int(old) == int(exp) & 0xFFFF_FFFF_FFFF_FFFF:
            mem[off] = np.uint64(int(new) & 0xFFFF_FFFF_FFFF_FFFF)
            self._mirror(region, replica, off, 1, mem)
        self.mn_bytes[self.placement[region][replica]] += 2 * L.WORD
        return old

    def faa(self, region: int, replica: int, off: int, delta: int):
        mem = self._mem(region, replica)
        if mem is None:
            return None
        old = int(mem[off])
        mem[off] = np.uint64((old + int(delta)) & 0xFFFF_FFFF_FFFF_FFFF)
        self._mirror(region, replica, off, 1, mem)
        self.mn_bytes[self.placement[region][replica]] += 2 * L.WORD
        return np.uint64(old)

    # ---------------- batched verbs (fleet mode) ---------------------------
    # One scheduler tick in fleet mode (core/fleet.py) executes the head verb
    # of EVERY (client, MN) queue pair at once.  These entry points serve a
    # whole tick's verbs of one kind with a handful of numpy array calls —
    # one gather/scatter per (region, replica[, length]) group — instead of
    # one Python-level pool call per verb.  Semantics per element are
    # identical to read/write/cas/faa above (including the None-on-dead-MN
    # crash-stop behavior and byte accounting).

    def read_batch(self, regions, replicas, offs, ns) -> list:
        """Vectorized READ.  Returns a list aligned with the inputs: a copy
        of the words per verb, or None where the target replica is dead."""
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        ns = np.asarray(ns, np.int64)
        out: list = [None] * len(regions)
        group = (regions << 36) | (replicas << 32) | ns
        for g in np.unique(group):
            sel = np.nonzero(group == g)[0]
            region, replica = int(regions[sel[0]]), int(replicas[sel[0]])
            n = int(ns[sel[0]])
            mem = self._mem(region, replica)
            if mem is None or n <= 0:
                continue                     # FAIL -> stays None
            rows = mem[offs[sel][:, None] + np.arange(n)]
            self.mn_bytes[self.placement[region][replica]] += \
                n * len(sel) * L.WORD
            for k, i in enumerate(sel):
                out[int(i)] = rows[k]
        return out

    def write_batch(self, regions, replicas, offs, words_list) -> list:
        """Vectorized WRITE of per-verb word lists.  Overlapping writes
        within one batch land in a fixed deterministic order — groups in
        sorted (region, replica, length) order, input order within a group
        — which is a legal serialization of same-tick concurrent writes
        (they are unordered RDMA-wise), and replayable because it depends
        only on the batch contents."""
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        ns = np.array([len(w) for w in words_list], np.int64)
        out = [False] * len(regions)
        group = (regions << 36) | (replicas << 32) | ns
        for g in np.unique(group):
            sel = np.nonzero(group == g)[0]
            region, replica = int(regions[sel[0]]), int(replicas[sel[0]])
            n = int(ns[sel[0]])
            mem = self._mem(region, replica)
            if mem is None:
                continue
            if n:
                vals = np.array(
                    [[int(x) & 0xFFFF_FFFF_FFFF_FFFF for x in words_list[i]]
                     for i in sel], np.uint64)
                idx = offs[sel][:, None] + np.arange(n)
                mem[idx] = vals
                self._mirror_idx(region, replica, idx, mem)
            self.mn_bytes[self.placement[region][replica]] += \
                n * len(sel) * L.WORD
            for i in sel:
                out[int(i)] = True
        return out

    def cas_batch(self, regions, replicas, offs, exps, news) -> list:
        """Vectorized CAS; returns old values (RDMA semantics) or None.
        Verbs targeting the *same word* are serialized in input order (the
        second CAS observes the first's outcome), exactly like sequential
        ``cas`` calls."""
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        exps = np.array([int(e) & 0xFFFF_FFFF_FFFF_FFFF for e in exps],
                        np.uint64)
        news = np.array([int(v) & 0xFFFF_FFFF_FFFF_FFFF for v in news],
                        np.uint64)
        out: list = [None] * len(regions)
        group = (regions << 36) | replicas
        for g in np.unique(group):
            sel = np.nonzero(group == g)[0]
            region, replica = int(regions[sel[0]]), int(replicas[sel[0]])
            mem = self._mem(region, replica)
            if mem is None:
                continue
            o = offs[sel]
            if len(np.unique(o)) == len(o):          # conflict-free fast path
                old = mem[o].copy()
                hit = old == exps[sel]
                mem[o[hit]] = news[sel][hit]
                if hit.any():
                    self._mirror_idx(region, replica, o[hit], mem)
                for k, i in enumerate(sel):
                    out[int(i)] = np.uint64(old[k])
            else:                                    # same-word races: serialize
                for i in sel:
                    old = np.uint64(mem[offs[i]])
                    if int(old) == int(exps[i]):
                        mem[offs[i]] = news[i]
                        self._mirror(region, replica, int(offs[i]), 1, mem)
                    out[int(i)] = old
            self.mn_bytes[self.placement[region][replica]] += \
                2 * len(sel) * L.WORD
        return out

    def faa_batch(self, regions, replicas, offs, deltas) -> list:
        """Vectorized FAA; returns old values or None.  Same-word verbs
        accumulate in input order (each sees the running sum)."""
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        deltas = np.array([int(d) & 0xFFFF_FFFF_FFFF_FFFF for d in deltas],
                          np.uint64)
        out: list = [None] * len(regions)
        group = (regions << 36) | replicas
        for g in np.unique(group):
            sel = np.nonzero(group == g)[0]
            region, replica = int(regions[sel[0]]), int(replicas[sel[0]])
            mem = self._mem(region, replica)
            if mem is None:
                continue
            o = offs[sel]
            if len(np.unique(o)) == len(o):
                old = mem[o].copy()
                mem[o] = old + deltas[sel]           # uint64 wraparound
                self._mirror_idx(region, replica, o, mem)
                for k, i in enumerate(sel):
                    out[int(i)] = np.uint64(old[k])
            else:
                for i in sel:
                    old = np.uint64(mem[offs[i]])
                    mem[offs[i]] = old + deltas[i]
                    self._mirror(region, replica, int(offs[i]), 1, mem)
                    out[int(i)] = old
            self.mn_bytes[self.placement[region][replica]] += \
                2 * len(sel) * L.WORD
        return out

    # ---------------- fused tick (fleet megakernel substrate) --------------
    # One fleet tick's READ/WRITE/CAS/FAA sweeps executed against the flat
    # region slab with GLOBAL word addresses (cell * region_words + off)
    # instead of one gather/scatter per (region, replica[, length]) group.
    # Results are bit-identical to the *_batch twins above — the twins stay
    # the oracle (and the tracer's instrumentation point); the fused path
    # delegates back to them wherever ordering could differ (dual-write
    # migration windows, overlapping same-tick writes).

    def _fused_cells(self, regions: np.ndarray, replicas: np.ndarray):
        """Per-verb (cell, mid): the slab cell of the addressed replica copy
        and its MN id; cell -1 where the verb FAILs (dead/absent replica).

        Resolution is a dense (region, replica) lookup table, rebuilt only
        when the topology token changes: fresh regions always carve a cell
        (slab.gen), rehomes and membership changes bump directory.gen, and
        MNs are crash-stop (_alive_gen covers kills and retires)."""
        tok = (self.slab.gen, self.directory.gen, self._alive_gen)
        lut = self._fused_lut
        if lut is None or lut[0] != tok:
            table = self.placement
            nr = (max(table) + 1) if table else 1
            nrep = max((len(r) for r in table.values()), default=1)
            cell_lut = np.full((nr, nrep), -1, np.int64)
            mid_lut = np.zeros((nr, nrep), np.int64)
            for region, reps in table.items():  # lint: allow-fused-loop (LUT rebuild — runs only on topology changes, not per tick)
                for replica, mid in enumerate(reps):  # lint: allow-fused-loop (LUT rebuild — bounded by the replication factor)
                    mn = self.mns[mid]
                    if not mn.alive or region not in mn.regions:
                        continue
                    cell = self.slab.cells.get((mid, region))
                    if cell is not None:
                        cell_lut[region, replica] = cell
                        mid_lut[region, replica] = mid
            lut = self._fused_lut = (tok, cell_lut, mid_lut)
        _tok, cell_lut, mid_lut = lut
        nr, nrep = cell_lut.shape
        # verb coords are built from placement lookups, so they are never
        # negative; two scalar reductions cover the hot path
        if regions.size == 0 or (int(regions.max()) < nr
                                 and int(replicas.max()) < nrep):
            return cell_lut[regions, replicas], mid_lut[regions, replicas]
        ok = (regions < nr) & (replicas < nrep)
        rg = np.where(ok, regions, 0)
        rp = np.where(ok, replicas, 0)
        return (np.where(ok, cell_lut[rg, rp], -1),
                np.where(ok, mid_lut[rg, rp], 0))

    def exec_fused_tick(self, reads=None, writes=None, cass=None, faas=None):
        """Execute one fleet tick's verb sweeps in ``_VERB_ORDER`` against
        the flat slab.  Each argument is the positional-arg tuple of the
        corresponding ``*_batch`` twin (or None); ``writes`` may carry
        two extra trailing args (per-verb lengths + pre-flattened uint64
        values, built by the fleet layer while draining lanes).  Returns
        the four result lists ``(read_out, write_out, cas_out, faa_out)``,
        element-wise identical to what the twins would return.

        During a live migration the dual-write mirror must observe every
        mutation, so the whole tick delegates to the (mirroring) twins."""
        if self.migrations:
            return (self.read_batch(*reads) if reads else [],
                    self.write_batch(*writes[:4]) if writes else [],
                    self.cas_batch(*cass) if cass else [],
                    self.faa_batch(*faas) if faas else [])
        r = self._fused_read_sweep(*reads) if reads else []
        w = self._fused_write_sweep(*writes) if writes else []
        c = self._fused_cas_sweep(*cass) if cass else []
        f = self._fused_faa_sweep(*faas) if faas else []
        return r, w, c, f

    def _fused_read_sweep(self, regions, replicas, offs, ns) -> list:
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        ns = np.asarray(ns, np.int64)
        cells, mids = self._fused_cells(regions, replicas)
        live = (cells >= 0) & (ns > 0)
        out: list = [None] * len(regions)
        if not live.any():
            return out
        flat = self.slab.buf
        base = cells * self.slab.region_words + offs
        self.mn_bytes += (np.bincount(
            mids[live], weights=ns[live] * L.WORD,
            minlength=self.mn_bytes.size)).astype(np.int64)
        # ONE ragged gather for every live verb regardless of length: flat
        # address vector built with the repeat/cumsum trick, then split
        # back into per-verb rows (views of the gathered copy)
        sel = np.nonzero(live)[0]
        ln = ns[sel]
        ends = np.cumsum(ln)
        addrs = np.repeat(base[sel], ln) \
            + (np.arange(int(ends[-1])) - np.repeat(ends - ln, ln))
        rows = flat[addrs]
        lo = 0
        for i, hi in zip(sel.tolist(), ends.tolist()):  # lint: allow-fused-loop (per-verb result unpack at the generator API boundary — same loop as the read_batch oracle)
            out[i] = rows[lo:hi]
            lo = hi
        return out

    def _fused_write_sweep(self, regions, replicas, offs, words_list,
                           ns=None, vals=None) -> list:
        regions = np.asarray(regions, np.int64)
        replicas_a = np.asarray(replicas, np.int64)
        offs_a = np.asarray(offs, np.int64)
        if ns is None:
            ns = np.fromiter(map(len, words_list), np.int64,
                             count=len(words_list))
        else:
            ns = np.asarray(ns, np.int64)
        cells, mids = self._fused_cells(regions, replicas_a)
        live = cells >= 0
        live_pos = live & (ns > 0)
        sel = np.nonzero(live_pos)[0]
        if len(sel):
            base = cells * self.slab.region_words + offs_a
            ln = ns[sel]
            ends = np.cumsum(ln)
            total = int(ends[-1])
            # ONE ragged scatter for every live verb (repeat/cumsum
            # addressing, values flattened in a single fromiter pass)
            # overlap test on per-verb [base, base+n) intervals: contiguous
            # word ranges overlap iff they share an address, so sorting the
            # ~V starts is equivalent to (and much cheaper than) sorting
            # the full ~sum(n) address vector
            order = np.argsort(base[sel], kind="stable")
            sb = base[sel][order]
            if ((sb[:-1] + ln[order][:-1]) > sb[1:]).any():
                # overlapping same-tick writes: their landing order is the
                # twin's (deterministic) group order — delegate the sweep
                return self.write_batch(regions, replicas, offs, words_list)
            addrs = np.repeat(base[sel], ln) \
                + (np.arange(total) - np.repeat(ends - ln, ln))
            if vals is not None:
                # values pre-flattened by the fleet layer: scatter them
                # directly (dropping dead verbs' words when any exist)
                if len(sel) != len(words_list):
                    vals = vals[np.repeat(live_pos, ns)]
            else:
                rows = words_list if len(sel) == len(words_list) \
                    else map(words_list.__getitem__, sel.tolist())
                try:
                    # all-C flattening: chain + one fromiter pass
                    vals = np.fromiter(chain.from_iterable(rows),
                                       np.uint64, count=total)
                except (OverflowError, TypeError, ValueError):
                    vals = np.fromiter(
                        (int(x) & 0xFFFF_FFFF_FFFF_FFFF
                         for i in sel.tolist() for x in words_list[i]),
                        np.uint64, count=total)
            self.slab.buf[addrs] = vals
        self.mn_bytes += (np.bincount(
            mids[live], weights=ns[live] * L.WORD,
            minlength=self.mn_bytes.size)).astype(np.int64)
        return live.tolist()

    def _fused_cas_sweep(self, regions, replicas, offs, exps, news) -> list:
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        try:
            exps = np.asarray(exps, np.uint64)
            news = np.asarray(news, np.uint64)
        except (OverflowError, TypeError, ValueError):
            exps = np.array([int(e) & 0xFFFF_FFFF_FFFF_FFFF for e in exps],
                            np.uint64)
            news = np.array([int(v) & 0xFFFF_FFFF_FFFF_FFFF for v in news],
                            np.uint64)
        cells, mids = self._fused_cells(regions, replicas)
        live = cells >= 0
        out: list = [None] * len(regions)
        if not live.any():
            return out
        flat = self.slab.buf
        addr = cells * self.slab.region_words + offs
        li = np.nonzero(live)[0]
        sa = np.sort(addr[li])
        if not (sa[1:] == sa[:-1]).any():        # common: no same-word race
            vsel, dsel = li, li[:0]
        else:
            _u, inv, counts = np.unique(addr[li], return_inverse=True,
                                        return_counts=True)
            dup = counts[inv] > 1
            vsel, dsel = li[~dup], li[dup]
        av = addr[vsel]                          # each word touched once
        old = flat[av]               # advanced indexing: already a copy
        hit = old == exps[vsel]
        flat[av[hit]] = news[vsel][hit]
        for i, o in zip(vsel.tolist(), old):  # lint: allow-fused-loop (per-verb result unpack at the generator API boundary — same loop as the cas_batch oracle)
            out[i] = o
        for i in dsel:  # lint: allow-fused-loop (same-word CAS races are inherently sequential — input order, exactly like the cas_batch oracle)
            a = int(addr[i])
            o = np.uint64(flat[a])
            if int(o) == int(exps[i]):
                flat[a] = news[i]
            out[int(i)] = o
        self.mn_bytes += np.bincount(
            mids[live], minlength=self.mn_bytes.size) * (2 * L.WORD)
        return out

    def _fused_faa_sweep(self, regions, replicas, offs, deltas) -> list:
        regions = np.asarray(regions, np.int64)
        replicas = np.asarray(replicas, np.int64)
        offs = np.asarray(offs, np.int64)
        try:
            deltas = np.asarray(deltas, np.uint64)
        except (OverflowError, TypeError, ValueError):
            deltas = np.array([int(d) & 0xFFFF_FFFF_FFFF_FFFF for d in deltas],
                              np.uint64)
        cells, mids = self._fused_cells(regions, replicas)
        live = cells >= 0
        out: list = [None] * len(regions)
        if not live.any():
            return out
        flat = self.slab.buf
        addr = cells * self.slab.region_words + offs
        li = np.nonzero(live)[0]
        sa = np.sort(addr[li])
        if not (sa[1:] == sa[:-1]).any():        # common: no same-word race
            vsel, dsel = li, li[:0]
        else:
            _u, inv, counts = np.unique(addr[li], return_inverse=True,
                                        return_counts=True)
            dup = counts[inv] > 1
            vsel, dsel = li[~dup], li[dup]
        av = addr[vsel]
        old = flat[av]               # advanced indexing: already a copy
        flat[av] = old + deltas[vsel]            # uint64 wraparound
        for i, o in zip(vsel.tolist(), old):  # lint: allow-fused-loop (per-verb result unpack at the generator API boundary — same loop as the faa_batch oracle)
            out[i] = o
        for i in dsel:  # lint: allow-fused-loop (same-word FAAs accumulate sequentially in input order, exactly like the faa_batch oracle)
            a = int(addr[i])
            o = np.uint64(flat[a])
            flat[a] = o + deltas[i]
            out[int(i)] = o
        self.mn_bytes += np.bincount(
            mids[live], minlength=self.mn_bytes.size) * (2 * L.WORD)
        return out

    # ---------------- MN-side coarse allocation (ALLOC RPC, §4.4) ----------
    def alloc_block(self, mid: int, cid: int):
        """MN-side handler: grab a free block from one of this MN's primary
        regions, record CID in the BAT of *all* region replicas, return
        (region_id, block_idx).  Compute-light: a cursor bump + r BAT writes.
        """
        mn = self.mns[mid]
        if not mn.alive:
            return None
        cfg = self.cfg
        for g in self.data_regions_of_mn(mid):
            cur = mn.alloc_cursor.get(g, 0)
            while cur < cfg.blocks_per_region:
                bat = mn.regions[g]
                if int(bat[cur]) == 0:
                    for rep_idx, rep_mid in enumerate(self.placement[g]):
                        rep = self.mns[rep_mid]
                        if rep.alive and g in rep.regions:
                            rep.regions[g][cur] = np.uint64(cid + 1)
                            self._mirror(g, rep_idx, cur, 1, rep.regions[g])
                    mn.alloc_cursor[g] = cur + 1
                    mn.cpu_ops += 1
                    return g, cur
                cur += 1
            mn.alloc_cursor[g] = cur
        return None  # MN out of memory

    def free_block(self, mid: int, region: int, block_idx: int):
        mn = self.mns[mid]
        if not mn.alive:
            return False
        for rep_idx, rep_mid in enumerate(self.placement[region]):
            rep = self.mns[rep_mid]
            if rep.alive and region in rep.regions:
                rep.regions[region][block_idx] = np.uint64(0)
                self._mirror(region, rep_idx, block_idx, 1,
                             rep.regions[region])
        mn.cpu_ops += 1
        return True

    # ---------------- block geometry ---------------------------------------
    def block_base(self, block_idx: int) -> int:
        """Word offset of a block's payload (bitmap comes first)."""
        cfg = self.cfg
        return cfg.bat_words + block_idx * cfg.block_words + cfg.bitmap_words

    def bitmap_base(self, block_idx: int) -> int:
        cfg = self.cfg
        return cfg.bat_words + block_idx * cfg.block_words

    # ---------------- failure injection ------------------------------------
    def crash_mn(self, mid: int):
        self.mns[mid].alive = False
        self._alive_gen += 1

    def recover_mn_placement(self, region: int, new_replicas: List[int]):
        """Master-side: re-home a region on a new replica set (copies bytes).
        Goes through the directory — the pinned-placement mutation path
        shared with the migration engine's cutover."""
        src = None
        for mid in self.placement[region]:
            mn = self.mns[mid]
            if mn.alive and region in mn.regions:
                src = mn.regions[region]
                break
        if src is None:
            from .faults import RegionLost  # local: faults->master->client->heap cycle
            raise RegionLost(region,
                             f"old placement {self.placement[region]}, "
                             f"requested re-home to {new_replicas}")
        # snapshot before carving: a slab growth re-binds views, so the
        # source view must not be held across host_region
        snap = src.copy()
        for mid in new_replicas:
            mn = self.mns[mid]
            if region not in mn.regions:
                mn.host_region(region)
                mn.regions[region][:] = snap
        self.directory.rehome(region, list(new_replicas))
