"""Bit-level layouts for FUSEE metadata (slots, pointers, log entries).

Everything in the disaggregated heap is word-addressed (8-byte words), the
granularity at which RDMA_CAS / RDMA_FAA are atomic.  All packing helpers work
on Python ints / numpy uint64 and are mirrored exactly by the JAX serving path
(`repro.serving.slots_jax`), which is differentially tested against this file.

Slot (one 8-byte RACE hash-index slot)::

    | fp : 8 | size_class : 8 | pointer : 48 |

Pointer (48 bits, region-relative so that one pointer names all r replicas)::

    | region_id : 20 | word_offset : 28 |

Embedded log entry (3 words = 24 B, stored at the *end* of each object so the
``used`` bit in the final word is written last — RDMA_WRITEs are
order-preserving within a QP, giving the paper's §4.5 integrity property)::

    w[-3]  old_value   (64-bit: former primary-slot content; 0 = uncommitted)
    w[-2]  | next_ptr : 48 | opcode : 8 | old_crc : 8 |
    w[-1]  | prev_ptr : 48 | unused : 14 | invalid : 1 | used : 1 |

Object layout (size class = power-of-two word count, min 8)::

    w[0]      key (64-bit)
    w[1]      | kv_crc : 8 | reserved : 24 | value_len_words : 32 |
    w[2:...]  value words
    ...free...
    w[-3:]    embedded log entry
"""
from __future__ import annotations

import numpy as np

WORD = 8  # bytes per word

# --- field widths -----------------------------------------------------------
FP_BITS = 8
SIZE_CLASS_BITS = 8
PTR_BITS = 48
REGION_BITS = 20
OFFSET_BITS = 28

OPCODE_INSERT = 1
OPCODE_UPDATE = 2
OPCODE_DELETE = 3

USED_BIT = 1 << 0
INVALID_BIT = 1 << 1

MIN_OBJ_WORDS = 8
LOG_WORDS = 3
HDR_WORDS = 2  # key + len/crc word

NULL = np.uint64(0)
# Sentinel returned by verbs targeting a crashed MN.  Chosen so it can never be
# a legal slot value (region_id of all-ones is reserved).
FAIL = np.uint64(0xFFFF_FFFF_FFFF_FFFF)

_MASK48 = (1 << 48) - 1
_MASK28 = (1 << 28) - 1
_MASK20 = (1 << 20) - 1
_MASK8 = (1 << 8) - 1


def _u64(x: int) -> np.uint64:
    return np.uint64(x & 0xFFFF_FFFF_FFFF_FFFF)


# --- pointer ----------------------------------------------------------------
def pack_ptr(region_id: int, offset: int) -> int:
    assert 0 <= region_id < (1 << REGION_BITS) - 1, region_id  # lint: allow-assert (hot packing path; all-ones reserved)
    assert 0 <= offset < (1 << OFFSET_BITS), offset  # lint: allow-assert (hot packing path)
    return (region_id << OFFSET_BITS) | offset


def ptr_region(ptr: int) -> int:
    return (int(ptr) >> OFFSET_BITS) & _MASK20


def ptr_offset(ptr: int) -> int:
    return int(ptr) & _MASK28


# --- slot -------------------------------------------------------------------
def pack_slot(fp: int, size_class: int, ptr: int) -> np.uint64:
    return _u64(((fp & _MASK8) << 56) | ((size_class & _MASK8) << 48) | (ptr & _MASK48))


def slot_fp(slot) -> int:
    return (int(slot) >> 56) & _MASK8


def slot_size_class(slot) -> int:
    return (int(slot) >> 48) & _MASK8


def slot_ptr(slot) -> int:
    return int(slot) & _MASK48


def is_empty(slot) -> bool:
    return int(slot) == 0


# --- key hashing ------------------------------------------------------------
# SplitMix64: cheap, good avalanche, reproducible in JAX (uint32-pair variant).
def hash64(key: int, seed: int = 0) -> int:
    z = (int(key) + 0x9E3779B97F4A7C15 * (seed + 1)) & 0xFFFF_FFFF_FFFF_FFFF
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFF_FFFF_FFFF_FFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFF_FFFF_FFFF_FFFF
    return z ^ (z >> 31)


def fingerprint(key: int) -> int:
    fp = hash64(key, seed=7) & _MASK8
    return fp if fp != 0 else 1  # fp 0 reserved for "empty"


def crc8(words) -> int:
    """Toy 8-bit checksum over a sequence of ints (stands in for CRC)."""
    acc = 0xAB
    for w in words:
        x = int(w)
        for sh in (0, 8, 16, 24, 32, 40, 48, 56):
            acc = ((acc << 1) ^ ((x >> sh) & 0xFF) ^ (0x1D if acc & 0x80 else 0)) & 0xFF
    return acc if acc != 0 else 1


# --- log entry --------------------------------------------------------------
def pack_log_mid(next_ptr: int, opcode: int, old_crc: int) -> np.uint64:
    return _u64(((next_ptr & _MASK48) << 16) | ((opcode & _MASK8) << 8) | (old_crc & _MASK8))


def log_mid_next(w) -> int:
    return (int(w) >> 16) & _MASK48


def log_mid_opcode(w) -> int:
    return (int(w) >> 8) & _MASK8


def log_mid_crc(w) -> int:
    return int(w) & _MASK8


def pack_log_tail(prev_ptr: int, used: bool, invalid: bool = False) -> np.uint64:
    return _u64(((prev_ptr & _MASK48) << 16)
                | (INVALID_BIT if invalid else 0)
                | (USED_BIT if used else 0))


def log_tail_prev(w) -> int:
    return (int(w) >> 16) & _MASK48


def log_tail_used(w) -> bool:
    return bool(int(w) & USED_BIT)


def log_tail_invalid(w) -> bool:
    return bool(int(w) & INVALID_BIT)


# --- object -----------------------------------------------------------------
def pack_len_word(value_len_words: int, kv_crc: int) -> np.uint64:
    return _u64(((kv_crc & _MASK8) << 56) | (value_len_words & 0xFFFF_FFFF))


def len_word_vlen(w) -> int:
    return int(w) & 0xFFFF_FFFF


def len_word_crc(w) -> int:
    return (int(w) >> 56) & _MASK8


def obj_words_needed(value_len_words: int) -> int:
    need = HDR_WORDS + value_len_words + LOG_WORDS
    return max(MIN_OBJ_WORDS, need)


def size_class_for(words: int) -> int:
    """Size classes are powers of two starting at MIN_OBJ_WORDS."""
    sc = 0
    cap = MIN_OBJ_WORDS
    while cap < words:
        cap <<= 1
        sc += 1
    return sc


def size_class_words(sc: int) -> int:
    return MIN_OBJ_WORDS << sc


def build_object(key: int, value, next_ptr: int, prev_ptr: int, opcode: int):
    """Return the full word list for an object (old_value left uncommitted)."""
    value = [int(v) for v in value]
    vlen = len(value)
    sc = size_class_for(obj_words_needed(vlen))
    n = size_class_words(sc)
    kv_crc = crc8([key, vlen] + value)
    words = [0] * n
    words[0] = int(key)
    words[1] = int(pack_len_word(vlen, kv_crc))
    for i, v in enumerate(value):
        words[2 + i] = v & 0xFFFF_FFFF_FFFF_FFFF
    words[n - 3] = 0  # old_value: uncommitted
    words[n - 2] = int(pack_log_mid(next_ptr, opcode, 0))
    words[n - 1] = int(pack_log_tail(prev_ptr, used=True))
    return words, sc


def parse_object(words):
    """Parse an object's word list -> dict (no integrity decisions here)."""
    n = len(words)
    key = int(words[0])
    vlen = len_word_vlen(words[1])
    kv_crc = len_word_crc(words[1])
    value = [int(w) for w in words[2:2 + vlen]]
    return dict(
        key=key,
        value=value,
        vlen=vlen,
        kv_crc=kv_crc,
        crc_ok=(crc8([key, vlen] + value) == kv_crc),
        old_value=np.uint64(int(words[n - 3]) & 0xFFFF_FFFF_FFFF_FFFF),
        next_ptr=log_mid_next(words[n - 2]),
        opcode=log_mid_opcode(words[n - 2]),
        old_crc=log_mid_crc(words[n - 2]),
        prev_ptr=log_tail_prev(words[n - 1]),
        used=log_tail_used(words[n - 1]),
        invalid=log_tail_invalid(words[n - 1]),
    )
