"""Verb / phase vocabulary shared by the client state machines, the master,
and the scheduler (sim.py).

A client op is a Python generator that yields ``Phase`` objects.  One phase is
one doorbell-batched verb group = **1 network RTT** (§4.6 RDMA optimizations:
doorbell batching + selective signaling make each phase a single round trip).
The scheduler executes the verbs of a phase one at a time, interleaved with
other clients' verbs (preserving per-(client, MN) FIFO), then resumes the
generator with the result list.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional


@dataclass
class Verb:
    kind: str                 # 'read' | 'write' | 'cas' | 'faa' | 'alloc' | 'free'
    region: int = 0
    replica: int = 0
    off: int = 0
    n: int = 0                # read length (words)
    words: Optional[list] = None
    exp: int = 0
    new: int = 0
    delta: int = 0
    mn: int = -1              # alloc/free RPC target
    # Lease epoch at issue time (stamped by the scheduler when the phase's
    # doorbell batch is posted).  A verb whose epoch is stale by execution
    # time FAILs instead of silently resolving its replica index against
    # the *new* placement — the §5.2 membership-change model: re-homing a
    # region invalidates outstanding MRs, so in-flight verbs bounce and
    # the client retries against the committed new epoch.  Without this, a
    # write issued as "replica 1" before an MN crash can land on whatever
    # node becomes replica 1 afterwards, and an acknowledged KV object can
    # be missing from the post-recovery primary.
    epoch: int = -1

    def target_mn(self, pool) -> int:
        if self.kind in ("alloc", "free"):
            return self.mn
        reps = pool.placement.get(self.region)
        if reps is None or self.replica >= len(reps):
            return -1
        return reps[self.replica]


# Typed retry/stall cause vocabulary (obs/spans.py span trees): why a
# phase was (re)issued.  "" = first-attempt protocol work.  Client state
# machines stamp these on the Phase; the verb tracer records them per row
# so the causal profiler can attribute every RTT of a retry loop to the
# event that forced it.
CAUSE_NONE = ""
CAUSE_CAS_LOST = "cas_lost"          # lost a SNAPSHOT/empty-slot CAS round
CAUSE_FP_COLLISION = "fp_collision"  # fp matched, object didn't verify (stale/collision)
CAUSE_STALE_EPOCH = "stale_epoch"    # §5.2 lease bounce / dead-MN FAIL -> reissue
CAUSE_LOSE_POLL = "lose_poll"        # SNAPSHOT loser polling the winner's commit
CAUSE_FULL = "full"                  # allocation pressure: re-ask after failed grant
CAUSE_MIG_DUAL = "mig_dual_write"    # executed inside a live-migration dual-write window
CAUSES = (CAUSE_NONE, CAUSE_CAS_LOST, CAUSE_FP_COLLISION, CAUSE_STALE_EPOCH,
          CAUSE_LOSE_POLL, CAUSE_FULL, CAUSE_MIG_DUAL)


@dataclass
class Phase:
    verbs: List[Verb]
    label: str = ""
    background: bool = False   # off the op's latency critical path (§4.4 frees,
                               # loser used-bit resets) but still bandwidth-counted
    cause: str = CAUSE_NONE    # typed retry/stall cause (see CAUSES above)


@dataclass
class MasterCall:
    """Client->master RPC (Alg 4 fail_query etc.). Costs rpc_rtts round trips."""
    kind: str                  # 'fail_query' | 'refresh' | 'init' | 'fail_report'
    payload: Any = None


# Op result statuses
OK = "OK"
NOT_FOUND = "NOT_FOUND"
EXISTS = "EXISTS"
FULL = "FULL"
CRASHED = "CRASHED"        # op's client crashed mid-flight (crash-stop §5.1);
                           # retriable on any live client after recovery


@dataclass
class OpResult:
    status: str
    value: Optional[list] = None
    rtts: int = 0              # critical-path RTTs actually spent
    bg_rtts: int = 0           # background round trips
    rule: Optional[str] = None # winning SNAPSHOT rule, for Fig-9/RTT accounting
    page: Optional[int] = None # device-backend page id backing this key

    @property
    def retriable(self) -> bool:
        """True when the op did not report an outcome and may be resubmitted
        on a live client (CRASHED: any partial effect is repaired or redone
        by §5.3 client recovery before it becomes observable)."""
        return self.status == CRASHED
